"""Streaming clustering service: multi-producer ingest + concurrent predict.

    PYTHONPATH=src python examples/streaming_service.py

Three producer threads push small (sub-k!) row chunks into the service
while a consumer thread answers predict queries the whole time — the
queue accumulates the first >= k rows, the background refresher folds
every micro-batch in with `partial_fit`, and each refresh publishes a
new immutable snapshot version that readers pick up without ever taking
a lock.
"""
import threading
import time

import numpy as np

from repro.api import FitConfig, NestedKMeans
from repro.data.synthetic import gaussian_blobs
from repro.serve import ClusterService, IngestQueue

K, DIM, CHUNK = 32, 16, 12          # CHUNK < K on purpose
N_PER_PRODUCER = 4000


def producer(svc: ClusterService, pid: int, X: np.ndarray):
    rng = np.random.default_rng(pid)
    for i in range(0, len(X), CHUNK):
        svc.ingest(X[i:i + CHUNK],
                   ids=[(pid, int(j)) for j in range(i, min(i + CHUNK,
                                                            len(X)))])
        if rng.random() < 0.1:      # bursty traffic
            time.sleep(0.002)


def consumer(svc: ClusterService, queries: np.ndarray, out: dict):
    served, versions = 0, []
    while not out.get("stop"):
        snap = svc.snapshot
        if snap is None:            # nothing published yet: keep polling
            time.sleep(0.005)
            continue
        labels = svc.predict(queries)
        assert labels.shape == (len(queries),)
        versions.append(snap.version)
        served += 1
    out["served"] = served
    out["versions"] = versions


def main():
    X, _ = gaussian_blobs(3 * N_PER_PRODUCER, k=K, dim=DIM, spread=5.0,
                          seed=0)
    parts = np.split(X, 3)
    queries = X[:256]

    km = NestedKMeans(FitConfig(k=K, b0=256, seed=0))     # unfitted!
    svc = ClusterService(km, micro_batch=512, flush_after_s=0.05,
                         queue=IngestQueue(max_rows=8192, dedup=True),
                         history_rows=4096).start()

    out = {}
    threads = [threading.Thread(target=producer, args=(svc, pid, part))
               for pid, part in enumerate(parts)]
    reader = threading.Thread(target=consumer, args=(svc, queries, out))
    t0 = time.time()
    reader.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    # let the refresher catch up with the tail of the stream
    while svc.queue.depth and time.time() - t0 < 30:
        time.sleep(0.01)
    out["stop"] = True
    reader.join()
    svc.stop()

    m = svc.export_metrics()
    snap = svc.snapshot
    versions = out["versions"]
    assert versions == sorted(versions), "snapshot versions not monotone!"
    print(f"ingested {m['queue']['accepted']} rows from 3 producers "
          f"(deduped={m['queue']['deduped']}) in {time.time() - t0:.2f}s")
    print(f"background refreshes: {m['refresh']['count']} "
          f"({m['refresh']['rows']} rows) -> snapshot v{snap.version}, "
          f"batch MSE {snap.batch_mse:.4f}")
    print(f"concurrent predicts served: {out['served']} "
          f"(p50 {m['predict']['latency']['p50_s'] * 1e3:.2f}ms, "
          f"versions observed {versions[0] if versions else '-'}"
          f"..{versions[-1] if versions else '-'}, all monotone)")
    print(f"final codebook: {snap.k} cells over {snap.dim}d, "
          f"occupancy min/max {snap.counts.min():.0f}/"
          f"{snap.counts.max():.0f}")


if __name__ == "__main__":
    main()
