"""Cluster an LM's token-embedding table with tb-inf (VQ / semantic dedup).

The classic application of web-scale k-means inside an LM stack: build a
k-codebook over the (vocab, d_model) embedding table — usable for
embedding compression, semantic dedup, or routing analysis. Uses the
reduced tinyllama config (full configs are dry-run-only on this box) and
the unified `repro.api` estimator.

    PYTHONPATH=src python examples/cluster_embeddings.py
"""
import jax
import numpy as np

from repro import configs
from repro.api import FitConfig, NestedKMeans
from repro.models import model as M

cfg = configs.get_reduced("tinyllama-1.1b")
params = M.init_params(jax.random.PRNGKey(0), cfg)
E = np.asarray(params["embed"], np.float32)          # (vocab, d)
print(f"embedding table: {E.shape}")

K = 32
km = NestedKMeans(FitConfig(k=K, algorithm="tb", rho=float("inf"),
                            b0=128, bounds="hamerly2", max_rounds=200,
                            seed=0)).fit(E)
print(f"tb-inf codebook: converged={km.converged_} rounds={km.n_rounds_}")

mse = -km.score(E) / E.shape[0]
print(f"VQ reconstruction MSE: {mse:.6f}")

# codebook utilisation via the estimator's inference surface
a = km.predict(E)
sizes = np.bincount(a, minlength=K)
print(f"codebook usage: min={sizes.min()} max={sizes.max()} "
      f"empty={int((sizes == 0).sum())}")
compression = E.shape[0] * E.shape[1] / (K * E.shape[1] + E.shape[0])
print(f"compression ratio vs raw table: {compression:.1f}x")

# -- out-of-core: the same fit streamed off disk ----------------------------
# For embedding corpora that don't fit in host memory, write them once
# to a chunked store (repro.data.store) and hand the store path to the
# estimator — the fit streams the nested prefix from disk. Done here
# with the same table so the in-memory run above is the reference.
import tempfile                                              # noqa: E402

from repro.data.store import write_store                     # noqa: E402

store_dir = tempfile.mkdtemp(prefix="embed_store_") + "/table"
write_store(store_dir, E, chunk_rows=4096)
km_disk = NestedKMeans(FitConfig(k=K, algorithm="tb", rho=float("inf"),
                                 b0=128, bounds="hamerly2",
                                 max_rounds=200, seed=0)).fit(store_dir)
print(f"streamed-from-disk codebook: converged={km_disk.converged_} "
      f"rounds={km_disk.n_rounds_} "
      f"VQ-MSE {-km_disk.score(E) / E.shape[0]:.6f}")
