"""Quickstart: nested mini-batch k-means (tb-inf) vs Lloyd.

    PYTHONPATH=src python examples/quickstart.py

Clusters a synthetic infinite-MNIST-style dataset with the paper's
turbocharged algorithm and prints the MSE-vs-work trajectory. On this
CPU container it runs a scaled-down N; the same code drives the
multi-pod engine (see examples/kmeans_e2e.py).
"""
import numpy as np

from repro.core import fit
from repro.data.synthetic import infmnist_like

N, K = 20_000, 50
X = infmnist_like(N + 2000, seed=0)
X_train, X_val = X[:N], X[N:]

print(f"clustering N={N} d={X.shape[1]} k={K}")
res_tb = fit(X_train, K, algorithm="tb", rho=float("inf"), b0=2000,
             bounds="hamerly2", X_val=X_val, max_rounds=400,
             time_budget_s=30, eval_every=5, seed=0)
print(f"\ntb-inf: {len(res_tb.telemetry)} rounds, "
      f"converged={res_tb.converged}, final MSE={res_tb.final_mse:.5f}")
print("round |      b | recomputed | batch MSE")
for t in res_tb.telemetry[::5]:
    if t["batch_mse"] is None:
        continue
    print(f"{t['round']:5d} | {t['b']:6d} | {t['n_recomputed']:10d} | "
          f"{t['batch_mse']:.5f}")

res_ll = fit(X_train, K, algorithm="lloyd", X_val=X_val, max_rounds=100,
             eval_every=10 ** 9, seed=0)
print(f"\nlloyd: {len(res_ll.telemetry)} rounds, "
      f"final MSE={res_ll.final_mse:.5f}")
print(f"tb-inf work saved: last-round distance computations "
      f"{res_tb.telemetry[-2]['n_recomputed']} / {N}")
