"""Quickstart: nested mini-batch k-means (tb-inf) vs Lloyd.

    PYTHONPATH=src python examples/quickstart.py

Clusters a synthetic infinite-MNIST-style dataset with the paper's
turbocharged algorithm through the unified `repro.api` surface and
prints the MSE-vs-work trajectory. On this CPU container it runs a
scaled-down N; the identical config drives the multi-pod mesh engine
(see examples/kmeans_e2e.py) by flipping `backend="mesh"`.
"""
import dataclasses

import numpy as np

from repro.api import FitConfig, NestedKMeans
from repro.data.synthetic import infmnist_like

N, K = 20_000, 50
X = infmnist_like(N + 2000, seed=0)
X_train, X_val = X[:N], X[N:]

print(f"clustering N={N} d={X.shape[1]} k={K}")
cfg = FitConfig(k=K, algorithm="tb", rho=float("inf"), b0=2000,
                bounds="hamerly2", max_rounds=400, time_budget_s=30,
                eval_every=5, seed=0)
km = NestedKMeans(cfg).fit(X_train, X_val=X_val)
print(f"\ntb-inf: {km.n_rounds_} rounds, converged={km.converged_}, "
      f"final MSE={km.final_mse_:.5f}")
print("round |      b | recomputed | batch MSE")
for t in km.telemetry_[::5]:
    if t.batch_mse is None:
        continue
    print(f"{t.round:5d} | {t.b:6d} | {t.n_recomputed:10d} | "
          f"{t.batch_mse:.5f}")

ll = NestedKMeans(dataclasses.replace(
    cfg, algorithm="lloyd", max_rounds=100, time_budget_s=float("inf"),
    eval_every=10 ** 9)).fit(X_train, X_val=X_val)
print(f"\nlloyd: {ll.n_rounds_} rounds, final MSE={ll.final_mse_:.5f}")
print(f"tb-inf work saved: last-round distance computations "
      f"{km.telemetry_[-2].n_recomputed} / {N}")
