"""End-to-end production driver for the paper's technique.

Demonstrates the full substrate on one box, entirely through the
unified `repro.api` surface:
  * sharded data pipeline (nested-prefix property across shards),
  * the same FitConfig driving the LocalEngine or the MeshEngine
    (shard_map; run with
    XLA_FLAGS=--xla_force_host_platform_device_count=8 for 8 shards),
  * checkpoint mid-run + elastic restart (FitConfig round-trips
    through the checkpoint manifest),
  * validation MSE telemetry.

    PYTHONPATH=src python examples/kmeans_e2e.py
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/kmeans_e2e.py --distributed
"""
import argparse
import dataclasses
import json
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import FitConfig, NestedKMeans
from repro.checkpoint.store import CheckpointStore
from repro.core.state import full_mse
from repro.data.synthetic import infmnist_like


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--n", type=int, default=20_000)
    args = ap.parse_args()

    X = infmnist_like(args.n + 2000, seed=0)
    X_train, X_val = X[: args.n], X[args.n:]
    k = 50

    if args.distributed:
        ndev = len(jax.devices())
        mesh = jax.make_mesh((ndev, 1), ("data", "model"))
        cfg = FitConfig(k=k, algorithm="tb", b0=2048, rho=float("inf"),
                        bounds="hamerly2", max_rounds=300, seed=0,
                        backend="mesh", data_axes=("data",),
                        capacity_floor=256)
        km = NestedKMeans(cfg, mesh=mesh).fit(X_train)
        print(f"distributed over {ndev} devices: "
              f"rounds={km.n_rounds_} converged={km.converged_}")
        mse = float(full_mse(jnp.asarray(X_val),
                             jnp.asarray(km.cluster_centers_)))
        print(f"val MSE {mse:.5f}")
        return

    # single-host run with mid-run checkpoint + elastic restart
    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d, keep=2)
        cfg = FitConfig(k=k, algorithm="tb", b0=2048, bounds="hamerly2",
                        max_rounds=12, seed=0)

        # phase 1: run 12 rounds, then "crash". The config itself rides
        # along in the manifest (to_dict/from_dict round-trip).
        km1 = NestedKMeans(cfg).fit(X_train)
        store.save(12, {"C": jnp.asarray(km1.cluster_centers_),
                        "b": jnp.asarray(km1.telemetry_[-1].b)})
        manifest = json.dumps(cfg.to_dict())
        print(f"phase-1: {km1.n_rounds_} rounds; checkpointed; "
              f"b={km1.telemetry_[-1].b}")

        # phase 2: restart from the checkpoint (warm centroids + batch)
        got = store.restore({"C": jnp.zeros((k, X.shape[1])),
                             "b": jnp.zeros((), jnp.int32)})
        cfg2 = dataclasses.replace(
            FitConfig.from_dict(json.loads(manifest)),
            b0=int(got["b"]), max_rounds=200, eval_every=10)
        km2 = NestedKMeans(cfg2).fit(X_train, X_val=X_val,
                                     init_C=np.asarray(got["C"]))
        print(f"phase-2 (restarted): converged={km2.converged_} "
              f"final MSE={km2.final_mse_:.5f}")


if __name__ == "__main__":
    main()
