"""End-to-end production driver for the paper's technique.

Demonstrates the full substrate on one box:
  * sharded data pipeline (nested-prefix property across shards),
  * distributed tb-inf rounds via shard_map (run with
    XLA_FLAGS=--xla_force_host_platform_device_count=8 to see 8 shards),
  * checkpoint mid-run + elastic restart,
  * validation MSE telemetry.

    PYTHONPATH=src python examples/kmeans_e2e.py
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/kmeans_e2e.py --distributed
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import CheckpointStore
from repro.core import fit
from repro.core.state import full_mse
from repro.data.synthetic import infmnist_like


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--n", type=int, default=20_000)
    args = ap.parse_args()

    X = infmnist_like(args.n + 2000, seed=0)
    X_train, X_val = X[: args.n], X[args.n:]
    k = 50

    if args.distributed:
        from repro.core.distributed import fit_distributed
        ndev = len(jax.devices())
        mesh = jax.make_mesh(
            (ndev, 1), ("data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 2)
        res = fit_distributed(X_train, k, mesh, data_axes=("data",),
                              b0=2048, rho=float("inf"),
                              bounds="hamerly2", max_rounds=300, seed=0)
        print(f"distributed over {ndev} devices: "
              f"rounds={len(res.telemetry)} converged={res.converged}")
        mse = float(full_mse(jnp.asarray(X_val), jnp.asarray(res.C)))
        print(f"val MSE {mse:.5f}")
        return

    # single-host run with mid-run checkpoint + elastic restart
    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d, keep=2)

        # phase 1: run 12 rounds, then "crash"
        res1 = fit(X_train, k, algorithm="tb", b0=2048,
                   bounds="hamerly2", max_rounds=12, seed=0)
        store.save(12, {"C": jnp.asarray(res1.C),
                        "b": jnp.asarray(res1.telemetry[-1]["b"])})
        print(f"phase-1: {len(res1.telemetry)} rounds; checkpointed; "
              f"b={res1.telemetry[-1]['b']}")

        # phase 2: restart from the checkpoint (warm centroids + batch)
        got = store.restore({"C": jnp.zeros((k, X.shape[1])),
                             "b": jnp.zeros((), jnp.int32)})
        res2 = fit(X_train, k, algorithm="tb", b0=int(got["b"]),
                   bounds="hamerly2", max_rounds=200, seed=0,
                   X_val=X_val, eval_every=10,
                   init_C=np.asarray(got["C"]))
        print(f"phase-2 (restarted): converged={res2.converged} "
              f"final MSE={res2.final_mse:.5f}")


if __name__ == "__main__":
    main()
