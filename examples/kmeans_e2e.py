"""End-to-end production driver for the paper's technique.

Demonstrates the full substrate on one box, entirely through the
unified `repro.api` surface:
  * sharded data pipeline (nested-prefix property across shards),
  * the same FitConfig driving the LocalEngine or the MeshEngine
    (shard_map; run with
    XLA_FLAGS=--xla_force_host_platform_device_count=8 for 8 shards),
  * IN-LOOP checkpointing + kill-and-resume: `run_loop` saves the full
    host-schedule state (S/v statistics, batch-growth position,
    patience, work clock, telemetry) every N rounds, so the resumed fit
    is bit-identical to an uninterrupted one — not a warm start that
    discards the nested statistics,
  * validation MSE telemetry.

    PYTHONPATH=src python examples/kmeans_e2e.py
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/kmeans_e2e.py --distributed
"""
import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp

from repro.api import CheckpointConfig, FitConfig, NestedKMeans
from repro.core.state import full_mse
from repro.data.synthetic import infmnist_like


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--n", type=int, default=20_000)
    args = ap.parse_args()

    X = infmnist_like(args.n + 2000, seed=0)
    X_train, X_val = X[: args.n], X[args.n:]
    k = 50

    if args.distributed:
        ndev = len(jax.devices())
        mesh = jax.make_mesh((ndev, 1), ("data", "model"))
        cfg = FitConfig(k=k, algorithm="tb", b0=2048, rho=float("inf"),
                        bounds="hamerly2", max_rounds=300, seed=0,
                        backend="mesh", data_axes=("data",),
                        capacity_floor=256)
        km = NestedKMeans(cfg, mesh=mesh).fit(X_train)
        print(f"distributed over {ndev} devices: "
              f"rounds={km.n_rounds_} converged={km.converged_}")
        mse = float(full_mse(jnp.asarray(X_val),
                             jnp.asarray(km.cluster_centers_)))
        print(f"val MSE {mse:.5f}")
        return

    # single-host run with in-loop checkpointing + kill-and-resume
    with tempfile.TemporaryDirectory() as d:
        ck = CheckpointConfig(checkpoint_dir=d, save_every=4, keep=2)
        cfg = FitConfig(k=k, algorithm="tb", b0=2048, bounds="hamerly2",
                        max_rounds=200, eval_every=10, seed=0,
                        checkpoint=ck)

        # phase 1: the fit "crashes" after 12 rounds. Every save_every
        # rounds run_loop wrote the FULL loop state — KMeansState (S/v,
        # bounds), current b, capacity bucket, patience, work clock,
        # telemetry — alongside the FitConfig.to_dict() manifest.
        km1 = NestedKMeans(dataclasses.replace(cfg, max_rounds=12))
        km1.fit(X_train)
        print(f"phase-1: {km1.n_rounds_} rounds, then 'crash'; "
              f"checkpointed b={km1.telemetry_[-1].b}")

        # phase 2: resume. The restored fit continues the growth
        # schedule bit-identically to an uninterrupted run (same
        # centroids, same telemetry) — and the restore is elastic: the
        # same checkpoint also resumes on a mesh at any shard count.
        km2 = NestedKMeans(cfg)
        km2.fit(X_train, X_val=X_val, resume=True)
        print(f"phase-2 (resumed at round {km1.n_rounds_}): "
              f"converged={km2.converged_} after {km2.n_rounds_} total "
              f"rounds, final MSE={km2.final_mse_:.5f}")


if __name__ == "__main__":
    main()
