import pytest

# NOTE: no XLA_FLAGS here on purpose — unit/smoke tests must see the real
# (single) device; only launch/dryrun.py forces 512 placeholder devices.


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running test, excluded from the scripts/ci_tier1.sh "
        "fast subset")


@pytest.fixture(scope="session")
def blobs():
    from repro.data.synthetic import gaussian_blobs
    X, centers = gaussian_blobs(4000, k=8, dim=16, spread=5.0, seed=0)
    return X, centers


@pytest.fixture(scope="session")
def blobs_val():
    from repro.data.synthetic import gaussian_blobs
    X, _ = gaussian_blobs(512, k=8, dim=16, spread=5.0, seed=1)
    return X
