"""CI gate for the multihost (jax.distributed) engine.

scripts/smoke_multihost.py covers the whole multi-process stack: a
single-process `backend="multihost"` fit bit-identical to the
MeshEngine (centroids, labels, per-point state, schedule), elkan bounds
on the sharded engines (local<->mesh parity on N % n_shards != 0 and
the XL engine's model-sharded l matrix), sharded `partial_fit`, and a
REAL 2-process CPU cluster over a localhost coordinator: identical
b_global/capacity/patience traces on both processes, every real row
labeled, process-0-only checkpoint writes, and the kill-one-process
resume onto a 1-process mesh. Subprocess-isolated because it forces
host devices via XLA_FLAGS and stands up jax.distributed, neither of
which may leak into the rest of the test session.
"""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_multihost_smoke_subprocess():
    """The full multihost e2e smoke (parent + 2-process cluster)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run([sys.executable, "scripts/smoke_multihost.py"],
                       env=env, capture_output=True, text=True,
                       timeout=900, cwd=repo)
    assert r.returncode == 0, r.stdout + r.stderr
    for marker in ("mesh<->multihost(1 process) bit-identical",
                   "multihost kill-and-resume (same topology): "
                   "bit-identical",
                   "elkan local<->mesh parity",
                   "elkan on XL (2 data x 2 model shards)",
                   "sharded partial_fit",
                   "both processes ran the identical "
                   "b_global/capacity/patience trace",
                   "2-process multihost resume: bit-identical",
                   "kill-one-process resume",
                   "multihost smoke OK"):
        assert marker in r.stdout, (marker, r.stdout)
