"""Loop-aware HLO cost model: validated against known programs."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline import analysis as ra
from repro.roofline import hlo_cost


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops():
    n, k, m = 256, 512, 128

    def f(a, b):
        return a @ b

    txt = _compiled_text(f, jax.ShapeDtypeStruct((n, k), jnp.float32),
                         jax.ShapeDtypeStruct((k, m), jnp.float32))
    c = hlo_cost.analyze(txt)
    expect = 2.0 * n * k * m
    assert 0.9 * expect <= c.flops <= 1.2 * expect, c.flops


def test_scan_multiplies_flops_by_trip_count():
    n, trips = 128, 20

    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=trips)
        return h

    txt = _compiled_text(f, jax.ShapeDtypeStruct((4, n), jnp.float32),
                         jax.ShapeDtypeStruct((n, n), jnp.float32))
    c = hlo_cost.analyze(txt)
    expect = trips * 2.0 * 4 * n * n
    assert 0.9 * expect <= c.flops <= 1.5 * expect, (c.flops, expect)


def test_nested_scan_trip_product():
    n, t1, t2 = 64, 5, 7

    def f(x, w):
        def outer(h, _):
            def inner(g, _):
                return g @ w, None
            g, _ = jax.lax.scan(inner, h, None, length=t2)
            return g, None
        h, _ = jax.lax.scan(outer, x, None, length=t1)
        return h

    txt = _compiled_text(f, jax.ShapeDtypeStruct((4, n), jnp.float32),
                         jax.ShapeDtypeStruct((n, n), jnp.float32))
    c = hlo_cost.analyze(txt)
    expect = t1 * t2 * 2.0 * 4 * n * n
    assert 0.8 * expect <= c.flops <= 1.6 * expect, (c.flops, expect)


def test_collective_parse_crafted_hlo():
    txt = """
HloModule test
ENTRY %main (p: f32[16]) -> f32[16] {
  %p = f32[16]{0} parameter(0)
  %ag = f32[128,16]{1,0} all-gather(%p), dimensions={0}
  %ar = f32[16]{0} all-reduce(%p), to_apply=%add
  ROOT %cp = f32[16]{0} collective-permute(%p), source_target_pairs={{0,1}}
}
"""
    stats = ra.parse_collectives(txt)
    assert stats.counts == {"all-gather": 1, "all-reduce": 1,
                            "collective-permute": 1}
    assert stats.by_kind["all-gather"] == 128 * 16 * 4
    assert stats.by_kind["all-reduce"] == 2 * 16 * 4
    assert stats.by_kind["collective-permute"] == 16 * 4


def test_roofline_terms_and_bottleneck():
    r = ra.roofline_terms(197e12, 819e9 * 2, 50e9 * 0.5,
                          model_flops=98.5e12)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(2.0)
    assert r.collective_s == pytest.approx(0.5)
    assert r.bottleneck == "memory"
    assert r.useful_ratio == pytest.approx(0.5)
    assert r.roofline_fraction() == pytest.approx(0.25)


def test_dus_stack_counts_slice_not_buffer():
    """Writing one layer's slice into a big stacked buffer inside a scan
    must count slice bytes, not the whole stack, per iteration."""
    L_, S, D = 16, 64, 32

    def f(x, stack):
        def body(c, i):
            return c, jax.lax.dynamic_update_slice_in_dim(
                stack, (x * 1.0)[None], 0, axis=0)[i]
        _, ys = jax.lax.scan(body, 0.0, jnp.arange(L_))
        return ys

    txt = _compiled_text(f, jax.ShapeDtypeStruct((S, D), jnp.float32),
                         jax.ShapeDtypeStruct((L_, S, D), jnp.float32))
    c = hlo_cost.analyze(txt)
    stack_bytes = L_ * S * D * 4
    # far below trips x full-stack traffic
    assert c.bytes < 0.5 * L_ * 3 * stack_bytes, c.bytes
