"""Faithfulness tests: every algorithm vs serial/numpy oracles.

The paper's central exactness claims:
  * bound tests never change assignments (tb == gb round-for-round);
  * mb's S/v form (Alg. 8) == the serial running-mean form (Alg. 1);
  * mb-f centroids are the exact mean of CURRENT assignments;
  * gb-inf with b0=N reproduces Lloyd's algorithm.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import driver, rounds
from repro.core.state import init_state, full_mse


def _fit(X, k, **kw):
    return driver.fit(X, k, X_val=None, max_rounds=kw.pop("max_rounds", 40),
                      **kw)


# ---------------------------------------------------------------------------
# bounding is exact: tb (either bound type) == gb assignments every round
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bounds", ["hamerly2", "elkan", "exponion"])
def test_bounds_never_change_assignments(blobs, bounds):
    X, _ = blobs
    k, b = 8, 512
    Xd = jnp.asarray(X)
    s_ref = init_state(Xd, k, bounds="none")
    s_tb = init_state(Xd, k, bounds=bounds)
    for r in range(12):
        s_ref, _ = rounds.nested_round(Xd, s_ref, b=b, rho=np.inf,
                                       bounds="none")
        s_tb, info = rounds.nested_round(Xd, s_tb, b=b, rho=np.inf,
                                         bounds=bounds)
        np.testing.assert_array_equal(np.asarray(s_ref.points.a[:b]),
                                      np.asarray(s_tb.points.a[:b]),
                                      err_msg=f"round {r}")
        np.testing.assert_allclose(np.asarray(s_ref.stats.C),
                                   np.asarray(s_tb.stats.C),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", ["local", "mesh"])
def test_bound_families_parity_across_backends(blobs, backend):
    """Property: every bound family's labels AND centroids are bit-equal
    to ``bounds="none"`` on the same backend / init / schedule, with an
    N that is not a multiple of any shard count (pad/tail rows in the
    sharded path). The mesh leg shards over however many devices exist
    (1 in plain CI; the multi-device N % n_shards != 0 case runs in
    scripts/smoke_bounds.py); xl/multihost parity lives there too.
    """
    import jax

    from repro import api

    X, _ = blobs
    X = X[:1003]                      # odd N: never divides shard counts
    kw = {}
    if backend == "mesh":
        kw["mesh"] = jax.make_mesh((jax.device_count(), 1),
                                   ("data", "model"))
    base = None
    for fam in ["none", "hamerly2", "elkan", "exponion"]:
        cfg = api.FitConfig(k=8, algorithm="tb", b0=256, rho=np.inf,
                            bounds=fam, max_rounds=25, seed=0,
                            backend=backend)
        out = api.fit(X, cfg, **kw)
        if base is None:
            base = out
        else:
            np.testing.assert_array_equal(out.labels, base.labels,
                                          err_msg=f"{fam}/{backend}")
            np.testing.assert_array_equal(out.C, base.C,
                                          err_msg=f"{fam}/{backend}")


def test_exponion_annulus_boundary_tie():
    """An inter-centroid distance EXACTLY on the annulus boundary
    (d(c_a, c_j) == R) must not change the assignment or loosen the
    stored second-nearest bound.

    Geometry (f32-exact integer coordinates): anchor c0=(0,0) with
    x=(1,0) so u=1; s(0)=d(c0,c1)=3 via c1=(0,3); R = 2u+s = 5 equals
    d(c0,c2) = d(c0,c3) = 5 exactly for c2=(5,0), c3=(-5,0). The
    lower bound is manually deflated to force a Hamerly failure, so the
    point really scans its annulus.
    """
    import dataclasses as dc

    from repro.core.state import build_exponion_geom

    C = jnp.asarray([[0.0, 0.0], [0.0, 3.0], [5.0, 0.0], [-5.0, 0.0]])
    x = jnp.asarray([[1.0, 0.0]])
    state = init_state(x, 4, bounds="exponion")
    state = dc.replace(
        state,
        stats=dc.replace(state.stats, C=C,
                         p=jnp.zeros(4, jnp.float32)),
        points=dc.replace(state.points,
                          a=jnp.asarray([0], jnp.int32),
                          d=jnp.asarray([1.0], jnp.float32),
                          lb=jnp.asarray([0.5], jnp.float32)))
    geom = build_exponion_geom(C)
    # both boundary centroids are INSIDE the candidate set (<= count)
    assert float(geom.s[0]) == 3.0
    a, d, lb, n_rec, overflow, _ = rounds._assign_exponion(
        x, state, state.points.a, None, use_shalf=False)
    assert int(a[0]) == 0                      # assignment unchanged
    assert float(d[0]) == pytest.approx(1.0)
    # lb is the EXACT second-nearest (c1 at sqrt(10)), proving the
    # candidate set contained the true runner-up despite the ties
    assert float(lb[0]) == pytest.approx(np.sqrt(10.0), rel=1e-6)
    # all 4 centroids scanned (boundary pair included) + 1 d_a refresh
    assert int(n_rec) == 5
    assert not bool(overflow)


def test_capacity_compaction_is_exact(blobs):
    """Pruned rounds with a small capacity == dense rounds (after the
    driver's overflow retry)."""
    X, _ = blobs
    k, b = 8, 1024
    Xd = jnp.asarray(X)
    s_a = init_state(Xd, k, bounds="none")
    s_b = init_state(Xd, k, bounds="hamerly2")
    cap = None
    for r in range(10):
        s_a, _ = rounds.nested_round(Xd, s_a, b=b, rho=np.inf,
                                     bounds="none")
        while True:
            s_b2, info = rounds.nested_round(Xd, s_b, b=b, rho=np.inf,
                                             bounds="hamerly2",
                                             capacity=cap)
            if not bool(info.overflow):
                break
            cap = None if cap is None or 2 * cap >= b else 2 * cap
        s_b = s_b2
        cap = 256   # deliberately small -> exercises retry next round
        np.testing.assert_array_equal(np.asarray(s_a.points.a[:b]),
                                      np.asarray(s_b.points.a[:b]))


# ---------------------------------------------------------------------------
# mb: S/v vectorised form == serial Alg. 1 oracle
# ---------------------------------------------------------------------------

def _serial_mb_round(X, idx, C, v):
    """Sculley's Algorithm 1, straight from the paper, in numpy."""
    C = C.copy()
    v = v.copy()
    a = {}
    for i in idx:                       # assignment step (C frozen)
        d = ((X[i] - C) ** 2).sum(1)
        a[i] = int(np.argmin(d))
    for i in idx:                       # update step (running mean)
        j = a[i]
        v[j] += 1
        eta = 1.0 / v[j]
        C[j] = (1 - eta) * C[j] + eta * X[i]
    return C, v


def test_mb_matches_serial_oracle(blobs):
    X, _ = blobs
    X = X[:600]
    k, b = 8, 100
    rng = np.random.default_rng(0)
    perm = rng.permutation(len(X))
    Xs = X[perm]
    Xd = jnp.asarray(Xs)

    state = init_state(Xd, k, bounds="none")
    C_np = np.asarray(state.stats.C).copy()
    v_np = np.zeros(k)
    order = rng.permutation(len(X))
    for r in range(4):
        idx = order[r * b:(r + 1) * b]
        state, _ = rounds.mb_round(Xd, jnp.asarray(idx), state, fixed=False)
        C_np, v_np = _serial_mb_round(Xs, idx, C_np, v_np)
        np.testing.assert_allclose(np.asarray(state.stats.C), C_np,
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"round {r}")


def test_mbf_centroids_are_exact_current_means(blobs):
    """After any number of mb-f rounds: C(j) == mean of x(i) whose most
    recent assignment is j (the paper's contamination-removal claim)."""
    X, _ = blobs
    X = X[:1000]
    k, b = 8, 200
    Xd = jnp.asarray(X)
    state = init_state(Xd, k, bounds="none")
    rng = np.random.default_rng(1)
    for r in range(8):
        idx = rng.permutation(len(X))[:b]
        state, _ = rounds.mb_round(Xd, jnp.asarray(idx), state, fixed=True)
    a = np.asarray(state.points.a)
    C = np.asarray(state.stats.C)
    for j in range(k):
        members = X[a == j]
        if len(members):
            np.testing.assert_allclose(C[j], members.mean(0), rtol=1e-4,
                                       atol=1e-4)


# ---------------------------------------------------------------------------
# gb-inf with b0 = N == Lloyd
# ---------------------------------------------------------------------------

def test_nested_full_batch_equals_lloyd(blobs):
    X, _ = blobs
    k = 8
    r1 = _fit(X, k, algorithm="lloyd", seed=3)
    r2 = _fit(X, k, algorithm="gb", b0=len(X), rho=np.inf, seed=3)
    m1 = float(full_mse(jnp.asarray(X), jnp.asarray(r1.C)))
    m2 = float(full_mse(jnp.asarray(X), jnp.asarray(r2.C)))
    assert r1.converged and r2.converged
    assert abs(m1 - m2) / m1 < 1e-5


# ---------------------------------------------------------------------------
# end-to-end quality + paper's qualitative claims
# ---------------------------------------------------------------------------

def test_all_algorithms_reach_reasonable_quality(blobs, blobs_val):
    X, centers = blobs
    k = centers.shape[0]
    base = float(full_mse(jnp.asarray(blobs_val),
                          jnp.asarray(centers, jnp.float32)))
    for algo, kw in [("lloyd", {}), ("mb", dict(b0=256)),
                     ("mbf", dict(b0=256)),
                     ("gb", dict(b0=256)),
                     ("tb", dict(b0=256, bounds="hamerly2")),
                     ("tb", dict(b0=256, bounds="elkan")),
                     ("tb", dict(b0=256, bounds="exponion"))]:
        res = driver.fit(X, k, algorithm=algo, max_rounds=60, seed=0, **kw)
        mse = float(full_mse(jnp.asarray(blobs_val), jnp.asarray(res.C)))
        assert mse < 2.5 * base, (algo, mse, base)


def test_turbo_pruning_kicks_in(blobs):
    """tb-inf: once converged at b=N, the bound test eliminates all
    distance work (n_recomputed -> 0) — the turbocharging effect."""
    X, _ = blobs
    res = driver.fit(X, 8, algorithm="tb", b0=512, bounds="hamerly2",
                     max_rounds=60, seed=0)
    assert res.converged
    assert res.telemetry[-1]["n_recomputed"] == 0
    # and pruning was already substantial before full convergence
    assert res.telemetry[-3]["n_recomputed"] < 0.05 * len(X)


def test_batch_growth_is_nested_and_monotone(blobs):
    X, _ = blobs
    res = driver.fit(X, 8, algorithm="gb", b0=128, max_rounds=60, seed=0)
    bs = [t["b"] for t in res.telemetry if t["b"]]
    assert all(b2 >= b1 for b1, b2 in zip(bs, bs[1:]))
    assert bs[-1] == len(X)          # reached the full dataset
    assert bs[0] == 128


def test_lloyd_elkan_equals_lloyd(blobs):
    """The Elkan-accelerated Lloyd (nested engine at b0=N with faithful
    per-(i,j) bounds) reaches the identical local minimum."""
    X, _ = blobs
    r1 = _fit(X, 8, algorithm="lloyd", seed=5)
    r2 = _fit(X, 8, algorithm="lloyd-elkan", seed=5, max_rounds=60)
    m1 = float(full_mse(jnp.asarray(X), jnp.asarray(r1.C)))
    m2 = float(full_mse(jnp.asarray(X), jnp.asarray(r2.C)))
    assert r1.converged and r2.converged
    assert abs(m1 - m2) / m1 < 1e-5


def test_sgd_is_mb_with_batch_one(blobs):
    X, _ = blobs
    res = driver.fit(X[:500], 4, algorithm="sgd", max_rounds=200, seed=0)
    assert all(t["b"] == 1 for t in res.telemetry)
    mse0 = res.telemetry[0]["batch_mse"]
    # single-point rounds still drive centroids somewhere sensible
    mse = float(full_mse(jnp.asarray(X[:500]), jnp.asarray(res.C)))
    assert np.isfinite(mse)


# ---------------------------------------------------------------------------
# growth controller: sigma_C exact for small-count clusters
# ---------------------------------------------------------------------------

def test_sigma_c_exact_for_small_counts():
    """sigma_C = sqrt(sse / (v(v-1))) must use the TRUE denominator for
    1 < v < 2: the old maximum(denom, 1.0) clamp silently deflated the
    noise estimate of exactly the small clusters the paper's balancing
    argument cares about (v=1.5 -> denom 0.75, clamped to 1.0)."""
    from repro.core import controller

    sse = jnp.asarray([3.0, 3.0, 3.0, 8.0])
    v = jnp.asarray([1.5, 1.0, 0.0, 4.0])
    sig = np.asarray(controller.sigma_c(sse, v))
    # v=1.5: sqrt(3 / (1.5 * 0.5)) = 2.0 exactly — NOT sqrt(3) ~ 1.732
    assert sig[0] == pytest.approx(2.0)
    assert np.isinf(sig[1]) and np.isinf(sig[2])     # v <= 1: undefined
    assert sig[3] == pytest.approx(np.sqrt(8.0 / 12.0))
    # the deflation changed growth votes: a cluster with v=1.5 and p just
    # above the clamped estimate must now vote grow at rho=1
    p = jnp.asarray([1.9, 1.0, 1.0, 1.0])
    ratios = np.asarray(controller.growth_ratios(sse, v, p))
    assert ratios[0] > 1.0                  # exact: 2.0/1.9 > 1
    assert np.sqrt(3.0) / 1.9 < 1.0         # clamped estimate would not
