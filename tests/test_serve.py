"""repro.serve: snapshots, ingest queue, ClusterService concurrency."""
import threading
import time

import numpy as np
import pytest

from repro.api import FitConfig, NestedKMeans, NotFittedError
from repro.serve import (ClusterService, CodebookSnapshot, IngestQueue,
                         SnapshotRef)


def wait_until(pred, timeout=20.0, dt=0.005):
    t0 = time.time()
    while time.time() - t0 < timeout:
        if pred():
            return True
        time.sleep(dt)
    return pred()


# -- IngestQueue ------------------------------------------------------------

def rows(n, d=4, base=0.0):
    return (np.arange(n * d, dtype=np.float32).reshape(n, d) + base)


def test_queue_accumulates_and_drains_fifo():
    q = IngestQueue(max_rows=100)
    assert q.put(rows(3)) == 3
    assert q.put(rows(2, base=100.0)) == 2
    got = q.get_batch(10)
    assert got is not None
    X, ids = got
    assert X.shape == (5, 4) and ids == [None] * 5
    np.testing.assert_array_equal(X[:3], rows(3))
    assert q.depth == 0


def test_queue_block_policy_times_out_and_counts_rejects():
    q = IngestQueue(max_rows=4, policy="block")
    assert q.put(rows(4)) == 4
    t0 = time.time()
    assert q.put(rows(2), timeout=0.05) == 0       # full: rejected
    assert time.time() - t0 >= 0.04
    s = q.stats()
    assert s["dropped_full"] == 2 and s["accepted"] == 4
    # a consumer makes room; a blocked producer then gets through
    def unblock():
        time.sleep(0.05)
        q.get_batch(2)
    threading.Thread(target=unblock).start()
    assert q.put(rows(1), timeout=5.0) == 1


def test_queue_drop_oldest_policy():
    q = IngestQueue(max_rows=4, policy="drop-oldest")
    q.put(rows(4))                      # rows 0..3
    assert q.put(rows(2, base=100.0)) == 2
    X, _ = q.get_batch(10)
    assert X.shape == (4, 4)
    # the two OLDEST rows were evicted; newest survive
    np.testing.assert_array_equal(X[-2:], rows(2, base=100.0))
    assert q.stats()["evicted"] == 2


def test_queue_reservoir_policy_is_bounded_sample():
    q = IngestQueue(max_rows=32, policy="reservoir", seed=0)
    for i in range(100):
        q.put(rows(8, base=float(i * 1000)))
    assert q.depth == 32                # never exceeds the bound
    s = q.stats()
    assert s["offered"] == 800
    assert s["evicted"] + s["dropped_full"] == 800 - 32
    X, _ = q.get_batch(100)
    # a real sample of the whole stream, not just the newest rows:
    # something from the first half must have survived (p ~ 1 - 2^-32)
    assert X.shape[0] == 32
    assert (X[:, 0] < 400 * 1000).any()


def test_queue_dedup_each_id_contributes_once():
    q = IngestQueue(max_rows=100, dedup=True)
    assert q.put(rows(3), ids=["a", "b", "c"]) == 3
    assert q.put(rows(3), ids=["b", "c", "d"]) == 1   # only "d" is new
    assert q.stats()["deduped"] == 2
    X, ids = q.get_batch(10)
    assert ids == ["a", "b", "c", "d"]
    # dedup survives draining: an id can never contribute twice
    assert q.put(rows(1), ids=["a"]) == 0


def test_queue_dedup_rejected_rows_may_be_redelivered():
    """An id is only 'seen' once its row is ACCEPTED: a row bounced by
    backpressure can be retried later without tripping the dedup."""
    q = IngestQueue(max_rows=2, policy="block", dedup=True)
    assert q.put(rows(2), ids=["a", "b"]) == 2
    assert q.put(rows(1, base=50.0), ids=["c"], timeout=0.02) == 0
    q.get_batch(2)                      # make room
    assert q.put(rows(1, base=50.0), ids=["c"]) == 1   # retry succeeds
    assert q.stats()["deduped"] == 0


def test_queue_blocked_put_raises_on_close():
    """A producer blocked on a full queue fails loudly when the queue is
    closed under it (refresher death) instead of silently dropping."""
    q = IngestQueue(max_rows=1, policy="block")
    q.put(rows(1))
    threading.Timer(0.05, q.close).start()
    with pytest.raises(RuntimeError):
        q.put(rows(1), timeout=10.0)


def test_queue_get_batch_allow_short_false_waits_for_min():
    q = IngestQueue(max_rows=100)
    q.put(rows(3))
    assert q.get_batch(10, min_rows=5, timeout=0.05,
                       allow_short=False) is None
    assert q.depth == 3                 # nothing drained
    got = q.get_batch(10, min_rows=5, timeout=0.05)   # short flush ok
    assert got is not None and got[0].shape[0] == 3


def test_queue_close_wakes_and_drains():
    q = IngestQueue(max_rows=100)
    q.put(rows(2))
    q.close()
    with pytest.raises(RuntimeError):
        q.put(rows(1))
    assert q.get_batch(10, min_rows=50, timeout=5.0)[0].shape[0] == 2
    assert q.get_batch(10, timeout=0.01) is None


# -- snapshots --------------------------------------------------------------

def test_snapshot_immutable_and_checksummed():
    exported = {"centroids": np.ones((4, 3), np.float32),
                "counts": np.ones((4,), np.float32),
                "n_rounds": 1, "batch_mse": 0.5}
    snap = CodebookSnapshot.create(1, exported)
    assert snap.verify()
    with pytest.raises(ValueError):
        snap.centroids[0, 0] = 9.0      # read-only
    a = snap.predict(np.zeros((2, 3), np.float32))
    assert a.shape == (2,)
    d = snap.transform(np.zeros((2, 3), np.float32))
    assert d.shape == (2, 4)


def test_snapshot_ref_rejects_version_regression():
    exported = {"centroids": np.ones((2, 2), np.float32),
                "counts": np.ones((2,), np.float32),
                "n_rounds": 1, "batch_mse": 0.5}
    ref = SnapshotRef()
    ref.publish(CodebookSnapshot.create(3, exported))
    with pytest.raises(ValueError):
        ref.publish(CodebookSnapshot.create(3, exported))
    assert ref.load().version == 3


# -- ClusterService ---------------------------------------------------------

@pytest.fixture(scope="module")
def stream_blobs():
    from repro.data.synthetic import gaussian_blobs
    X, _ = gaussian_blobs(6000, k=8, dim=8, spread=5.0, seed=0)
    return X


def test_service_first_batch_accumulates_below_k(stream_blobs):
    """partial_fit via the queue accepts sub-k batches: the service
    publishes once >= k rows have ACCUMULATED from tiny ingests."""
    k = 32
    km = NestedKMeans(FitConfig(k=k, b0=64, seed=0))
    svc = ClusterService(km, micro_batch=128, flush_after_s=0.02).start()
    try:
        with pytest.raises(NotFittedError):
            svc.predict(stream_blobs[:4])
        for i in range(0, 4 * k, 5):        # chunks of 5 << k
            svc.ingest(stream_blobs[i:i + 5])
        assert wait_until(lambda: svc.snapshot is not None)
        labels = svc.predict(stream_blobs[:64])
        assert labels.shape == (64,) and labels.max() < k
        v1 = svc.snapshot.version
        # sub-k batches keep streaming AFTER the first publication too
        svc.ingest(stream_blobs[200:207])
        assert wait_until(lambda: svc.queue.depth == 0)
    finally:
        svc.stop()
    assert svc.snapshot.version > v1        # the tail flush refreshed
    assert svc.export_metrics()["refresh"]["rows"] >= 4 * k


def test_service_concurrent_predict_no_torn_reads(stream_blobs):
    """Hammer predict from several threads while the refresher runs:
    every observed snapshot verifies its checksum (no torn reads) and
    versions are monotone per reader."""
    k = 16
    km = NestedKMeans(FitConfig(k=k, b0=256, seed=0))
    km.fit(stream_blobs[:2000])
    svc = ClusterService(km, micro_batch=256, flush_after_s=0.01).start()
    stop = threading.Event()
    errors, n_reads = [], [0] * 4

    def reader(slot):
        last = 0
        Q = stream_blobs[slot * 100:slot * 100 + 50]
        while not stop.is_set():
            snap = svc.snapshot
            if not snap.verify():
                errors.append(f"torn read at v{snap.version}")
                return
            if snap.version < last:
                errors.append(f"version regressed {last}->{snap.version}")
                return
            last = snap.version
            labels = svc.predict(Q)
            if labels.shape != (50,) or labels.max() >= k:
                errors.append(f"bad labels {labels.shape}")
                return
            n_reads[slot] += 1

    readers = [threading.Thread(target=reader, args=(i,))
               for i in range(4)]
    for t in readers:
        t.start()
    v0 = svc.snapshot.version
    pos = 2000
    t0 = time.time()
    while time.time() - t0 < 2.0:
        svc.ingest(stream_blobs[pos:pos + 100])
        pos = 2000 + (pos - 2000 + 100) % 3900
        time.sleep(0.002)
    # refreshes must actually have happened while readers hammered
    assert wait_until(lambda: svc.snapshot.version > v0 + 3)
    stop.set()
    for t in readers:
        t.join()
    svc.stop()
    assert not errors, errors
    assert all(n > 0 for n in n_reads)
    m = svc.export_metrics()
    assert m["refresh"]["count"] >= 4
    assert m["predict"]["requests"] == sum(n_reads)


def test_service_snapshot_isolated_from_later_refreshes(stream_blobs):
    """A reader holding an old snapshot keeps a consistent codebook even
    after many refreshes replaced it."""
    k = 8
    km = NestedKMeans(FitConfig(k=k, b0=128, seed=0))
    km.fit(stream_blobs[:1000])
    svc = ClusterService(km, micro_batch=64, flush_after_s=0.01).start()
    held = svc.snapshot
    C_held = held.centroids.copy()
    for i in range(10):
        svc.ingest(stream_blobs[1000 + 64 * i:1000 + 64 * (i + 1)])
    assert wait_until(lambda: svc.snapshot.version >= held.version + 3)
    svc.stop()
    assert held.verify()
    np.testing.assert_array_equal(held.centroids, C_held)
    assert svc.snapshot.version > held.version


def test_service_escalates_on_drift(stream_blobs):
    """A manual escalation re-fits on the history reservoir without
    invalidating reads, and bumps the snapshot version."""
    k = 8
    km = NestedKMeans(FitConfig(k=k, b0=128, max_rounds=30, seed=0))
    km.fit(stream_blobs[:1000])
    svc = ClusterService(km, micro_batch=128, flush_after_s=0.01,
                         history_rows=1024).start()
    svc.ingest(stream_blobs[1000:2024])
    assert wait_until(lambda: svc.queue.depth == 0)
    svc.stop()
    v_before = svc.snapshot.version
    svc.escalate()
    assert svc.snapshot.version > v_before
    assert svc.export_metrics()["refresh"]["escalations"] == 1
    assert svc.snapshot.verify()


def test_estimator_partial_fit_is_thread_safe(stream_blobs):
    """Two writers racing partial_fit: every batch's contribution lands
    exactly once (total counts == total rows folded)."""
    k = 8
    km = NestedKMeans(FitConfig(k=k, b0=128, seed=0))
    km.fit(stream_blobs[:1000])
    n0 = float(np.sum(km.counts_))
    per_thread, batches = 100, 8

    def writer(tid):
        for j in range(batches):
            lo = 1000 + (tid * batches + j) * per_thread
            km.partial_fit(stream_blobs[lo:lo + per_thread])

    ws = [threading.Thread(target=writer, args=(i,)) for i in range(4)]
    for w in ws:
        w.start()
    for w in ws:
        w.join()
    assert float(np.sum(km.counts_)) == pytest.approx(
        n0 + 4 * batches * per_thread)
    assert km.n_rounds_ == len(km.telemetry_)


def test_service_background_refresh_runs_sharded(stream_blobs):
    """The ROADMAP serving follow-up: a mesh-backed estimator streams
    through the service's background refresher (partial_fit routes
    through the configured engine now, not just the local one)."""
    import jax
    k = 8
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    km = NestedKMeans(FitConfig(k=k, b0=256, max_rounds=30, seed=0,
                                backend="mesh"), mesh=mesh)
    km.fit(stream_blobs[:1000])
    svc = ClusterService(km, micro_batch=256, flush_after_s=0.01).start()
    try:
        n0 = float(np.sum(km.counts_))
        svc.ingest(stream_blobs[1000:2024])
        assert wait_until(lambda: svc.queue.depth == 0)
    finally:
        svc.stop()
    assert float(np.sum(km.counts_)) == pytest.approx(n0 + 1024)
    labels = svc.predict(stream_blobs[:64])
    assert labels.shape == (64,) and labels.max() < k
    assert svc.snapshot.verify()
