"""Checkpoint store: roundtrip, atomicity, GC, checksums, elasticity."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore


def tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
            "nested": {"b": jnp.asarray(rng.normal(size=(4,)),
                                        jnp.bfloat16),
                       "step": jnp.asarray(7, jnp.int32)}}


def test_roundtrip(tmp_path):
    store = CheckpointStore(tmp_path, keep=2)
    t = tree()
    store.save(3, t)
    got = store.restore(jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_keep_n_gc(tmp_path):
    store = CheckpointStore(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        store.save(s, tree(s))
    assert store.steps() == [3, 4]


def test_background_save_then_restore(tmp_path):
    store = CheckpointStore(tmp_path, keep=3)
    t = tree(5)
    store.save(10, t, background=True)
    store.wait()
    assert store.latest_step() == 10
    got = store.restore(jax.tree.map(jnp.zeros_like, t))
    np.testing.assert_array_equal(np.asarray(t["w"]),
                                  np.asarray(got["w"]))


def test_checksum_detects_corruption(tmp_path):
    store = CheckpointStore(tmp_path)
    t = tree()
    store.save(1, t)
    d = store._step_dirs()[1]
    # corrupt one leaf
    target = next(d.glob("arr_*.npy"))
    arr = np.load(target)
    arr = np.asarray(arr).copy()
    flat = arr.reshape(-1).view(np.uint8)
    flat[0] ^= 0xFF
    np.save(target, arr)
    with pytest.raises(IOError):
        store.restore(jax.tree.map(jnp.zeros_like, t))


def test_crashed_tmp_dir_is_ignored(tmp_path):
    store = CheckpointStore(tmp_path)
    t = tree()
    store.save(1, t)
    # simulate a crashed writer
    fake = tmp_path / "step_000000002.tmp-9999"
    fake.mkdir()
    (fake / "garbage").write_text("x")
    assert store.latest_step() == 1
    store.restore(jax.tree.map(jnp.zeros_like, t))


def test_missing_leaf_raises(tmp_path):
    store = CheckpointStore(tmp_path)
    store.save(1, {"a": jnp.zeros((2,))})
    with pytest.raises(KeyError):
        store.restore({"a": jnp.zeros((2,)), "b": jnp.zeros((3,))})


def test_crash_during_overwrite_keeps_previous(tmp_path, monkeypatch):
    """Re-saving a step is atomic: a writer that crashes at ANY point
    before its rename lands must leave the previous checkpoint intact.
    (The old implementation did rmtree(final) THEN rename — a crash
    between the two lost the only copy.)"""
    store = CheckpointStore(tmp_path)
    t1 = tree(1)
    store.save(5, t1)

    calls = {"n": 0}
    real_rename = os.rename

    def crashing_rename(src, dst):
        calls["n"] += 1
        raise OSError("simulated crash before the atomic rename")

    monkeypatch.setattr(os, "rename", crashing_rename)
    with pytest.raises(OSError):
        store.save(5, tree(2))
    monkeypatch.setattr(os, "rename", real_rename)
    assert calls["n"] == 1

    # previous checkpoint is fully readable
    assert store.latest_step() == 5
    got = store.restore(jax.tree.map(jnp.zeros_like, t1))
    np.testing.assert_array_equal(np.asarray(t1["w"]),
                                  np.asarray(got["w"]))


def test_overwrite_same_step_newest_wins(tmp_path):
    store = CheckpointStore(tmp_path)
    store.save(3, tree(1))
    t2 = tree(2)
    store.save(3, t2)
    assert store.steps() == [3]
    got = store.restore(jax.tree.map(jnp.zeros_like, t2))
    np.testing.assert_array_equal(np.asarray(t2["w"]),
                                  np.asarray(got["w"]))
    # the superseded version was garbage-collected
    assert len([p for p in (tmp_path).glob("step_*")
                if ".tmp-" not in p.name]) == 1


def test_legacy_unversioned_dir_still_restorable(tmp_path):
    """Checkpoints written by the pre-versioning layout (plain
    ``step_X`` dirs) stay readable, and a versioned rewrite of the same
    step supersedes them."""
    store = CheckpointStore(tmp_path)
    t1 = tree(1)
    store.save(2, t1)
    d = store._step_dirs()[2]
    legacy = tmp_path / "step_000000002"
    os.rename(d, legacy)                  # devolve to the legacy layout
    assert store.steps() == [2]
    got = store.restore(jax.tree.map(jnp.zeros_like, t1))
    np.testing.assert_array_equal(np.asarray(t1["w"]),
                                  np.asarray(got["w"]))
    t2 = tree(9)
    store.save(2, t2)                     # versioned rewrite wins
    got = store.restore(jax.tree.map(jnp.zeros_like, t2))
    np.testing.assert_array_equal(np.asarray(t2["w"]),
                                  np.asarray(got["w"]))
    assert not legacy.exists()            # superseded + gc'd


def test_gc_reaps_stale_tmp_dirs(tmp_path):
    """A crashed writer's fresh-named .tmp- dir can never match a later
    write's cleanup check; _gc reaps it once it is old enough."""
    import time as _time
    store = CheckpointStore(tmp_path)
    stale = tmp_path / "step_000000007.v123.tmp-4242"
    stale.mkdir()
    old = _time.time() - 3600
    os.utime(stale, (old, old))
    fresh = tmp_path / "step_000000008.v456.tmp-4242"
    fresh.mkdir()                         # a live writer's tmp survives
    store.save(9, tree())
    assert not stale.exists()
    assert fresh.exists()
    assert store.steps() == [9]


def test_clear_removes_all_checkpoints(tmp_path):
    store = CheckpointStore(tmp_path)
    store.save(1, tree(1))
    store.save(2, tree(2))
    store.clear()
    assert store.steps() == []
    assert store.latest_step() is None


def test_extra_json_roundtrip(tmp_path):
    store = CheckpointStore(tmp_path)
    extra = {"loop": {"b_global": 512, "t_work": 1.5},
             "config": {"k": 8}}
    store.save(4, tree(), extra=extra)
    assert store.read_extra() == extra
    assert store.read_extra(4) == extra
    store.save(5, tree())
    assert store.read_extra(5) is None    # extra is optional per step


def test_kmeans_growth_state_roundtrip(tmp_path):
    """The engine's full state (incl. growth schedule) is restorable —
    elastic restart of a nested run."""
    from repro.core.state import init_state
    import dataclasses
    X = jnp.asarray(np.random.default_rng(0).normal(size=(64, 8)),
                    jnp.float32)
    s = init_state(X, 4, bounds="hamerly2")
    meta = {"b": jnp.asarray(16), "b0": jnp.asarray(8),
            "seed": jnp.asarray(0)}
    store = CheckpointStore(tmp_path)
    store.save(0, {"state": s, "meta": meta})
    got = store.restore({"state": init_state(X, 4, bounds="hamerly2"),
                         "meta": jax.tree.map(jnp.zeros_like, meta)})
    assert int(got["meta"]["b"]) == 16
    np.testing.assert_array_equal(np.asarray(s.stats.C),
                                  np.asarray(got["state"].stats.C))
