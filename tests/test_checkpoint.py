"""Checkpoint store: roundtrip, atomicity, GC, checksums, elasticity."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore


def tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
            "nested": {"b": jnp.asarray(rng.normal(size=(4,)),
                                        jnp.bfloat16),
                       "step": jnp.asarray(7, jnp.int32)}}


def test_roundtrip(tmp_path):
    store = CheckpointStore(tmp_path, keep=2)
    t = tree()
    store.save(3, t)
    got = store.restore(jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_keep_n_gc(tmp_path):
    store = CheckpointStore(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        store.save(s, tree(s))
    assert store.steps() == [3, 4]


def test_background_save_then_restore(tmp_path):
    store = CheckpointStore(tmp_path, keep=3)
    t = tree(5)
    store.save(10, t, background=True)
    store.wait()
    assert store.latest_step() == 10
    got = store.restore(jax.tree.map(jnp.zeros_like, t))
    np.testing.assert_array_equal(np.asarray(t["w"]),
                                  np.asarray(got["w"]))


def test_checksum_detects_corruption(tmp_path):
    store = CheckpointStore(tmp_path)
    t = tree()
    store.save(1, t)
    d = tmp_path / "step_000000001"
    # corrupt one leaf
    target = next(d.glob("arr_*.npy"))
    arr = np.load(target)
    arr = np.asarray(arr).copy()
    flat = arr.reshape(-1).view(np.uint8)
    flat[0] ^= 0xFF
    np.save(target, arr)
    with pytest.raises(IOError):
        store.restore(jax.tree.map(jnp.zeros_like, t))


def test_crashed_tmp_dir_is_ignored(tmp_path):
    store = CheckpointStore(tmp_path)
    t = tree()
    store.save(1, t)
    # simulate a crashed writer
    fake = tmp_path / "step_000000002.tmp-9999"
    fake.mkdir()
    (fake / "garbage").write_text("x")
    assert store.latest_step() == 1
    store.restore(jax.tree.map(jnp.zeros_like, t))


def test_missing_leaf_raises(tmp_path):
    store = CheckpointStore(tmp_path)
    store.save(1, {"a": jnp.zeros((2,))})
    with pytest.raises(KeyError):
        store.restore({"a": jnp.zeros((2,)), "b": jnp.zeros((3,))})


def test_kmeans_growth_state_roundtrip(tmp_path):
    """The engine's full state (incl. growth schedule) is restorable —
    elastic restart of a nested run."""
    from repro.core.state import init_state
    import dataclasses
    X = jnp.asarray(np.random.default_rng(0).normal(size=(64, 8)),
                    jnp.float32)
    s = init_state(X, 4, bounds="hamerly2")
    meta = {"b": jnp.asarray(16), "b0": jnp.asarray(8),
            "seed": jnp.asarray(0)}
    store = CheckpointStore(tmp_path)
    store.save(0, {"state": s, "meta": meta})
    got = store.restore({"state": init_state(X, 4, bounds="hamerly2"),
                         "meta": jax.tree.map(jnp.zeros_like, meta)})
    assert int(got["meta"]["b"]) == 16
    np.testing.assert_array_equal(np.asarray(s.stats.C),
                                  np.asarray(got["state"].stats.C))
