"""AdamW, schedules, data generators, pipelines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import pipeline, synthetic
from repro.optim import adamw


def test_adamw_minimises_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=5, decay_steps=200,
                            weight_decay=0.0, grad_clip=1e9)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros((3,))}
    state = adamw.init(params)

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda p_: jnp.sum((p_["w"] - target) ** 2))(p)
        return adamw.update(p, g, s, cfg)

    for _ in range(200):
        params, state, m = step(params, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, decay_steps=100,
                            min_lr_ratio=0.1)
    lrs = [float(adamw.schedule(cfg, jnp.asarray(s))) for s in
           [0, 5, 10, 50, 100, 1000]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, abs=1e-6)
    assert lrs[5] == pytest.approx(0.1, abs=1e-6)


def test_grad_clip_bounds_update_norm():
    cfg = adamw.AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((4,))}
    state = adamw.init(params)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, m = adamw.update(params, g, state, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


# -- data -------------------------------------------------------------------

def test_infmnist_like_shape_range_determinism():
    a = synthetic.infmnist_like(200, seed=7)
    b = synthetic.infmnist_like(200, seed=7)
    assert a.shape == (200, 784)
    assert a.min() >= 0.0 and a.max() <= 1.0
    np.testing.assert_array_equal(a, b)
    assert not np.allclose(a, synthetic.infmnist_like(200, seed=8))


def test_rcv1_like_rows_are_normalised_sparseish():
    X = synthetic.rcv1_like(100, dim=512, avg_nnz=30, seed=0)
    norms = np.linalg.norm(X, axis=1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-5)
    nnz = (X != 0).sum(1)
    assert nnz.mean() < 120          # sparse-ish


def test_kmeans_sharded_source_nested_prefix():
    X = np.arange(64, dtype=np.float32)[:, None]
    src = pipeline.KMeansShardedSource(X, n_shards=4, seed=0)
    b = 16
    union = np.concatenate([src.shard(s)[: b // 4] for s in range(4)])
    expect = src.global_prefix(b)
    np.testing.assert_array_equal(np.sort(union.ravel()),
                                  np.sort(expect.ravel()))


def test_kmeans_sharded_source_pads_like_mesh_engine():
    """n % n_shards != 0: host source matches the MeshEngine placement.

    `_MeshRun` builds its device layout from the SAME
    `nested_shard_layout` the source uses; this test independently
    recomputes the engine's reshape/transpose interleave and checks the
    source against it, so the shared helper can't silently change
    semantics for one consumer.
    """
    n_real, n_shards, seed = 67, 4, 3
    X = np.arange(n_real, dtype=np.float32)[:, None] + 1.0
    src = pipeline.KMeansShardedSource(X, n_shards=n_shards, seed=seed)
    lay = src.layout
    assert lay.n_storage == 68 and lay.n_storage % n_shards == 0

    # the engine's device placement: pad with X[:1], shuffle, interleave
    Xp = np.concatenate([X, np.repeat(X[:1], lay.n_storage - n_real,
                                      axis=0)])
    Xh = Xp[lay.perm].reshape(lay.n_storage // n_shards, n_shards, -1) \
        .transpose(1, 0, 2)
    for s in range(n_shards):
        np.testing.assert_array_equal(src.shard(s), Xh[s])
        nv = src.n_valid(s)
        # real rows are prefix-contiguous; the tail is structural pads
        assert np.all(src.shard(s)[nv:] == X[0])
    # per-shard n_valid matches the engine's mask semantics: every real
    # row is valid on exactly one shard
    assert int(lay.n_valid.sum()) == n_real
    allv = np.concatenate([src.shard_valid(s) for s in range(n_shards)])
    np.testing.assert_array_equal(np.sort(allv.ravel()),
                                  np.sort(X.ravel()))
    # orig_index: -1 exactly on the pad storage rows
    oi = lay.orig_index()
    assert int((oi < 0).sum()) == lay.n_storage - n_real
    np.testing.assert_array_equal(np.sort(oi[oi >= 0]), np.arange(n_real))


def test_kmeans_sharded_source_prefix_property_with_pads():
    """Union of per-shard prefixes == global shuffle prefix, pads or not."""
    X = np.arange(37, dtype=np.float32)[:, None]
    src = pipeline.KMeansShardedSource(X, n_shards=4, seed=1)
    b = 16
    union = np.concatenate([src.shard(s)[: b // 4] for s in range(4)])
    expect = src.global_prefix(b)
    np.testing.assert_array_equal(np.sort(union.ravel()),
                                  np.sort(expect.ravel()))
    with pytest.raises(ValueError):
        src.global_prefix(38)       # pads may never enter a prefix


def test_lm_batches_seekable():
    lb = pipeline.LMBatches(vocab=100, batch=4, seq=16, n_tokens=10_000,
                            seed=0)
    a = lb.at(3)
    b = lb.at(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


@pytest.mark.slow
def test_distributed_engine_subprocess():
    """Multi-device shard_map equivalence (8 forced host devices).

    Deterministic by construction: the subprocess forces 8 host devices
    via XLA_FLAGS and every RNG in the smoke script is explicitly
    seeded, so the distributed-vs-single-host comparison is stable.
    """
    import subprocess, sys, os
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "scripts/smoke_distributed.py"],
                       env=env, capture_output=True, text=True,
                       timeout=600, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stdout + r.stderr
