"""repro.analysis: the invariant checkers must pass on the clean tree
AND still flag every planted historical bug class with file:line."""
import subprocess
import sys

import numpy as np
import pytest

from repro.analysis import allowlist as al
from repro.analysis import donation, replicated_lint, retrace
from repro.analysis.report import Violation, repo_root

FIXTURE = repo_root() / "src/repro/analysis/_selftest.py"


# -- replicated-control-flow lint -------------------------------------------

class TestLint:
    def test_clean_tree_is_clean(self):
        assert replicated_lint.run() == []

    def test_planted_violations_flagged_with_location(self):
        found = replicated_lint.lint_file(FIXTURE, mode="engine")
        by_kind = {}
        for v in found:
            by_kind.setdefault(v.kind, []).append(v)
        assert set(by_kind) >= {"branch", "host-coercion", "rng-draw"}
        text = FIXTURE.read_text().splitlines()
        for v in found:
            assert v.file.endswith("_selftest.py")
            assert v.line >= 1
            # the reported line really contains the reported snippet root
            assert v.detail.split("(")[0].split()[0][:8] in text[v.line - 1]

    def test_branch_on_device_scalar_is_the_pr2_site(self):
        found = replicated_lint.lint_file(FIXTURE, mode="engine")
        branches = [v for v in found
                    if v.kind == "branch"
                    and "jnp.max(state.stats.p)" in v.detail]
        assert len(branches) == 1
        assert branches[0].qualname == "LeakyRun.nested_step"

    def test_loop_region_catches_unsafe_branch(self, tmp_path):
        bad = tmp_path / "loop.py"
        bad.write_text(
            "def run_loop(run, config):\n"
            "    for _ in range(config.max_rounds):\n"
            "        new_state, info = run.nested_step(run.state, 1, None)\n"
            "        if info.overflow:\n"       # raw device read
            "            break\n")
        found = replicated_lint.lint_file(bad, mode="loop")
        assert [v.kind for v in found] == ["branch"]
        assert "info.overflow" in found[0].detail

    def test_loop_region_accepts_sanctioned_derivation(self, tmp_path):
        ok = tmp_path / "loop.py"
        ok.write_text(
            "def run_loop(run, config):\n"
            "    for _ in range(config.max_rounds):\n"
            "        new_state, info = run.nested_step(run.state, 1, None)\n"
            "        hinfo = fetch_round_info(info)\n"
            "        if hinfo.overflow:\n"
            "            break\n"
            "        flag = run.sync_flag(True)\n"
            "        if flag:\n"
            "            break\n")
        assert replicated_lint.lint_file(ok, mode="loop") == []

    def test_wall_clock_taints_branches(self, tmp_path):
        bad = tmp_path / "loop.py"
        bad.write_text(
            "import time\n"
            "def run_loop(run, config):\n"
            "    t0 = time.perf_counter()\n"
            "    while True:\n"
            "        if time.perf_counter() - t0 > config.budget:\n"
            "            break\n")
        found = replicated_lint.lint_file(bad, mode="loop")
        assert [v.kind for v in found] == ["branch"]


class TestAllowlist:
    def test_entry_requires_reason(self, tmp_path):
        f = tmp_path / "allow.txt"
        f.write_text("a.py::f::branch::x\n")
        with pytest.raises(ValueError, match="reason"):
            al.load(f)

    def test_matching_is_narrow(self):
        e = al.Entry(file="a.py", qualname="f", kind="branch",
                     substring="foo", reason="r", lineno=1)
        v = Violation(checker="lint", kind="branch", file="a.py",
                      line=3, qualname="f", detail="if foo > 1")
        assert e.matches(v)
        assert not e.matches(
            Violation(checker="lint", kind="host-coercion", file="a.py",
                      line=3, qualname="f", detail="if foo > 1"))
        assert not e.matches(
            Violation(checker="lint", kind="branch", file="b.py",
                      line=3, qualname="f", detail="if foo > 1"))

    def test_stale_entries_become_violations(self, tmp_path):
        f = tmp_path / "allow.txt"
        f.write_text("gone.py::f::branch::*  # excuses nothing\n")
        out = replicated_lint.run(files=[], allowlist_path=f)
        assert [v.kind for v in out] == ["stale-allowlist"]

    def test_repo_allowlist_parses_and_every_entry_is_used(self):
        entries = al.load()
        assert entries, "repo allowlist should sanction the known sites"
        raw = []
        for p, m in replicated_lint.default_files():
            raw.extend(replicated_lint.lint_file(p, m))
        _, used = al.apply(raw, entries)
        assert len(used) == len(entries)


# -- retrace accounting (pure logic + planted schedule) ----------------------

class TestRetraceLogic:
    def _site(self):
        return dict(site_file="x.py", site_line=1, qualname="t")

    def _key(self, b, cap, **extra):
        statics = {"b": b, "capacity": cap, "rho": 1.9,
                   "bounds": "hamerly2", **extra}
        return ("nested_round",
                tuple(sorted((k, repr(v)) for k, v in statics.items())))

    def test_one_trace_per_bucket_is_clean(self):
        diff = {self._key(32, None): 1, self._key(64, 16): 1}
        out = retrace.trace_violations(
            diff, [(32, None), (64, 16)], "nested_round", **self._site())
        assert out == []

    def test_warm_cache_missing_trace_is_not_a_violation(self):
        out = retrace.trace_violations(
            {}, [(32, None)], "nested_round", **self._site())
        assert out == []

    def test_rho_keyed_retrace_flagged(self):
        diff = {self._key(32, 16, rho=1.90): 1,
                self._key(32, 16, rho=1.91): 1}
        out = retrace.trace_violations(
            diff, [(32, 16)], "nested_round", **self._site())
        assert [v.kind for v in out] == ["retrace"]
        assert "rho" in out[0].detail

    def test_uninvoked_bucket_flagged(self):
        diff = {self._key(128, None): 1}
        out = retrace.trace_violations(
            diff, [(32, None)], "nested_round", **self._site())
        assert [v.kind for v in out] == ["unexpected-trace"]

    def test_lattice(self):
        out = retrace.lattice_violations(
            [(32, None), (64, 16), (100, None), (64, 24)],
            32, 100, **self._site())
        kinds = sorted(v.detail for v in out
                       if v.kind == "off-lattice-bucket")
        # b=100 IS on the chain (doubling capped at b_max);
        # capacity=24 is not a power of two
        assert len(kinds) == 1 and "capacity=24" in kinds[0]

    def test_planted_schedules_flagged(self):
        found = retrace.selftest()
        kinds = {v.kind for v in found}
        assert {"retrace", "off-lattice-bucket"} <= kinds
        assert all(v.file.endswith("_selftest.py") for v in found)

    def test_local_fit_traces_on_lattice(self):
        assert retrace.audit_backend("local", n=1024) == []


# -- donation audits ---------------------------------------------------------

class TestDonation:
    def test_every_scanned_site_is_registered(self):
        keys = {(f, name) for f, _, name in donation.scan_sites()}
        assert keys, "scan should find the shared piece_update writer"
        assert keys == set(donation.REGISTRY)

    def test_engine_data_path_donations_alias(self):
        assert donation.run() == []

    def test_planted_copying_donation_flagged(self):
        found = donation.selftest()
        assert any(v.kind == "not-aliased" for v in found)
        assert all(v.file.endswith("_selftest.py") for v in found)
        assert all(v.line > 1 for v in found)

    def test_unregistered_site_reported(self, tmp_path, monkeypatch):
        (tmp_path / "rogue.py").write_text(
            "import jax\n"
            "rogue = jax.jit(lambda x: x + 1, donate_argnums=0)\n")
        monkeypatch.setattr(donation, "SCAN_GLOBS", ("rogue.py",))
        sites = donation.scan_sites(root=tmp_path)
        assert any(name == "rogue" for _, _, name in sites)
        monkeypatch.setattr(donation, "scan_sites",
                            lambda root=None: sites)
        out = donation.run()
        assert any(v.kind == "unregistered-donation"
                   and v.qualname == "rogue" for v in out)


# -- host-sync audit ---------------------------------------------------------

class TestHostSync:
    def test_loop_drives_the_audit_seam(self):
        """round_scope once per round; sanctioned scopes cover every
        crossing the loop makes."""
        from repro.api.config import FitConfig
        from repro.api.engines import make_engine
        from repro.api.loop import LoopAudit, run_loop
        import contextlib

        calls = {"round": 0, "sanctioned": []}

        class Spy(LoopAudit):
            def round_scope(self):
                calls["round"] += 1
                return contextlib.nullcontext()

            def sanctioned_scope(self, what):
                calls["sanctioned"].append(what)
                return contextlib.nullcontext()

        rng = np.random.default_rng(0)
        X = rng.normal(size=(512, 4)).astype(np.float32)
        config = FitConfig(k=4, b0=64, seed=0, max_rounds=8,
                           eval_every=2).resolve(512)
        run = make_engine(config).begin(
            X, config, X_val=X[:64])
        out = run_loop(run, config, audit=Spy())
        n_rounds = sum(1 for t in out.telemetry
                       if t.batch_mse is not None)
        assert calls["round"] >= n_rounds
        assert set(calls["sanctioned"]) >= {"round_info", "eval_mse"}
        # one scalar landing per overflow attempt, >= one per round
        assert (calls["sanctioned"].count("round_info")
                >= n_rounds)

    @pytest.mark.slow
    def test_clean_local_fit_has_no_unsanctioned_syncs(self):
        from repro.analysis import hostsync
        assert hostsync.audit_backend("local", n=1024) == []

    @pytest.mark.slow
    def test_planted_device_branch_flagged(self):
        from repro.analysis import hostsync
        found = hostsync.selftest()
        assert found
        assert all(v.file.endswith("_selftest.py") for v in found)
        assert any(v.kind == "d2h-float" for v in found)
        assert all(v.qualname == "nested_step" for v in found)

    def test_interceptor_restores_the_array_type(self):
        import jax
        from repro.analysis.hostsync import HostSyncAudit

        x = jax.numpy.ones(())
        cls = type(x)
        before = cls.__float__
        audit = HostSyncAudit()
        with audit.installed():
            assert cls.__float__ is not before
            # outside a round scope: conversions pass through silently
            assert float(x) == 1.0
        assert cls.__float__ is before
        assert audit.violations == []


# -- CLI ---------------------------------------------------------------------

class TestCLI:
    def _run(self, *args):
        return subprocess.run(
            [sys.executable, "-m", "repro.analysis", *args],
            capture_output=True, text=True,
            cwd=repo_root(),
            env={"PYTHONPATH": str(repo_root() / "src"),
                 "PATH": "/usr/bin:/bin:/usr/local/bin"})

    def test_lint_exits_zero_on_clean_tree(self):
        r = self._run("lint")
        assert r.returncode == 0, r.stdout + r.stderr
        assert "[lint] OK" in r.stdout

    def test_lint_selftest_exits_zero_and_lists_findings(self):
        r = self._run("lint", "--selftest")
        assert r.returncode == 0, r.stdout + r.stderr
        assert "_selftest.py" in r.stdout
