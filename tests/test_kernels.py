"""Pallas kernels vs pure-jnp oracles, interpret=True shape/dtype sweeps;
the `kernels.plan` dispatch layer; the compat alias version guard."""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.cluster_sum import cluster_sum_pallas
from repro.kernels.kmeans_assign import assign_top2_pallas
from repro.kernels.plan import KernelPlan, next_pow2, resolve_plan

SHAPES = [
    (64, 7, 5),          # tiny, heavy padding
    (256, 32, 50),       # paper k
    (300, 784, 50),      # infMNIST dims, unaligned n
    (512, 128, 128),     # aligned everything
    (1000, 200, 257),    # k crosses one block boundary
]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("n,d,k", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_assign_top2_matches_ref(n, d, k, dtype):
    rng = np.random.default_rng(n + d + k)
    x = jnp.asarray(rng.normal(size=(n, d)), dtype)
    c = jnp.asarray(rng.normal(size=(k, d)) * 2, dtype)
    a_p, d1_p, d2_p = assign_top2_pallas(x, c, bn=128, bk=128,
                                         interpret=True)
    a_r, d1_r, d2_r = ref.assign_top2_ref(x, c)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(d1_p, d1_r, rtol=tol, atol=tol * 10)
    np.testing.assert_allclose(d2_p, d2_r, rtol=tol, atol=tol * 10)
    # assignments may differ only where d1 ties within tolerance
    diff = np.asarray(a_p) != np.asarray(a_r)
    if diff.any():
        d2m = ref.pairwise_dist2(x, c)
        for i in np.where(diff)[0]:
            assert abs(d2m[i, a_p[i]] - d2m[i, a_r[i]]) < tol * 100


@pytest.mark.parametrize("n,d,k", SHAPES)
def test_cluster_sum_matches_ref(n, d, k):
    rng = np.random.default_rng(n * 7 + d)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    a = jnp.asarray(rng.integers(0, k, n), jnp.int32)
    w = jnp.asarray(rng.choice([-1.0, 0.0, 1.0], n), jnp.float32)
    kp = k + (-k % 128)
    s_p, v_p = cluster_sum_pallas(x, a, kp, weights=w, bn=128, bd=128,
                                  interpret=True)
    s_r, v_r = ref.cluster_sum_ref(x, a, k, weights=w)
    np.testing.assert_allclose(s_p[:k], s_r, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(v_p[:k], v_r, rtol=1e-5, atol=1e-5)
    assert np.all(np.asarray(s_p[k:]) == 0)


def test_assign_top2_second_distance_is_true_second():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(128, 16)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(9, 16)), jnp.float32)
    _, d1, d2 = assign_top2_pallas(x, c, bn=128, bk=128, interpret=True)
    d2m = np.sort(np.asarray(ref.pairwise_dist2(x, c)), axis=1)
    np.testing.assert_allclose(d1, d2m[:, 0], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(d2, d2m[:, 1], rtol=1e-5, atol=1e-5)


def test_ops_wrappers_roundtrip():
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(200, 33)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(7, 33)), jnp.float32)
    for backend in ("ref", "pallas"):
        a, d1, d2 = ops.assign_top2(x, c, backend=backend)
        s, v = ops.cluster_sum(x, a, 7, backend=backend)
        assert a.shape == (200,) and s.shape == (7, 33) and v.shape == (7,)
        np.testing.assert_allclose(
            np.asarray(v).sum(), 200.0, rtol=1e-6)


@pytest.mark.parametrize("n,d,k", [(100, 16, 5), (256, 64, 32),
                                   (300, 48, 7)])
def test_fused_round_matches_ref(n, d, k):
    from repro.kernels.fused_round import fused_round_pallas, fused_round_ref
    rng = np.random.default_rng(n + k)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(k, d)) * 2, jnp.float32)
    a_p, d1_p, d2_p, S_p, v_p, sse_p = fused_round_pallas(
        x, c, bn=128, interpret=True)
    a_r, d1_r, d2_r, S_r, v_r, sse_r = fused_round_ref(x, c)
    np.testing.assert_array_equal(np.asarray(a_p), np.asarray(a_r))
    np.testing.assert_allclose(d1_p, d1_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(d2_p, d2_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(S_p, S_r, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(v_p, v_r, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(sse_p, sse_r, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("n,d,k", [(100, 16, 5), (256, 64, 32),
                                   (300, 48, 7), (64, 129, 7)])
def test_fused_nested_round_matches_ref(n, d, k):
    """The PR 9 fused nested round (assign + Hamerly keep + delta-S/v
    in one pass) vs its jnp oracle: labels exact, accumulators close —
    including awkward shapes (k % 128 != 0, n % bn != 0, d non-tile)
    and pad rows (a_prev=-1 / settled / invalid) contributing zero."""
    from repro.kernels.fused_round import (fused_nested_round_pallas,
                                           fused_nested_round_ref)
    rng = np.random.default_rng(n * 3 + k)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(k, d)) * 2, jnp.float32)
    a_prev = jnp.asarray(rng.integers(-1, k, size=n), jnp.int32)
    settled = jnp.asarray(rng.random(n) < 0.3)
    d_keep = jnp.asarray(rng.random(n), jnp.float32)
    lb_keep = jnp.asarray(rng.random(n), jnp.float32)
    valid = jnp.asarray(rng.random(n) < 0.9)
    args = (x, c, a_prev, settled, d_keep, lb_keep, valid)
    a_p, d_p, lb_p, S_p, v_p, sse_p = fused_nested_round_pallas(
        *args, bn=64, interpret=True)
    a_r, d_r, lb_r, S_r, v_r, sse_r = fused_nested_round_ref(*args)
    np.testing.assert_array_equal(np.asarray(a_p), np.asarray(a_r))
    np.testing.assert_allclose(d_p, d_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(lb_p, lb_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(S_p, S_r, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(v_p, v_r, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(sse_p, sse_r, rtol=1e-4, atol=1e-3)


# -- the dispatch plan -------------------------------------------------------

def test_resolve_plan_auto_rule():
    """auto (kernel_backend=None) resolves to ref off-TPU, and the
    explicit spellings are honoured verbatim."""
    import jax
    plan = resolve_plan(None, b=1024, k=16, d=8)
    expect = "pallas" if jax.default_backend() == "tpu" else "ref"
    assert plan.backend == expect
    assert resolve_plan("ref", b=1024, k=16, d=8).backend == "ref"
    p = resolve_plan("pallas", b=1024, k=16, d=8)
    assert p.backend == "pallas"
    assert p.interpret == (jax.default_backend() != "tpu")
    with pytest.raises(ValueError):
        resolve_plan("cuda", b=1024, k=16, d=8)


def test_resolve_plan_bucketing_and_cache():
    """Shapes in the same pow2 bucket share ONE cached plan object
    (identity — the lru_cache is what keeps jit statics stable);
    different buckets get different plans."""
    a = resolve_plan("pallas", b=1000, k=16, d=8)
    b = resolve_plan("pallas", b=700, k=13, d=5)    # same pow2 bucket
    assert a is b
    assert a.bucket == (1024, 16, 8)
    c = resolve_plan("pallas", b=1025, k=16, d=8)
    assert c is not a and c.bucket[0] == 2048


def test_plan_blocks_and_to_dict():
    plan = resolve_plan("pallas", b=4096, k=200, d=300)
    assert plan.bk == 128 and plan.bd in (128, 256)
    assert 8 <= plan.bn <= 512
    assert plan.source in ("table", "tuned", "cached")
    d = plan.to_dict()
    assert d["backend"] == "pallas" and tuple(d["bucket"]) == plan.bucket
    # frozen + hashable: the plan rides in jit static args
    assert hash(plan) == hash(KernelPlan(**{
        f: getattr(plan, f) for f in
        ("backend", "interpret", "bn", "bk", "bd", "bucket", "source")}))
    assert next_pow2(5) == 8 and next_pow2(8) == 8 and next_pow2(1) == 1


def test_ops_dispatch_through_plan_awkward_shapes():
    """ops.assign_top2 / cluster_sum / fused_nested_round driven by a
    resolved plan (not a backend string) at shapes off every tile
    boundary, weighted included."""
    from repro.kernels import ops
    rng = np.random.default_rng(7)
    n, d, k = 321, 19, 37                  # nothing divides anything
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(k, d)) * 2, jnp.float32)
    w = jnp.asarray(rng.choice([0.5, 1.0, 2.0], n), jnp.float32)
    plan = resolve_plan("pallas", b=n, k=k, d=d)
    a_p, d1_p, d2_p = ops.assign_top2(x, c, plan=plan)
    a_r, d1_r, d2_r = ref.assign_top2_ref(x, c)
    np.testing.assert_allclose(d1_p, d1_r, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(d2_p, d2_r, rtol=1e-5, atol=1e-4)
    s_p, v_p = ops.cluster_sum(x, a_p, k, weights=w, plan=plan)
    s_r, v_r = ref.cluster_sum_ref(x, a_r, k, weights=w)
    np.testing.assert_allclose(s_p, s_r, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(v_p, v_r, rtol=1e-5, atol=1e-5)
    # ref plan routes to the oracles exactly
    rp = resolve_plan("ref", b=n, k=k, d=d)
    a2, _, _ = ops.assign_top2(x, c, plan=rp)
    np.testing.assert_array_equal(np.asarray(a2), np.asarray(a_r))


# -- compat version guard ----------------------------------------------------

def test_compiler_params_alias_version_guard():
    """`kernels.compat.CompilerParams` must resolve on this jax, accept
    the dimension_semantics the kernels pass, and — on jax >= 0.6,
    where the rename landed upstream — be the new-name class itself."""
    import jax
    from jax.experimental.pallas import tpu as pltpu

    from repro.kernels.compat import CompilerParams
    assert CompilerParams is not None
    cp = CompilerParams(dimension_semantics=("arbitrary",))
    assert tuple(cp.dimension_semantics) == ("arbitrary",)
    major, minor = (int(v) for v in jax.__version__.split(".")[:2])
    if (major, minor) >= (0, 6):
        assert hasattr(pltpu, "CompilerParams"), \
            "jax >= 0.6 must ship pltpu.CompilerParams"
        assert CompilerParams is pltpu.CompilerParams
    else:
        assert CompilerParams in (
            getattr(pltpu, "CompilerParams", None),
            getattr(pltpu, "TPUCompilerParams", None))


# -- the end-to-end smoke ----------------------------------------------------

@pytest.mark.slow
def test_kernel_dispatch_subprocess():
    """scripts/smoke_kernels.py: fused-round op parity, pallas-vs-ref
    fit bit-parity (local tb/gb + XL m=2/m=1), and the retrace/hostsync
    auditors staying green with the plan active. Subprocess-isolated
    because it forces 8 host devices via XLA_FLAGS."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run([sys.executable, "scripts/smoke_kernels.py"],
                       env=env, capture_output=True, text=True,
                       timeout=600, cwd=repo)
    assert r.returncode == 0, r.stdout + r.stderr
    for marker in ("op parity", "local tb (fused hamerly2)",
                   "local gb (fused bounds-free)", "xl (4,2) m=2",
                   "xl (8,1) m=1 (fused)", "kernels smoke OK"):
        assert marker in r.stdout, (marker, r.stdout)
