"""Pallas kernels vs pure-jnp oracles, interpret=True shape/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.cluster_sum import cluster_sum_pallas
from repro.kernels.kmeans_assign import assign_top2_pallas

SHAPES = [
    (64, 7, 5),          # tiny, heavy padding
    (256, 32, 50),       # paper k
    (300, 784, 50),      # infMNIST dims, unaligned n
    (512, 128, 128),     # aligned everything
    (1000, 200, 257),    # k crosses one block boundary
]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("n,d,k", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_assign_top2_matches_ref(n, d, k, dtype):
    rng = np.random.default_rng(n + d + k)
    x = jnp.asarray(rng.normal(size=(n, d)), dtype)
    c = jnp.asarray(rng.normal(size=(k, d)) * 2, dtype)
    a_p, d1_p, d2_p = assign_top2_pallas(x, c, bn=128, bk=128,
                                         interpret=True)
    a_r, d1_r, d2_r = ref.assign_top2_ref(x, c)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(d1_p, d1_r, rtol=tol, atol=tol * 10)
    np.testing.assert_allclose(d2_p, d2_r, rtol=tol, atol=tol * 10)
    # assignments may differ only where d1 ties within tolerance
    diff = np.asarray(a_p) != np.asarray(a_r)
    if diff.any():
        d2m = ref.pairwise_dist2(x, c)
        for i in np.where(diff)[0]:
            assert abs(d2m[i, a_p[i]] - d2m[i, a_r[i]]) < tol * 100


@pytest.mark.parametrize("n,d,k", SHAPES)
def test_cluster_sum_matches_ref(n, d, k):
    rng = np.random.default_rng(n * 7 + d)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    a = jnp.asarray(rng.integers(0, k, n), jnp.int32)
    w = jnp.asarray(rng.choice([-1.0, 0.0, 1.0], n), jnp.float32)
    kp = k + (-k % 128)
    s_p, v_p = cluster_sum_pallas(x, a, kp, weights=w, bn=128, bd=128,
                                  interpret=True)
    s_r, v_r = ref.cluster_sum_ref(x, a, k, weights=w)
    np.testing.assert_allclose(s_p[:k], s_r, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(v_p[:k], v_r, rtol=1e-5, atol=1e-5)
    assert np.all(np.asarray(s_p[k:]) == 0)


def test_assign_top2_second_distance_is_true_second():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(128, 16)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(9, 16)), jnp.float32)
    _, d1, d2 = assign_top2_pallas(x, c, bn=128, bk=128, interpret=True)
    d2m = np.sort(np.asarray(ref.pairwise_dist2(x, c)), axis=1)
    np.testing.assert_allclose(d1, d2m[:, 0], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(d2, d2m[:, 1], rtol=1e-5, atol=1e-5)


def test_ops_wrappers_roundtrip():
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(200, 33)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(7, 33)), jnp.float32)
    for backend in ("ref", "pallas"):
        a, d1, d2 = ops.assign_top2(x, c, backend=backend)
        s, v = ops.cluster_sum(x, a, 7, backend=backend)
        assert a.shape == (200,) and s.shape == (7, 33) and v.shape == (7,)
        np.testing.assert_allclose(
            np.asarray(v).sum(), 200.0, rtol=1e-6)


@pytest.mark.parametrize("n,d,k", [(100, 16, 5), (256, 64, 32),
                                   (300, 48, 7)])
def test_fused_round_matches_ref(n, d, k):
    from repro.kernels.fused_round import fused_round_pallas, fused_round_ref
    rng = np.random.default_rng(n + k)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(k, d)) * 2, jnp.float32)
    a_p, d1_p, d2_p, S_p, v_p, sse_p = fused_round_pallas(
        x, c, bn=128, interpret=True)
    a_r, d1_r, d2_r, S_r, v_r, sse_r = fused_round_ref(x, c)
    np.testing.assert_array_equal(np.asarray(a_p), np.asarray(a_r))
    np.testing.assert_allclose(d1_p, d1_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(d2_p, d2_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(S_p, S_r, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(v_p, v_r, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(sse_p, sse_r, rtol=1e-4, atol=1e-3)
