"""The out-of-core data plane: chunked store, stored shard source, and
store-backed fits.

The fast tests cover the format round-trip (ragged tail, dtypes, odd
append sizes), crc corruption detection, the LRU read accounting, the
blocked permutation's chunk-frontier property, StoredShardSource ==
KMeansShardedSource row-for-row at ``N % n_shards != 0``, the local
engine's stored-fit bit-parity, and the checkpoint dataset-fingerprint
gate. The slow test runs scripts/smoke_store.py, which repeats the
parity on mesh/xl/multihost and on a REAL 2-process cluster.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.data.store import (ChunkStore, StoreWriter, StoredShardSource,
                              dataset_fingerprint, store_permutation,
                              write_store)


def _rows(n, d=6, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, d)).astype(dtype)


# ---------------------------------------------------------------------------
# format round-trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,chunk_rows", [(0, 8), (5, 8), (8, 8),
                                          (17, 8), (1000, 64), (257, 256)])
def test_roundtrip(tmp_path, n, chunk_rows):
    X = _rows(n)
    st_dir = tmp_path / "st"
    write_store(st_dir, X, chunk_rows=chunk_rows)
    with ChunkStore(st_dir, verify=True) as st:
        assert (st.n, st.d) == X.shape
        assert st.n_chunks == -(-n // chunk_rows)
        np.testing.assert_array_equal(st.rows(0, n), X)
        if n:
            idx = np.random.default_rng(1).integers(0, n, 3 * n)
            np.testing.assert_array_equal(st.take(idx), X[idx])
            mid = st.rows(n // 3, 2 * n // 3)
            np.testing.assert_array_equal(mid, X[n // 3:2 * n // 3])


@pytest.mark.parametrize("dtype", ["float32", "float64", "float16"])
def test_roundtrip_dtypes(tmp_path, dtype):
    X = _rows(100, dtype=np.dtype(dtype))
    write_store(tmp_path / "st", X, chunk_rows=32)
    with ChunkStore(tmp_path / "st") as st:
        assert st.dtype == np.dtype(dtype)
        np.testing.assert_array_equal(st.rows(0, 100), X)


def test_writer_odd_appends_match_write_store(tmp_path):
    """Appending in arbitrary pieces produces the identical store."""
    X = _rows(531)
    write_store(tmp_path / "a", X, chunk_rows=100)
    with StoreWriter(tmp_path / "b", d=X.shape[1],
                     chunk_rows=100) as w:
        at = 0
        for size in (1, 7, 99, 100, 101, 223):
            w.append(X[at:at + size])
            at += size
        w.append(X[at:])
    a, b = ChunkStore(tmp_path / "a"), ChunkStore(tmp_path / "b")
    assert a.checksum == b.checksum
    np.testing.assert_array_equal(a.rows(0, 531), b.rows(0, 531))


def test_writer_abort_leaves_no_index(tmp_path):
    """An exception mid-write must not publish a readable (torn) store."""
    try:
        with StoreWriter(tmp_path / "st", d=4, chunk_rows=8) as w:
            w.append(_rows(20, d=4))
            raise RuntimeError("interrupted")
    except RuntimeError:
        pass
    with pytest.raises(FileNotFoundError, match="not a chunk store"):
        ChunkStore(tmp_path / "st")


def test_corruption_detected(tmp_path):
    X = _rows(64)
    write_store(tmp_path / "st", X, chunk_rows=16)
    with open(tmp_path / "st" / "data.bin", "r+b") as f:
        f.seek(16 * X.shape[1] * 4 + 5)      # a byte inside chunk 1
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0xFF]))
    st = ChunkStore(tmp_path / "st", verify=True)
    st.chunk(0)                              # untouched chunk still reads
    with pytest.raises(IOError, match="corrupt"):
        st.chunk(1)
    # without verify the flipped byte goes unnoticed (documented trade)
    ChunkStore(tmp_path / "st").chunk(1)


def test_lru_and_metrics(tmp_path):
    X = _rows(160)
    write_store(tmp_path / "st", X, chunk_rows=16)    # 10 chunks
    st = ChunkStore(tmp_path / "st", cache_chunks=4)
    st.rows(0, 160)                          # sequential: 10 cold loads
    m = st.metrics
    assert m.chunk_loads == 10 and m.cache_hits == 0
    assert m.bytes_read == X.nbytes and m.rows_served == 160
    st.take(np.arange(160 - 16 * 4, 160))    # the 4 cached tail chunks
    assert st.metrics.chunk_loads == 10      # all hits
    assert st.metrics.cache_hits == 4
    st.chunk(0)                              # evicted long ago: a reload
    assert st.metrics.chunk_loads == 11


def test_prefetch_warms_cache(tmp_path):
    X = _rows(128)
    write_store(tmp_path / "st", X, chunk_rows=16)
    with ChunkStore(tmp_path / "st", prefetch_depth=4) as st:
        assert st.prefetch([0, 1]) == 2
        deadline = 200
        while st.metrics.prefetched < 2 and deadline:
            import time
            time.sleep(0.01)
            deadline -= 1
        assert st.metrics.prefetched == 2
        st.chunk(0), st.chunk(1)
        assert st.metrics.cache_hits == 2    # served without a load
    assert ChunkStore(tmp_path / "st").prefetch([0]) == 0  # no thread


# ---------------------------------------------------------------------------
# hypothesis: the round-trip holds for arbitrary shapes and reads
# ---------------------------------------------------------------------------

try:        # optional dev dependency: only this one test needs it
    from hypothesis import given, settings, strategies as st_
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st_.data())
    def test_roundtrip_property(tmp_path_factory, data):
        n = data.draw(st_.integers(0, 400))
        d = data.draw(st_.integers(1, 12))
        chunk_rows = data.draw(st_.integers(1, 64))
        X = _rows(n, d=d, seed=data.draw(st_.integers(0, 999)))
        path = tmp_path_factory.mktemp("hyp") / "st"
        write_store(path, X, chunk_rows=chunk_rows)
        with ChunkStore(path, verify=True,
                        cache_chunks=data.draw(st_.integers(1, 6))) as st:
            np.testing.assert_array_equal(st.rows(0, n), X)
            if n:
                lo = data.draw(st_.integers(0, n))
                hi = data.draw(st_.integers(lo, n))
                np.testing.assert_array_equal(st.rows(lo, hi), X[lo:hi])
                idx = np.asarray(data.draw(st_.lists(
                    st_.integers(0, n - 1), max_size=50)), dtype=np.int64)
                np.testing.assert_array_equal(st.take(idx), X[idx])
            perm = store_permutation(n, chunk_rows,
                                     data.draw(st_.integers(0, 99)))
            assert sorted(perm) == list(range(n))
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_roundtrip_property():
        pass


# ---------------------------------------------------------------------------
# the blocked permutation and the stored shard source
# ---------------------------------------------------------------------------

def test_store_permutation_chunk_frontier():
    """Every prefix of the blocked shuffle is a run of whole chunks plus
    one partial frontier chunk — the property that bounds disk reads."""
    n, chunk_rows = 1000, 64
    perm = store_permutation(n, chunk_rows, seed=3)
    assert sorted(perm) == list(range(n))
    assert not np.array_equal(perm, np.arange(n))
    for b in (1, 64, 100, 500, 999):
        touched = np.unique(perm[:b] // chunk_rows)
        assert len(touched) <= -(-b // chunk_rows) + 1
    np.testing.assert_array_equal(
        store_permutation(n, chunk_rows, seed=3, shuffle=False),
        np.arange(n))


def test_stored_source_matches_in_memory(tmp_path):
    """StoredShardSource == KMeansShardedSource(perm_override) row for
    row, with N % n_shards != 0 so tail pads are live."""
    from repro.data.pipeline import KMeansShardedSource
    N, n_shards, chunk_rows = 4001, 4, 256
    X = _rows(N, d=8)
    write_store(tmp_path / "st", X, chunk_rows=chunk_rows)
    src = StoredShardSource(tmp_path / "st", n_shards, seed=1)
    perm = store_permutation(N, chunk_rows, seed=1)
    ref = KMeansShardedSource(X, n_shards, seed=1, perm_override=perm)
    assert src.layout.rows_per_shard == ref.layout.rows_per_shard
    for s in range(n_shards):
        assert src.n_valid(s) == ref.n_valid(s)
        np.testing.assert_array_equal(src.shard(s), ref.shard(s))
        np.testing.assert_array_equal(src.shard_valid(s),
                                      ref.shard_valid(s))
    np.testing.assert_array_equal(src.global_prefix(1000),
                                  ref.global_prefix(1000))
    # block() is the streaming window: vertical slices of shard()
    blk = src.block(np.arange(n_shards), 10, 50)
    for s in range(n_shards):
        np.testing.assert_array_equal(blk[s], ref.shard(s)[10:50])
    src.close()


def test_fingerprint_identity(tmp_path):
    X = _rows(300)
    write_store(tmp_path / "a", X, chunk_rows=64)
    write_store(tmp_path / "b", X, chunk_rows=64)
    write_store(tmp_path / "c", _rows(300, seed=9), chunk_rows=64)
    fa = dataset_fingerprint(ChunkStore(tmp_path / "a"))
    assert fa == dataset_fingerprint(ChunkStore(tmp_path / "b"))
    assert fa != dataset_fingerprint(ChunkStore(tmp_path / "c"))
    assert fa["kind"] == "store"
    ga = dataset_fingerprint(X)
    assert ga["kind"] == "array"
    assert ga == dataset_fingerprint(X.copy())
    assert ga != dataset_fingerprint(_rows(300, seed=9))


# ---------------------------------------------------------------------------
# store-backed fits (local engine; sharded engines in the slow smoke)
# ---------------------------------------------------------------------------

def _fit_cfg(**kw):
    from repro import api
    kw.setdefault("k", 4)
    kw.setdefault("b0", 128)
    kw.setdefault("max_rounds", 40)
    kw.setdefault("seed", 2)
    return api.FitConfig(**kw)


def test_local_stored_fit_bit_parity(tmp_path):
    from repro import api
    N, chunk_rows = 1003, 128
    X = _rows(N, d=8, seed=4)
    write_store(tmp_path / "st", X, chunk_rows=chunk_rows)
    st = ChunkStore(tmp_path / "st")
    out_s = api.fit(st, _fit_cfg())
    perm = store_permutation(N, chunk_rows, seed=2)
    out_m = api.fit(X[perm], _fit_cfg(shuffle=False))
    np.testing.assert_array_equal(out_s.C, out_m.C)
    np.testing.assert_array_equal(out_s.labels[perm], out_m.labels)
    ta = [r.to_dict() for r in out_s.telemetry]
    tb = [r.to_dict() for r in out_m.telemetry]
    for r in ta + tb:
        r.pop("t")                   # wall-clock differs by definition
    assert ta == tb
    # ... and the frontier property: the fit read the store about once
    assert st.metrics.bytes_read <= 1.6 * X.nbytes


def test_fit_from_path_and_data_source(tmp_path):
    from repro import api
    X = _rows(600, d=8)
    write_store(tmp_path / "st", X, chunk_rows=128)
    out_a = api.fit(str(tmp_path / "st"), _fit_cfg())
    km = api.NestedKMeans(_fit_cfg(data_source=str(tmp_path / "st")))
    km.fit()                         # no X: config names the store
    np.testing.assert_array_equal(out_a.C, km.cluster_centers_)
    with pytest.raises(ValueError, match="needs data"):
        api.NestedKMeans(_fit_cfg()).fit()


def test_store_rejects_non_nested_algorithms(tmp_path):
    from repro import api
    write_store(tmp_path / "st", _rows(600, d=8), chunk_rows=128)
    with pytest.raises(ValueError, match="data_source"):
        _fit_cfg(algorithm="mb", data_source=str(tmp_path / "st"))
    with pytest.raises(ValueError, match="out-of-core"):
        api.fit(str(tmp_path / "st"), _fit_cfg(algorithm="lloyd"))


def test_resume_fingerprint_gate(tmp_path):
    """Resuming a checkpoint against a different dataset fails loudly."""
    import dataclasses

    from repro import api
    X = _rows(600, d=8, seed=4)
    write_store(tmp_path / "st", X, chunk_rows=128)
    write_store(tmp_path / "other", _rows(600, d=8, seed=5),
                chunk_rows=128)
    ck = api.CheckpointConfig(checkpoint_dir=str(tmp_path / "ck"),
                              save_every=2)
    cfg = _fit_cfg(checkpoint=ck)
    api.fit(ChunkStore(tmp_path / "st"),
            dataclasses.replace(cfg, max_rounds=5))
    with pytest.raises(ValueError, match="different dataset"):
        api.NestedKMeans(cfg).fit(ChunkStore(tmp_path / "other"),
                                  resume=True)
    # same store: resumes cleanly, and in-memory arrays gate too
    api.NestedKMeans(cfg).fit(ChunkStore(tmp_path / "st"), resume=True)
    ck2 = api.CheckpointConfig(checkpoint_dir=str(tmp_path / "ck2"),
                               save_every=2)
    cfg2 = _fit_cfg(checkpoint=ck2)
    api.fit(X, dataclasses.replace(cfg2, max_rounds=5))
    with pytest.raises(ValueError, match="different dataset"):
        api.NestedKMeans(cfg2).fit(_rows(600, d=8, seed=5), resume=True)


def test_writer_cli_synthetic(tmp_path):
    from repro.data.store import writer
    out = str(tmp_path / "st")
    writer.main([out, "--synthetic", "blobs", "--n", "500", "--dim",
                 "8", "--classes", "4", "--chunk-rows", "128"])
    with ChunkStore(out, verify=True) as st:
        assert (st.n, st.d) == (500, 8)
        assert st.rows(0, 500).std() > 0


# ---------------------------------------------------------------------------
# the full stack (mesh / xl / multihost / 2-process cluster)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_store_smoke_subprocess():
    """scripts/smoke_store.py: stored-fit bit-parity on every backend,
    kill-and-resume from disk, and the real 2-process streamed fit."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run([sys.executable, "scripts/smoke_store.py"],
                       env=env, capture_output=True, text=True,
                       timeout=900, cwd=repo)
    assert r.returncode == 0, r.stdout + r.stderr
    for marker in ("local stored fit: bit-identical",
                   "mesh stored fit: bit-identical",
                   "xl stored fit: bit-identical",
                   "multihost(1 process) stored == mesh stored",
                   "read amplification",
                   "stored kill-and-resume: bit-identical",
                   "resume against a different store: refused",
                   "chunk corruption: crc verification",
                   "2-process stored cluster: identical traces",
                   "kill-one-process resume from the store",
                   "store smoke OK"):
        assert marker in r.stdout, (marker, r.stdout)
