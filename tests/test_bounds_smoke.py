"""Bound-family end-to-end smoke (scripts/smoke_bounds.py), subprocess-
isolated because it forces 8 host devices via XLA_FLAGS."""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_bounds_smoke_subprocess():
    """Exponion on every backend: family parity vs bounds="none"
    (local/mesh/xl/multihost, N % n_shards != 0, degenerate rings),
    cross-backend bit-parity including the exact-annulus pair counts,
    mesh kill-and-resume + elastic restore, and the retrace/hostsync/
    replicated-lint auditors staying green with exponion."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run([sys.executable, "scripts/smoke_bounds.py"],
                       env=env, capture_output=True, text=True,
                       timeout=900, cwd=repo)
    assert r.returncode == 0, r.stdout + r.stderr
    for marker in ("family parity[local]", "family parity[mesh(4)]",
                   "family parity[xl(4,2)]", "family parity[multihost]",
                   "family parity[xl(1,8) degenerate rings]",
                   "cross-backend[xl(1,1) == local]",
                   "cross-backend[mesh == multihost]",
                   "exponion mesh kill-and-resume: bit-identical",
                   "replicated-control-flow lint: clean",
                   "bounds smoke OK"):
        assert marker in r.stdout, (marker, r.stdout)
