"""The unified `repro.api` surface: config, engines, estimator.

Key guarantees:
  * FitConfig validates and round-trips through JSON-safe dicts;
  * NestedKMeans.fit == legacy driver.fit BIT-IDENTICALLY (centroids
    and telemetry) — the refactor moved the loop, not the math;
  * partial_fit is exactly one nested_round on the streamed batch;
  * the shared loop serves every legacy algorithm alias.
"""
import dataclasses
import json
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import driver, rounds
from repro.core.state import init_state


# ---------------------------------------------------------------------------
# FitConfig
# ---------------------------------------------------------------------------

def test_fitconfig_roundtrip_through_json():
    cfg = api.FitConfig(k=50, algorithm="tb", rho=math.inf, b0=2000,
                        bounds="hamerly2", time_budget_s=30.0, seed=3,
                        kernel_backend="ref", data_axes=("pod", "data"))
    wire = json.dumps(cfg.to_dict())      # must be strict JSON (inf-safe)
    assert "Infinity" not in wire
    back = api.FitConfig.from_dict(json.loads(wire))
    assert back == cfg
    assert back.rho == math.inf and back.data_axes == ("pod", "data")


def test_fitconfig_defaults_roundtrip():
    cfg = api.FitConfig(k=8)
    assert api.FitConfig.from_dict(cfg.to_dict()) == cfg


@pytest.mark.parametrize("bad", [
    dict(k=0),
    dict(k=8, algorithm="kmeans++"),
    dict(k=8, bounds="yinyang"),
    dict(k=8, b0=0),
    dict(k=8, rho=0.0),
    dict(k=8, eval_every=0),
    dict(k=8, kernel_backend="cuda"),
    dict(k=8, backend="tpu-pod"),
    dict(k=8, backend="mesh", algorithm="mb"),   # mesh is nested-only
    dict(k=8, backend="xl", algorithm="lloyd"),  # xl is nested-only
    dict(k=8, backend="multihost", algorithm="mbf"),
    dict(k=8, backend="xl", model_axis=""),      # needs a real axis name
    dict(k=8, backend="xl", data_axes=("model",),
         model_axis="model"),                    # axes must be disjoint
    # coordinator fields: all three together, and multihost-only
    dict(k=8, backend="multihost", coordinator_address="localhost:1"),
    dict(k=8, backend="mesh", coordinator_address="localhost:1",
         num_processes=2, process_id=0),
    dict(k=8, backend="multihost", coordinator_address="localhost:1",
         num_processes=2, process_id=2),         # id out of range
])
def test_fitconfig_validation_rejects(bad):
    with pytest.raises(ValueError):
        api.FitConfig(**bad)


def test_fitconfig_xl_roundtrip():
    cfg = api.FitConfig(k=16, algorithm="tb", backend="xl",
                        data_axes=("pod", "data"), model_axis="mdl",
                        rho=100.0)
    back = api.FitConfig.from_dict(cfg.to_dict())
    assert back == cfg
    assert back.backend == "xl" and back.model_axis == "mdl"


def test_fitconfig_from_dict_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown"):
        api.FitConfig.from_dict({"k": 8, "banana": 1})


def test_fitconfig_resolve_aliases():
    n = 1000
    assert api.FitConfig(k=4, algorithm="sgd").resolve(n).b0 == 1
    le = api.FitConfig(k=4, algorithm="lloyd-elkan").resolve(n)
    assert (le.algorithm, le.b0, le.bounds) == ("tb", n, "elkan")
    gb = api.FitConfig(k=4, algorithm="gb").resolve(n)
    assert (gb.algorithm, gb.bounds) == ("tb", "none")
    assert api.FitConfig(k=4, algorithm="mb").resolve(n).bounds == "none"


# ---------------------------------------------------------------------------
# estimator vs legacy driver: bit-identical
# ---------------------------------------------------------------------------

def test_fit_bit_identical_to_legacy_driver(blobs, blobs_val):
    """tb-inf through NestedKMeans == driver.fit: same centroids bits,
    same telemetry stream."""
    X, _ = blobs
    k = 8
    legacy = driver.fit(X, k, algorithm="tb", rho=math.inf, b0=512,
                        bounds="hamerly2", X_val=blobs_val, max_rounds=40,
                        eval_every=5, seed=0)
    km = api.NestedKMeans(api.FitConfig(
        k=k, algorithm="tb", rho=math.inf, b0=512, bounds="hamerly2",
        max_rounds=40, eval_every=5, seed=0)).fit(X, X_val=blobs_val)
    np.testing.assert_array_equal(legacy.C, km.cluster_centers_)
    assert legacy.converged == km.converged_
    assert len(legacy.telemetry) == km.n_rounds_
    for old, new in zip(legacy.telemetry, km.telemetry_):
        d = new.to_dict()
        # t is wall-clock (jit compile lands in whichever runs first)
        assert {k: v for k, v in old.items() if k != "t"} \
            == {k: v for k, v in d.items() if k != "t"}


def test_fit_bit_identical_mb_and_lloyd(blobs):
    """The resampling stream (mb) and lloyd paths also moved intact."""
    X, _ = blobs
    for algo, kw in [("mb", dict(b0=256)), ("mbf", dict(b0=256)),
                     ("lloyd", {})]:
        legacy = driver.fit(X, 8, algorithm=algo, max_rounds=15, seed=2,
                            **kw)
        out = api.fit(X, api.FitConfig(k=8, algorithm=algo, max_rounds=15,
                                       seed=2, **kw))
        np.testing.assert_array_equal(legacy.C, out.C), algo


def test_callback_streams_telemetry(blobs):
    X, _ = blobs
    seen = []
    api.fit(X, api.FitConfig(k=8, b0=512, max_rounds=8, seed=0),
            on_round=seen.append)
    assert len(seen) == 8
    assert all(isinstance(r, api.Telemetry) for r in seen)
    assert [r.round for r in seen] == list(range(8))


# ---------------------------------------------------------------------------
# estimator inference surface
# ---------------------------------------------------------------------------

def test_predict_transform_score(blobs, blobs_val):
    X, centers = blobs
    k = centers.shape[0]
    km = api.NestedKMeans(api.FitConfig(k=k, b0=512, max_rounds=60,
                                        seed=0)).fit(X)
    a = km.predict(blobs_val)
    D = km.transform(blobs_val)
    assert a.shape == (len(blobs_val),) and D.shape == (len(blobs_val), k)
    # predict is argmin of transform
    np.testing.assert_array_equal(a, np.argmin(D, axis=1))
    # score == -sum of squared nearest distances
    np.testing.assert_allclose(-km.score(blobs_val),
                               (D.min(axis=1) ** 2).sum(), rtol=1e-4)


def test_unfitted_estimator_raises(blobs_val):
    km = api.NestedKMeans(api.FitConfig(k=4))
    with pytest.raises(api.NotFittedError):
        km.predict(blobs_val)


def test_labels_are_in_caller_row_order(blobs):
    """The engines shuffle internally; labels_ must come back in the
    caller's row order (== predict with the final centroids once
    converged)."""
    X, _ = blobs
    km = api.NestedKMeans(api.FitConfig(k=8, b0=512, max_rounds=80,
                                        seed=0)).fit(X)
    assert km.converged_
    labels = km.labels_
    assert labels.shape == (len(X),) and labels.min() >= 0
    np.testing.assert_array_equal(labels, km.predict(X))


def test_legacy_algorithms_list_matches_api():
    assert driver.ALGORITHMS == api.ALGORITHMS


def test_partial_fit_runs_sharded(blobs):
    """partial_fit streams through the configured engine (the old
    local-only restriction is gone): a mesh-backed stream on a trivial
    1-device mesh matches the local stream after a shared fit."""
    import jax
    X, _ = blobs
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    km_l = api.NestedKMeans(api.FitConfig(k=8, b0=512, seed=0))
    km_m = api.NestedKMeans(api.FitConfig(k=8, b0=512, seed=0,
                                          backend="mesh"), mesh=mesh)
    km_l.fit(X[:2048])
    km_m.fit(X[:2048])
    for i in range(2):
        batch = X[2048 + i * 500:2048 + (i + 1) * 500]
        km_l.partial_fit(batch)
        km_m.partial_fit(batch)
    assert km_m.counts_.sum() == km_l.counts_.sum()
    assert km_m.telemetry_[-1].b == 500
    np.testing.assert_allclose(km_l.cluster_centers_,
                               km_m.cluster_centers_, atol=1e-3)


# ---------------------------------------------------------------------------
# partial_fit: the streaming primitive
# ---------------------------------------------------------------------------

def test_partial_fit_is_one_nested_round(blobs):
    """partial_fit on a fitted estimator == one nested_round whose stats
    are the estimator's and whose points are the fresh batch."""
    X, _ = blobs
    k = 8
    km = api.NestedKMeans(api.FitConfig(k=k, b0=512, max_rounds=30,
                                        seed=0)).fit(X[:2048])
    batch = X[2048:2048 + 256]

    # oracle: the same round by hand
    Xd = jnp.asarray(batch)
    state = init_state(Xd, k, bounds="hamerly2")
    state = dataclasses.replace(state, stats=km.outcome_.state.stats)
    want, want_info = rounds.nested_round(
        Xd, state, b=256, rho=math.inf, bounds="hamerly2", capacity=None,
        use_shalf=True)

    n_before = km.n_rounds_
    km.partial_fit(batch)
    np.testing.assert_array_equal(np.asarray(want.stats.C),
                                  km.cluster_centers_)
    rec = km.telemetry_[-1]
    assert km.n_rounds_ == n_before + 1
    assert rec.b == 256
    assert rec.n_changed == int(want_info.n_changed)
    assert rec.batch_mse == pytest.approx(float(want_info.batch_mse))


def test_partial_fit_from_scratch_then_stream(blobs):
    """partial_fit bootstraps without fit() and keeps absorbing batches."""
    X, _ = blobs
    km = api.NestedKMeans(api.FitConfig(k=8))
    for i in range(4):
        km.partial_fit(X[i * 512:(i + 1) * 512])
    assert km.n_rounds_ == 4
    assert km.cluster_centers_.shape == (8, X.shape[1])
    # all four batches are in the running statistics
    assert km.counts_.sum() == pytest.approx(4 * 512)
    a = km.predict(X[:512])
    assert a.min() >= 0 and a.max() < 8


def test_partial_fit_first_batch_must_cover_k():
    with pytest.raises(ValueError, match=">= k"):
        api.NestedKMeans(api.FitConfig(k=64)).partial_fit(
            np.zeros((8, 4), np.float32))


def test_partial_fit_after_fit_staleness_contract(blobs):
    """partial_fit moves the centroids past the fit's outcome, so the
    fit-scoped attributes (labels_/outcome_) raise NotFittedError
    instead of silently serving stale assignments; the live surface
    (centers, predict, telemetry) keeps working."""
    X, _ = blobs
    km = api.NestedKMeans(api.FitConfig(k=8, b0=512, max_rounds=30,
                                        seed=0)).fit(X[:2048])
    _ = km.labels_            # fresh after fit
    _ = km.outcome_
    km.partial_fit(X[2048:2048 + 256])
    with pytest.raises(api.NotFittedError, match="stale"):
        _ = km.labels_
    with pytest.raises(api.NotFittedError, match="stale"):
        _ = km.outcome_
    # the streaming surface stays live
    assert km.cluster_centers_.shape == (8, X.shape[1])
    assert km.predict(X[:64]).shape == (64,)
    # a fresh fit() clears the staleness
    km.fit(X[:2048])
    assert km.labels_.shape == (2048,)


# ---------------------------------------------------------------------------
# engines
# ---------------------------------------------------------------------------

def test_make_engine_selects_backend():
    assert isinstance(api.make_engine(api.FitConfig(k=4)),
                      api.LocalEngine)
    with pytest.raises(ValueError, match="mesh"):
        api.make_engine(api.FitConfig(k=4, backend="mesh"))
    with pytest.raises(ValueError, match="Mesh"):
        api.make_engine(api.FitConfig(k=4, backend="xl"))
    # multihost builds its own mesh lazily (at begin) when none given
    assert isinstance(api.make_engine(api.FitConfig(k=4,
                                                    backend="multihost")),
                      api.MultiHostEngine)


def test_fitconfig_multihost_roundtrip():
    cfg = api.FitConfig(k=8, backend="multihost",
                        coordinator_address="localhost:1234",
                        num_processes=2, process_id=1)
    back = api.FitConfig.from_dict(json.loads(json.dumps(cfg.to_dict())))
    assert back == cfg
    assert (back.coordinator_address, back.num_processes,
            back.process_id) == ("localhost:1234", 2, 1)


def test_multihost_single_device_matches_mesh(blobs):
    """backend="multihost" with one process and one device is the mesh
    engine bit for bit (the multi-device / multi-process face of this
    parity chain lives in scripts/smoke_multihost.py)."""
    import jax
    X, _ = blobs
    cfg = api.FitConfig(k=8, b0=512, max_rounds=40, seed=0)
    mesh = jax.make_mesh((1,), ("data",))
    out_m = api.fit(X, dataclasses.replace(cfg, backend="mesh"),
                    mesh=mesh)
    out_h = api.fit(X, dataclasses.replace(cfg, backend="multihost"))
    assert out_m.converged and out_h.converged
    np.testing.assert_array_equal(out_m.C, out_h.C)
    np.testing.assert_array_equal(out_m.labels, out_h.labels)
    for ra, rb in zip(out_m.telemetry, out_h.telemetry):
        da, db = ra.to_dict(), rb.to_dict()
        da.pop("t"), db.pop("t")
        assert da == db


def test_xl_engine_begin_on_trivial_mesh():
    """XLEngine.begin stands up the sharded layout on a 1x1 mesh (the
    k % model-axis divisibility error needs forced multi-device hosts
    and is covered by the smoke in tests/test_distributed_xl.py)."""
    import jax
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    run = api.XLEngine(mesh).begin(
        np.random.default_rng(0).normal(size=(64, 8)).astype(np.float32),
        api.FitConfig(k=4, backend="xl").resolve(64))
    assert run.n_shards == 1 and run.n_points == 64
    assert run.state.stats.C.shape == (4, 8)


def test_run_loop_time_budget_zero(blobs):
    X, _ = blobs
    out = api.fit(X, api.FitConfig(k=8, time_budget_s=0.0))
    assert out.telemetry == [] and not out.converged


def test_outcome_carries_config(blobs):
    X, _ = blobs
    cfg = api.FitConfig(k=8, algorithm="gb", b0=256, max_rounds=10)
    out = api.fit(X, cfg)
    # outcome records the RESOLVED config (canonical algorithm)
    assert out.config.algorithm == "tb" and out.config.bounds == "none"
    assert out.config.k == 8
