"""In-loop checkpoint / kill-and-resume through `run_loop`.

The contract: a fit checkpointed at round R and resumed produces
BIT-IDENTICAL centroids and telemetry (minus wall-clock ``t``) to an
uninterrupted run — the checkpoint captures the full host-schedule
state (KMeansState, b, capacity, patience, work clock, telemetry and
the mb resampling stream), not just centroids. The mesh/elastic side
(2-shard subprocess, shard-count change across restore) lives in
scripts/smoke_resume_mesh.py, driven here by a slow marker.
"""
import dataclasses
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import rounds
from repro.core.state import init_state


def _telemetry_equal_minus_t(a, b):
    assert len(a) == len(b)
    for ra, rb in zip(a, b):
        da, db = ra.to_dict(), rb.to_dict()
        da.pop("t"), db.pop("t")
        assert da == db, (da, db)


# ---------------------------------------------------------------------------
# LocalEngine kill-and-resume
# ---------------------------------------------------------------------------

def test_local_kill_and_resume_bit_identical(tmp_path, blobs, blobs_val):
    """tb fit interrupted at round 7, resumed: centroids + telemetry
    bit-identical to the uninterrupted run."""
    X, _ = blobs
    cfg = api.FitConfig(k=8, b0=512, max_rounds=40, eval_every=5, seed=0)
    out_a = api.fit(X, cfg, X_val=blobs_val)
    assert out_a.converged

    ck = api.CheckpointConfig(checkpoint_dir=str(tmp_path), save_every=3)
    api.fit(X, dataclasses.replace(cfg, max_rounds=7, checkpoint=ck),
            X_val=blobs_val)
    km = api.NestedKMeans(dataclasses.replace(cfg, checkpoint=ck))
    km.fit(X, X_val=blobs_val, resume=True)

    np.testing.assert_array_equal(out_a.C, km.cluster_centers_)
    _telemetry_equal_minus_t(out_a.telemetry, km.telemetry_)
    assert km.converged_


def test_exponion_kill_and_resume_bit_identical(tmp_path, blobs,
                                                blobs_val):
    """Exponion's per-point state is hamerly2's (d, lb) layout and its
    geometry table is rebuilt per round, never checkpointed — so an
    interrupted exponion fit resumes bit-identically with the SAME
    checkpoint machinery (no new state in the manifest)."""
    X, _ = blobs
    cfg = api.FitConfig(k=8, b0=512, bounds="exponion", max_rounds=40,
                        eval_every=5, seed=0)
    out_a = api.fit(X, cfg, X_val=blobs_val)
    assert out_a.converged

    ck = api.CheckpointConfig(checkpoint_dir=str(tmp_path), save_every=3)
    api.fit(X, dataclasses.replace(cfg, max_rounds=7, checkpoint=ck),
            X_val=blobs_val)
    km = api.NestedKMeans(dataclasses.replace(cfg, checkpoint=ck))
    km.fit(X, X_val=blobs_val, resume=True)

    np.testing.assert_array_equal(out_a.C, km.cluster_centers_)
    _telemetry_equal_minus_t(out_a.telemetry, km.telemetry_)
    assert km.converged_


def test_exponion_resume_config_must_match(tmp_path, blobs):
    """A checkpointed hamerly2 fit cannot be resumed as exponion: the
    bound family rides in the manifest's resolved config."""
    X, _ = blobs
    ck = api.CheckpointConfig(checkpoint_dir=str(tmp_path), save_every=2)
    api.fit(X, api.FitConfig(k=8, b0=512, bounds="hamerly2",
                             max_rounds=4, seed=0, checkpoint=ck))
    km = api.NestedKMeans(api.FitConfig(k=8, b0=512, bounds="exponion",
                                        max_rounds=10, seed=0,
                                        checkpoint=ck))
    with pytest.raises(ValueError, match="bounds"):
        km.fit(X, resume=True)


def test_local_resume_restores_mb_stream(tmp_path, blobs):
    """mbf resumes bit-identically: the resampling permutation, stream
    position and host RNG state all ride in the checkpoint."""
    X, _ = blobs
    cfg = api.FitConfig(k=8, algorithm="mbf", b0=700, max_rounds=14,
                        seed=2)
    out_a = api.fit(X, cfg)

    ck = api.CheckpointConfig(checkpoint_dir=str(tmp_path), save_every=2)
    api.fit(X, dataclasses.replace(cfg, max_rounds=5, checkpoint=ck))
    km = api.NestedKMeans(dataclasses.replace(cfg, checkpoint=ck))
    km.fit(X, resume=True)
    np.testing.assert_array_equal(out_a.C, km.cluster_centers_)
    _telemetry_equal_minus_t(out_a.telemetry, km.telemetry_)


def test_resume_of_finished_fit_is_noop(tmp_path, blobs):
    X, _ = blobs
    ck = api.CheckpointConfig(checkpoint_dir=str(tmp_path), save_every=5)
    cfg = api.FitConfig(k=8, b0=512, max_rounds=60, seed=0, checkpoint=ck)
    out_a = api.fit(X, cfg)
    assert out_a.converged
    km = api.NestedKMeans(cfg).fit(X, resume=True)
    assert km.converged_
    np.testing.assert_array_equal(out_a.C, km.cluster_centers_)
    _telemetry_equal_minus_t(out_a.telemetry, km.telemetry_)


def test_resume_without_checkpoint_config_raises(blobs):
    X, _ = blobs
    with pytest.raises(ValueError, match="checkpoint"):
        api.NestedKMeans(api.FitConfig(k=8)).fit(X, resume=True)


def test_resume_with_empty_dir_starts_fresh(tmp_path, blobs):
    X, _ = blobs
    ck = api.CheckpointConfig(checkpoint_dir=str(tmp_path), save_every=50)
    km = api.NestedKMeans(api.FitConfig(k=8, b0=512, max_rounds=10,
                                        checkpoint=ck))
    km.fit(X, resume=True)        # nothing on disk yet: cold start
    assert km.n_rounds_ == 10


def test_fresh_fit_supersedes_stale_checkpoints(tmp_path, blobs):
    """A NON-resume checkpointed fit into a directory holding an older
    run clears it: otherwise the old higher-numbered steps would GC the
    new run's early saves on arrival, and a later resume would silently
    restore the stale fit."""
    from repro.checkpoint.store import CheckpointStore
    X, _ = blobs
    ck = api.CheckpointConfig(checkpoint_dir=str(tmp_path), save_every=2)
    cfg = api.FitConfig(k=8, b0=512, max_rounds=60, seed=0, checkpoint=ck)
    api.fit(X, cfg)                       # long run, high step numbers
    store = CheckpointStore(tmp_path)
    old_latest = store.latest_step()
    out = api.fit(X, dataclasses.replace(cfg, max_rounds=4))  # fresh fit
    assert store.latest_step() == 4       # old steps gone, new run kept
    assert store.latest_step() != old_latest
    km = api.NestedKMeans(dataclasses.replace(cfg, max_rounds=4))
    km.fit(X, resume=True)                # resumes the NEW run, not the
    np.testing.assert_array_equal(out.C, km.cluster_centers_)  # stale one


def test_resume_rejects_foreign_manifest(tmp_path, blobs):
    X, _ = blobs
    ck = api.CheckpointConfig(checkpoint_dir=str(tmp_path), save_every=2)
    api.fit(X, api.FitConfig(k=8, b0=512, max_rounds=4, seed=0,
                             checkpoint=ck))
    km = api.NestedKMeans(api.FitConfig(k=8, b0=512, max_rounds=10,
                                        seed=1, checkpoint=ck))
    with pytest.raises(ValueError, match="seed"):
        km.fit(X, resume=True)


def test_checkpoint_manifest_carries_fitconfig(tmp_path, blobs):
    """Every step dir carries the exact resolved FitConfig dict."""
    from repro.checkpoint.store import CheckpointStore
    X, _ = blobs
    ck = api.CheckpointConfig(checkpoint_dir=str(tmp_path), save_every=2)
    cfg = api.FitConfig(k=8, algorithm="gb", b0=512, max_rounds=6,
                        checkpoint=ck)
    api.fit(X, cfg)
    store = CheckpointStore(tmp_path)
    extra = store.read_extra()
    got = api.FitConfig.from_dict(extra["config"])
    assert got == cfg.resolve(len(X))    # manifest holds the RESOLVED cfg
    assert extra["loop"]["rounds_done"] == store.latest_step()


# ---------------------------------------------------------------------------
# the final-eval double-count fix
# ---------------------------------------------------------------------------

def test_no_duplicate_final_val_record(blobs, blobs_val):
    """With eval_every=1 the last in-loop round already evaluated
    validation; run_loop must not append a second eval at the same t."""
    X, _ = blobs
    out = api.fit(X, api.FitConfig(k=8, b0=512, max_rounds=30,
                                   eval_every=1, seed=0),
                  X_val=blobs_val)
    assert all(r.batch_mse is not None for r in out.telemetry)
    assert out.telemetry[-1].val_mse is not None
    # sparse cadence still gets the final eval record
    out2 = api.fit(X, api.FitConfig(k=8, b0=512, max_rounds=30,
                                    eval_every=1000, seed=0),
                   X_val=blobs_val)
    assert out2.telemetry[-1].batch_mse is None
    assert out2.telemetry[-1].val_mse is not None


# ---------------------------------------------------------------------------
# n_valid masking (the unit-level face of the mesh tail-row fix)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bounds", ["none", "hamerly2", "elkan",
                                    "exponion"])
def test_nested_round_n_valid_masks_tail(bounds):
    """nested_round(n_valid=m) == nested_round over X[:m]: masked tail
    rows stay unassigned and contribute nothing to the statistics."""
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    k, b, m = 4, 32, 27

    full = init_state(X, k, bounds=bounds)
    masked, info_m = rounds.nested_round(
        X, full, b=b, rho=math.inf, bounds=bounds, capacity=None,
        n_valid=jnp.asarray(m))
    ref, info_r = rounds.nested_round(
        X[:m], init_state(X, k, bounds=bounds), b=m, rho=math.inf,
        bounds=bounds, capacity=None)

    np.testing.assert_array_equal(np.asarray(masked.stats.C),
                                  np.asarray(ref.stats.C))
    np.testing.assert_array_equal(np.asarray(masked.stats.v),
                                  np.asarray(ref.stats.v))
    np.testing.assert_array_equal(np.asarray(masked.stats.sse),
                                  np.asarray(ref.stats.sse))
    a = np.asarray(masked.points.a)
    assert (a[m:b] == -1).all()          # masked rows never assigned
    assert (a[:m] >= 0).all()
    assert int(info_m.n_active) == m
    assert float(info_m.batch_mse) == pytest.approx(
        float(info_r.batch_mse))


def test_round_info_carries_p_max(blobs):
    """The convergence check reads p_max from RoundInfo (no per-round
    host sync of state.stats.p); it must equal max(p) of the new state."""
    X, _ = blobs
    state = init_state(jnp.asarray(X), 8, bounds="hamerly2")
    new, info = rounds.nested_round(jnp.asarray(X), state, b=512,
                                    rho=math.inf, bounds="hamerly2",
                                    capacity=None)
    assert float(info.p_max) == pytest.approx(
        float(jnp.max(new.stats.p)))


# ---------------------------------------------------------------------------
# mesh: subprocess (2 data shards, non-divisible N, elastic restore)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_mesh_resume_subprocess():
    """Kill-and-resume on the MeshEngine: bit-identical same-shard
    resume, tail-row labeling with N % n_shards != 0, and elastic
    restore onto 4 shards and onto the LocalEngine."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "scripts/smoke_resume_mesh.py"],
                       env=env, capture_output=True, text=True,
                       timeout=600, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stdout + r.stderr
