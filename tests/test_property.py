"""Hypothesis property tests on the engine's invariants.

hypothesis is an optional dev dependency (see requirements-dev.txt);
without it this module skips instead of aborting collection.
"""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import controller, rounds
from repro.core.state import init_state
from repro.kernels import ref
from repro.optim import compression


def _data(draw, nmax=512, dmax=24, kmax=12):
    n = draw(st.integers(16, nmax))
    d = draw(st.integers(2, dmax))
    k = draw(st.integers(2, kmax))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d)).astype(np.float32) * \
        draw(st.sampled_from([0.1, 1.0, 10.0]))
    return X, k


@st.composite
def dataset(draw):
    return _data(draw)


@settings(max_examples=25, deadline=None)
@given(dataset())
def test_nested_round_invariants(data):
    """After any nested round: S == sum of active members, v == counts,
    sse == sum d^2, d(i) == true distance for recomputed points, lb is a
    valid lower bound on the 2nd-nearest distance."""
    X, k = data
    n = X.shape[0]
    b = max(k + 1, n // 2)
    Xd = jnp.asarray(X)
    state = init_state(Xd, k, bounds="hamerly2")
    for _ in range(3):
        state, info = rounds.nested_round(Xd, state, b=b, rho=np.inf,
                                          bounds="hamerly2")
    a = np.asarray(state.points.a[:b])
    S = np.asarray(state.stats.S)
    v = np.asarray(state.stats.v)
    sse = np.asarray(state.stats.sse)
    d = np.asarray(state.points.d[:b])
    lb = np.asarray(state.points.lb[:b])
    C = np.asarray(state.stats.C)

    for j in range(k):
        members = X[:b][a == j]
        np.testing.assert_allclose(S[j], members.sum(0) if len(members)
                                   else np.zeros(X.shape[1]),
                                   rtol=2e-4, atol=2e-3)
        assert v[j] == len(members)

    d2 = np.asarray(ref.pairwise_dist2(Xd[:b], jnp.asarray(C)))
    true_d = np.sqrt(np.maximum(d2[np.arange(b), a], 0))
    # stored d may be stale-but-exact-at-assignment; after a round with
    # p=0 it equals the true distance. Here just check consistency of sse.
    np.testing.assert_allclose(sse.sum(), (d ** 2).sum(), rtol=1e-3,
                               atol=1e-2)
    # lb validity: the stored lb bounds the 2nd-nearest distance to the
    # ASSIGNMENT-TIME centroids; stats.C is post-update, so allow p_max
    # slack (the decay that next round's bound test will apply).
    p_max = float(np.max(np.asarray(state.stats.p)))
    part = np.partition(d2, 1, axis=1)
    second = np.sqrt(np.maximum(part[:, 1], 0))
    assert np.all(second >= lb - p_max - 1e-3)


@settings(max_examples=25, deadline=None)
@given(dataset())
def test_assignments_always_nearest_after_dense_round(data):
    X, k = data
    n = X.shape[0]
    Xd = jnp.asarray(X)
    state = init_state(Xd, k, bounds="none")
    state, _ = rounds.nested_round(Xd, state, b=n, rho=np.inf,
                                   bounds="none")
    a = np.asarray(state.points.a)
    # the round assigns against the PRE-update centroids (first k points)
    d2 = np.asarray(ref.pairwise_dist2(Xd, Xd[:k]))
    best = d2[np.arange(n), a]
    assert np.all(best <= d2.min(axis=1) + 1e-4)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(0.0, 1e6), min_size=2, max_size=33),
       st.floats(0.5, 1e4))
def test_controller_median_rule(ps, rho):
    """Doubling happens iff the lower-median ratio crosses rho."""
    k = len(ps)
    p = jnp.asarray(ps, jnp.float32)
    v = jnp.full((k,), 10.0)
    sse = jnp.ones((k,)) * 90.0          # sigma = 1 for every cluster
    grow, r = controller.should_grow(sse, v, p, rho)
    ratios = np.where(np.asarray(p) > 0, 1.0 / np.maximum(ps, 1e-30),
                      np.inf)
    expect = np.sort(ratios)[(k - 1) // 2] >= rho
    assert bool(grow) == bool(expect)


def test_controller_rho_inf_majority_rule():
    """rho=inf: double iff MORE than half the centroids are unchanged."""
    k = 10
    v = jnp.full((k,), 10.0)
    sse = jnp.ones((k,))
    for n_zero, expect in [(5, False), (6, True), (10, True), (0, False)]:
        p = jnp.asarray([0.0] * n_zero + [1.0] * (k - n_zero))
        grow, _ = controller.should_grow(sse, v, p, np.inf)
        assert bool(grow) == expect, (n_zero, expect)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 16), st.integers(1, 6))
def test_compression_error_feedback_converges(seed, steps):
    """Sum of decoded grads -> sum of true grads (error feedback)."""
    rng = np.random.default_rng(seed)
    g_true = rng.normal(size=(64,)).astype(np.float32)
    err = jnp.zeros((64,))
    total_sent = np.zeros((64,))
    for _ in range(steps):
        q, scale, err_new = compression.encode(jnp.asarray(g_true) + err)
        decoded = compression.decode(q.astype(jnp.int32), scale)
        total_sent += np.asarray(decoded)
        err = err_new
    # cumulative transmitted == cumulative true, up to one step's residual
    resid = np.abs(steps * g_true - total_sent).max()
    assert resid <= np.abs(np.asarray(err)).max() + 1e-4
