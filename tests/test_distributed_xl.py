"""CI gate for the centroid-sharded kmeans_xl path — now loop-driven.

scripts/smoke_xl.py covers the whole XL stack: the one-shot round vs a
Lloyd oracle, the log-depth sharded top-2 fold (parity with the single
device kernel, same-shard top-2, cross-shard exact ties), the XLEngine
driven end-to-end by the shared `run_loop` (bit-identical to the
Local/Mesh engines where the layout coincides, full labeling for
N % n_shards != 0), checkpoint/elastic restart XL<->local, and the
config's rho reaching the sharded growth controller. Subprocess-
isolated because it forces 8 host devices via XLA_FLAGS, which must not
leak into the rest of the test session.
"""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_xl_engine_subprocess():
    """The full XL-engine e2e smoke on a forced 8-device host mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run([sys.executable, "scripts/smoke_xl.py"],
                       env=env, capture_output=True, text=True,
                       timeout=600, cwd=repo)
    assert r.returncode == 0, r.stdout + r.stderr
    for marker in ("fold parity", "XL(1,1) == LocalEngine",
                   "XL(2,1) == MeshEngine", "XL->XL resume bit-identical",
                   "rho threading + gb-on-xl OK", "xl smoke OK"):
        assert marker in r.stdout, (marker, r.stdout)
