"""CI gate for the centroid-sharded kmeans_xl round.

Promoted from scripts/smoke_distributed.py so the XL round — which has
no Engine driving it yet (ROADMAP: next open Engine slot) — is
regression-tested, not just dev-smoked. Subprocess-isolated because it
forces 8 host devices via XLA_FLAGS, which must not leak into the rest
of the test session.
"""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_xl_round_subprocess():
    """make_xl_round + make_dp_round match an exact Lloyd oracle on a
    (4, 2) mesh with centroids sharded over the model axis."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run([sys.executable, "scripts/smoke_xl.py"],
                       env=env, capture_output=True, text=True,
                       timeout=600, cwd=repo)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "xl smoke OK" in r.stdout
