"""The observability plane: tracer, registry, efficiency model, and the
instrumented fit loop.

The pure-python tests (tracer nesting/rotation/merge, histogram bounds,
Prometheus export, registry semantics, Telemetry JSON round-trip) need
no jax at all — `repro.obs` imports neither jax nor numpy, and one test
pins that property. The jax tests drive real traced fits: round events
must match the loop's own schedule trace, `telemetry_` must round-trip
through `to_dict`, and the host-sync auditor must stay SILENT with a
`FitObserver` attached. The slow test runs scripts/smoke_obs.py, which
repeats the traced fit + hostsync gate on mesh/xl/multihost.
"""
import json
import math
import os
import re
import subprocess
import sys

import pytest

from repro.obs import (OBS_SCHEMA, Histogram, MetricsRegistry,
                       ServeMetrics, SpanTracer, WorkModel, read_events,
                       summarize, trace_files)


# ---------------------------------------------------------------------------
# tracer: nesting, rotation, merge
# ---------------------------------------------------------------------------

def test_span_nesting_and_attrs(tmp_path):
    with SpanTracer(tmp_path) as tr:
        with tr.span("outer", phase="warm"):
            tr.event("tick", n=1)
            with tr.span("inner"):
                pass
    ev = read_events(tmp_path)
    by_name = {e.get("name"): e for e in ev if "name" in e}
    outer, inner, tick = by_name["outer"], by_name["inner"], by_name["tick"]
    assert outer["ph"] == "span" and outer["parent"] is None
    assert outer["attrs"] == {"phase": "warm"}
    assert inner["parent"] == outer["id"]
    assert tick["ph"] == "event" and tick["parent"] == outer["id"]
    # spans are written at EXIT but ts is the START offset
    assert inner["ts"] >= outer["ts"]
    assert outer["dur_s"] >= inner["dur_s"] >= 0.0
    assert all(e["schema"] == OBS_SCHEMA for e in ev)


def test_rotation_and_merged_order(tmp_path):
    with SpanTracer(tmp_path, rotate_bytes=4096) as tr:
        for i in range(300):
            tr.event("e", i=i, pad="x" * 40)
    files = trace_files(tmp_path)
    assert len(files) > 1, "4096-byte rotation never triggered"
    ev = [e for e in read_events(tmp_path) if e.get("name") == "e"]
    assert [e["attrs"]["i"] for e in ev] == list(range(300))


def test_multiprocess_merge_and_filter(tmp_path):
    for pid in (0, 1):
        with SpanTracer(tmp_path, process_id=pid) as tr:
            for r in range(3):
                tr.event("round", round=r, kscans=10, dt_s=0.5)
    ev = read_events(tmp_path)
    assert {e["pid"] for e in ev} == {0, 1}
    only0 = read_events(tmp_path, process_id=0)
    assert {e["pid"] for e in only0} == {0}
    s = summarize(ev)
    assert s["processes"] == [0, 1]
    assert s["rounds_by_process"] == {0: 3, 1: 3}
    # round scalars come from the lead process ONLY (RoundInfo is
    # psum-reduced — summing across processes would double-count)
    assert s["rounds"] == 3 and s["kscans_total"] == 30


def test_reader_is_loud(tmp_path):
    with pytest.raises(FileNotFoundError):
        read_events(tmp_path)
    with SpanTracer(tmp_path) as tr:
        tr.event("ok")
    f = trace_files(tmp_path)[0]
    with open(f, "a", encoding="utf-8") as fh:
        fh.write('{"schema": 999, "ph": "event"}\n')
    with pytest.raises(ValueError, match="newer"):
        read_events(tmp_path)
    with open(f, "w", encoding="utf-8") as fh:
        fh.write("not json\n")
    with pytest.raises(ValueError, match="corrupt"):
        read_events(tmp_path)


def test_tracer_survives_numpy_scalars(tmp_path):
    np = pytest.importorskip("numpy")
    with SpanTracer(tmp_path) as tr:
        tr.event("e", a=np.int64(3), b=np.float32(0.5))
    e = [x for x in read_events(tmp_path) if x.get("name") == "e"][0]
    assert e["attrs"]["a"] == 3
    assert abs(e["attrs"]["b"] - 0.5) < 1e-9


# ---------------------------------------------------------------------------
# metrics: histogram bounds, registry, exporters
# ---------------------------------------------------------------------------

def test_histogram_percentiles_within_bucket_factor():
    h = Histogram("t")
    vals = [i / 1000.0 for i in range(1, 1001)]     # 1ms .. 1s uniform
    for v in vals:
        h.record(v)
    for q in (0.50, 0.99):
        true = vals[int(q * (len(vals) - 1))]
        est = h.percentile(q)
        assert true <= est <= true * Histogram.BASE * 1.001, (q, est, true)
    d = h.to_dict()
    assert d["count"] == 1000 and d["max_s"] == 1.0
    assert abs(d["mean_s"] - sum(vals) / 1000) < 1e-9
    assert set(d) == {"count", "mean_s", "p50_s", "p99_s", "max_s"}


def test_registry_semantics():
    r = MetricsRegistry()
    c = r.counter("c", "help")
    assert r.counter("c") is c                      # get-or-create
    with pytest.raises(ValueError, match="Counter"):
        r.gauge("c")
    with pytest.raises(ValueError, match="negative"):
        c.inc(-1)
    c.inc(2)
    r.gauge("g").set(1.5)
    r.histogram("h").record(0.25)
    d = r.to_dict()
    assert d["counters"]["c"] == 2
    assert d["gauges"]["g"] == 1.5
    assert d["histograms"]["h"]["count"] == 1


def test_prometheus_export_format():
    r = MetricsRegistry()
    r.counter("fit rounds", "completed rounds").inc(3)
    r.gauge("util").set(0.5)
    h = r.histogram("lat", "latency")
    for v in (0.001, 0.01, 0.01, 0.1):
        h.record(v)
    text = r.to_prometheus()
    assert "# TYPE fit_rounds counter\nfit_rounds 3" in text
    assert "# TYPE util gauge\nutil 0.5" in text
    assert "# HELP fit_rounds completed rounds" in text
    # histogram buckets are CUMULATIVE and +Inf equals the total count
    counts = [int(m) for m in
              re.findall(r'lat_bucket\{le="[^"]+"\} (\d+)', text)]
    assert counts == sorted(counts)
    assert counts[-1] == 4 and 'le="+Inf"' in text
    assert "lat_count 4" in text
    assert every_line_parses(text)


def every_line_parses(text):
    pat = re.compile(r"^(# (HELP|TYPE) [a-zA-Z_:][a-zA-Z0-9_:]* .+"
                     r'|[a-zA-Z_:][a-zA-Z0-9_:]*(_bucket\{le="[^"]+"\})? '
                     r"[0-9eE.+-]+|[a-zA-Z_:][a-zA-Z0-9_:]* NaN)$")
    return all(pat.match(line) for line in text.splitlines())


def test_serve_metrics_schema_byte_compatible():
    m = ServeMetrics()
    m.observe_predict(0.002, 128)
    m.observe_refresh(0.050, 256)
    m.observe_escalation()
    m.observe_ingest()
    d = m.to_dict(queue_stats={"rows": 1, "dropped": 0})
    assert set(d) == {"predict", "refresh", "ingest_calls", "queue"}
    assert set(d["predict"]) == {"requests", "rows", "latency"}
    assert set(d["refresh"]) == {"count", "rows", "escalations", "latency"}
    assert set(d["predict"]["latency"]) == {"count", "mean_s", "p50_s",
                                            "p99_s", "max_s"}
    assert d["predict"] == {"requests": 1, "rows": 128,
                            "latency": m.predict_latency.to_dict()}
    assert d["refresh"]["count"] == 1 and d["refresh"]["escalations"] == 1
    assert d["ingest_calls"] == 1
    json.dumps(d)                                   # JSON-safe
    # the legacy import path still resolves to the same classes
    from repro.serve.metrics import ServeMetrics as Legacy
    assert Legacy is ServeMetrics


def test_workmodel_prices_rounds():
    w = WorkModel(k=50, d=64)
    rw = w.round_work(1000, dt_s=0.01)
    assert rw.kscans == 1000 and rw.dist_evals == 50_000
    assert rw.flops == 3.0 * 64 * 50_000
    assert rw.hbm_bytes == 4 * (1000 * 64 + 50 * 64)
    assert rw.bound_s > 0 and 0 < rw.utilization < 1
    assert w.round_work(0).dist_evals == 0


def test_obs_package_is_accelerator_free():
    code = ("import sys, repro.obs, repro.obs.sink, repro.obs.__main__; "
            "bad = [m for m in ('jax', 'numpy') if m in sys.modules]; "
            "assert not bad, bad; print('clean')")
    env = dict(os.environ, PYTHONPATH="src")
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run([sys.executable, "-c", code], env=env, cwd=repo,
                       capture_output=True, text=True, timeout=120)
    assert r.returncode == 0 and "clean" in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# Telemetry round-trip
# ---------------------------------------------------------------------------

def test_telemetry_json_roundtrip_nonfinite():
    from repro.api.telemetry import Telemetry
    rec = Telemetry(round=3, t=1.5, b=256, batch_mse=float("nan"),
                    n_changed=2, n_recomputed=100, grow=True,
                    r_median=float("inf"), val_mse=None)
    d = rec.to_dict()
    assert d["batch_mse"] == "nan" and d["r_median"] == "inf"
    text = json.dumps(d)                # strict-parser safe
    back = Telemetry.from_dict(json.loads(text))
    assert math.isnan(back.batch_mse) and math.isinf(back.r_median)
    assert back.round == 3 and back.b == 256 and back.val_mse is None
    finite = Telemetry(round=0, t=0.1, b=8, batch_mse=2.0, n_changed=1,
                       n_recomputed=8, grow=False, r_median=0.5,
                       val_mse=3.0)
    assert Telemetry.from_dict(
        json.loads(json.dumps(finite.to_dict()))) == finite


# ---------------------------------------------------------------------------
# the instrumented fit (local backend; the smoke covers the rest)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def traced_fit(tmp_path_factory):
    import numpy as np

    from repro.api.config import FitConfig
    from repro.api.engines import make_engine
    from repro.api.loop import run_loop
    from repro.obs import FitObserver

    td = tmp_path_factory.mktemp("trace")
    rng = np.random.default_rng(0)
    n, d, k = 4096, 16, 8
    X = rng.normal(size=(n, d)).astype(np.float32)
    X_val = rng.normal(size=(512, d)).astype(np.float32)
    config = FitConfig(k=k, b0=256, seed=0, max_rounds=20,
                       eval_every=4, capacity_floor=32).resolve(n)
    run = make_engine(config).begin(X, config, X_val=X_val)
    schedule = []
    with FitObserver(td, k=k, d=d, meta={"backend": "local"}) as obs:
        out = run_loop(run, config, trace=schedule, obs=obs)
    return td, out, schedule


def test_round_events_match_schedule_trace(traced_fit):
    td, out, schedule = traced_fit
    ev = read_events(td)
    rounds = [e for e in ev if e.get("name") == "round"]
    assert len(rounds) == len(schedule) > 0
    for e, s in zip(rounds, schedule):
        assert e["attrs"]["round"] == s["round"]
        assert e["attrs"]["quiet_rounds"] == s["quiet_rounds"]
    s = summarize(ev)
    assert s["rounds"] == len(schedule)
    assert s["kscans_total"] == sum(r.n_recomputed for r in out.telemetry)
    # the roofline gauge priced at least one round
    assert all(e["attrs"]["utilization"] is None
               or 0 < e["attrs"]["utilization"] <= 1 for e in rounds)
    assert any(e["attrs"]["utilization"] is not None for e in rounds)
    names = {e.get("name") for e in ev}
    assert {"fit_start", "fit_end", "round"} <= names


def test_metrics_json_written_at_close(traced_fit):
    td, out, schedule = traced_fit
    path = td / "metrics-p00000.json"
    m = json.loads(path.read_text())
    assert m["counters"]["fit_rounds"] == len(schedule)
    assert m["counters"]["fit_kscans"] == sum(
        r.n_recomputed for r in out.telemetry)
    assert m["histograms"]["fit_round_seconds"]["count"] == len(schedule)
    assert 0 < m["gauges"]["fit_roofline_utilization"] <= 1


def test_estimator_telemetry_roundtrip(tmp_path, blobs, blobs_val):
    import dataclasses

    from repro.api import FitConfig, NestedKMeans, Telemetry
    X, _ = blobs
    cfg = FitConfig(k=8, b0=256, seed=0, max_rounds=12,
                    trace_dir=str(tmp_path / "tr"))
    km = NestedKMeans(cfg).fit(X, X_val=blobs_val)
    assert km.telemetry_
    for rec in km.telemetry_:
        back = Telemetry.from_dict(json.loads(json.dumps(rec.to_dict())))
        assert dataclasses.asdict(back) == dataclasses.asdict(rec)
    # partial_fit extends telemetry through the SAME record builder
    n0 = len(km.telemetry_)
    km.partial_fit(X[:256])
    rec = km.telemetry_[-1]
    assert len(km.telemetry_) == n0 + 1 and rec.round == n0
    assert rec.b == 256 and rec.batch_mse is not None
    # the traced fit wrote a parseable event log
    assert summarize(read_events(tmp_path / "tr"))["rounds"] > 0


def test_fitconfig_trace_dir_validation():
    from repro.api import FitConfig
    with pytest.raises(ValueError, match="trace_dir"):
        FitConfig(k=8, trace_dir="")
    d = FitConfig(k=8, trace_dir="/tmp/x").to_dict()
    assert d["trace_dir"] == "/tmp/x"
    from repro.api.config import FitConfig as FC
    assert FC.from_dict(d).trace_dir == "/tmp/x"


def test_hostsync_silent_with_tracing_on(tmp_path):
    """The acceptance gate: a FitObserver attached to an audited fit
    adds ZERO unsanctioned device->host syncs."""
    from repro.analysis import hostsync
    found = hostsync.audit_backend(backend="local",
                                   trace_dir=str(tmp_path))
    assert found == []
    assert summarize(read_events(tmp_path))["rounds"] > 0


def test_cli_summarize_and_tail(tmp_path, capsys):
    from repro.obs.__main__ import main
    with SpanTracer(tmp_path) as tr:
        tr.event("round", round=0, kscans=5, dt_s=0.1)
    assert main(["summarize", str(tmp_path)]) == 0
    s = json.loads(capsys.readouterr().out)
    assert s["rounds"] == 1 and s["kscans_total"] == 5
    assert main(["tail", str(tmp_path), "-n", "1"]) == 0
    line = capsys.readouterr().out.strip()
    assert json.loads(line)["name"] == "round"
    assert main(["summarize", str(tmp_path / "nope")]) == 2


# ---------------------------------------------------------------------------
# the full stack (every backend, forced host devices)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_obs_smoke_subprocess():
    """scripts/smoke_obs.py: traced fits on local/mesh/xl/multihost with
    round events == schedule trace, plus lint + hostsync with tracing
    on every backend."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run([sys.executable, "scripts/smoke_obs.py"],
                       env=env, capture_output=True, text=True,
                       timeout=600, cwd=repo)
    assert r.returncode == 0, r.stdout + r.stderr
    for marker in ("local: rounds=", "mesh: rounds=", "xl: rounds=",
                   "multihost: rounds=", "replicated lint: clean",
                   "multihost: hostsync clean with tracing on",
                   "obs smoke OK"):
        assert marker in r.stdout, f"missing {marker!r}:\n{r.stdout}"
