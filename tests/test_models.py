"""Per-arch smoke tests (reduced configs) + cache-semantics correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M

# ~2.5 min of per-arch compiles: full tier-1 only (scripts/ci_tier1.sh
# runs the fast subset without these)
pytestmark = pytest.mark.slow


def make_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S))),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder.n_ctx, cfg.encoder.d_frontend)),
            jnp.float32)
    if cfg.family == "vlm":
        P = cfg.encoder.n_ctx
        batch["tokens"] = batch["tokens"][:, : S - P]
        batch["labels"] = batch["labels"][:, : S - P]
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, P, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", configs.list_archs())
def test_smoke_forward_and_train_step(arch):
    """One forward + one train step on CPU: shapes + no NaNs."""
    cfg = configs.get_reduced(arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    loss, metrics = jax.jit(lambda p, b: M.train_loss(p, b, cfg))(
        params, batch)
    assert np.isfinite(float(loss))

    from repro.optim import adamw
    from repro.train import step as tstep
    train = jax.jit(tstep.make_train_step(cfg, n_micro=2))
    opt = adamw.init(params)
    p2, o2, m2 = train(params, opt, batch)
    assert np.isfinite(float(m2["loss"]))
    # params actually changed (global delta; some leaves may have no grad)
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(p2)))
    assert delta > 0.0


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-2.7b",
                                  "jamba-v0.1-52b", "whisper-tiny",
                                  "granite-moe-1b-a400m", "internvl2-76b"])
def test_decode_matches_full_forward(arch):
    """Prefill S tokens then decode token S == full forward on S+1 tokens
    (exact KV-cache / SSM-state semantics).

    MoE archs get a large capacity factor: capacity-based token dropping
    legitimately depends on the total token count, so exact prefill/
    forward agreement needs drops disabled.
    """
    import dataclasses
    cfg = configs.get_reduced(arch)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    B, S = 2, 16
    batch = make_batch(cfg, B=B, S=S + 1, seed=2)
    toks = batch["tokens"]
    S_tok = toks.shape[1]

    prefix = cfg.encoder.n_ctx if cfg.family == "vlm" else 0
    cache_len = S_tok + prefix + 4
    pre_batch = {k: v for k, v in batch.items() if k != "labels"}
    pre_batch["tokens"] = toks[:, :-1]
    logits_p, cache = jax.jit(
        lambda p, b: M.prefill(p, b, cfg, cache_len=cache_len))(
        params, pre_batch)
    logits_d, _ = jax.jit(lambda p, t, c: M.decode_step(p, t, c, cfg))(
        params, toks[:, -1:], cache)

    full_batch = dict(pre_batch)
    full_batch["tokens"] = toks
    logits_f, _ = jax.jit(
        lambda p, b: M.prefill(p, b, cfg, cache_len=cache_len))(
        params, full_batch)

    np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                               np.asarray(logits_f[:, -1]),
                               rtol=6e-2, atol=6e-2)   # bf16 activations


def test_moe_capacity_drops_are_bounded():
    """With cf=1.25 and balanced-ish routing most tokens are kept."""
    from repro.models import layers as L
    cfg = configs.get_reduced("granite-moe-1b-a400m")
    p = L.init_moe(jax.random.PRNGKey(0), cfg.d_model, cfg.moe)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model),
                          jnp.bfloat16)
    out, aux = L.moe_fwd(p, x, cfg.moe)
    assert out.shape == x.shape
    assert np.isfinite(float(aux))
    assert float(jnp.abs(out.astype(jnp.float32)).mean()) > 0


def test_flash_attention_matches_naive():
    from repro.models import layers as L
    rng = np.random.default_rng(0)
    B, S, H, KV, Dh = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, Dh)), jnp.float32)
    out = L.flash_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
    # naive reference
    G = H // KV
    qh = q.reshape(B, S, KV, G, Dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qh, k) * Dh ** -0.5
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    ref = jnp.einsum("bhgqk,bkhd->bqhgd", p, v).reshape(B, S, H, Dh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)


def test_param_counts_match_configs():
    """Analytic param_count ~ the advertised model size (sanity of the
    6ND roofline numerator)."""
    expected = {
        "tinyllama-1.1b": 1.1e9,
        "llama3.2-3b": 3.2e9,
        "codeqwen1.5-7b": 7.2e9,
        "qwen1.5-32b": 32e9,
        "mamba2-2.7b": 2.7e9,
        "jamba-v0.1-52b": 52e9,
        "qwen3-moe-235b-a22b": 235e9,
        "granite-moe-1b-a400m": 1.3e9,
        "internvl2-76b": 76e9,
    }
    for arch, n in expected.items():
        got = configs.get_config(arch).param_count()
        assert 0.7 * n < got < 1.45 * n, (arch, got, n)
    # MoE active counts
    a22 = configs.get_config("qwen3-moe-235b-a22b").active_param_count()
    assert 15e9 < a22 < 30e9, a22
    a04 = configs.get_config("granite-moe-1b-a400m").active_param_count()
    assert 0.25e9 < a04 < 0.8e9, a04
