"""`LocalEngine` — single-process bucketed-jit rounds."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import FitConfig
from repro.api.engines.base import EngineRun
from repro.core import rounds
from repro.core.state import (ElkanBounds, KMeansState, PointState,
                              full_mse, init_state)
from repro.kernels.plan import resolve_plan
from repro.util.device import piece_update

# shared with estimator.partial_fit so streaming batches of a repeated
# shape hit the same jit cache as fit(). The resolved KernelPlan is a
# frozen (hashable) dataclass, so it rides the static args exactly like
# the bucket keys — one trace per (b, capacity, plan) tuple, and the
# plan is constant for a fit.
nested_jit = jax.jit(
    rounds.nested_round,
    static_argnames=("b", "rho", "bounds", "capacity", "use_shalf",
                     "plan", "data_axes"))
_mb_jit = jax.jit(rounds.mb_round, static_argnames=("fixed", "plan"))
_lloyd_jit = jax.jit(rounds.lloyd_round, static_argnames=("plan",))


# rows fetched off a ChunkStore per device-buffer update: bounds the
# host memory in flight and keeps the update-jit cache at one executable
# for full segments plus a handful of ragged tails
_IO_SEG_ROWS = 65536


class _LocalRun(EngineRun):
    def __init__(self, X, config: FitConfig, X_val, init_C):
        from repro.data.store import (ChunkStore, dataset_fingerprint,
                                      store_permutation)
        rng = np.random.default_rng(config.seed)
        self._store = X if isinstance(X, ChunkStore) else None
        if self._store is not None:
            # out-of-core: a zero device buffer filled lazily to the
            # current nested prefix (`_ensure_prefix`); the host never
            # holds more than one fetch segment of rows at a time. The
            # chunk-blocked permutation keeps the disk frontier
            # sequential — see repro.data.store.source.
            N = self._store.n
            perm = store_permutation(N, self._store.chunk_rows,
                                     config.seed, shuffle=config.shuffle)
            self._Xd = jnp.zeros((N, self._store.d), jnp.float32)
            self._filled = 0
            # shared donated segment writer (repro.util.device): the
            # donation auditor proves it aliases rather than copies
            self._upd = piece_update
            self.data_fingerprint = self._store.fingerprint()
        else:
            X = np.asarray(X)
            N = X.shape[0]
            perm = rng.permutation(N) if config.shuffle else np.arange(N)
            self._Xd = jnp.asarray(X[perm])
            self._filled = N
            self.data_fingerprint = dataset_fingerprint(X)
        self._Xv = jnp.asarray(X_val) if X_val is not None else None
        self._config = config
        self._rng = rng
        self._perm = perm
        if self._store is not None:
            # paper init needs the first k shuffled rows materialised
            self._ensure_prefix(min(N, max(config.k, 1)))

        state = init_state(self._Xd, config.k, bounds=config.bounds)
        if init_C is not None:       # warm start (checkpoint restart)
            state = dataclasses.replace(state, stats=dataclasses.replace(
                state.stats, C=jnp.asarray(init_C, jnp.float32)))
        self.state = state
        self.b = min(config.b0, N)
        self.b_max = N
        self.n_shards = 1
        self.n_active_target = N
        self.orig_index = perm        # storage row i holds X[perm[i]]
        self.n_points = N
        # kernel dispatch: resolved ONCE for the fit at its maximum
        # batch bucket; every round below threads this plan
        self.kernel_plan = resolve_plan(config.kernel_backend, b=N,
                                        k=config.k, d=self._Xd.shape[1],
                                        bounds=config.bounds)
        # mb/mbf resampling stream (paper footnote 1: cycle a reshuffle)
        self._mb_pos = 0
        self._mb_perm = rng.permutation(N)

    def _ensure_prefix(self, b: int) -> None:
        """Fill the device buffer with shuffled rows [filled, b) off the
        store, in bounded segments. No-op for in-memory fits and for
        already-covered prefixes — steady-state rounds fetch nothing."""
        if self._store is None or b <= self._filled:
            return
        with self._obs.span("ingest", rows=b - self._filled):
            lo = self._filled
            while lo < b:
                hi = min(b, lo + _IO_SEG_ROWS)
                rows = self._store.take(self._perm[lo:hi]).astype(
                    np.float32, copy=False)
                self._Xd = self._upd(self._Xd, jnp.asarray(rows),
                                     np.int32(lo))
                lo = hi
            self._filled = b

    def store_metrics(self):
        if self._store is None:
            return None
        return self._store.metrics.to_dict()

    def nested_step(self, state, b, capacity):
        self._ensure_prefix(b)
        return nested_jit(self._Xd, state, b=b, rho=self._config.rho,
                          bounds=self._config.bounds, capacity=capacity,
                          use_shalf=self._config.use_shalf,
                          plan=self.kernel_plan)

    def lloyd_step(self, state):
        return _lloyd_jit(self._Xd, state, plan=self.kernel_plan)

    def mb_step(self, state, fixed):
        N, b = self.b_max, self.b
        if self._mb_pos + b > N:
            self._mb_perm = self._rng.permutation(N)
            self._mb_pos = 0
        idx = jnp.asarray(self._mb_perm[self._mb_pos:self._mb_pos + b])
        self._mb_pos += b
        return _mb_jit(self._Xd, idx, state, fixed=fixed,
                       plan=self.kernel_plan)

    def eval_mse(self, state):
        if self._Xv is None:
            return None
        return float(full_mse(self._Xv, state.stats.C))

    # -- checkpointing ------------------------------------------------------
    # storage row i holds shuffle position i, so storage order IS the
    # canonical order for the local engine.

    def capture(self, state):
        tree = {
            "stats": jax.tree.map(np.asarray, state.stats),
            "a": np.asarray(state.points.a),
            "d": np.asarray(state.points.d),
            "lb": np.asarray(state.points.lb),
            "round": np.asarray(state.round),
            "mb_perm": np.asarray(self._mb_perm),
        }
        if state.elkan is not None:
            tree["elkan_l"] = np.asarray(state.elkan.l)
        meta = {
            "engine": "local", "n_shards": 1, "n_points": self.n_points,
            "has_mb": True, "has_elkan": state.elkan is not None,
            "mb_pos": self._mb_pos,
            "rng_state": self._rng.bit_generator.state,
        }
        return tree, meta

    def restore(self, store, step, meta):
        proto = {"stats": self.state.stats,
                 "a": self.state.points.a, "d": self.state.points.d,
                 "lb": self.state.points.lb, "round": self.state.round}
        if meta.get("has_elkan"):
            if self.state.elkan is None:
                raise ValueError(
                    "checkpoint carries elkan bounds but this config "
                    "does not use bounds='elkan'")
            proto["elkan_l"] = self.state.elkan.l
        if meta.get("has_mb"):
            proto["mb_perm"] = jnp.asarray(self._mb_perm)
        got = store.restore(proto, step=step)
        if meta.get("has_mb"):
            self._mb_perm = np.asarray(got["mb_perm"])
            self._mb_pos = int(meta["mb_pos"])
        if meta.get("rng_state") is not None:
            self._rng.bit_generator.state = meta["rng_state"]
        points = PointState(a=got["a"], d=got["d"], lb=got["lb"])
        elkan = (ElkanBounds(l=got["elkan_l"]) if meta.get("has_elkan")
                 else None)
        return KMeansState(stats=got["stats"], points=points,
                           elkan=elkan, round=got["round"])


class LocalEngine:
    """Single-process engine over the bucketed-jit round functions."""

    def begin(self, X, config: FitConfig, *, X_val=None,
              init_C=None) -> EngineRun:
        return _LocalRun(X, config, X_val, init_C)
