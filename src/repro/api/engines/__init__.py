"""Execution engines: one module per backend, one contract (`base`).

  local      bucketed-jit rounds, single process, single device
  mesh       shard_map over a device mesh; points row-sharded, stats
             replicated
  xl         mesh + centroids sharded over the model axis (k too large
             to replicate)
  multihost  mesh across `jax.distributed` processes (pod scale)

All are driven by the ONE host loop in `repro.api.loop`; `make_engine`
maps `FitConfig.backend` to the right one.
"""
from __future__ import annotations

from repro.api.config import FitConfig
from repro.api.engines.base import Engine, EngineRun
from repro.api.engines.local import LocalEngine, nested_jit
from repro.api.engines.mesh import MeshEngine
from repro.api.engines.multihost import MultiHostEngine
from repro.api.engines.xl import XLEngine

__all__ = ["Engine", "EngineRun", "LocalEngine", "MeshEngine",
           "MultiHostEngine", "XLEngine", "make_engine", "nested_jit"]


def make_engine(config: FitConfig, *, mesh=None) -> Engine:
    """Engine for ``config.backend`` ("mesh"/"xl" require a mesh;
    "multihost" builds one over every process's devices when omitted)."""
    if config.backend in ("mesh", "xl"):
        if mesh is None:
            raise ValueError(
                f"backend={config.backend!r} needs a jax.sharding.Mesh")
        return MeshEngine(mesh) if config.backend == "mesh" \
            else XLEngine(mesh)
    if config.backend == "multihost":
        return MultiHostEngine(mesh)
    return LocalEngine()
