"""`XLEngine` — centroids sharded over the model axis (kmeans_xl scale)."""
from __future__ import annotations

from jax.sharding import PartitionSpec as P

from repro.api.config import FitConfig
from repro.api.engines.base import EngineRun
from repro.api.engines.mesh import _MeshRun


class _XLRun(_MeshRun):
    """A `_MeshRun` whose cluster stats are sharded over ``model_axis``.

    Data placement, b units (per-data-shard rows), the n_valid tail mask
    and the canonical checkpoint layout are all inherited from the mesh
    run — checkpoints are written with FULL (k, d) stats, so an XL
    checkpoint restores elastically onto local/mesh engines and onto any
    model-axis size that divides k, and vice versa. Only the state
    placement and the compiled round differ.
    """
    _engine_name = "xl"

    def __init__(self, X, config: FitConfig, mesh, X_val, init_C):
        if config.model_axis not in mesh.shape:
            raise ValueError(
                f"backend='xl' needs mesh axis "
                f"{config.model_axis!r} (config.model_axis) to shard "
                f"the centroids over, but the mesh only has axes "
                f"{tuple(mesh.axis_names)}")
        m = int(mesh.shape[config.model_axis])
        if config.k % m:
            raise ValueError(
                f"backend='xl' shards the k={config.k} centroids over "
                f"mesh axis {config.model_axis!r} of size {m}; k must "
                f"divide evenly")
        super().__init__(X, config, mesh, X_val, init_C)

    def _stat_specs(self):
        from repro.core.distributed_xl import xl_state_specs
        return xl_state_specs(self._config.data_axes,
                              self._config.model_axis).stats

    def _elkan_spec(self):
        # one (rows_local, k_local) block per device: rows follow the
        # data shards, the k column follows the centroid shards
        return P(self._config.data_axes, self._config.model_axis)

    def nested_step(self, state, b, capacity):
        from repro.core.distributed_xl import make_xl_nested_round
        self._ensure_prefix(b)   # out-of-core: no-op on in-memory fits
        round_fn = make_xl_nested_round(
            self._mesh, self._config.data_axes,
            model_axis=self._config.model_axis, b_local=b,
            rho=self._config.rho, bounds=self._config.bounds,
            capacity=capacity, use_shalf=self._config.use_shalf,
            n_real=self._n_real, plan=self.kernel_plan)
        return round_fn(self._Xd, state)


class XLEngine:
    """Centroid-sharded engine: points over data axes, k over model.

    The regime past `MeshEngine`: when k*d no longer replicates (the
    ~10^5-centroid massive-data setting), each model shard scans only
    its k-slice with the fused top-2 kernel, the per-point top-2 triples
    are tree-folded over the model axis, and the S/v deltas are
    psum_scatter'ed so no device ever materialises full-k statistics.
    Drives the same `run_loop` (growth, overflow retry, patience,
    checkpoints) as every other engine.
    """

    def __init__(self, mesh):
        self.mesh = mesh

    def begin(self, X, config: FitConfig, *, X_val=None,
              init_C=None) -> EngineRun:
        return _XLRun(X, config, self.mesh, X_val, init_C)
