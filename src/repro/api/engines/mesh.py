"""`MeshEngine` — shard_map over a (single-process) device mesh.

`_MeshRun` is also the base class of the XL and multihost runs: all
placement goes through two hooks — `_put_global(arr, spec)` (host/local
array -> mesh-placed global array) and `_fetch(arr)` (global array ->
host numpy) — and the layout itself is a PartitionSpec pytree from
`_state_specs`. A subclass that changes WHERE things live (k-sharded
stats, process-spanning shards) overrides those hooks; the data layout
math, the canonical checkpoint order and the round schedule are
inherited untouched.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.api.config import FitConfig
from repro.api.engines.base import EngineRun
from repro.core.state import (ClusterStats, ElkanBounds, KMeansState,
                              PointState, full_mse)


def _slice_shape(idx, shape):
    """Concrete shape of a device's slice of a global array."""
    return tuple((sl.stop if sl.stop is not None else dim)
                 - (sl.start or 0) for sl, dim in zip(idx, shape))


# donated per-device-piece writer for `_ensure_prefix` — shared with
# the local engine and proven aliased by the donation auditor; see
# repro.util.device for why it is NOT a shard_map'd update.
from repro.util.device import piece_update as _piece_update


class _MeshRun(EngineRun):
    _engine_name = "mesh"

    def __init__(self, X, config: FitConfig, mesh, X_val, init_C):
        from repro.data.pipeline import nested_shard_layout
        from repro.data.store import (ChunkStore, StoredShardSource,
                                      dataset_fingerprint)

        data_axes = config.data_axes
        n_shards = int(np.prod([mesh.shape[a] for a in data_axes]))
        self._config = config
        self._mesh = mesh
        if isinstance(X, ChunkStore):
            # out-of-core: the layout's shuffle is the store's chunk-
            # blocked permutation (sequential disk frontier); rows are
            # fetched lazily up to the nested prefix (`_ensure_prefix`)
            # instead of placed up front.
            self._src = StoredShardSource(X, n_shards, seed=config.seed,
                                          shuffle=config.shuffle)
            N_real = X.n
            self._dim = X.d
            lay = self._src.layout
            self.data_fingerprint = X.fingerprint()
        else:
            # the placement (shuffle + structural tail pads + round-robin
            # interleave) is shared with data.pipeline.KMeansShardedSource;
            # padded rows sit at the tail of every shard and b_local is
            # capped below them, so they can never enter a nested prefix.
            self._src = None
            X = np.asarray(X)
            N_real = X.shape[0]
            self._dim = X.shape[1]
            lay = nested_shard_layout(N_real, n_shards, seed=config.seed,
                                      shuffle=config.shuffle)
            self.data_fingerprint = dataset_fingerprint(X)
        self._layout = lay
        N = lay.n_storage
        self._N = N
        self.n_shards = n_shards
        self.n_points = N_real
        self.n_active_target = N_real
        self.b = max(1, min(config.b0, N_real) // n_shards)
        # every shard's real rows are prefix-contiguous in its storage
        # slice; shards whose last storage row is a structural pad cap
        # their active prefix via the per-shard n_valid mask inside the
        # round, so b_max covers EVERY real row — including the tail
        # rows of the low shards when N_real % n_shards != 0.
        self.b_max = max(1, N // n_shards)
        # per-shard real-row cap is derived inside the sharded round
        # from the shard's axis index; None disables masking entirely
        self._n_real = N_real if N_real % n_shards else None
        # storage row shard*(N/s)+i holds shuffle position i*s+shard;
        # positions >= N_real are structural pads
        self._pos = lay.pos
        self.orig_index = lay.orig_index()
        self._Xv = jnp.asarray(X_val) if X_val is not None else None

        if self._src is None:
            self._Xd = self._place_data(X)
            self._filled = self.b_max
        else:
            self._Xd = self._zeros_data()
            self._filled = 0
        if init_C is not None:
            C0 = np.asarray(init_C, np.float32)
        else:
            # paper init: first k of the global shuffle. Indices past
            # N_real (k > N_real only) are structural pads == X[0].
            idx = lay.perm[:config.k]
            idx = np.where(idx < N_real, idx, 0)
            C0 = (self._src.store.take(idx) if self._src is not None
                  else X[idx]).astype(np.float32)
        # kernel dispatch: one plan for the fit, resolved at the
        # per-shard batch bucket (the shapes the kernels actually see)
        from repro.kernels.plan import resolve_plan
        self.kernel_plan = resolve_plan(config.kernel_backend,
                                        b=self.b_max, k=config.k,
                                        d=self._dim, bounds=config.bounds)
        self.state = self._place_state(self._host_init_state(C0))

    # -- layout hooks (overridden by _XLRun / _MultiHostRun) ----------------

    def _put_global(self, arr, spec) -> jax.Array:
        """Place a host/local array onto the mesh as ``spec`` says."""
        return jax.device_put(arr, NamedSharding(self._mesh, spec))

    def _fetch(self, arr) -> np.ndarray:
        """A mesh-placed array back on the host (single-process: free)."""
        return np.asarray(arr)

    def _stat_specs(self) -> ClusterStats:
        """PartitionSpec pytree of the cluster stats (replicated here;
        the XL engine k-shards them over ``model_axis``)."""
        return ClusterStats(C=P(), S=P(), v=P(), sse=P(), p=P())

    def _elkan_spec(self):
        """Spec of the per-(i, j) elkan lower-bound matrix (rows follow
        the points; the k column is replicated here, model-sharded on
        the XL engine)."""
        return P(self._config.data_axes, None)

    def _state_specs(self, with_elkan: bool) -> KMeansState:
        row = P(self._config.data_axes)
        return KMeansState(
            stats=self._stat_specs(),
            points=PointState(a=row, d=row, lb=row),
            elkan=(ElkanBounds(l=self._elkan_spec()) if with_elkan
                   else None),
            round=P())

    def _place_state(self, state: KMeansState) -> KMeansState:
        specs = self._state_specs(state.elkan is not None)
        return jax.tree.map(self._put_global, state, specs)

    def _place_data(self, X: np.ndarray) -> jax.Array:
        lay = self._layout
        if lay.n_storage > self.n_points:
            X = np.concatenate(
                [X, np.repeat(X[:1], lay.n_storage - self.n_points,
                              axis=0)])
        N, s = lay.n_storage, self.n_shards
        Xh = X[lay.perm].reshape(N // s, s, -1).transpose(1, 0, 2)
        return self._put_global(jnp.asarray(Xh.reshape(N, -1)),
                                P(self._config.data_axes, None))

    # -- out-of-core placement (store-backed fits) --------------------------
    # The data buffer starts as zeros and is filled to the current
    # nested prefix on demand: `nested_step` calls `_ensure_prefix(b)`,
    # which fetches only storage rows [filled, b) of every shard — the
    # "reuse old, append new" schedule as disk reads. Fetches run in
    # fixed-size per-shard segments so host memory in flight stays
    # bounded and the donated update jit compiles one full-segment
    # executable plus a handful of ragged tails.

    #: per-shard rows per fetch segment (host rows in flight per update
    #: = _IO_SEG_ROWS * n_shards on single-process meshes)
    _IO_SEG_ROWS = 8192

    def _data_spec(self):
        return P(self._config.data_axes, None)

    def _zeros_data(self) -> jax.Array:
        """The empty (n_storage, d) buffer, assembled from per-device
        zero pieces — no process ever materialises the global shape."""
        shape = (self._N, self._dim)
        sh = NamedSharding(self._mesh, self._data_spec())
        pieces = [
            jax.device_put(np.zeros(_slice_shape(idx, shape), np.float32),
                           dev)
            for dev, idx in
            sh.addressable_devices_indices_map(shape).items()]
        return jax.make_array_from_single_device_arrays(shape, sh, pieces)

    def _fetch_block(self, shards: np.ndarray, lo: int, hi: int):
        """Storage rows [lo, hi) of the given shards, host-side float32
        of shape (len(shards), hi - lo, d).

        All requested shards come off the ChunkStore in ONE `block`
        call. Under the round-robin layout every chunk holds rows of
        every shard, so a per-shard loop would reload each covering
        chunk once per shard (the segment can span more chunks than the
        LRU keeps); fetched together, each chunk of the frontier is
        read once — and the prefix-delta schedule then reads the store
        about once per fit, not once per round.
        """
        return self._src.block(shards, lo, hi).astype(np.float32,
                                                      copy=False)

    def _ensure_prefix(self, b: int) -> None:
        if self._src is None or b <= self._filled:
            return
        with self._obs.span("ingest", rows=b - self._filled):
            shape, sh = self._Xd.shape, self._Xd.sharding
            rps = shape[0] // self.n_shards    # storage rows per shard
            # shard id held by each addressable piece (this process's
            # devices only on multihost; replicas repeat under the XL
            # engine's model axis and each replica is written in place)
            owned = [(s.index[0].start or 0) // rps
                     for s in self._Xd.addressable_shards]
            uniq, inv = np.unique(np.asarray(owned), return_inverse=True)
            lo = self._filled
            while lo < b:
                hi = min(b, lo + self._IO_SEG_ROWS)
                blk = self._fetch_block(uniq, lo, hi)
                pieces = [
                    _piece_update(s.data,
                                  jax.device_put(blk[inv[j]], s.device),
                                  np.int32(lo))
                    for j, s in enumerate(self._Xd.addressable_shards)]
                self._Xd = jax.make_array_from_single_device_arrays(
                    shape, sh, pieces)
                lo = hi
            self._filled = b
            # warm the chunks of the NEXT doubling while this round
            # computes
            self._src.prefetch_positions(
                b * self.n_shards,
                min(2 * b, self.b_max) * self.n_shards)

    def store_metrics(self):
        if self._src is None:
            return None
        return self._src.store.metrics.to_dict()

    def _host_init_state(self, C0: np.ndarray) -> KMeansState:
        """The paper's initial state, built host-side.

        Mirrors `core.state.init_state` value for value; constructed
        from numpy because a multi-process data array cannot be sliced
        for C0 on the host (every process already holds X).
        """
        k, N = self._config.k, self._N
        stats = ClusterStats(
            C=C0, S=np.zeros((k, self._dim), np.float32),
            v=np.zeros((k,), np.float32), sse=np.zeros((k,), np.float32),
            p=np.zeros((k,), np.float32))
        points = PointState(a=np.full((N,), -1, np.int32),
                            d=np.zeros((N,), np.float32),
                            lb=np.zeros((N,), np.float32))
        elkan = (ElkanBounds(l=np.zeros((N, k), np.float32))
                 if self._config.bounds == "elkan" else None)
        return KMeansState(stats=stats, points=points, elkan=elkan,
                           round=np.zeros((), np.int32))

    # -- round executors ----------------------------------------------------

    def nested_step(self, state, b, capacity):
        from repro.core.distributed import make_sharded_round
        self._ensure_prefix(b)
        round_fn = make_sharded_round(
            self._mesh, self._config.data_axes, b_local=b,
            rho=self._config.rho, bounds=self._config.bounds,
            capacity=capacity, use_shalf=self._config.use_shalf,
            n_real=self._n_real, plan=self.kernel_plan)
        return round_fn(self._Xd, state)

    def eval_mse(self, state):
        if self._Xv is None:
            return None
        return float(full_mse(self._Xv, state.stats.C))

    # -- streaming (estimator.partial_fit) ----------------------------------

    def place_stats(self, state, stats):
        placed = jax.tree.map(self._put_global, stats, self._stat_specs())
        return dataclasses.replace(state, stats=placed)

    # -- checkpointing ------------------------------------------------------
    # storage row shard*(N/s)+i holds shuffle position i*s+shard, so
    # canonical order is storage gathered, permuted by _pos, pads cut.

    def _canon(self, arr) -> np.ndarray:
        h = self._fetch(arr)
        out = np.empty_like(h)
        out[self._pos] = h
        return out[:self.n_points]

    def capture(self, state):
        tree = {
            "stats": jax.tree.map(self._fetch, state.stats),
            "a": self._canon(state.points.a),
            "d": self._canon(state.points.d),
            "lb": self._canon(state.points.lb),
            "round": self._fetch(state.round),
        }
        if state.elkan is not None:
            tree["elkan_l"] = self._canon(state.elkan.l)
        meta = {"engine": self._engine_name, "n_shards": self.n_shards,
                "n_points": self.n_points, "has_mb": False,
                "has_elkan": state.elkan is not None}
        return tree, meta

    def _canonical_proto(self, meta):
        """Zero pytree with the canonical checkpoint shapes/dtypes."""
        k, d = self._config.k, self._dim
        n = self.n_points
        proto = {
            "stats": ClusterStats(C=np.zeros((k, d), np.float32),
                                  S=np.zeros((k, d), np.float32),
                                  v=np.zeros((k,), np.float32),
                                  sse=np.zeros((k,), np.float32),
                                  p=np.zeros((k,), np.float32)),
            "a": np.zeros((n,), np.int32),
            "d": np.zeros((n,), np.float32),
            "lb": np.zeros((n,), np.float32),
            "round": np.zeros((), np.int32),
        }
        if meta.get("has_elkan"):
            proto["elkan_l"] = np.zeros((n, k), np.float32)
        return proto

    def _read_canonical(self, store, step, meta):
        """The canonical host tree off the disk (hook: the multihost run
        reads on the coordinator and broadcasts)."""
        got = store.restore(self._canonical_proto(meta), step=step)
        return jax.tree.map(np.asarray, got)

    def restore(self, store, step, meta):
        want_elkan = self._config.bounds == "elkan"
        if meta.get("has_elkan") and not want_elkan:
            raise ValueError(
                "checkpoint carries elkan bounds but this config does "
                "not use bounds='elkan'")
        if want_elkan and not meta.get("has_elkan"):
            raise ValueError(
                "config uses bounds='elkan' but the checkpoint carries "
                "no elkan bound state")
        host = self._read_canonical(store, step, meta)

        row = P(self._config.data_axes)

        # per-point leaves come back canonical; re-pad + re-interleave
        # for THIS mesh's shard count, then place per the layout specs
        def place(h, fill, spec):
            h = np.asarray(h)
            full = np.full((self._N,) + h.shape[1:], fill, h.dtype)
            full[:self.n_points] = h
            return self._put_global(full[self._pos], spec)

        stats = jax.tree.map(self._put_global, host["stats"],
                             self._stat_specs())
        points = PointState(a=place(host["a"], -1, row),
                            d=place(host["d"], 0.0, row),
                            lb=place(host["lb"], 0.0, row))
        elkan = (ElkanBounds(l=place(host["elkan_l"], 0.0,
                                     self._elkan_spec()))
                 if want_elkan else None)
        return KMeansState(stats=stats, points=points, elkan=elkan,
                           round=self._put_global(host["round"], P()))


class MeshEngine:
    """Multi-device engine: points row-sharded, cluster stats replicated.

    The S/v/sse deltas are psum-reduced inside the round, so the stats —
    and therefore the controller's growth decision — are bit-identical
    on every shard with no host round-trip. Only the nested (gb/tb)
    family is supported; `FitConfig.__post_init__` enforces this.
    """

    def __init__(self, mesh):
        self.mesh = mesh

    def begin(self, X, config: FitConfig, *, X_val=None,
              init_C=None) -> EngineRun:
        return _MeshRun(X, config, self.mesh, X_val, init_C)
