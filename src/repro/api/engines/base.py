"""The `Engine` / `EngineRun` contract every backend implements.

An `Engine` owns data placement and compiled rounds; `EngineRun` is one
fit in flight. The host loop (`repro.api.loop.run_loop`) is written
against this contract only — it never imports a concrete engine — and
every quantity it branches on is either a static from the resolved
`FitConfig` or a device-computed scalar out of `RoundInfo`.

Process awareness: a run may span several OS processes (the multihost
engine). The base class defines the process hooks as single-process
no-ops so the local/mesh/xl engines pay nothing; `_MultiHostRun`
overrides them with `jax.distributed` collectives. The contract each
hook must honour is documented on the hook — the loop's correctness on
a pod rests on these contracts, not on the loop's own code.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Dict, Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import FitConfig
from repro.core.state import ClusterStats, KMeansState, RoundInfo


class _NullObsSink:
    """Default obs sink: every engine hook is a guaranteed no-op.

    This is deliberately NOT `api.loop.ObsSink` (loop imports this
    module; importing loop back would cycle) — just the two hooks an
    engine body ever touches. `run_loop` swaps in the real sink via
    `EngineRun.bind_obs` before the first round.
    """

    def span(self, name: str, **attrs):
        return contextlib.nullcontext()

    def count(self, name: str, n: int = 1) -> None:
        pass


_NO_OBS = _NullObsSink()


class EngineRun:
    """One fit in flight: placed data + initial state + round executors.

    Subclasses set:
      state            initial KMeansState (already placed/sharded)
      b                initial batch size in ENGINE UNITS (global rows
                       for LocalEngine, per-shard rows for MeshEngine)
      b_max            largest batch in engine units
      n_shards         data shards (1 for local)
      n_active_target  info.n_active value meaning "full data active"
      orig_index       (n_storage,) int: original caller row held at
                       each internal storage row (-1 = structural pad)
      n_points         caller's dataset size (pads excluded)
      data_fingerprint JSON-safe content identity of the fitted dataset
                       (`repro.data.store.dataset_fingerprint`); written
                       into checkpoint extras so a resume against a
                       different dataset fails loudly. None disables
                       the check.
    """
    state: KMeansState
    b: int
    b_max: int
    n_shards: int = 1
    n_active_target: int = 0
    orig_index: np.ndarray = None
    n_points: int = 0
    data_fingerprint: Optional[Dict[str, Any]] = None
    #: the fit's resolved `repro.kernels.plan.KernelPlan` (None only for
    #: engines predating the dispatch plane); surfaced in `FitOutcome`
    #: and the benchmark manifests.
    kernel_plan: Optional[Any] = None

    # -- round executors (pure: state in -> (state, info)) ------------------

    def nested_step(self, state: KMeansState, b: int,
                    capacity: Optional[int]
                    ) -> Tuple[KMeansState, RoundInfo]:
        raise NotImplementedError(
            f"{type(self).__name__} does not run the nested family")

    def lloyd_step(self, state: KMeansState
                   ) -> Tuple[KMeansState, RoundInfo]:
        raise NotImplementedError(
            f"{type(self).__name__} does not run lloyd")

    def mb_step(self, state: KMeansState, fixed: bool
                ) -> Tuple[KMeansState, RoundInfo]:
        raise NotImplementedError(
            f"{type(self).__name__} does not run mb/mbf")

    def eval_mse(self, state: KMeansState) -> Optional[float]:
        """Validation MSE of the current centroids (None: no val set).

        Multi-process contract: must return the SAME float on every
        process (the loop's eval cadence and telemetry feed off it).
        """
        return None

    # -- observability (see repro.obs; default: no-ops) ---------------------

    #: the bound obs sink; engine bodies call ``self._obs.span(...)`` /
    #: ``self._obs.count(...)`` unconditionally — the null sink makes
    #: untraced fits pay two attribute loads, nothing more.
    _obs: Any = _NO_OBS

    def bind_obs(self, obs: Any) -> None:
        """Attach the fit's obs sink (called once by `run_loop` before
        round 0). The sink must only ever be handed HOST values — an
        engine must never pass it a live device array (the hostsync
        auditor enforces this on instrumented fits)."""
        self._obs = obs if obs is not None else _NO_OBS

    def store_metrics(self) -> Optional[Dict[str, Any]]:
        """Cumulative `repro.data.store` read metrics as a JSON-safe
        dict, or None when this run is not store-backed. Host-side
        counters only — reading them must not touch a device."""
        return None

    # -- host-side views of device state ------------------------------------

    def host_points(self, state: KMeansState) -> np.ndarray:
        """The (n_storage,) assignment vector on the host.

        Multi-process contract: a collective — every process calls it at
        the same loop point and receives the full vector.
        """
        return np.asarray(state.points.a)

    def fetch_stats(self, state: KMeansState) -> ClusterStats:
        """Cluster stats usable from THIS process (host or local device).

        The default hands back the state's own stats leaves (fully
        addressable on every single-process engine). Multi-process runs
        override with a gather so `predict`/`export_codebook` on the
        estimator never touch non-addressable shards.
        """
        return state.stats

    def place_stats(self, state: KMeansState,
                    stats: ClusterStats) -> KMeansState:
        """Return ``state`` with ``stats`` placed in this engine's layout
        (replicated / k-sharded / process-spanning as the engine needs).
        The streaming path (`NestedKMeans.partial_fit`) uses this to
        carry the running statistics into a freshly placed batch run."""
        return dataclasses.replace(
            state, stats=jax.tree.map(jnp.asarray, stats))

    # -- checkpointing (canonical = global-shuffle row order) ---------------

    def capture(self, state: KMeansState) -> Tuple[Dict[str, Any],
                                                   Dict[str, Any]]:
        """(host pytree, JSON-safe engine meta) for a checkpoint.

        Per-point arrays are returned in CANONICAL order — the position
        of each real row in the seed-determined global shuffle, pads
        dropped. The canonical layout depends only on (seed, N_real), so
        a checkpoint written by any engine at any shard count restores
        onto any other (elastic restart).

        Multi-process contract: a collective (it gathers sharded
        leaves); every process calls it, only the coordinator writes the
        result to disk.
        """
        raise NotImplementedError

    def restore(self, store: Any, step: int,
                meta: Dict[str, Any]) -> KMeansState:
        """Rebuild an engine-layout state from a canonical checkpoint.

        Multi-process contract: the coordinator reads the arrays and
        broadcasts them; every process places the SAME canonical values
        into its local shards.
        """
        raise NotImplementedError

    # -- process awareness (single-process defaults) ------------------------
    #
    # The loop derives every per-round decision from shard-replicated
    # RoundInfo scalars, so its control flow is already bit-identical on
    # every process BY CONSTRUCTION. These hooks cover the residue: who
    # writes checkpoints, how processes agree on host-only facts (the
    # wall clock, what is on disk), and rendezvous points.

    #: True on the process allowed to touch the checkpoint directory.
    is_coordinator: bool = True

    def barrier(self) -> None:
        """Block until every process reaches this point (no-op single
        process). The loop calls it around checkpoint writes so no
        process races ahead of a save/clear it may later depend on."""

    def sync_flag(self, flag: bool) -> bool:
        """Replicate a HOST-derived boolean from the coordinator.

        The one loop decision not derivable from device scalars is the
        wall-clock budget (`time_budget_s`): clocks drift between
        processes, so each round the coordinator's verdict is broadcast
        and every process obeys it. Single-process: identity.
        """
        return bool(flag)

    def resolve_resume(self, store: Any
                       ) -> Tuple[Optional[int], Optional[Dict[str, Any]]]:
        """(latest step, its ``extra`` dict) — replicated across
        processes. ``(None, None)`` when the store holds no checkpoints.
        Multi-process runs read on the coordinator and broadcast, so a
        resume decision can never diverge on an eventually-consistent
        filesystem."""
        step = store.latest_step()
        if step is None:
            return None, None
        return step, store.read_extra(step)


@runtime_checkable
class Engine(Protocol):
    """An execution backend: owns data placement + compiled rounds."""

    def begin(self, X, config: FitConfig, *,
              X_val=None, init_C: Optional[np.ndarray] = None) -> EngineRun:
        """Shuffle/pad/place ``X`` and build the initial state."""
        ...
