"""`MultiHostEngine` — the mesh engine across `jax.distributed` processes.

Every process runs the SAME host loop over the SAME global schedule
(see `repro.api.loop`'s replication invariant); this module only
changes WHERE arrays live and HOW the host sees them:

  * placement: a process cannot `device_put` onto devices it does not
    own, so `_put_global` assembles global arrays from process-local
    single-device shards (`jax.make_array_from_single_device_arrays`).
    The data placement slices each process's rows straight out of the
    shared `nested_shard_layout` (`ShardLayout.shard_orig_rows`): a
    process materialises only its own shards' rows, never the padded
    permuted copy of the whole dataset.
  * host views: a row-sharded global array is not addressable from any
    one process, so `_fetch` replicates it with a jitted identity
    (compiling to one all-gather) and reads the local copy. Replicated
    arrays (stats, RoundInfo scalars) are read directly — every
    process holds the full value.
  * checkpoints: only process 0 writes (`is_coordinator`); `capture`'s
    gathers and `restore`'s coordinator-read + `broadcast_one_to_all`
    are collectives every process joins, bracketed by the loop's
    `barrier()` calls.

Bit-compatibility: on ONE process this run places the same rows on the
same devices as `_MeshRun` and executes the same
`make_sharded_round` executable, so a single-process multihost fit is
bit-identical (centroids, labels, per-point state, schedule) to the
mesh engine — asserted by scripts/smoke_multihost.py, which also spawns
a real 2-process CPU cluster with a local coordinator.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import multihost_utils
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.api.config import FitConfig
from repro.api.engines.base import EngineRun
from repro.api.engines.mesh import _MeshRun


class _MultiHostRun(_MeshRun):
    _engine_name = "multihost"

    def __init__(self, X, config: FitConfig, mesh, X_val, init_C):
        # one executable per aval: the replicating identity behind
        # _fetch (an all-gather over whatever the input's sharding is)
        self._replicate = jax.jit(
            lambda t: t, out_shardings=NamedSharding(mesh, P()))
        super().__init__(X, config, mesh, X_val, init_C)

    # -- layout hooks -------------------------------------------------------

    def _put_global(self, arr, spec):
        sh = NamedSharding(self._mesh, spec)
        arr = np.asarray(arr)
        pieces = [
            jax.device_put(arr[idx], dev)
            for dev, idx in
            sh.addressable_devices_indices_map(arr.shape).items()]
        return jax.make_array_from_single_device_arrays(
            arr.shape, sh, pieces)

    def _place_data(self, X):
        # per-process row placement: each local device holds exactly one
        # data shard's slice; pull that shard's rows straight from the
        # layout instead of materialising the full padded permutation
        lay = self._layout
        shape = (lay.n_storage, self._dim)
        sh = NamedSharding(self._mesh, P(self._config.data_axes, None))
        rps = lay.rows_per_shard
        pieces = []
        for dev, idx in sh.addressable_devices_indices_map(shape).items():
            s = (idx[0].start or 0) // rps
            rows = lay.shard_orig_rows(s)   # (rps,) caller rows, -1 = pad
            Xl = X[np.where(rows >= 0, rows, 0)]  # pads are X[0] copies
            pieces.append(jax.device_put(jnp.asarray(Xl), dev))
        return jax.make_array_from_single_device_arrays(shape, sh, pieces)

    # out-of-core `_ensure_prefix` needs no override: the base run
    # derives shard ids from `_Xd.addressable_shards`, which on a
    # multi-process mesh are exactly this process's devices — each
    # process reads only its own shards' rows off its own store handle.

    def _fetch(self, arr):
        if not isinstance(arr, jax.Array) or arr.is_fully_addressable:
            return np.asarray(arr)
        if arr.sharding.is_fully_replicated:
            return np.asarray(arr.addressable_data(0))
        # row-sharded across processes: all-gather, read the local copy
        return np.asarray(self._replicate(arr).addressable_data(0))

    # -- host views ---------------------------------------------------------

    def eval_mse(self, state):
        if self._Xv is None:
            return None
        # fetch C first: X_val lives process-locally, and one jit cannot
        # mix a process-local array with a multi-process global one
        from repro.core.state import full_mse
        return float(full_mse(self._Xv,
                              jnp.asarray(self._fetch(state.stats.C))))

    def host_points(self, state):
        return self._fetch(state.points.a)

    def fetch_stats(self, state):
        return jax.tree.map(self._fetch, state.stats)

    # -- process awareness --------------------------------------------------

    @property
    def is_coordinator(self) -> bool:
        return jax.process_index() == 0

    def barrier(self) -> None:
        if jax.process_count() == 1:
            return
        multihost_utils.sync_global_devices("repro.api.loop")

    def sync_flag(self, flag: bool) -> bool:
        if jax.process_count() == 1:
            return bool(flag)
        return bool(int(multihost_utils.broadcast_one_to_all(
            np.int32(bool(flag)))))

    def resolve_resume(self, store):
        if jax.process_count() == 1:
            return super().resolve_resume(store)
        # the coordinator's filesystem is the source of truth: step and
        # metadata are broadcast so every process resumes the same run
        # even when the checkpoint directory is not shared
        payload = b""
        if self.is_coordinator:
            step, extra = super().resolve_resume(store)
            if extra is not None:
                payload = json.dumps(extra).encode()
            head = np.array([step if step is not None else -1,
                             len(payload)], np.int64)
        else:
            head = np.zeros((2,), np.int64)
        head = multihost_utils.broadcast_one_to_all(head)
        step, n = int(head[0]), int(head[1])
        extra = None
        if n:
            buf = np.zeros((n,), np.uint8)
            if self.is_coordinator:
                buf[:] = np.frombuffer(payload, np.uint8)
            # broadcast upcasts for its psum on some jax versions —
            # force the byte dtype back before decoding
            buf = np.asarray(multihost_utils.broadcast_one_to_all(buf),
                             dtype=np.uint8)
            extra = json.loads(buf.tobytes().decode())
        return (None, None) if step < 0 else (step, extra)

    def _read_canonical(self, store, step, meta):
        if jax.process_count() == 1:
            return super()._read_canonical(store, step, meta)
        proto = self._canonical_proto(meta)
        host = (super()._read_canonical(store, step, meta)
                if self.is_coordinator else proto)
        got = multihost_utils.broadcast_one_to_all(host)
        # pin dtypes: the broadcast may upcast narrow leaves for its psum
        return jax.tree.map(
            lambda g, p: np.asarray(g, dtype=np.asarray(p).dtype),
            got, proto)


class MultiHostEngine:
    """`jax.distributed` engine: the mesh schedule at pod scale.

    Build one per process (same config everywhere) and call `begin` with
    the SAME dataset on every process; the engine places each process's
    rows, and the shared `run_loop` — whose control flow is replicated
    by construction — drives the fit with no cross-process coordination
    beyond the collectives inside the compiled round.

    ``mesh`` may be omitted: `begin` then initialises `jax.distributed`
    from the config's coordinator fields (if set and not already up)
    and builds a flat data mesh over every device of every process
    (`repro.launch.mesh.make_multihost_mesh`).
    """

    def __init__(self, mesh=None):
        self.mesh = mesh

    def begin(self, X, config: FitConfig, *, X_val=None,
              init_C=None) -> EngineRun:
        if self.mesh is None:
            from repro.launch.mesh import (ensure_multihost_initialized,
                                           make_multihost_mesh)
            ensure_multihost_initialized(config)
            self.mesh = make_multihost_mesh(config.data_axes)
        return _MultiHostRun(X, config, self.mesh, X_val, init_C)
