"""THE host control loop, shared by every engine — and every process.

One `run_loop` drives the growth schedule, power-of-two capacity
bucketing, overflow retry, convergence patience, telemetry and in-loop
checkpointing for all backends (local / mesh / xl / multihost).

## The process-replicated control-flow invariant

On a multi-process (jax.distributed) run EVERY process executes this
loop over its own copy of the host state. There is no leader election
and no per-round consensus protocol; instead the loop is written so its
control flow is bit-identical on every process BY CONSTRUCTION:

  * every per-round decision — batch growth (`info.grow`), capacity
    sizing (`info.n_recomputed`), overflow retry (`info.overflow`),
    convergence patience (`info.n_changed` / `info.p_max` /
    `info.n_active`) — branches ONLY on scalars out of `RoundInfo`,
    which the round functions psum-reduce across every data shard
    before returning. A replicated device scalar fetched on two
    processes yields the same bits, so both take the same branch.
  * the data placement, initial centroids and the mini-batch resampling
    stream are all seeded deterministically from the resolved
    `FitConfig` (`config.seed`), never from ambient host entropy, so
    every process holds the same global shuffle and the same schedule
    inputs at round 0.
  * the ONE intrinsically host-local quantity — the wall clock behind
    `time_budget_s` — is resolved by the coordinator and broadcast
    through `run.sync_flag` before anyone acts on it (clocks drift;
    replicated flags do not). With the default infinite budget the
    hook is never consulted.
  * filesystem facts (which checkpoint step is latest, its metadata)
    go through `run.resolve_resume`, which multi-process runs answer
    on the coordinator and broadcast.

Checkpoint writes are coordinator-only (`run.is_coordinator`), with a
`run.barrier()` after every save/clear so no process races ahead of a
directory state it may later restore from. `run.capture` / `restore`
are collectives — every process participates in the gathers and
broadcasts even though only one touches the disk.

Anything appended to this loop must preserve the invariant: derive new
decisions from `RoundInfo` (extend it if needed — it is psum-reduced in
one place per engine), or route them through a `run` hook that
guarantees replication.
"""
from __future__ import annotations

import dataclasses
import math
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple, Union

import jax
import numpy as np

from repro.api.config import FitConfig
from repro.api.engines.base import EngineRun
from repro.api.telemetry import RoundCallback, Telemetry, final_val_mse
from repro.checkpoint.store import CheckpointStore
from repro.core.state import KMeansState, RoundInfo


# --------------------------------------------------------------------------
# result record
# --------------------------------------------------------------------------

@dataclasses.dataclass
class FitOutcome:
    """What a fit produces: centroids + full state + structured telemetry.

    ``labels`` is in the CALLER's row order (the engines shuffle and, on
    a mesh, interleave/pad internally; the inverse mapping is applied
    here). ``-1`` marks rows the nested batch never reached.
    """
    C: np.ndarray
    state: KMeansState
    labels: np.ndarray
    telemetry: List[Telemetry]
    converged: bool
    algorithm: str
    config: FitConfig

    @property
    def final_mse(self) -> float:
        return final_val_mse(self.telemetry)


# --------------------------------------------------------------------------
# capacity policy (shared)
# --------------------------------------------------------------------------

def next_pow2(x: int) -> int:
    return 1 << max(0, int(x - 1).bit_length())


def cap_bucket(need: int, b: int, floor: int) -> Optional[int]:
    """Power-of-two capacity with 2x slack; None == recompute everything."""
    cap = max(floor, next_pow2(2 * max(need, 1)))
    return None if cap >= b else cap


# --------------------------------------------------------------------------
# THE shared host loop
# --------------------------------------------------------------------------

def run_loop(run: EngineRun, config: FitConfig, *,
             on_round: Optional[RoundCallback] = None,
             resume_from: Optional[Union[str, Path, CheckpointStore]] = None,
             resolved_resume: Optional[Tuple[int, Dict[str, Any]]] = None,
             trace: Optional[List[Dict[str, Any]]] = None
             ) -> FitOutcome:
    """Growth schedule + capacity bucketing + overflow retry + patience.

    ``config`` must already be `resolve()`d (no alias algorithms). The
    loop is backend-agnostic AND process-agnostic: see the module
    docstring for the replication invariant that makes the same code
    drive one device, a host mesh, or a multi-process pod.

    When ``config.checkpoint`` is set, the FULL loop state — engine
    state, batch size, capacity bucket, patience counter, work clock and
    telemetry — is saved atomically every ``save_every`` rounds (plus
    once at loop exit) alongside the ``config.to_dict()`` manifest.
    ``resume_from`` (a directory or `CheckpointStore`) restores the
    latest such checkpoint through the engine's canonical layout, so a
    killed fit continues bit-identically — and a fit checkpointed on
    one shard count (or process count) resumes on another (elastic
    restart). ``resolved_resume``: the ``(step, extra)`` pair a caller
    already obtained from ``run.resolve_resume`` on the same store
    (the estimator validates the manifest first); passing it avoids a
    second read — and on multihost a second cluster-wide broadcast —
    of the same payload.

    ``trace``: optional list; one dict per completed round —
    ``{"round", "b_global", "capacity", "quiet_rounds"}`` — is appended
    AFTER the round's schedule updates. This is the loop's control-flow
    fingerprint: two processes of the same multihost fit must produce
    identical traces (scripts/smoke_multihost.py asserts exactly that).
    """
    algorithm = config.algorithm
    bounds = config.bounds
    state = run.state
    b = run.b
    capacity: Optional[int] = None
    telemetry: List[Telemetry] = []
    t_work = 0.0
    quiet_rounds = 0
    converged = False
    start_round = 0
    timed = math.isfinite(config.time_budget_s)

    ckpt = config.checkpoint
    store = (CheckpointStore(ckpt.checkpoint_dir, keep=ckpt.keep)
             if ckpt is not None else None)

    if store is not None and resume_from is None:
        # a FRESH checkpointed fit supersedes whatever run lives in the
        # directory: left in place, the old (higher-numbered) steps
        # would garbage-collect this run's early saves on arrival and a
        # later resume would silently restore the stale fit
        if run.is_coordinator and store.latest_step() is not None:
            store.clear()
        run.barrier()

    if resume_from is not None:
        rstore = (resume_from if isinstance(resume_from, CheckpointStore)
                  else CheckpointStore(resume_from,
                                       keep=ckpt.keep if ckpt else 3))
        step, extra = (resolved_resume if resolved_resume is not None
                       else run.resolve_resume(rstore))
        if step is None:
            raise FileNotFoundError(
                f"resume_from={resume_from!r} holds no checkpoints")
        if not extra or "loop" not in extra:
            raise ValueError(
                f"checkpoint step {step} has no loop metadata; it was "
                f"not written by run_loop")
        emeta, loop = extra["engine"], extra["loop"]
        # dataset identity gate: a resume against a DIFFERENT dataset
        # would restore per-point state that describes rows the new data
        # does not have — silently producing garbage labels. Fingerprints
        # are JSON-safe dicts, so old checkpoints (no "data" key) skip
        # the check rather than break.
        saved_fp = extra.get("data")
        fp = getattr(run, "data_fingerprint", None)
        if saved_fp is not None and fp is not None and saved_fp != fp:
            diff = sorted(k for k in set(saved_fp) | set(fp)
                          if saved_fp.get(k) != fp.get(k))
            raise ValueError(
                f"checkpoint step {step} was written for a different "
                f"dataset (fingerprint differs on {diff}: checkpoint "
                f"{saved_fp} vs this fit {fp}); resuming would silently "
                f"mislabel the new data — refusing")
        state = run.restore(rstore, step, emeta)
        telemetry = [Telemetry.from_dict(r) for r in extra["telemetry"]]
        t_work = float(loop["t_work"])
        quiet_rounds = int(loop["quiet_rounds"])
        converged = bool(loop.get("converged", False))
        start_round = int(loop["rounds_done"])
        # b is stored in GLOBAL rows; ceil-divide onto this engine's
        # shard count so every previously-seen point stays inside the
        # prefix when the shard count changed across the restore.
        b = max(1, min(-(-int(loop["b_global"]) // run.n_shards),
                       run.b_max))
        cap = loop.get("capacity")
        capacity = (int(cap) if cap is not None
                    and int(emeta.get("n_shards", 0)) == run.n_shards
                    else None)
        run.barrier()

    def record(info: RoundInfo) -> None:
        rec = Telemetry(
            round=len(telemetry), t=t_work, b=int(info.n_active),
            batch_mse=float(info.batch_mse),
            n_changed=int(info.n_changed),
            n_recomputed=int(info.n_recomputed),
            grow=bool(info.grow), r_median=float(info.r_median),
            val_mse=(run.eval_mse(state)
                     if len(telemetry) % config.eval_every == 0 else None))
        telemetry.append(rec)
        if on_round:
            on_round(rec)

    def save_checkpoint() -> None:
        # capture is a collective (it gathers sharded leaves); every
        # process runs it, only the coordinator touches the disk
        tree, emeta = run.capture(state)
        extra = {
            "config": config.to_dict(),
            "data": run.data_fingerprint,
            "engine": emeta,
            "loop": {"rounds_done": len(telemetry),
                     "b_global": b * run.n_shards, "capacity": capacity,
                     "quiet_rounds": quiet_rounds, "t_work": t_work,
                     "converged": converged},
            "telemetry": [r.to_dict() for r in telemetry],
        }
        if run.is_coordinator:
            store.save(len(telemetry), tree, extra=extra,
                       background=ckpt.background)
        run.barrier()

    for _ in range(start_round, config.max_rounds):
        if converged:        # resumed an already-finished fit
            break
        if timed:
            # the wall clock is the one host-local input to the
            # schedule: the coordinator decides, every process obeys
            if run.sync_flag(t_work >= config.time_budget_s):
                break
        t0 = time.perf_counter()

        if algorithm == "lloyd":
            new_state, info = run.lloyd_step(state)
        elif algorithm in ("mb", "mbf"):
            new_state, info = run.mb_step(state, fixed=(algorithm == "mbf"))
        else:  # tb family (incl. gb via bounds="none")
            while True:
                new_state, info = run.nested_step(state, b, capacity)
                if not bool(info.overflow):
                    break
                # overflow retry: same input state, doubled bucket —
                # exactness is never traded for speed.
                capacity = (None if capacity is None or 2 * capacity >= b
                            else 2 * capacity)

        jax.block_until_ready(new_state.stats.C)
        t_work += time.perf_counter() - t0
        state = new_state
        record(info)

        if algorithm == "tb":
            if bounds == "hamerly2":
                need = -(-int(info.n_recomputed) // run.n_shards)
                if bool(info.grow) and b < run.b_max:
                    # a doubling adds b new points that always need a
                    # full pass — start the grown bucket dense
                    capacity = None
                else:
                    capacity = cap_bucket(need, b, config.capacity_floor)
            if bool(info.grow):
                b = min(2 * b, run.b_max)
            # p_max rides along in the psum-consistent RoundInfo — no
            # extra device->host sync outside the timed region
            if (int(info.n_active) >= run.n_active_target
                    and int(info.n_changed) == 0
                    and float(info.p_max) == 0.0):
                quiet_rounds += 1
            else:
                quiet_rounds = 0
            if trace is not None:
                trace.append({"round": len(telemetry) - 1,
                              "b_global": b * run.n_shards,
                              "capacity": capacity,
                              "quiet_rounds": quiet_rounds})
            if quiet_rounds >= config.converge_patience:
                converged = True
                break
        elif algorithm == "lloyd":
            if int(info.n_changed) == 0:
                converged = True
                break

        if store is not None and len(telemetry) % ckpt.save_every == 0:
            save_checkpoint()

    if store is not None:
        # one final save so a resumed-after-finish fit is a no-op loop
        save_checkpoint()
        if run.is_coordinator:
            store.wait()
        run.barrier()

    # final validation point (outside the timed region, like every eval),
    # unless the last in-loop round already evaluated validation — a
    # second eval at the same t would double-count it in the telemetry
    if telemetry and telemetry[-1].val_mse is not None:
        final = None
    else:
        final = run.eval_mse(state)
    if final is not None:
        # b is per-shard; b * n_shards includes the structural pad rows
        # on a non-divisible mesh, so cap at the real dataset size
        telemetry.append(Telemetry(
            round=len(telemetry), t=t_work,
            b=min(b * run.n_shards, run.n_points),
            batch_mse=None, n_changed=0, n_recomputed=0, grow=False,
            r_median=None, val_mse=final))

    # un-shuffle the final assignments back to the caller's row order;
    # host_points is a gather collective on multi-process runs
    a = np.asarray(run.host_points(state))
    labels = np.full(run.n_points, -1, np.int32)
    valid = run.orig_index >= 0
    labels[run.orig_index[valid]] = a[valid]

    stats = run.fetch_stats(state)
    return FitOutcome(C=np.asarray(stats.C), state=state,
                      labels=labels, telemetry=telemetry,
                      converged=converged, algorithm=algorithm,
                      config=config)
