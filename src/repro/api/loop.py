"""THE host control loop, shared by every engine — and every process.

One `run_loop` drives the growth schedule, power-of-two capacity
bucketing, overflow retry, convergence patience, telemetry and in-loop
checkpointing for all backends (local / mesh / xl / multihost).

## The process-replicated control-flow invariant

On a multi-process (jax.distributed) run EVERY process executes this
loop over its own copy of the host state. There is no leader election
and no per-round consensus protocol; instead the loop is written so its
control flow is bit-identical on every process BY CONSTRUCTION:

  * every per-round decision — batch growth (`info.grow`), capacity
    sizing (`info.n_recomputed`), overflow retry (`info.overflow`),
    convergence patience (`info.n_changed` / `info.p_max` /
    `info.n_active`) — branches ONLY on scalars out of `RoundInfo`,
    which the round functions psum-reduce across every data shard
    before returning. A replicated device scalar fetched on two
    processes yields the same bits, so both take the same branch.
  * the data placement, initial centroids and the mini-batch resampling
    stream are all seeded deterministically from the resolved
    `FitConfig` (`config.seed`), never from ambient host entropy, so
    every process holds the same global shuffle and the same schedule
    inputs at round 0.
  * the ONE intrinsically host-local quantity — the wall clock behind
    `time_budget_s` — is resolved by the coordinator and broadcast
    through `run.sync_flag` before anyone acts on it (clocks drift;
    replicated flags do not). With the default infinite budget the
    hook is never consulted.
  * filesystem facts (which checkpoint step is latest, its metadata)
    go through `run.resolve_resume`, which multi-process runs answer
    on the coordinator and broadcast.

Checkpoint writes are coordinator-only (`run.is_coordinator`), with a
`run.barrier()` after every save/clear so no process races ahead of a
directory state it may later restore from. `run.capture` / `restore`
are collectives — every process participates in the gathers and
broadcasts even though only one touches the disk.

The RoundInfo scalars land on the host at ONE site — `fetch_round_info`,
called once per round (once per overflow attempt) — and every branch
below it reads the resulting plain-Python `HostRoundInfo`. That makes
the invariant mechanically checkable: `repro.analysis` lints this module
for branches that do not derive from `HostRoundInfo` / the resolved
config / the sanctioned `run` primitives (`python -m repro.analysis
lint`), and audits a live fit for device->host syncs outside the
`LoopAudit` sanctioned scopes (`python -m repro.analysis hostsync`).
Both run in CI via scripts/ci_static.sh.

Anything appended to this loop must preserve the invariant: derive new
decisions from `RoundInfo` (extend it if needed — it is psum-reduced in
one place per engine, and lands via `fetch_round_info`), or route them
through a `run` hook that guarantees replication — and keep the
checkers green rather than allowlisting around them.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple, Union

import jax
import numpy as np

from repro.api.config import FitConfig
from repro.api.engines.base import EngineRun
from repro.api.telemetry import RoundCallback, Telemetry, final_val_mse
from repro.checkpoint.store import CheckpointStore
from repro.core.state import KMeansState, RoundInfo


# --------------------------------------------------------------------------
# the ONE steady-state device->host crossing
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HostRoundInfo:
    """`RoundInfo` landed on the host: plain Python scalars.

    Every per-round decision in `run_loop` branches on THIS object (or
    on the resolved config / engine statics) — never on a live device
    value. The fields are psum-reduced before they leave the round, so
    the same bits land on every process (see the module docstring).
    """
    batch_mse: float
    n_changed: int
    n_recomputed: int
    n_active: int
    overflow: bool
    grow: bool
    r_median: float
    p_max: float


def fetch_round_info(info: RoundInfo) -> HostRoundInfo:
    """Land the round's psum-reduced scalars on the host in ONE transfer.

    This is the single sanctioned device->host read of the steady-state
    loop: everything the schedule branches on crosses here, together,
    once per round. Scattering `float(info.x)` reads through the loop
    body would work too — but then nothing distinguishes a sanctioned
    sync from an accidental one, and the host-sync auditor
    (`repro.analysis.hostsync`) could not scope its guard. Keep new
    device reads OUT of the loop body: extend `RoundInfo` instead and
    read the field off the result of this function.
    """
    host = jax.device_get(info)
    return HostRoundInfo(
        batch_mse=float(host.batch_mse), n_changed=int(host.n_changed),
        n_recomputed=int(host.n_recomputed), n_active=int(host.n_active),
        overflow=bool(host.overflow), grow=bool(host.grow),
        r_median=float(host.r_median), p_max=float(host.p_max))


class LoopAudit:
    """Instrumentation seam for `repro.analysis.hostsync`.

    `run_loop` brackets every round body with ``round_scope()`` and each
    sanctioned device<->host crossing inside it with
    ``sanctioned_scope(what)``, where ``what`` is one of:

      * ``"round_info"`` — the `fetch_round_info` scalar landing;
      * ``"eval_mse"``   — validation eval at the configured cadence;
      * ``"sync_flag"``  — the coordinator's wall-clock broadcast;
      * ``"checkpoint"`` — `run.capture` gathers + store writes.

    The default scopes are no-ops, so production fits pay nothing. The
    host-sync auditor subclasses this to disallow transfers inside the
    round scope and re-allow them inside the sanctioned scopes — any
    OTHER device->host sync in the steady-state loop becomes a
    diagnosable violation instead of a silent stall-per-round.
    """

    def round_scope(self):
        return contextlib.nullcontext()

    def sanctioned_scope(self, what: str):
        return contextlib.nullcontext()


_NULL_AUDIT = LoopAudit()


class ObsSink:
    """Observability seam for `repro.obs` — sibling of `LoopAudit`.

    `run_loop` hands every completed round's HOST-landed scalars (the
    `HostRoundInfo`, the schedule's b/capacity/patience values, the
    work-clock delta, the data-store read counters) to ``round_end``,
    brackets eval/checkpoint (and, via `EngineRun.bind_obs`, store
    ingest) with ``span``, and notes overflow retries with ``count``.

    The base class is a no-op, so untraced fits pay a few method calls
    per ROUND — nothing per point, and nothing on a device. The real
    implementation is `repro.obs.FitObserver` (structured JSONL traces,
    a metrics registry, the roofline utilization gauge), which this
    seam deliberately does not import: observers consume only values
    that already crossed at a sanctioned point, so instrumentation can
    never add a device->host sync — the hostsync auditor runs with
    tracing ON to prove it.
    """

    def span(self, name: str, **attrs):
        return contextlib.nullcontext()

    def count(self, name: str, n: int = 1) -> None:
        pass

    def round_end(self, round: int, hinfo: "HostRoundInfo",
                  **attrs) -> None:
        pass

    def fit_end(self, **summary) -> None:
        pass

    def close(self) -> None:
        pass


_NULL_OBS = ObsSink()


# --------------------------------------------------------------------------
# result record
# --------------------------------------------------------------------------

@dataclasses.dataclass
class FitOutcome:
    """What a fit produces: centroids + full state + structured telemetry.

    ``labels`` is in the CALLER's row order (the engines shuffle and, on
    a mesh, interleave/pad internally; the inverse mapping is applied
    here). ``-1`` marks rows the nested batch never reached.
    """
    C: np.ndarray
    state: KMeansState
    labels: np.ndarray
    telemetry: List[Telemetry]
    converged: bool
    algorithm: str
    config: FitConfig
    #: the engine's resolved `KernelPlan` as a JSON-safe dict (backend,
    #: block sizes, bucket, tuner provenance); benchmark manifests
    #: record it so "which kernels actually ran" is never a null again
    kernel_plan: Optional[Dict[str, Any]] = None

    @property
    def final_mse(self) -> float:
        return final_val_mse(self.telemetry)


# --------------------------------------------------------------------------
# capacity policy (shared)
# --------------------------------------------------------------------------

def next_pow2(x: int) -> int:
    return 1 << max(0, int(x - 1).bit_length())


def cap_bucket(need: int, b: int, floor: int) -> Optional[int]:
    """Power-of-two capacity with 2x slack; None == recompute everything."""
    cap = max(floor, next_pow2(2 * max(need, 1)))
    return None if cap >= b else cap


# --------------------------------------------------------------------------
# THE shared host loop
# --------------------------------------------------------------------------

def run_loop(run: EngineRun, config: FitConfig, *,
             on_round: Optional[RoundCallback] = None,
             resume_from: Optional[Union[str, Path, CheckpointStore]] = None,
             resolved_resume: Optional[Tuple[int, Dict[str, Any]]] = None,
             trace: Optional[List[Dict[str, Any]]] = None,
             audit: Optional[LoopAudit] = None,
             obs: Optional[ObsSink] = None
             ) -> FitOutcome:
    """Growth schedule + capacity bucketing + overflow retry + patience.

    ``config`` must already be `resolve()`d (no alias algorithms). The
    loop is backend-agnostic AND process-agnostic: see the module
    docstring for the replication invariant that makes the same code
    drive one device, a host mesh, or a multi-process pod.

    When ``config.checkpoint`` is set, the FULL loop state — engine
    state, batch size, capacity bucket, patience counter, work clock and
    telemetry — is saved atomically every ``save_every`` rounds (plus
    once at loop exit) alongside the ``config.to_dict()`` manifest.
    ``resume_from`` (a directory or `CheckpointStore`) restores the
    latest such checkpoint through the engine's canonical layout, so a
    killed fit continues bit-identically — and a fit checkpointed on
    one shard count (or process count) resumes on another (elastic
    restart). ``resolved_resume``: the ``(step, extra)`` pair a caller
    already obtained from ``run.resolve_resume`` on the same store
    (the estimator validates the manifest first); passing it avoids a
    second read — and on multihost a second cluster-wide broadcast —
    of the same payload.

    ``trace``: optional list; one dict per completed round —
    ``{"round", "b_global", "capacity", "quiet_rounds"}`` — is appended
    AFTER the round's schedule updates. This is the loop's control-flow
    fingerprint: two processes of the same multihost fit must produce
    identical traces (scripts/smoke_multihost.py asserts exactly that).

    ``audit``: optional `LoopAudit` whose scopes bracket each round body
    and its sanctioned device<->host crossings (the host-sync auditor's
    hook). ``None`` uses the no-op scopes.

    ``obs``: optional `ObsSink` receiving each round's host-landed
    scalars, span timings (eval / checkpoint / store ingest) and
    overflow-retry counts — usually a `repro.obs.FitObserver`. ``None``
    uses the no-op sink. The loop does NOT close the sink; its creator
    does (the estimator closes the observer it built from
    ``config.trace_dir``).
    """
    audit = audit if audit is not None else _NULL_AUDIT
    obs = obs if obs is not None else _NULL_OBS
    algorithm = config.algorithm
    bounds = config.bounds
    state = run.state
    b = run.b
    capacity: Optional[int] = None
    telemetry: List[Telemetry] = []
    t_work = 0.0
    quiet_rounds = 0
    converged = False
    start_round = 0
    timed = math.isfinite(config.time_budget_s)
    run.bind_obs(obs)

    ckpt = config.checkpoint
    store = (CheckpointStore(ckpt.checkpoint_dir, keep=ckpt.keep)
             if ckpt is not None else None)

    if store is not None and resume_from is None:
        # a FRESH checkpointed fit supersedes whatever run lives in the
        # directory: left in place, the old (higher-numbered) steps
        # would garbage-collect this run's early saves on arrival and a
        # later resume would silently restore the stale fit
        if run.is_coordinator and store.latest_step() is not None:
            store.clear()
        run.barrier()

    if resume_from is not None:
        rstore = (resume_from if isinstance(resume_from, CheckpointStore)
                  else CheckpointStore(resume_from,
                                       keep=ckpt.keep if ckpt else 3))
        step, extra = (resolved_resume if resolved_resume is not None
                       else run.resolve_resume(rstore))
        if step is None:
            raise FileNotFoundError(
                f"resume_from={resume_from!r} holds no checkpoints")
        if not extra or "loop" not in extra:
            raise ValueError(
                f"checkpoint step {step} has no loop metadata; it was "
                f"not written by run_loop")
        emeta, loop = extra["engine"], extra["loop"]
        # dataset identity gate: a resume against a DIFFERENT dataset
        # would restore per-point state that describes rows the new data
        # does not have — silently producing garbage labels. Fingerprints
        # are JSON-safe dicts, so old checkpoints (no "data" key) skip
        # the check rather than break.
        saved_fp = extra.get("data")
        fp = getattr(run, "data_fingerprint", None)
        if saved_fp is not None and fp is not None and saved_fp != fp:
            diff = sorted(k for k in set(saved_fp) | set(fp)
                          if saved_fp.get(k) != fp.get(k))
            raise ValueError(
                f"checkpoint step {step} was written for a different "
                f"dataset (fingerprint differs on {diff}: checkpoint "
                f"{saved_fp} vs this fit {fp}); resuming would silently "
                f"mislabel the new data — refusing")
        state = run.restore(rstore, step, emeta)
        telemetry = [Telemetry.from_dict(r) for r in extra["telemetry"]]
        t_work = float(loop["t_work"])
        quiet_rounds = int(loop["quiet_rounds"])
        converged = bool(loop.get("converged", False))
        start_round = int(loop["rounds_done"])
        # b is stored in GLOBAL rows; ceil-divide onto this engine's
        # shard count so every previously-seen point stays inside the
        # prefix when the shard count changed across the restore.
        b = max(1, min(-(-int(loop["b_global"]) // run.n_shards),
                       run.b_max))
        cap = loop.get("capacity")
        capacity = (int(cap) if cap is not None
                    and int(emeta.get("n_shards", 0)) == run.n_shards
                    else None)
        run.barrier()

    def record(hinfo: HostRoundInfo, dt_s: float) -> None:
        val_mse = None
        if len(telemetry) % config.eval_every == 0:
            # validation eval is a sanctioned device->host read (it is
            # outside the paper's timed region, like every eval)
            with audit.sanctioned_scope("eval_mse"), obs.span("eval_mse"):
                val_mse = run.eval_mse(state)
        rec = Telemetry.from_round(hinfo, round=len(telemetry), t=t_work,
                                   val_mse=val_mse)
        telemetry.append(rec)
        # the obs sink sees only already-host-landed values: hinfo, the
        # schedule's own plain-Python scalars, and the engine's host-side
        # store counters — nothing here can add a device->host sync.
        # b/capacity are PRE-update: the values THIS round actually used.
        obs.round_end(rec.round, hinfo, dt_s=dt_s, t_work=t_work,
                      b_global=min(b * run.n_shards, run.n_points),
                      capacity=capacity, quiet_rounds=quiet_rounds,
                      algorithm=algorithm, val_mse=val_mse,
                      store=run.store_metrics())
        if on_round:
            on_round(rec)

    def save_checkpoint() -> None:
        # capture is a collective (it gathers sharded leaves); every
        # process runs it, only the coordinator touches the disk
        tree, emeta = run.capture(state)
        extra = {
            "config": config.to_dict(),
            "data": run.data_fingerprint,
            "engine": emeta,
            "loop": {"rounds_done": len(telemetry),
                     "b_global": b * run.n_shards, "capacity": capacity,
                     "quiet_rounds": quiet_rounds, "t_work": t_work,
                     "converged": converged},
            "telemetry": [r.to_dict() for r in telemetry],
        }
        if run.is_coordinator:
            store.save(len(telemetry), tree, extra=extra,
                       background=ckpt.background)
        run.barrier()

    for _ in range(start_round, config.max_rounds):
        if converged:        # resumed an already-finished fit
            break
        with audit.round_scope():
            if timed:
                # the wall clock is the one host-local input to the
                # schedule: the coordinator decides, every process obeys
                with audit.sanctioned_scope("sync_flag"):
                    out_of_time = run.sync_flag(
                        t_work >= config.time_budget_s)
                if out_of_time:
                    break
            t0 = time.perf_counter()

            if algorithm == "lloyd":
                new_state, info = run.lloyd_step(state)
            elif algorithm in ("mb", "mbf"):
                new_state, info = run.mb_step(
                    state, fixed=(algorithm == "mbf"))
            else:  # tb family (incl. gb via bounds="none")
                while True:
                    new_state, info = run.nested_step(state, b, capacity)
                    jax.block_until_ready(new_state.stats.C)
                    with audit.sanctioned_scope("round_info"):
                        hinfo = fetch_round_info(info)
                    if not hinfo.overflow:
                        break
                    # overflow retry: same input state, doubled bucket —
                    # exactness is never traded for speed.
                    obs.count("overflow_retry")
                    capacity = (None
                                if capacity is None or 2 * capacity >= b
                                else 2 * capacity)

            if algorithm in ("lloyd", "mb", "mbf"):
                jax.block_until_ready(new_state.stats.C)
                with audit.sanctioned_scope("round_info"):
                    hinfo = fetch_round_info(info)
            dt_s = time.perf_counter() - t0
            t_work += dt_s
            state = new_state
            record(hinfo, dt_s)

            if algorithm == "tb":
                if bounds == "hamerly2":
                    need = -(-hinfo.n_recomputed // run.n_shards)
                    if hinfo.grow and b < run.b_max:
                        # a doubling adds b new points that always need
                        # a full pass — start the grown bucket dense
                        capacity = None
                    else:
                        capacity = cap_bucket(need, b,
                                              config.capacity_floor)
                if hinfo.grow:
                    b = min(2 * b, run.b_max)
                # p_max rides along in the psum-consistent RoundInfo —
                # no extra device->host sync outside the timed region
                if (hinfo.n_active >= run.n_active_target
                        and hinfo.n_changed == 0
                        and hinfo.p_max == 0.0):
                    quiet_rounds += 1
                else:
                    quiet_rounds = 0
                if trace is not None:
                    trace.append({"round": len(telemetry) - 1,
                                  "b_global": b * run.n_shards,
                                  "capacity": capacity,
                                  "quiet_rounds": quiet_rounds})
                if quiet_rounds >= config.converge_patience:
                    converged = True
                    break
            elif algorithm == "lloyd":
                if hinfo.n_changed == 0:
                    converged = True
                    break

            if store is not None and len(telemetry) % ckpt.save_every == 0:
                # capture's gathers + the coordinator's disk write are
                # sanctioned crossings (bracketed by run.barrier)
                with audit.sanctioned_scope("checkpoint"), \
                        obs.span("checkpoint"):
                    save_checkpoint()

    if store is not None:
        # one final save so a resumed-after-finish fit is a no-op loop
        with obs.span("checkpoint"):
            save_checkpoint()
            if run.is_coordinator:
                store.wait()
        run.barrier()

    # final validation point (outside the timed region, like every eval),
    # unless the last in-loop round already evaluated validation — a
    # second eval at the same t would double-count it in the telemetry
    if telemetry and telemetry[-1].val_mse is not None:
        final = None
    else:
        final = run.eval_mse(state)
    if final is not None:
        # b is per-shard; b * n_shards includes the structural pad rows
        # on a non-divisible mesh, so cap at the real dataset size
        telemetry.append(Telemetry(
            round=len(telemetry), t=t_work,
            b=min(b * run.n_shards, run.n_points),
            batch_mse=None, n_changed=0, n_recomputed=0, grow=False,
            r_median=None, val_mse=final))

    obs.fit_end(rounds=len(telemetry), t_work=t_work, converged=converged)

    # un-shuffle the final assignments back to the caller's row order;
    # host_points is a gather collective on multi-process runs
    a = np.asarray(run.host_points(state))
    labels = np.full(run.n_points, -1, np.int32)
    valid = run.orig_index >= 0
    labels[run.orig_index[valid]] = a[valid]

    stats = run.fetch_stats(state)
    plan = getattr(run, "kernel_plan", None)
    return FitOutcome(C=np.asarray(stats.C), state=state,
                      labels=labels, telemetry=telemetry,
                      converged=converged, algorithm=algorithm,
                      config=config,
                      kernel_plan=plan.to_dict() if plan else None)
