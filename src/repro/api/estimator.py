"""`NestedKMeans`: the sklearn-style front door to every engine.

    from repro.api import FitConfig, NestedKMeans

    km = NestedKMeans(FitConfig(k=50, algorithm="tb", b0=2000))
    km.fit(X_train, X_val=X_val)
    labels = km.predict(X_new)

`partial_fit` is the serving-path primitive: it folds a fresh batch into
the running S/v statistics with ONE nested round (new points enter with
``a == -1`` exactly like a batch doubling), so a stream of batches keeps
refining the codebook without re-touching old data.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Dict, List, Optional

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import FitConfig
from repro.api.engines import Engine, make_engine, nested_jit
from repro.api.loop import FitOutcome, fetch_round_info, run_loop
from repro.api.telemetry import RoundCallback, Telemetry
from repro.checkpoint.store import CheckpointStore
from repro.core.state import full_mse, init_state
from repro.kernels import ops

# config fields that must agree between a checkpoint manifest and the
# resuming config for the restored state to be meaningful (max_rounds /
# budgets / backend / shard layout may all change across a restart)
_RESUME_KEYS = ("k", "algorithm", "rho", "b0", "bounds", "seed",
                "use_shalf", "shuffle")


class NotFittedError(RuntimeError):
    pass


class NestedKMeans:
    """Estimator over a `FitConfig` and an execution `Engine`.

    After `fit` / `partial_fit`:
      cluster_centers_   (k, d) float32 ndarray
      labels_            (n,) assignments of the fitted data (fit only)
      inertia_           batch MSE at the last round (fit only)
      telemetry_         List[Telemetry], one per host round
      converged_         bool
      n_rounds_          len(telemetry_)

    Thread-safety: `fit` / `partial_fit` / `adopt` serialise on an
    internal lock, so a background refresher may stream batches while
    other threads call `predict` / `transform` — the readers never take
    the lock (they read `_stats` once; the whole stats pytree is swapped
    atomically, never mutated in place). `export_codebook` snapshots the
    codebook under the same lock for `repro.serve`.
    """

    def __init__(self, config: FitConfig, *, engine: Optional[Engine] = None,
                 mesh=None, on_round: Optional[RoundCallback] = None):
        self.config = config
        self.engine = engine or make_engine(config, mesh=mesh)
        self.on_round = on_round
        self.telemetry_: List[Telemetry] = []
        self._outcome: Optional[FitOutcome] = None
        self._stats = None          # streaming ClusterStats (partial_fit)
        self._outcome_stale = False  # partial_fit moved the centroids
        # serialises the WRITERS (fit/partial_fit/adopt); readers are
        # lock-free — they load self._stats once and work on that pytree
        self._lock = threading.RLock()

    # -- fitting ------------------------------------------------------------

    def fit(self, X=None, *, X_val=None,
            init_C: Optional[np.ndarray] = None,
            resume: bool = False) -> "NestedKMeans":
        """Run the configured algorithm to convergence / budget.

        ``X`` may be an in-memory array, an on-disk chunk-store path (or
        open `ChunkStore`) for an out-of-core fit, or omitted entirely
        when ``config.data_source`` names the store. Store-backed fits
        stream the nested prefix from disk and are bit-identical to the
        in-memory fit over the same row sequence (nested family only —
        mb/lloyd rescan the full dataset every round).

        ``resume=True`` (requires ``config.checkpoint``) restores the
        latest in-loop checkpoint from ``checkpoint_dir`` and continues
        the fit from there — bit-identically on the same engine, and
        elastically across a shard-count (or local<->mesh) change. With
        no checkpoint on disk yet the fit simply starts fresh. Resuming
        against a different dataset than the checkpoint's is a loud
        error (the manifest carries a dataset fingerprint).
        """
        from pathlib import Path
        from repro.data.store import ChunkStore
        with self._lock:
            if X is None:
                if self.config.data_source is None:
                    raise ValueError(
                        "fit() needs data: pass X (array or store "
                        "path), or set config.data_source")
                X = self.config.data_source
            if isinstance(X, (str, Path)):
                X = ChunkStore(X)
            if isinstance(X, ChunkStore):
                n = X.n
            else:
                n = int(np.asarray(X).shape[0])
            cfg = self.config.resolve(n)
            if isinstance(X, ChunkStore) and cfg.algorithm not in (
                    "tb", "gb"):
                raise ValueError(
                    f"out-of-core fits stream the nested prefix; "
                    f"algorithm={self.config.algorithm!r} needs the "
                    f"full dataset in memory every round (pass X as an "
                    f"array)")
            if resume and cfg.checkpoint is None:
                raise ValueError(
                    "fit(resume=True) requires config.checkpoint")
            run = self.engine.begin(X, cfg, X_val=X_val, init_C=init_C)
            obs = None
            if cfg.trace_dir is not None:
                # built lazily so untraced fits never import repro.obs;
                # process_id keys the per-process JSONL files on
                # multihost (every process traces its own host loop)
                from repro.obs import FitObserver
                obs = FitObserver(
                    cfg.trace_dir, process_id=jax.process_index(),
                    k=cfg.k, d=int(run.state.stats.C.shape[-1]),
                    bounds=cfg.bounds,
                    meta={"backend": cfg.backend,
                          "algorithm": cfg.algorithm,
                          "bounds": cfg.bounds,
                          "n_points": run.n_points,
                          "n_shards": run.n_shards, "seed": cfg.seed})
            resume_from = None
            resolved = None
            if resume:
                store = CheckpointStore(cfg.checkpoint.checkpoint_dir,
                                        keep=cfg.checkpoint.keep)
                # the resume decision goes through the run so it is
                # process-replicated: on multihost the coordinator's
                # filesystem is the source of truth and its verdict is
                # broadcast — no process can start fresh while another
                # restores
                step, extra = run.resolve_resume(store)
                if step is not None:
                    saved = (extra or {}).get("config")
                    if saved:
                        want = cfg.to_dict()
                        bad = [k for k in _RESUME_KEYS
                               if k in saved and saved[k] != want[k]]
                        if bad:
                            raise ValueError(
                                f"checkpoint manifest disagrees with the "
                                f"resuming config on {bad}; refusing to "
                                f"restore a foreign fit")
                    resume_from = store
                    resolved = (step, extra)
            try:
                out = run_loop(run, cfg, on_round=self.on_round,
                               resume_from=resume_from,
                               resolved_resume=resolved, obs=obs)
            finally:
                if obs is not None:
                    obs.close()
            self._outcome = out
            # fetch_stats: the state's own leaves on single-process
            # engines; a host gather on multihost (so predict/export
            # never touch non-addressable shards)
            self._stats = run.fetch_stats(out.state)
            self._outcome_stale = False
            # copy: later partial_fit records must not mutate the
            # outcome's own telemetry history
            self.telemetry_ = list(out.telemetry)
            return self

    def partial_fit(self, X) -> "NestedKMeans":
        """Fold one streaming batch into the codebook (one nested round).

        The incoming points enter unseen (``a == -1``): the round assigns
        them, adds them to S/v, and moves the centroids to the updated
        means — the exact update a batch doubling applies to new points
        inside `fit`. Repeated calls keep absorbing traffic at O(batch)
        cost per call.

        Runs on ANY backend: the local engine streams through one jitted
        round; the sharded engines (mesh/xl/multihost) place the batch
        with their usual layout and run one full-prefix sharded round,
        carrying the running statistics in via `EngineRun.place_stats`.
        Each distinct batch shape compiles one executable per backend —
        stream fixed-size micro-batches (as `repro.serve.ClusterService`
        does) to stay on one.
        """
        with self._lock:
            X = np.asarray(X)
            cfg = self.config.resolve(int(X.shape[0]))
            if self._stats is None and X.shape[0] < cfg.k:
                raise ValueError(
                    f"first partial_fit batch must have >= k={cfg.k} "
                    f"rows (repro.serve.IngestQueue accumulates sub-k "
                    f"contributions into a big-enough first batch)")
            t_prev = self.telemetry_[-1].t if self.telemetry_ else 0.0
            t0 = time.perf_counter()
            if cfg.backend == "local":
                Xd = jnp.asarray(X)
                state = init_state(Xd, cfg.k, bounds=cfg.bounds)
                if self._stats is not None:
                    # carry the running statistics; bounds state restarts
                    # per batch (new points have no history to bound
                    # against)
                    state = dataclasses.replace(
                        state, stats=jax.tree.map(jnp.asarray,
                                                  self._stats))
                from repro.kernels.plan import resolve_plan
                plan = resolve_plan(cfg.kernel_backend,
                                    b=int(X.shape[0]), k=cfg.k,
                                    d=int(X.shape[1]), bounds=cfg.bounds)
                new_state, info = nested_jit(
                    Xd, state, b=int(X.shape[0]), rho=cfg.rho,
                    bounds=cfg.bounds, capacity=None,
                    use_shalf=cfg.use_shalf, plan=plan)
                jax.block_until_ready(new_state.stats.C)
                new_stats = new_state.stats
            else:
                # sharded streaming: place the batch like a fit would
                # (shuffle + interleave + structural pads are harmless —
                # the S/v delta is order-independent and pads are masked
                # out by n_valid), then run ONE full-prefix round
                run = self.engine.begin(
                    X, cfg, init_C=(np.asarray(self._stats.C)
                                    if self._stats is not None else None))
                state = run.state
                if self._stats is not None:
                    state = run.place_stats(state, self._stats)
                new_state, info = run.nested_step(state, run.b_max, None)
                jax.block_until_ready(new_state.stats.C)
                new_stats = run.fetch_stats(new_state)
            self._stats = new_stats
            if self._outcome is not None:
                # the centroids have moved past the fit's outcome: its
                # labels/state no longer describe this estimator
                self._outcome_stale = True
            # one transfer + the shared record builder — the same path
            # run_loop takes, so the two telemetry streams cannot drift
            hinfo = fetch_round_info(info)
            rec = Telemetry.from_round(
                hinfo, round=len(self.telemetry_),
                t=t_prev + time.perf_counter() - t0)
            self.telemetry_.append(rec)
            if self.on_round:
                self.on_round(rec)
            return self

    def adopt(self, outcome: FitOutcome) -> "NestedKMeans":
        """Rehydrate this estimator from a previously produced outcome.

        Lets a serving process rebuild an estimator from a `FitOutcome`
        computed elsewhere (e.g. by `repro.api.fit` in a training job)
        and keep streaming into it with `partial_fit`.
        """
        if outcome.config.k != self.config.k:
            raise ValueError(
                f"cannot adopt an outcome fitted with "
                f"k={outcome.config.k} into an estimator configured "
                f"for k={self.config.k}")
        with self._lock:
            self._outcome = outcome
            self._stats = outcome.state.stats
            self._outcome_stale = False
            self.telemetry_ = list(outcome.telemetry)
            return self

    def export_codebook(self) -> Dict[str, Any]:
        """Atomic host-side copy of the codebook, for snapshot publishers.

        Returns ``{"centroids", "counts", "n_rounds", "batch_mse"}``
        captured under the writer lock, so a concurrent `partial_fit`
        can never be observed half-applied. The arrays are fresh numpy
        copies owned by the caller.
        """
        with self._lock:
            self._require_fitted()
            return {
                "centroids": np.array(self._stats.C, dtype=np.float32,
                                      copy=True),
                "counts": np.array(self._stats.v, dtype=np.float32,
                                   copy=True),
                "n_rounds": len(self.telemetry_),
                "batch_mse": self.inertia_,
            }

    # -- fitted attributes --------------------------------------------------

    def _require_fitted(self):
        if self._stats is None:
            raise NotFittedError("call fit() or partial_fit() first")

    @property
    def cluster_centers_(self) -> np.ndarray:
        self._require_fitted()
        return np.asarray(self._stats.C)

    @property
    def counts_(self) -> np.ndarray:
        """Per-cluster membership counts v (codebook occupancy)."""
        self._require_fitted()
        return np.asarray(self._stats.v)

    @property
    def stats_(self):
        """The running `ClusterStats` (C/S/v/sse/p) — host-reachable on
        every backend (fit/partial_fit store them through the engine's
        `fetch_stats`, so even a multi-process fit's stats can be read,
        adopted or re-placed from any one process)."""
        self._require_fitted()
        return self._stats

    def _require_fresh_outcome(self, what: str):
        if self._outcome is None:
            raise NotFittedError(f"{what} requires a full fit()")
        if self._outcome_stale:
            raise NotFittedError(
                f"{what} is stale: partial_fit() has moved the centroids "
                f"since fit(); use predict(X) for fresh assignments or "
                f"refit")

    @property
    def labels_(self) -> np.ndarray:
        """Assignments of the fitted data, in the caller's row order
        (-1 = row never entered the nested batch). Raises
        `NotFittedError` once `partial_fit` has moved the centroids past
        the fit that produced them."""
        self._require_fitted()
        self._require_fresh_outcome("labels_")
        return self._outcome.labels

    @property
    def inertia_(self) -> float:
        self._require_fitted()
        for rec in reversed(self.telemetry_):
            if rec.batch_mse is not None:
                return rec.batch_mse
        return float("nan")

    @property
    def converged_(self) -> bool:
        return self._outcome.converged if self._outcome else False

    @property
    def n_rounds_(self) -> int:
        return len(self.telemetry_)

    @property
    def outcome_(self) -> FitOutcome:
        """The `FitOutcome` of the last fit(). Raises `NotFittedError`
        once `partial_fit` has moved the centroids past it."""
        self._require_fitted()
        self._require_fresh_outcome("outcome_")
        return self._outcome

    @property
    def final_mse_(self) -> float:
        from repro.api.telemetry import final_val_mse
        return final_val_mse(self.telemetry_)

    # -- inference ----------------------------------------------------------

    def predict(self, X) -> np.ndarray:
        """Nearest-centroid index for each row of ``X``."""
        self._require_fitted()
        a, _, _ = ops.assign_top2(jnp.asarray(X), self._stats.C,
                                  backend=self.config.kernel_backend)
        return np.asarray(a)

    def transform(self, X) -> np.ndarray:
        """Euclidean distance of each row to every centroid: (n, k)."""
        self._require_fitted()
        from repro.kernels import ref
        d2 = ref.pairwise_dist2(jnp.asarray(X), self._stats.C)
        return np.asarray(jnp.sqrt(jnp.maximum(d2, 0.0)))

    def score(self, X) -> float:
        """Negative inertia (−sum of squared distances), sklearn-style."""
        self._require_fitted()
        X = jnp.asarray(X)
        return -float(full_mse(X, self._stats.C)) * int(X.shape[0])
