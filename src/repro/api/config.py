"""`FitConfig`: the single, validated, serialisable fit specification.

Replaces the 18-kwarg `repro.core.fit(...)` signature and the divergent
`fit_distributed(...)` kwargs bag. A config is frozen (hashable, safe to
use as a cache key for compiled-executable reuse), validates itself at
construction, and round-trips through plain dicts — the format used by
checkpoint metadata and benchmark manifests.

Non-finite floats (`rho=inf`, `time_budget_s=inf`) are encoded as the
string ``"inf"`` in `to_dict()` so manifests stay strict-JSON.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Any, Dict, Optional, Tuple

ALGORITHMS = ("lloyd", "lloyd-elkan", "mb", "sgd", "mbf", "gb", "tb")
BOUNDS = ("none", "hamerly2", "elkan", "exponion")

# elkan's per-(point, centroid) lower-bound matrix is O(n*k) f32 — fine
# for the paper-scale reference path, a silent OOM at serving-scale k.
# Warn once the matrix would cross this many bytes (64 MB per shard).
ELKAN_STATE_WARN_BYTES = 64 * 1024 * 1024


def bound_state_bytes(bounds: str, n: int, k: int) -> int:
    """Per-shard bytes of per-point bound state for ``n`` local rows.

    hamerly2/exponion keep two f32 scalars per point (`PointState.d` /
    `.lb`); elkan adds the (n, k) f32 lower-bound matrix. Recorded in
    benchmark manifests so memory-vs-work tradeoffs are auditable.
    """
    if bounds == "elkan":
        return 4 * n * (k + 2)
    if bounds in ("hamerly2", "exponion"):
        return 4 * n * 2
    return 0


BACKENDS = ("local", "mesh", "xl", "multihost")

# algorithms driven by the nested grow-batch loop (the tb/gb family)
NESTED_ALGOS = ("gb", "tb", "lloyd-elkan")

# backends whose rounds run under shard_map (points row-sharded)
SHARDED_BACKENDS = ("mesh", "xl", "multihost")


def _enc_float(x: float) -> Any:
    if math.isinf(x):
        return "inf" if x > 0 else "-inf"
    return float(x)


def _dec_float(x: Any) -> float:
    return float(x)


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    """In-loop checkpointing policy for `repro.api.loop.run_loop`.

    Attributes:
      checkpoint_dir  directory for the `CheckpointStore` (created on
                      first save).
      save_every      save the full loop state every N host rounds (a
                      final save always happens at loop exit).
      keep            keep-N garbage collection of old steps.
      background      snapshot to host RAM synchronously, write to disk
                      on a worker thread (the loop keeps dispatching).
    """
    checkpoint_dir: str
    save_every: int = 10
    keep: int = 3
    background: bool = False

    def __post_init__(self):
        if not self.checkpoint_dir:
            raise ValueError("checkpoint_dir must be a non-empty path")
        if self.save_every < 1:
            raise ValueError(f"save_every must be >= 1, got "
                             f"{self.save_every}")
        if self.keep < 1:
            raise ValueError(f"keep must be >= 1, got {self.keep}")

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "CheckpointConfig":
        unknown = set(d) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise ValueError(
                f"unknown CheckpointConfig fields: {sorted(unknown)}")
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class FitConfig:
    """Everything a fit needs besides the data and the execution engine.

    Attributes mirror the paper's knobs:
      k           number of clusters.
      algorithm   lloyd | lloyd-elkan | mb | sgd | mbf | gb | tb.
      rho         batch-growth threshold (Alg. 6); inf = gb-inf/tb-inf.
      b0          initial (global) batch size for the nested family /
                  fixed batch size for mb / mbf.
      bounds      none | hamerly2 | elkan | exponion (nested family
                  only). All bound families are EXACT — labels are
                  bit-equal to bounds="none" on every backend; they
                  differ only in how much provably-unnecessary work
                  they skip and how much state they carry:
                    none      no state, every point scans all k.
                    hamerly2  2 f32/point; failing points scan all k
                              (capacity-compacted). The default.
                    elkan     (n, k) f32 lower-bound matrix — tightest
                              per-pair pruning, but O(n*k) memory: at
                              k=1024, b=64k that is 256 MB f32 PER
                              SHARD (construction warns at k >= 512;
                              prefer exponion at large k).
                    exponion  2 f32/point (hamerly2's layout); failing
                              points scan only an annular candidate
                              set from the sorted inter-centroid
                              table — the large-k family.
      capacity_floor  smallest power-of-two recompute bucket the
                  capacity policy will compile (see driver docstring).
      max_rounds / time_budget_s   work budgets.
      eval_every  validation-MSE cadence (rounds), when X_val is given.
      use_shalf   include Hamerly's s(j)/2 test in the hamerly2 bound.
      kernel_backend  None (auto: pallas on TPU, ref elsewhere) |
                  "ref" | "pallas" — resolved once per fit into a
                  `repro.kernels.plan.KernelPlan` at `engine.begin`.
      shuffle     pre-shuffle the data (paper init = first k of shuffle).
      converge_patience  quiet full-batch rounds before declaring
                  convergence.
      seed        numpy PRNG seed for shuffle + mb resampling.
      backend     "local" (single process) | "mesh" (shard_map engine,
                  centroids replicated) | "xl" (shard_map engine with
                  the centroids additionally sharded over model_axis —
                  for k too large to replicate) | "multihost" (the mesh
                  engine across jax.distributed processes; every
                  process runs the same loop over its own rows).
      data_axes   mesh axes the points are row-sharded over
                  (mesh/xl/multihost).
      model_axis  mesh axis the centroids are sharded over (xl only);
                  k must divide by the axis size.
      data_source path of an on-disk `repro.data.store` chunk store to
                  stream the training rows from (out-of-core fits).
                  `NestedKMeans.fit()` may then be called with no X; a
                  store path or `ChunkStore` passed directly to fit()
                  takes precedence. Nested family only — mb/lloyd
                  resample or scan the full dataset each round, which
                  defeats the bounded-memory prefix streaming.
      checkpoint  optional `CheckpointConfig`: save the full loop state
                  every N rounds so the fit can be killed and resumed
                  (see `NestedKMeans.fit(resume=True)`). On multihost
                  only process 0 writes; any process count can restore.
      coordinator_address / num_processes / process_id
                  jax.distributed initialisation for backend=
                  "multihost" (set all three, with a per-process
                  process_id, or none — None means the caller already
                  initialised jax.distributed, or runs one process).
      trace_dir   directory for `repro.obs` structured traces: the
                  estimator attaches a `FitObserver` writing rotating
                  JSONL span/event logs (per-process files on
                  multihost) plus a metrics export. None (default)
                  disables tracing — the loop's obs seam is a no-op.
                  Read back with ``python -m repro.obs summarize DIR``.
    """
    k: int
    algorithm: str = "tb"
    rho: float = math.inf
    b0: int = 5000
    bounds: str = "hamerly2"
    capacity_floor: int = 1024
    max_rounds: int = 10_000
    time_budget_s: float = math.inf
    eval_every: int = 10
    use_shalf: bool = True
    kernel_backend: Optional[str] = None
    shuffle: bool = True
    converge_patience: int = 2
    seed: int = 0
    backend: str = "local"
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    data_source: Optional[str] = None
    checkpoint: Optional[CheckpointConfig] = None
    coordinator_address: Optional[str] = None
    num_processes: Optional[int] = None
    process_id: Optional[int] = None
    trace_dir: Optional[str] = None

    def __post_init__(self):
        if isinstance(self.checkpoint, dict):
            object.__setattr__(self, "checkpoint",
                               CheckpointConfig.from_dict(self.checkpoint))
        if not isinstance(self.k, int) or self.k < 1:
            raise ValueError(f"k must be a positive int, got {self.k!r}")
        if self.algorithm not in ALGORITHMS:
            raise ValueError(f"unknown algorithm {self.algorithm!r}; "
                             f"expected one of {ALGORITHMS}")
        if self.bounds not in BOUNDS:
            raise ValueError(f"unknown bounds {self.bounds!r}; "
                             f"expected one of {BOUNDS}")
        if self.bounds == "elkan" and self.k >= 512:
            # n is unknown until fit time, so gate on k alone: at this k
            # any batch >= 32k rows crosses ELKAN_STATE_WARN_BYTES.
            warnings.warn(
                f"bounds='elkan' allocates an O(n*k) f32 lower-bound "
                f"matrix — at k={self.k} that is "
                f"{4 * self.k / 1024:.0f} KB per point per shard "
                f"(k=1024, b=64k: 256 MB). For large k prefer "
                f"bounds='exponion': hamerly2-sized state with annular "
                f"candidate pruning.", ResourceWarning, stacklevel=2)
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"expected one of {BACKENDS}")
        if self.b0 < 1:
            raise ValueError(f"b0 must be >= 1, got {self.b0}")
        if self.rho <= 0:
            raise ValueError(f"rho must be > 0, got {self.rho}")
        if self.max_rounds < 1:
            raise ValueError(f"max_rounds must be >= 1, got "
                             f"{self.max_rounds}")
        if self.eval_every < 1:
            raise ValueError(f"eval_every must be >= 1, got "
                             f"{self.eval_every}")
        if self.converge_patience < 1:
            raise ValueError("converge_patience must be >= 1")
        if self.capacity_floor < 1:
            raise ValueError("capacity_floor must be >= 1")
        if self.kernel_backend not in (None, "ref", "pallas"):
            raise ValueError(f"unknown kernel_backend "
                             f"{self.kernel_backend!r}")
        if self.backend in SHARDED_BACKENDS \
                and self.algorithm not in NESTED_ALGOS:
            raise ValueError(
                f"the {self.backend} engine only runs the nested family "
                f"(gb/tb/lloyd-elkan); got algorithm={self.algorithm!r}")
        if self.data_source is not None:
            if not isinstance(self.data_source, str) or not self.data_source:
                raise ValueError(
                    f"data_source must be a non-empty store path, got "
                    f"{self.data_source!r}")
            if self.algorithm not in NESTED_ALGOS:
                raise ValueError(
                    f"data_source streams the nested prefix from disk; "
                    f"algorithm={self.algorithm!r} rescans or resamples "
                    f"the full dataset each round (pass X in memory "
                    f"instead)")
        coord = (self.coordinator_address, self.num_processes,
                 self.process_id)
        if any(c is not None for c in coord) \
                and any(c is None for c in coord):
            raise ValueError(
                "set coordinator_address, num_processes and process_id "
                "together (or none of them)")
        if self.coordinator_address is not None \
                and self.backend != "multihost":
            raise ValueError(
                f"coordinator fields only apply to backend='multihost', "
                f"got backend={self.backend!r}")
        if self.num_processes is not None and self.num_processes < 1:
            raise ValueError(f"num_processes must be >= 1, got "
                             f"{self.num_processes}")
        if self.process_id is not None and not (
                0 <= self.process_id < (self.num_processes or 1)):
            raise ValueError(
                f"process_id must be in [0, num_processes), got "
                f"{self.process_id} of {self.num_processes}")
        if self.trace_dir is not None and (
                not isinstance(self.trace_dir, str) or not self.trace_dir):
            raise ValueError(
                f"trace_dir must be a non-empty directory path or None, "
                f"got {self.trace_dir!r}")
        if not isinstance(self.data_axes, tuple):
            object.__setattr__(self, "data_axes", tuple(self.data_axes))
        if not self.model_axis or not isinstance(self.model_axis, str):
            raise ValueError(
                f"model_axis must be a non-empty mesh axis name, got "
                f"{self.model_axis!r}")
        if self.backend == "xl" and self.model_axis in self.data_axes:
            raise ValueError(
                f"model_axis {self.model_axis!r} cannot also be a data "
                f"axis {self.data_axes!r}")

    # -- canonicalisation ---------------------------------------------------

    def resolve(self, n: int) -> "FitConfig":
        """Fold the paper's algorithm aliases into their canonical forms.

        sgd == mb with b=1; lloyd-elkan == tb at b0=N with elkan bounds;
        gb == tb with bounds="none"; the non-bounded algorithms carry
        bounds="none". ``n`` is the dataset size (lloyd-elkan needs it).
        """
        c = self
        if c.algorithm == "sgd":
            c = dataclasses.replace(c, algorithm="mb", b0=1)
        if c.algorithm == "lloyd-elkan":
            c = dataclasses.replace(c, algorithm="tb", b0=n,
                                    bounds="elkan", rho=math.inf)
        if c.algorithm == "gb":
            c = dataclasses.replace(c, algorithm="tb", bounds="none")
        if c.algorithm in ("lloyd", "mb", "mbf"):
            c = dataclasses.replace(c, bounds="none")
        return c

    # -- serialisation ------------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        """JSON-safe dict (inf encoded as the string "inf")."""
        d = dataclasses.asdict(self)
        d["rho"] = _enc_float(self.rho)
        d["time_budget_s"] = _enc_float(self.time_budget_s)
        d["data_axes"] = list(self.data_axes)
        if self.checkpoint is not None:
            d["checkpoint"] = self.checkpoint.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "FitConfig":
        d = dict(d)
        unknown = set(d) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise ValueError(f"unknown FitConfig fields: {sorted(unknown)}")
        if "rho" in d:
            d["rho"] = _dec_float(d["rho"])
        if "time_budget_s" in d:
            d["time_budget_s"] = _dec_float(d["time_budget_s"])
        if "data_axes" in d:
            d["data_axes"] = tuple(d["data_axes"])
        return cls(**d)
