"""Structured per-round telemetry replacing the ad-hoc dict records.

The engines emit one `Telemetry` record per round; callbacks receive the
record as it is appended, so a serving loop can stream progress without
polling. `to_dict()` keeps the exact key set the legacy dict records
used, so checkpoints/manifests written by older runs stay readable —
and is JSON-safe: numpy scalars are coerced to plain Python and
non-finite floats encode as ``"nan"`` / ``"inf"`` / ``"-inf"`` strings
(bare NaN in a JSON file is rejected by strict parsers), which
`from_dict` decodes back. `from_round` is the ONE way a host-landed
round becomes a record, shared by `run_loop` and `partial_fit` so the
two paths can never drift.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional

_INT_FIELDS = frozenset({"round", "b", "n_changed", "n_recomputed"})
_FLOAT_FIELDS = frozenset({"t", "batch_mse", "r_median", "val_mse"})


def _enc_value(name: str, v: Any) -> Any:
    if v is None:
        return None
    if name in _INT_FIELDS:
        return int(v)
    if name in _FLOAT_FIELDS:
        f = float(v)
        if math.isnan(f):
            return "nan"
        if math.isinf(f):
            return "inf" if f > 0 else "-inf"
        return f
    return bool(v)


def _dec_value(name: str, v: Any) -> Any:
    if name in _FLOAT_FIELDS and isinstance(v, str):
        return float(v)
    return v


@dataclasses.dataclass(frozen=True)
class Telemetry:
    """One host-loop round.

    ``t`` is cumulative *compute* wall-clock (validation eval excluded,
    matching the paper's protocol §4.3). ``val_mse`` is None on rounds
    where validation was not evaluated.
    """
    round: int                 # 0-based host-loop round index
    t: float                   # cumulative compute seconds
    b: int                     # active (global) batch size this round
    batch_mse: Optional[float]
    n_changed: int
    n_recomputed: int
    grow: bool
    r_median: Optional[float]  # controller's median sigma_C/p ratio
    val_mse: Optional[float] = None

    def to_dict(self) -> Dict[str, Any]:
        """JSON-safe dict: plain-Python scalars, non-finite floats as
        ``"nan"``/``"inf"``/``"-inf"`` strings."""
        return {f.name: _enc_value(f.name, getattr(self, f.name))
                for f in dataclasses.fields(self)}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Telemetry":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: _dec_value(k, v) for k, v in d.items()
                      if k in known})

    @classmethod
    def from_round(cls, hinfo, *, round: int, t: float,
                   val_mse: Optional[float] = None) -> "Telemetry":
        """One record from a host-landed round.

        ``hinfo`` is duck-typed (any object with the `HostRoundInfo`
        fields) so this module stays import-light; both `run_loop` and
        `NestedKMeans.partial_fit` build their records here.
        """
        return cls(round=int(round), t=float(t), b=int(hinfo.n_active),
                   batch_mse=float(hinfo.batch_mse),
                   n_changed=int(hinfo.n_changed),
                   n_recomputed=int(hinfo.n_recomputed),
                   grow=bool(hinfo.grow),
                   r_median=float(hinfo.r_median),
                   val_mse=None if val_mse is None else float(val_mse))


# callback invoked with each record as it is produced
RoundCallback = Callable[[Telemetry], None]


def final_val_mse(telemetry: List[Telemetry]) -> float:
    """Last recorded validation MSE (nan if none was ever evaluated)."""
    for rec in reversed(telemetry):
        if rec.val_mse is not None:
            return rec.val_mse
    return float("nan")
