"""Structured per-round telemetry replacing the ad-hoc dict records.

The engines emit one `Telemetry` record per round; callbacks receive the
record as it is appended, so a serving loop can stream progress without
polling. `to_dict()` keeps the exact key set the legacy dict records
used, so checkpoints/manifests written by older runs stay readable.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class Telemetry:
    """One host-loop round.

    ``t`` is cumulative *compute* wall-clock (validation eval excluded,
    matching the paper's protocol §4.3). ``val_mse`` is None on rounds
    where validation was not evaluated.
    """
    round: int                 # 0-based host-loop round index
    t: float                   # cumulative compute seconds
    b: int                     # active (global) batch size this round
    batch_mse: Optional[float]
    n_changed: int
    n_recomputed: int
    grow: bool
    r_median: Optional[float]  # controller's median sigma_C/p ratio
    val_mse: Optional[float] = None

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Telemetry":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


# callback invoked with each record as it is produced
RoundCallback = Callable[[Telemetry], None]


def final_val_mse(telemetry: List[Telemetry]) -> float:
    """Last recorded validation MSE (nan if none was ever evaluated)."""
    for rec in reversed(telemetry):
        if rec.val_mse is not None:
            return rec.val_mse
    return float("nan")
