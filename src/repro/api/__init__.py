"""repro.api — the single public surface of the reproduction.

    from repro.api import FitConfig, NestedKMeans

    cfg = FitConfig(k=50, algorithm="tb", rho=float("inf"), b0=2000)
    km = NestedKMeans(cfg).fit(X_train, X_val=X_val)
    labels = km.predict(X_new)

Execution backends are swappable without touching caller code:

    from repro.api import MeshEngine
    km = NestedKMeans(dataclasses.replace(cfg, backend="mesh"),
                      mesh=my_mesh).fit(X)

`fit()` is a functional convenience over the estimator for scripts that
just want a `FitOutcome`. The legacy entry points (`repro.core.fit`,
`repro.core.distributed.fit_distributed`) are deprecation shims over
this package and will not grow new features.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.api.config import (ALGORITHMS, BACKENDS, BOUNDS,
                              CheckpointConfig, FitConfig)
from repro.api.engines import (Engine, EngineRun, LocalEngine, MeshEngine,
                               MultiHostEngine, XLEngine, make_engine)
from repro.api.estimator import NestedKMeans, NotFittedError
from repro.api.loop import (FitOutcome, HostRoundInfo, LoopAudit, ObsSink,
                            cap_bucket, fetch_round_info, next_pow2,
                            run_loop)
from repro.api.telemetry import RoundCallback, Telemetry, final_val_mse


def fit(X, config: FitConfig, *, X_val=None, mesh=None,
        init_C: Optional[np.ndarray] = None,
        on_round: Optional[RoundCallback] = None) -> FitOutcome:
    """One-call fit: build the engine for ``config`` and run it."""
    km = NestedKMeans(config, mesh=mesh, on_round=on_round)
    km.fit(X, X_val=X_val, init_C=init_C)
    return km.outcome_


__all__ = [
    "FitConfig", "CheckpointConfig", "NestedKMeans", "NotFittedError",
    "fit",
    "Engine", "EngineRun", "LocalEngine", "MeshEngine", "MultiHostEngine",
    "XLEngine", "make_engine",
    "run_loop", "FitOutcome", "HostRoundInfo", "LoopAudit", "ObsSink",
    "fetch_round_info", "Telemetry", "RoundCallback",
    "final_val_mse", "cap_bucket", "next_pow2",
    "ALGORITHMS", "BOUNDS", "BACKENDS",
]
