"""Back-compat shim — the engine/loop stack now lives in layered modules.

The 700-line module that mixed the host control loop with every engine
implementation was split for the multi-process refactor:

  repro.api.loop              run_loop + FitOutcome (+ the process-
                              replicated control-flow invariant doc)
  repro.api.engines.base      EngineRun / Engine contract
  repro.api.engines.local     LocalEngine (bucketed jit)
  repro.api.engines.mesh      MeshEngine (shard_map)
  repro.api.engines.xl        XLEngine (centroid-sharded)
  repro.api.engines.multihost MultiHostEngine (jax.distributed)

Everything importable from here before the split still is; new code
should import from `repro.api` (public) or the specific module.
"""
from __future__ import annotations

from repro.api.engines.base import Engine, EngineRun
from repro.api.engines.local import LocalEngine, nested_jit
from repro.api.engines.local import _LocalRun  # noqa: F401
from repro.api.engines.local import _lloyd_jit, _mb_jit  # noqa: F401
from repro.api.engines.mesh import MeshEngine
from repro.api.engines.mesh import _MeshRun  # noqa: F401
from repro.api.engines.multihost import MultiHostEngine
from repro.api.engines.multihost import _MultiHostRun  # noqa: F401
from repro.api.engines.xl import XLEngine
from repro.api.engines.xl import _XLRun  # noqa: F401
from repro.api.engines import make_engine
from repro.api.loop import FitOutcome, cap_bucket, next_pow2, run_loop

__all__ = [
    "Engine", "EngineRun", "FitOutcome", "LocalEngine", "MeshEngine",
    "MultiHostEngine", "XLEngine", "cap_bucket", "make_engine",
    "nested_jit", "next_pow2", "run_loop",
]
