"""Execution engines + the ONE host loop shared by all of them.

Before this package existed the growth schedule, power-of-two capacity
bucketing, overflow retry, convergence patience and wall-clock telemetry
were copy-pasted between `core/driver.py` (single device) and
`core/distributed.py` (shard_map). They now live once, in `run_loop`;
an `Engine` only knows how to place data and execute one compiled round.

  Engine.begin(X, config, ...)  -> EngineRun   (data placement + state)
  EngineRun.nested_step/lloyd_step/mb_step     (one compiled round)
  run_loop(run, config, ...)    -> FitOutcome  (the host schedule)

`LocalEngine` wraps the bucketed-jit rounds; `MeshEngine` wraps the
shard_map rounds with points row-sharded over the mesh's data axes and
replicated cluster stats; `XLEngine` additionally shards the centroids
over the mesh's model axis for k too large to replicate. All produce
bit-identical centroids for the same (data placement, config) because
every round function is exact and the host schedule is shared.
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Protocol, Tuple, Union, \
    runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import FitConfig
from repro.api.telemetry import RoundCallback, Telemetry, final_val_mse
from repro.checkpoint.store import CheckpointStore
from repro.core import rounds
from repro.core.state import (ElkanBounds, KMeansState, PointState,
                              RoundInfo, full_mse, init_state)


# --------------------------------------------------------------------------
# result record
# --------------------------------------------------------------------------

@dataclasses.dataclass
class FitOutcome:
    """What a fit produces: centroids + full state + structured telemetry.

    ``labels`` is in the CALLER's row order (the engines shuffle and, on
    a mesh, interleave/pad internally; the inverse mapping is applied
    here). ``-1`` marks rows the nested batch never reached.
    """
    C: np.ndarray
    state: KMeansState
    labels: np.ndarray
    telemetry: List[Telemetry]
    converged: bool
    algorithm: str
    config: FitConfig

    @property
    def final_mse(self) -> float:
        return final_val_mse(self.telemetry)


# --------------------------------------------------------------------------
# capacity policy (shared)
# --------------------------------------------------------------------------

def next_pow2(x: int) -> int:
    return 1 << max(0, int(x - 1).bit_length())


def cap_bucket(need: int, b: int, floor: int) -> Optional[int]:
    """Power-of-two capacity with 2x slack; None == recompute everything."""
    cap = max(floor, next_pow2(2 * max(need, 1)))
    return None if cap >= b else cap


# --------------------------------------------------------------------------
# the Engine protocol
# --------------------------------------------------------------------------

class EngineRun:
    """One fit in flight: placed data + initial state + round executors.

    Subclasses set:
      state            initial KMeansState (already placed/sharded)
      b                initial batch size in ENGINE UNITS (global rows
                       for LocalEngine, per-shard rows for MeshEngine)
      b_max            largest batch in engine units
      n_shards         data shards (1 for local)
      n_active_target  info.n_active value meaning "full data active"
      orig_index       (n_storage,) int: original caller row held at
                       each internal storage row (-1 = structural pad)
      n_points         caller's dataset size (pads excluded)
    """
    state: KMeansState
    b: int
    b_max: int
    n_shards: int = 1
    n_active_target: int = 0
    orig_index: np.ndarray = None
    n_points: int = 0

    # -- round executors (pure: state in -> (state, info)) ------------------

    def nested_step(self, state: KMeansState, b: int,
                    capacity: Optional[int]
                    ) -> Tuple[KMeansState, RoundInfo]:
        raise NotImplementedError(
            f"{type(self).__name__} does not run the nested family")

    def lloyd_step(self, state: KMeansState
                   ) -> Tuple[KMeansState, RoundInfo]:
        raise NotImplementedError(
            f"{type(self).__name__} does not run lloyd")

    def mb_step(self, state: KMeansState, fixed: bool
                ) -> Tuple[KMeansState, RoundInfo]:
        raise NotImplementedError(
            f"{type(self).__name__} does not run mb/mbf")

    def eval_mse(self, state: KMeansState) -> Optional[float]:
        """Validation MSE of the current centroids (None: no val set)."""
        return None

    # -- checkpointing (canonical = global-shuffle row order) ---------------

    def capture(self, state: KMeansState) -> Tuple[Dict[str, Any],
                                                   Dict[str, Any]]:
        """(host pytree, JSON-safe engine meta) for a checkpoint.

        Per-point arrays are returned in CANONICAL order — the position
        of each real row in the seed-determined global shuffle, pads
        dropped. The canonical layout depends only on (seed, N_real), so
        a checkpoint written by any engine at any shard count restores
        onto any other (elastic restart).
        """
        raise NotImplementedError

    def restore(self, store: "CheckpointStore", step: int,
                meta: Dict[str, Any]) -> KMeansState:
        """Rebuild an engine-layout state from a canonical checkpoint."""
        raise NotImplementedError


@runtime_checkable
class Engine(Protocol):
    """An execution backend: owns data placement + compiled rounds."""

    def begin(self, X, config: FitConfig, *,
              X_val=None, init_C: Optional[np.ndarray] = None) -> EngineRun:
        """Shuffle/pad/place ``X`` and build the initial state."""
        ...


# --------------------------------------------------------------------------
# THE shared host loop
# --------------------------------------------------------------------------

def run_loop(run: EngineRun, config: FitConfig, *,
             on_round: Optional[RoundCallback] = None,
             resume_from: Optional[Union[str, Path, CheckpointStore]] = None
             ) -> FitOutcome:
    """Growth schedule + capacity bucketing + overflow retry + patience.

    ``config`` must already be `resolve()`d (no alias algorithms). The
    loop is backend-agnostic: every quantity it branches on comes from
    the (psum-reduced, hence shard-replicated) RoundInfo, so the same
    schedule drives one device or a pod mesh.

    When ``config.checkpoint`` is set, the FULL loop state — engine
    state, batch size, capacity bucket, patience counter, work clock and
    telemetry — is saved atomically every ``save_every`` rounds (plus
    once at loop exit) alongside the ``config.to_dict()`` manifest.
    ``resume_from`` (a directory or `CheckpointStore`) restores the
    latest such checkpoint through the engine's canonical layout, so a
    killed fit continues bit-identically — and a fit checkpointed on
    one shard count resumes on another (elastic restart).
    """
    algorithm = config.algorithm
    bounds = config.bounds
    state = run.state
    b = run.b
    capacity: Optional[int] = None
    telemetry: List[Telemetry] = []
    t_work = 0.0
    quiet_rounds = 0
    converged = False
    start_round = 0

    ckpt = config.checkpoint
    store = (CheckpointStore(ckpt.checkpoint_dir, keep=ckpt.keep)
             if ckpt is not None else None)

    if store is not None and resume_from is None \
            and store.latest_step() is not None:
        # a FRESH checkpointed fit supersedes whatever run lives in the
        # directory: left in place, the old (higher-numbered) steps
        # would garbage-collect this run's early saves on arrival and a
        # later resume would silently restore the stale fit
        store.clear()

    if resume_from is not None:
        rstore = (resume_from if isinstance(resume_from, CheckpointStore)
                  else CheckpointStore(resume_from,
                                       keep=ckpt.keep if ckpt else 3))
        step = rstore.latest_step()
        if step is None:
            raise FileNotFoundError(
                f"resume_from={resume_from!r} holds no checkpoints")
        extra = rstore.read_extra(step)
        if not extra or "loop" not in extra:
            raise ValueError(
                f"checkpoint step {step} has no loop metadata; it was "
                f"not written by run_loop")
        emeta, loop = extra["engine"], extra["loop"]
        state = run.restore(rstore, step, emeta)
        telemetry = [Telemetry.from_dict(r) for r in extra["telemetry"]]
        t_work = float(loop["t_work"])
        quiet_rounds = int(loop["quiet_rounds"])
        converged = bool(loop.get("converged", False))
        start_round = int(loop["rounds_done"])
        # b is stored in GLOBAL rows; ceil-divide onto this engine's
        # shard count so every previously-seen point stays inside the
        # prefix when the shard count changed across the restore.
        b = max(1, min(-(-int(loop["b_global"]) // run.n_shards),
                       run.b_max))
        cap = loop.get("capacity")
        capacity = (int(cap) if cap is not None
                    and int(emeta.get("n_shards", 0)) == run.n_shards
                    else None)

    def record(info: RoundInfo) -> None:
        rec = Telemetry(
            round=len(telemetry), t=t_work, b=int(info.n_active),
            batch_mse=float(info.batch_mse),
            n_changed=int(info.n_changed),
            n_recomputed=int(info.n_recomputed),
            grow=bool(info.grow), r_median=float(info.r_median),
            val_mse=(run.eval_mse(state)
                     if len(telemetry) % config.eval_every == 0 else None))
        telemetry.append(rec)
        if on_round:
            on_round(rec)

    def save_checkpoint() -> None:
        tree, emeta = run.capture(state)
        extra = {
            "config": config.to_dict(),
            "engine": emeta,
            "loop": {"rounds_done": len(telemetry),
                     "b_global": b * run.n_shards, "capacity": capacity,
                     "quiet_rounds": quiet_rounds, "t_work": t_work,
                     "converged": converged},
            "telemetry": [r.to_dict() for r in telemetry],
        }
        store.save(len(telemetry), tree, extra=extra,
                   background=ckpt.background)

    for _ in range(start_round, config.max_rounds):
        if converged:        # resumed an already-finished fit
            break
        if t_work >= config.time_budget_s:
            break
        t0 = time.perf_counter()

        if algorithm == "lloyd":
            new_state, info = run.lloyd_step(state)
        elif algorithm in ("mb", "mbf"):
            new_state, info = run.mb_step(state, fixed=(algorithm == "mbf"))
        else:  # tb family (incl. gb via bounds="none")
            while True:
                new_state, info = run.nested_step(state, b, capacity)
                if not bool(info.overflow):
                    break
                # overflow retry: same input state, doubled bucket —
                # exactness is never traded for speed.
                capacity = (None if capacity is None or 2 * capacity >= b
                            else 2 * capacity)

        jax.block_until_ready(new_state.stats.C)
        t_work += time.perf_counter() - t0
        state = new_state
        record(info)

        if algorithm == "tb":
            if bounds == "hamerly2":
                need = -(-int(info.n_recomputed) // run.n_shards)
                if bool(info.grow) and b < run.b_max:
                    # a doubling adds b new points that always need a
                    # full pass — start the grown bucket dense
                    capacity = None
                else:
                    capacity = cap_bucket(need, b, config.capacity_floor)
            if bool(info.grow):
                b = min(2 * b, run.b_max)
            # p_max rides along in the psum-consistent RoundInfo — no
            # extra device->host sync outside the timed region
            if (int(info.n_active) >= run.n_active_target
                    and int(info.n_changed) == 0
                    and float(info.p_max) == 0.0):
                quiet_rounds += 1
                if quiet_rounds >= config.converge_patience:
                    converged = True
                    break
            else:
                quiet_rounds = 0
        elif algorithm == "lloyd":
            if int(info.n_changed) == 0:
                converged = True
                break

        if store is not None and len(telemetry) % ckpt.save_every == 0:
            save_checkpoint()

    if store is not None:
        # one final save so a resumed-after-finish fit is a no-op loop
        save_checkpoint()
        store.wait()

    # final validation point (outside the timed region, like every eval),
    # unless the last in-loop round already evaluated validation — a
    # second eval at the same t would double-count it in the telemetry
    if telemetry and telemetry[-1].val_mse is not None:
        final = None
    else:
        final = run.eval_mse(state)
    if final is not None:
        # b is per-shard; b * n_shards includes the structural pad rows
        # on a non-divisible mesh, so cap at the real dataset size
        telemetry.append(Telemetry(
            round=len(telemetry), t=t_work,
            b=min(b * run.n_shards, run.n_points),
            batch_mse=None, n_changed=0, n_recomputed=0, grow=False,
            r_median=None, val_mse=final))

    # un-shuffle the final assignments back to the caller's row order
    a = np.asarray(state.points.a)
    labels = np.full(run.n_points, -1, np.int32)
    valid = run.orig_index >= 0
    labels[run.orig_index[valid]] = a[valid]

    return FitOutcome(C=np.asarray(state.stats.C), state=state,
                      labels=labels, telemetry=telemetry,
                      converged=converged, algorithm=algorithm,
                      config=config)


# --------------------------------------------------------------------------
# LocalEngine — single-process bucketed jit
# --------------------------------------------------------------------------

# shared with estimator.partial_fit so streaming batches of a repeated
# shape hit the same jit cache as fit()
nested_jit = jax.jit(
    rounds.nested_round,
    static_argnames=("b", "rho", "bounds", "capacity", "use_shalf",
                     "kernel_backend", "data_axes"))
_mb_jit = jax.jit(rounds.mb_round,
                  static_argnames=("fixed", "kernel_backend"))
_lloyd_jit = jax.jit(rounds.lloyd_round, static_argnames=("kernel_backend",))


class _LocalRun(EngineRun):
    def __init__(self, X, config: FitConfig, X_val, init_C):
        rng = np.random.default_rng(config.seed)
        X = np.asarray(X)
        N = X.shape[0]
        perm = rng.permutation(N) if config.shuffle else np.arange(N)
        self._Xd = jnp.asarray(X[perm])
        self._Xv = jnp.asarray(X_val) if X_val is not None else None
        self._config = config
        self._rng = rng

        state = init_state(self._Xd, config.k, bounds=config.bounds)
        if init_C is not None:       # warm start (checkpoint restart)
            state = dataclasses.replace(state, stats=dataclasses.replace(
                state.stats, C=jnp.asarray(init_C, jnp.float32)))
        self.state = state
        self.b = min(config.b0, N)
        self.b_max = N
        self.n_shards = 1
        self.n_active_target = N
        self.orig_index = perm        # storage row i holds X[perm[i]]
        self.n_points = N
        # mb/mbf resampling stream (paper footnote 1: cycle a reshuffle)
        self._mb_pos = 0
        self._mb_perm = rng.permutation(N)

    def nested_step(self, state, b, capacity):
        return nested_jit(self._Xd, state, b=b, rho=self._config.rho,
                          bounds=self._config.bounds, capacity=capacity,
                          use_shalf=self._config.use_shalf,
                          kernel_backend=self._config.kernel_backend)

    def lloyd_step(self, state):
        return _lloyd_jit(self._Xd, state,
                          kernel_backend=self._config.kernel_backend)

    def mb_step(self, state, fixed):
        N, b = self.b_max, self.b
        if self._mb_pos + b > N:
            self._mb_perm = self._rng.permutation(N)
            self._mb_pos = 0
        idx = jnp.asarray(self._mb_perm[self._mb_pos:self._mb_pos + b])
        self._mb_pos += b
        return _mb_jit(self._Xd, idx, state, fixed=fixed,
                       kernel_backend=self._config.kernel_backend)

    def eval_mse(self, state):
        if self._Xv is None:
            return None
        return float(full_mse(self._Xv, state.stats.C))

    # -- checkpointing ------------------------------------------------------
    # storage row i holds shuffle position i, so storage order IS the
    # canonical order for the local engine.

    def capture(self, state):
        tree = {
            "stats": jax.tree.map(np.asarray, state.stats),
            "a": np.asarray(state.points.a),
            "d": np.asarray(state.points.d),
            "lb": np.asarray(state.points.lb),
            "round": np.asarray(state.round),
            "mb_perm": np.asarray(self._mb_perm),
        }
        if state.elkan is not None:
            tree["elkan_l"] = np.asarray(state.elkan.l)
        meta = {
            "engine": "local", "n_shards": 1, "n_points": self.n_points,
            "has_mb": True, "has_elkan": state.elkan is not None,
            "mb_pos": self._mb_pos,
            "rng_state": self._rng.bit_generator.state,
        }
        return tree, meta

    def restore(self, store, step, meta):
        proto = {"stats": self.state.stats,
                 "a": self.state.points.a, "d": self.state.points.d,
                 "lb": self.state.points.lb, "round": self.state.round}
        if meta.get("has_elkan"):
            if self.state.elkan is None:
                raise ValueError(
                    "checkpoint carries elkan bounds but this config "
                    "does not use bounds='elkan'")
            proto["elkan_l"] = self.state.elkan.l
        if meta.get("has_mb"):
            proto["mb_perm"] = jnp.asarray(self._mb_perm)
        got = store.restore(proto, step=step)
        if meta.get("has_mb"):
            self._mb_perm = np.asarray(got["mb_perm"])
            self._mb_pos = int(meta["mb_pos"])
        if meta.get("rng_state") is not None:
            self._rng.bit_generator.state = meta["rng_state"]
        points = PointState(a=got["a"], d=got["d"], lb=got["lb"])
        elkan = (ElkanBounds(l=got["elkan_l"]) if meta.get("has_elkan")
                 else None)
        return KMeansState(stats=got["stats"], points=points,
                           elkan=elkan, round=got["round"])


class LocalEngine:
    """Single-process engine over the bucketed-jit round functions."""

    def begin(self, X, config: FitConfig, *, X_val=None,
              init_C=None) -> EngineRun:
        return _LocalRun(X, config, X_val, init_C)


# --------------------------------------------------------------------------
# MeshEngine — shard_map over the device mesh
# --------------------------------------------------------------------------

class _MeshRun(EngineRun):
    _engine_name = "mesh"

    def __init__(self, X, config: FitConfig, mesh, X_val, init_C):
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.data.pipeline import nested_shard_layout

        data_axes = config.data_axes
        n_shards = int(np.prod([mesh.shape[a] for a in data_axes]))
        self._config = config
        self._mesh = mesh
        X = np.asarray(X)
        N_real = X.shape[0]
        # the placement (shuffle + structural tail pads + round-robin
        # interleave) is shared with data.pipeline.KMeansShardedSource;
        # padded rows sit at the tail of every shard and b_local is
        # capped below them, so they can never enter a nested prefix.
        lay = nested_shard_layout(N_real, n_shards, seed=config.seed,
                                  shuffle=config.shuffle)
        if lay.n_storage > N_real:
            X = np.concatenate(
                [X, np.repeat(X[:1], lay.n_storage - N_real, axis=0)])
        N = lay.n_storage
        perm = lay.perm
        Xh = X[perm].reshape(N // n_shards, n_shards, -1).transpose(1, 0, 2)
        self._Xd = jax.device_put(
            jnp.asarray(Xh.reshape(N, -1)),
            NamedSharding(mesh, P(data_axes, None)))
        C0 = (jnp.asarray(init_C, jnp.float32) if init_C is not None
              else jnp.asarray(X[perm[:config.k]], jnp.float32))

        state = init_state(self._Xd, config.k, bounds=config.bounds)
        state = dataclasses.replace(
            state, stats=dataclasses.replace(state.stats, C=C0))
        self.state = self._place_state(state)

        self._Xv = jnp.asarray(X_val) if X_val is not None else None
        self.b = max(1, min(config.b0, N_real) // n_shards)
        # every shard's real rows are prefix-contiguous in its storage
        # slice; shards whose last storage row is a structural pad cap
        # their active prefix via the per-shard n_valid mask inside the
        # round, so b_max covers EVERY real row — including the tail
        # rows of the low shards when N_real % n_shards != 0.
        self.b_max = max(1, N // n_shards)
        self.n_shards = n_shards
        self.n_active_target = N_real
        self._N = N
        # per-shard real-row cap is derived inside the sharded round
        # from the shard's axis index; None disables masking entirely
        self._n_real = N_real if N_real % n_shards else None
        # storage row shard*(N/s)+i holds shuffle position i*s+shard;
        # positions >= N_real are structural pads
        self._pos = lay.pos
        self.orig_index = lay.orig_index()
        self.n_points = N_real

    # -- engine-layout hooks (overridden by _XLRun) -------------------------

    def _place_state(self, state: KMeansState) -> KMeansState:
        from repro.core.distributed import shard_state
        return shard_state(state, self._mesh, self._config.data_axes)

    def _stat_shardings(self):
        """Sharding pytree of ``state.stats`` for the elastic restore."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        rep = NamedSharding(self._mesh, P())
        return jax.tree.map(lambda _: rep, self.state.stats)

    def nested_step(self, state, b, capacity):
        from repro.core.distributed import make_sharded_round
        round_fn = make_sharded_round(
            self._mesh, self._config.data_axes, b_local=b,
            rho=self._config.rho, bounds=self._config.bounds,
            capacity=capacity, use_shalf=self._config.use_shalf,
            n_real=self._n_real)
        return round_fn(self._Xd, state)

    def eval_mse(self, state):
        if self._Xv is None:
            return None
        return float(full_mse(self._Xv, state.stats.C))

    # -- checkpointing ------------------------------------------------------
    # storage row shard*(N/s)+i holds shuffle position i*s+shard, so
    # canonical order is storage gathered, permuted by _pos, pads cut.

    def capture(self, state):
        def canon(arr):
            h = np.asarray(arr)
            out = np.empty_like(h)
            out[self._pos] = h
            return out[:self.n_points]

        tree = {
            "stats": jax.tree.map(np.asarray, state.stats),
            "a": canon(state.points.a),
            "d": canon(state.points.d),
            "lb": canon(state.points.lb),
            "round": np.asarray(state.round),
        }
        meta = {"engine": self._engine_name, "n_shards": self.n_shards,
                "n_points": self.n_points, "has_mb": False,
                "has_elkan": False}
        return tree, meta

    def restore(self, store, step, meta):
        from jax.sharding import NamedSharding, PartitionSpec as P
        rep = NamedSharding(self._mesh, P())
        row = NamedSharding(self._mesh, P(self._config.data_axes))

        # small leaves go through the elastic re-shard machinery (stats
        # are stored full/canonical; _stat_shardings re-places them in
        # this engine's layout — replicated here, k-sharded on the XL
        # engine)
        small = {"stats": self.state.stats, "round": self.state.round}
        small_sh = {"stats": self._stat_shardings(), "round": rep}
        got = store.restore(small, step=step, shardings=small_sh)

        # per-point leaves come back canonical; re-pad + re-interleave
        # for THIS mesh's shard count, then row-shard
        pts = store.restore({"a": jnp.zeros((self.n_points,), jnp.int32),
                             "d": jnp.zeros((self.n_points,), jnp.float32),
                             "lb": jnp.zeros((self.n_points,),
                                             jnp.float32)}, step=step)

        def place(h, fill):
            h = np.asarray(h)
            full = np.full((self._N,), fill, h.dtype)
            full[:self.n_points] = h
            return jax.device_put(jnp.asarray(full[self._pos]), row)

        points = PointState(a=place(pts["a"], -1),
                            d=place(pts["d"], 0.0),
                            lb=place(pts["lb"], 0.0))
        return KMeansState(stats=got["stats"], points=points,
                           elkan=None, round=got["round"])


class MeshEngine:
    """Multi-device engine: points row-sharded, cluster stats replicated.

    The S/v/sse deltas are psum-reduced inside the round, so the stats —
    and therefore the controller's growth decision — are bit-identical
    on every shard with no host round-trip. Only the nested (gb/tb)
    family is supported; `FitConfig.__post_init__` enforces this.
    """

    def __init__(self, mesh):
        self.mesh = mesh

    def begin(self, X, config: FitConfig, *, X_val=None,
              init_C=None) -> EngineRun:
        return _MeshRun(X, config, self.mesh, X_val, init_C)


# --------------------------------------------------------------------------
# XLEngine — centroids sharded over the model axis (kmeans_xl scale)
# --------------------------------------------------------------------------

class _XLRun(_MeshRun):
    """A `_MeshRun` whose cluster stats are sharded over ``model_axis``.

    Data placement, b units (per-data-shard rows), the n_valid tail mask
    and the canonical checkpoint layout are all inherited from the mesh
    run — checkpoints are written with FULL (k, d) stats, so an XL
    checkpoint restores elastically onto local/mesh engines and onto any
    model-axis size that divides k, and vice versa. Only the state
    placement and the compiled round differ.
    """
    _engine_name = "xl"

    def __init__(self, X, config: FitConfig, mesh, X_val, init_C):
        if config.model_axis not in mesh.shape:
            raise ValueError(
                f"backend='xl' needs mesh axis "
                f"{config.model_axis!r} (config.model_axis) to shard "
                f"the centroids over, but the mesh only has axes "
                f"{tuple(mesh.axis_names)}")
        m = int(mesh.shape[config.model_axis])
        if config.k % m:
            raise ValueError(
                f"backend='xl' shards the k={config.k} centroids over "
                f"mesh axis {config.model_axis!r} of size {m}; k must "
                f"divide evenly")
        super().__init__(X, config, mesh, X_val, init_C)

    def _place_state(self, state: KMeansState) -> KMeansState:
        from repro.core.distributed_xl import shard_state_xl
        return shard_state_xl(state, self._mesh, self._config.data_axes,
                              self._config.model_axis)

    def _stat_shardings(self):
        from jax.sharding import NamedSharding

        from repro.core.distributed_xl import xl_state_specs
        specs = xl_state_specs(self._config.data_axes,
                               self._config.model_axis)
        return jax.tree.map(lambda sp: NamedSharding(self._mesh, sp),
                            specs.stats)

    def nested_step(self, state, b, capacity):
        from repro.core.distributed_xl import make_xl_nested_round
        round_fn = make_xl_nested_round(
            self._mesh, self._config.data_axes,
            model_axis=self._config.model_axis, b_local=b,
            rho=self._config.rho, bounds=self._config.bounds,
            capacity=capacity, use_shalf=self._config.use_shalf,
            n_real=self._n_real,
            kernel_backend=self._config.kernel_backend)
        return round_fn(self._Xd, state)


class XLEngine:
    """Centroid-sharded engine: points over data axes, k over model.

    The regime past `MeshEngine`: when k*d no longer replicates (the
    ~10^5-centroid massive-data setting), each model shard scans only
    its k-slice with the fused top-2 kernel, the per-point top-2 triples
    are tree-folded over the model axis, and the S/v deltas are
    psum_scatter'ed so no device ever materialises full-k statistics.
    Drives the same `run_loop` (growth, overflow retry, patience,
    checkpoints) as every other engine.
    """

    def __init__(self, mesh):
        self.mesh = mesh

    def begin(self, X, config: FitConfig, *, X_val=None,
              init_C=None) -> EngineRun:
        return _XLRun(X, config, self.mesh, X_val, init_C)


def make_engine(config: FitConfig, *, mesh=None) -> Engine:
    """Engine for ``config.backend`` ("mesh"/"xl" require a mesh)."""
    if config.backend in ("mesh", "xl"):
        if mesh is None:
            raise ValueError(
                f"backend={config.backend!r} needs a jax.sharding.Mesh")
        return MeshEngine(mesh) if config.backend == "mesh" \
            else XLEngine(mesh)
    return LocalEngine()
