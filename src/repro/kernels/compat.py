"""Version compatibility for the Pallas TPU API.

Newer jax renamed `pltpu.TPUCompilerParams` to `pltpu.CompilerParams`;
this container's jax (0.4.x) only ships the old name. Every kernel
imports `CompilerParams` from here so both spellings work.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))
if CompilerParams is None:  # pragma: no cover - very old jax
    raise ImportError(
        "neither pltpu.CompilerParams nor pltpu.TPUCompilerParams exists; "
        "jax is too old for these kernels")
