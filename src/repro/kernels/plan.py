"""Kernel dispatch plane: one resolved `KernelPlan` per fit.

`kernel_backend` used to be a raw string threaded through every round
helper, with a per-call `_auto_backend` default buried in `ops.py`.
This module replaces that with a single resolution step: an engine (or
`ops` itself, for legacy string callers) calls `resolve_plan` ONCE and
threads the frozen result everywhere a kernel is launched.

The plan is keyed on the (b, k, d) **pow2 bucket lattice** — the same
lattice `api.loop` uses for jit cache buckets — so a fit whose nested
batch doubles through b0, 2*b0, ... N shares one plan for the whole
trajectory (the bucket is taken at b_max). Because `KernelPlan` is a
frozen dataclass it is hashable with a stable repr, which lets the
engines put it straight into `jax.jit` static args and into
`util.tracecount` statics without widening the retrace auditor's
bucket key.

Block sizes (bn rows / bk centroid cols / bd feature cols) come from a
per-bucket autotuner cached under ``artifacts/tune/`` — gated by the
``REPRO_TUNE_KERNELS`` env var because measuring candidates costs real
wall time — with a deterministic fallback table when tuning is off and
no cache entry exists. The table is what CI exercises; tuning can only
ever change performance, never results.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

_TUNE_ENV = "REPRO_TUNE_KERNELS"
_TUNE_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "tune"

#: tuner candidate grid — small on purpose: 12 timed points per bucket.
_CANDIDATES = tuple((bn, bk, bd)
                    for bn in (128, 256, 512)
                    for bk in (128, 256)
                    for bd in (128, 256))


def next_pow2(x: int) -> int:
    """Smallest power of two >= x (>= 1)."""
    return 1 << max(0, int(x) - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """Resolved kernel dispatch for one fit.

    Frozen + hashable: engines pass the plan through jit static args,
    so everything here must be decided before tracing and constant for
    the fit's lifetime.
    """

    backend: str                    # "ref" | "pallas"
    interpret: bool                 # pallas interpret mode (non-TPU)
    bn: int                         # rows per point tile
    bk: int                         # centroid columns per assign tile
    bd: int                         # feature columns per cluster-sum tile
    bucket: Tuple[int, int, int]    # pow2 (b, k, d) lattice cell
    source: str                     # "table" | "tuned" | "cached"
    family: str = "unset"           # bound family the plan serves — the
                                    # fused pallas round only covers
                                    # none/hamerly2; elkan/exponion route
                                    # through the per-op kernels, and
                                    # manifests need the plan itself to
                                    # say which shape a fit actually ran

    def to_dict(self) -> Dict[str, Any]:
        """JSON form for benchmark manifests / FitOutcome."""
        return {"backend": self.backend, "interpret": self.interpret,
                "bn": self.bn, "bk": self.bk, "bd": self.bd,
                "bucket": list(self.bucket), "source": self.source,
                "family": self.family}


def _table_blocks(bucket: Tuple[int, int, int]) -> Tuple[int, int, int]:
    """Deterministic fallback block sizes for a bucket.

    bn tracks the batch bucket (capped at 512 so a huge fit still tiles
    X), bk is one MXU lane tile, bd widens for high-dimensional data so
    the cluster-sum grid does not degenerate into tiny feature strips.
    """
    bp2, _kp2, dp2 = bucket
    bn = min(512, max(8, bp2))
    bk = 128
    bd = 256 if dp2 >= 256 else 128
    return bn, bk, bd


def _cache_path(platform: str, bucket: Tuple[int, int, int]) -> Path:
    b, k, d = bucket
    return _TUNE_DIR / f"{platform}-b{b}-k{k}-d{d}.json"


def _tune_blocks(platform: str,
                 bucket: Tuple[int, int, int]) -> Tuple[int, int, int]:
    """Time the candidate grid on bucket-shaped synthetic data.

    Sizes are clamped so interpret-mode tuning on CPU stays in seconds;
    the measured op mix (assign + cluster-sum) is the nested round's
    inner loop, so the argmin transfers.
    """
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.cluster_sum import cluster_sum_pallas
    from repro.kernels.kmeans_assign import assign_top2_pallas

    bp2, kp2, dp2 = bucket
    n = int(min(bp2, 2048))
    k = int(min(kp2, 512))
    d = int(min(dp2, 512))
    kp = k + (-k % 128)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((k, d)), jnp.float32)
    a = jnp.asarray(rng.integers(0, k, size=n), jnp.int32)
    interpret = platform != "tpu"

    best: Optional[Tuple[float, int, int, int]] = None
    for bn, bk, bd in _CANDIDATES:
        bn_eff = max(8, min(bn, next_pow2(n)))

        def run() -> None:
            out = assign_top2_pallas(x, c, bn=bn_eff, bk=min(bk, kp),
                                     interpret=interpret)
            sums = cluster_sum_pallas(x, a, kp, bn=bn_eff, bd=bd,
                                      interpret=interpret)
            jax.block_until_ready((out, sums))

        run()                                    # compile / warm
        t0 = time.perf_counter()
        run()
        dt = time.perf_counter() - t0
        if best is None or dt < best[0]:
            best = (dt, bn, bk, bd)
    assert best is not None
    return best[1], best[2], best[3]


@functools.lru_cache(maxsize=None)
def _resolve_cached(kernel_backend: Optional[str],
                    bucket: Tuple[int, int, int],
                    platform: str, tune: bool,
                    family: str) -> KernelPlan:
    from repro.util.env import apply_kernel_flags

    # Satellite of the dispatch refactor: the env-module flag shaping is
    # applied on the SAME path that decides to launch kernels, so a fit
    # that resolves a plan gets the platform's XLA flags without its
    # launcher having called set_platform.
    apply_kernel_flags(platform)

    backend = kernel_backend or ("pallas" if platform == "tpu" else "ref")
    bn, bk, bd = _table_blocks(bucket)
    source = "table"
    path = _cache_path(platform, bucket)
    if path.is_file():
        try:
            blob = json.loads(path.read_text())
            bn, bk, bd = int(blob["bn"]), int(blob["bk"]), int(blob["bd"])
            source = "cached"
        except (ValueError, KeyError, OSError):
            pass                    # unreadable cache entry → table
    elif tune:
        bn, bk, bd = _tune_blocks(platform, bucket)
        source = "tuned"
        try:
            _TUNE_DIR.mkdir(parents=True, exist_ok=True)
            path.write_text(json.dumps(
                {"platform": platform, "bucket": list(bucket),
                 "bn": bn, "bk": bk, "bd": bd}, sort_keys=True) + "\n")
        except OSError:
            pass                    # read-only checkout: keep the result
    return KernelPlan(backend=backend, interpret=(platform != "tpu"),
                      bn=bn, bk=bk, bd=bd, bucket=bucket, source=source,
                      family=family)


def resolve_plan(kernel_backend: Optional[str] = None, *, b: int, k: int,
                 d: int, platform: Optional[str] = None,
                 tune: Optional[bool] = None,
                 bounds: Optional[str] = None) -> KernelPlan:
    """Resolve ``config.kernel_backend`` into a per-fit `KernelPlan`.

    Call once per fit with the fit's maximum batch (b), k and d; the
    result is cached per (backend, bucket, platform, family), so the
    legacy per-call path through `ops` pays only a dict lookup.

      kernel_backend  None (auto: pallas iff TPU) | "ref" | "pallas"
      platform        defaults to ``jax.default_backend()``
      tune            defaults to the ``REPRO_TUNE_KERNELS`` env var
      bounds          the fit's bound family, recorded on the plan for
                      manifests (elkan/exponion never take the fused
                      pallas round — the plan should say so). Purely
                      informational: block sizes don't depend on it.
    """
    if kernel_backend not in (None, "ref", "pallas"):
        raise ValueError(f"unknown kernel_backend {kernel_backend!r}")
    if platform is None:
        import jax
        platform = jax.default_backend()
    if tune is None:
        tune = os.environ.get(_TUNE_ENV, "") not in ("", "0")
    bucket = (next_pow2(b), next_pow2(k), next_pow2(d))
    return _resolve_cached(kernel_backend, bucket, str(platform),
                           bool(tune), bounds or "unset")
