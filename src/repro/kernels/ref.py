"""Pure-jnp oracles for the Pallas kernels.

These define the semantics; kernels are asserted allclose against them
across shape/dtype sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_dist2(x: jax.Array, c: jax.Array) -> jax.Array:
    """Squared euclidean distances, (n, k) for x (n, d), c (k, d). f32.

    Row norms via einsum (lowers to a dot): XLA-CPU otherwise
    materialises the full x*x intermediate — 0.55 TB/device on the
    kmeans_xl dry-run (EXPERIMENTS.md §Perf iteration 3a).
    """
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    xn = jnp.einsum("nd,nd->n", x, x)[:, None]          # (n, 1)
    cn = jnp.einsum("kd,kd->k", c, c)[None, :]          # (1, k)
    d2 = xn - 2.0 * (x @ c.T) + cn
    return jnp.maximum(d2, 0.0)


def assign_top2_ref(x: jax.Array, c: jax.Array):
    """For each point: (nearest-centroid index, min dist^2, 2nd-min dist^2).

    The 2nd-min initialises the Hamerly lower bound. k == 1 returns +inf
    as the second distance.
    """
    d2 = pairwise_dist2(x, c)
    a = jnp.argmin(d2, axis=1).astype(jnp.int32)
    d1 = jnp.min(d2, axis=1)
    k = c.shape[0]
    if k == 1:
        d_2nd = jnp.full_like(d1, jnp.inf)
    else:
        masked = jnp.where(jax.nn.one_hot(a, k, dtype=bool), jnp.inf, d2)
        d_2nd = jnp.min(masked, axis=1)
    return a, d1, d_2nd


def cluster_sum_ref(x: jax.Array, a: jax.Array, k: int, *,
                    weights: jax.Array | None = None):
    """Per-cluster sums S (k, d) and counts v (k,) of x grouped by a.

    ``weights`` (n,) scales each point's contribution (used for +1/-1 delta
    updates in mb-f / nested rounds).
    """
    x = x.astype(jnp.float32)
    if weights is None:
        weights = jnp.ones((x.shape[0],), jnp.float32)
    xw = x * weights[:, None]
    s = jax.ops.segment_sum(xw, a, num_segments=k)
    v = jax.ops.segment_sum(weights, a, num_segments=k)
    return s, v
