"""Per-cluster sum/count as a one-hot MXU matmul Pallas kernel.

TPU scatter-adds serialise; for small-to-moderate k the MXU-friendly form
``S = onehot(a).T @ x`` is the idiomatic replacement for segment_sum. Used
for the bulk cluster-sum over newly-entered points in nested rounds.

Grid: (d_blocks, n_blocks) with n sequential so the (k, bd) output block
accumulates across point tiles; counts are folded on the first d block only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.compat import CompilerParams


def _cluster_sum_kernel(x_ref, a_ref, w_ref, s_ref, v_ref, *, k: int):
    d_idx = pl.program_id(0)
    n_idx = pl.program_id(1)

    x = x_ref[...].astype(jnp.float32)             # (bn, bd)
    a = a_ref[...]                                 # (bn,)
    w = w_ref[...].astype(jnp.float32)             # (bn,) weights

    row = jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], k), 1)
    onehot = jnp.where(row == a[:, None], w[:, None], 0.0)   # (bn, k)

    part = jax.lax.dot_general(
        onehot, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)        # (k, bd)

    @pl.when(n_idx == 0)
    def _init():
        s_ref[...] = part

    @pl.when(n_idx != 0)
    def _acc():
        s_ref[...] += part

    @pl.when(d_idx == 0)
    def _counts():
        vpart = jnp.sum(onehot, axis=0)            # (k,)

        @pl.when(n_idx == 0)
        def _vinit():
            v_ref[...] = vpart

        @pl.when(n_idx != 0)
        def _vacc():
            v_ref[...] += vpart


@functools.partial(jax.jit, static_argnames=("k", "bn", "bd", "interpret"))
def cluster_sum_pallas(x: jax.Array, a: jax.Array, k: int, *,
                       weights: jax.Array | None = None, bn: int = 256,
                       bd: int = 256, interpret: bool = False):
    """S (k, d) f32, v (k,) f32 — weighted per-cluster sums of x by a.

    Padded points get weight 0 (and cluster 0) so they contribute nothing.
    """
    n, d = x.shape
    if weights is None:
        weights = jnp.ones((n,), jnp.float32)
    n_pad = -n % bn
    d_pad = -d % bd
    if n_pad:
        x = jnp.pad(x, ((0, n_pad), (0, 0)))
        a = jnp.pad(a, (0, n_pad))
        weights = jnp.pad(weights, (0, n_pad))
    if d_pad:
        x = jnp.pad(x, ((0, 0), (0, d_pad)))
    np_, dp = x.shape

    grid = (dp // bd, np_ // bn)
    kernel = functools.partial(_cluster_sum_kernel, k=k)
    s, v = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda di, ni: (ni, di)),
            pl.BlockSpec((bn,), lambda di, ni: (ni,)),
            pl.BlockSpec((bn,), lambda di, ni: (ni,)),
        ],
        out_specs=[
            pl.BlockSpec((k, bd), lambda di, ni: (0, di)),
            pl.BlockSpec((k,), lambda di, ni: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, dp), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        # the (k,) counts output block is revisited across BOTH grid dims
        # (it is only written when d_idx == 0), so the d dimension must be
        # sequential too — revisited output blocks are illegal on parallel
        # dims in Mosaic.
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(x, a, weights)
    return s[:, :d], v
