"""Jit'd public wrappers around the Pallas kernels.

On non-TPU backends (this container) the kernels run in interpret mode so
the kernel bodies execute exactly as written; on TPU they compile to Mosaic.
``backend="ref"`` routes to the pure-jnp oracle (used for tiny shapes where
padding to MXU tiles would dominate, and as the semantic fallback).
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.cluster_sum import cluster_sum_pallas
from repro.kernels.kmeans_assign import assign_top2_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _auto_backend(n: int, k: int) -> str:
    if _on_tpu():
        return "pallas"
    # interpret-mode pallas is a python-level emulation: correct but slow.
    # On CPU the oracle IS the fast path; pallas stays available for
    # explicit kernel validation.
    return "ref"


def assign_top2(x: jax.Array, c: jax.Array, *, backend: str | None = None,
                bn: int = 256, bk: int = 128):
    """(a, d1_sq, d2_sq): nearest / 2nd-nearest squared distances."""
    n, k = x.shape[0], c.shape[0]
    backend = backend or _auto_backend(n, k)
    if backend == "ref":
        return ref.assign_top2_ref(x, c)
    return assign_top2_pallas(x, c, bn=bn, bk=min(bk, _pad128(k)),
                              interpret=not _on_tpu())


def cluster_sum(x: jax.Array, a: jax.Array, k: int, *,
                weights: jax.Array | None = None,
                backend: str | None = None, bn: int = 256, bd: int = 256):
    """Weighted per-cluster sums S (k,d) and counts v (k,)."""
    backend = backend or _auto_backend(x.shape[0], k)
    if backend == "ref":
        return ref.cluster_sum_ref(x, a, k, weights=weights)
    s, v = cluster_sum_pallas(x, a, _pad128(k), weights=weights, bn=bn,
                              bd=bd, interpret=not _on_tpu())
    return s[:k], v[:k]


def _pad128(k: int) -> int:
    return k + (-k % 128)
