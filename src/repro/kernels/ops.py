"""Public kernel ops, dispatched through a resolved `KernelPlan`.

Engines resolve a plan ONCE per fit (`plan.resolve_plan`) and pass it
down; every op here takes ``plan=`` and launches accordingly. Legacy
callers that still hold a backend STRING (serve snapshots,
`NestedKMeans.predict`) pass ``backend=`` instead and get a per-bucket
cached plan resolved on the spot — same dispatch rules, no second code
path. On non-TPU platforms (this container) pallas runs in interpret
mode so the kernel bodies execute exactly as written; on TPU they
compile to Mosaic. ``"ref"`` routes to the pure-jnp oracle — the fast
path on CPU and the semantic baseline everywhere.
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.cluster_sum import cluster_sum_pallas
from repro.kernels.fused_round import (fused_nested_round_pallas,
                                       fused_nested_round_ref)
from repro.kernels.kmeans_assign import assign_top2_pallas
from repro.kernels.plan import KernelPlan, next_pow2, resolve_plan


def _pad128(k: int) -> int:
    return k + (-k % 128)


def _plan_for(plan: KernelPlan | None, backend: str | None, n: int,
              k: int, d: int) -> KernelPlan:
    """A resolved plan wins; otherwise resolve one from the legacy
    backend string (or None = auto) at this call's shape bucket."""
    if plan is not None:
        return plan
    return resolve_plan(backend, b=n, k=k, d=d)


def _clamp_bn(bn: int, n: int) -> int:
    """Row tile no larger than the (pow2-padded) batch: a plan tuned at
    b_max still launches sane grids for the small early nested rounds."""
    return max(8, min(bn, next_pow2(n)))


def assign_top2(x: jax.Array, c: jax.Array, *,
                plan: KernelPlan | None = None,
                backend: str | None = None):
    """(a, d1_sq, d2_sq): nearest / 2nd-nearest squared distances."""
    n, k = x.shape[0], c.shape[0]
    p = _plan_for(plan, backend, n, k, x.shape[1])
    if p.backend == "ref":
        return ref.assign_top2_ref(x, c)
    return assign_top2_pallas(x, c, bn=_clamp_bn(p.bn, n),
                              bk=min(p.bk, _pad128(k)),
                              interpret=p.interpret)


def cluster_sum(x: jax.Array, a: jax.Array, k: int, *,
                weights: jax.Array | None = None,
                plan: KernelPlan | None = None,
                backend: str | None = None):
    """Weighted per-cluster sums S (k,d) and counts v (k,)."""
    p = _plan_for(plan, backend, x.shape[0], k, x.shape[1])
    if p.backend == "ref":
        return ref.cluster_sum_ref(x, a, k, weights=weights)
    s, v = cluster_sum_pallas(x, a, _pad128(k), weights=weights,
                              bn=_clamp_bn(p.bn, x.shape[0]), bd=p.bd,
                              interpret=p.interpret)
    return s[:k], v[:k]


def fused_nested_round(x: jax.Array, c: jax.Array, a_prev: jax.Array,
                       settled: jax.Array, d_keep: jax.Array,
                       lb_keep: jax.Array, valid: jax.Array, *,
                       plan: KernelPlan | None = None):
    """Fused nested-round pass: assign + Hamerly keep-select + delta-S/v
    + sse in one sweep over x (see `fused_round.fused_nested_round_pallas`).

    Bound DECISIONS (the ``settled`` mask) stay with the caller
    (`core.rounds`) so the growth/bound schedule cannot drift between
    backends; this op only executes them.
    """
    n, k = x.shape[0], c.shape[0]
    p = _plan_for(plan, None, n, k, x.shape[1])
    if p.backend == "ref":
        return fused_nested_round_ref(x, c, a_prev, settled, d_keep,
                                      lb_keep, valid)
    return fused_nested_round_pallas(x, c, a_prev, settled, d_keep,
                                     lb_keep, valid,
                                     bn=_clamp_bn(p.bn, n),
                                     interpret=p.interpret)
