"""Fused k-means round kernel: assign + cluster-sum in ONE pass over X.

The paper's assignment step followed by the S/v/sse accumulation reads X
twice when expressed as separate ops (and XLA-CPU materialises another
3-5 staged intermediates — measured 1.8 TB vs the 0.27 TB single-pass
floor on kmeans_xl; EXPERIMENTS.md §Perf). On TPU the whole round fits a
single Pallas kernel:

  * the full centroid block C (k, d) stays VMEM-resident (k=4096, d=1024
    bf16 = 8 MiB against ~128 MiB VMEM),
  * grid over point tiles (sequential): each (bn, d) X tile is read from
    HBM exactly once; the MXU computes scores = X·Cᵀ; the VPU folds
    top-2 (argmin via one-hot max trick) and accumulates
        S += onehotᵀ·X       (MXU)
        v += Σ onehot, sse += Σ d²
    into revisited (k, d)/(k,) output blocks that never leave VMEM.

HBM traffic per round = |X| + |C| + |outputs| — the optimal single pass.
Distance identities: ||x-c||² = ||x||² - 2x·c + ||c||²; the scores matrix
only needs (-2x·c + ||c||²) for the argmin, ||x||² is added back on the
winning value only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.compat import CompilerParams


def _round_kernel(x_ref, c_ref, cn_ref, a_ref, d1_ref, d2_ref, s_ref,
                  v_ref, sse_ref, *, k: int):
    n_idx = pl.program_id(0)

    x = x_ref[...].astype(jnp.float32)            # (bn, d)
    c = c_ref[...].astype(jnp.float32)            # (k, d) VMEM-resident
    cn = cn_ref[...].astype(jnp.float32)          # (k,)

    xn = jnp.sum(x * x, axis=1, keepdims=True)    # (bn, 1)
    dot = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    # partial distance (no xn): argmin-equivalent, cheaper to fold
    pd = cn[None, :] - 2.0 * dot                  # (bn, k)

    b1 = jnp.min(pd, axis=1)
    a = jnp.argmin(pd, axis=1).astype(jnp.int32)
    cols = jax.lax.broadcasted_iota(jnp.int32, pd.shape, 1)
    b2 = jnp.min(jnp.where(cols == a[:, None], jnp.inf, pd), axis=1)

    d1 = jnp.maximum(b1 + xn[:, 0], 0.0)          # true squared distances
    d2 = jnp.maximum(b2 + xn[:, 0], 0.0)

    a_ref[...] = a
    d1_ref[...] = d1
    d2_ref[...] = d2

    onehot = (cols == a[:, None]).astype(jnp.float32)     # (bn, k)
    s_part = jax.lax.dot_general(onehot, x, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    v_part = jnp.sum(onehot, axis=0)
    sse_part = jnp.sum(onehot * d1[:, None], axis=0)

    @pl.when(n_idx == 0)
    def _init():
        s_ref[...] = s_part
        v_ref[...] = v_part
        sse_ref[...] = sse_part

    @pl.when(n_idx != 0)
    def _acc():
        s_ref[...] += s_part
        v_ref[...] += v_part
        sse_ref[...] += sse_part


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def fused_round_pallas(x: jax.Array, c: jax.Array, *, bn: int = 256,
                       interpret: bool = False):
    """One fused assignment+accumulation pass.

    x: (n, d), c: (k, d). Returns (a, d1_sq, d2_sq, S, v, sse) where S/v/
    sse are the per-cluster sums/counts/sse of THIS pass. n padded to bn;
    padded rows are masked out of the accumulators by the wrapper.
    """
    n, d = x.shape
    k = c.shape[0]
    n_pad = -n % bn
    cn = jnp.sum(c.astype(jnp.float32) ** 2, axis=1)
    if n_pad:
        x = jnp.pad(x, ((0, n_pad), (0, 0)))
    np_ = x.shape[0]

    kernel = functools.partial(_round_kernel, k=k)
    a, d1, d2, S, v, sse = pl.pallas_call(
        kernel,
        grid=(np_ // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), jnp.int32),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(x, c, cn)
    if n_pad:
        # padded rows were assigned to argmin over real centroids; remove
        # their contributions (they are all-zero rows: d1 = ||c_a||^2)
        pad_a = a[n:]
        pad_d1 = d1[n:]
        S = S.at[pad_a].add(-jnp.zeros((n_pad, d), jnp.float32))
        v = v.at[pad_a].add(-1.0)
        sse = sse.at[pad_a].add(-pad_d1)
    return a[:n], d1[:n], d2[:n], S, v, sse


def fused_round_ref(x: jax.Array, c: jax.Array):
    """Pure-jnp oracle for the fused round."""
    from repro.kernels import ref

    d2m = ref.pairwise_dist2(x, c)
    a = jnp.argmin(d2m, axis=1).astype(jnp.int32)
    d1 = jnp.min(d2m, axis=1)
    k = c.shape[0]
    cols = jnp.arange(k)[None, :]
    d2nd = jnp.min(jnp.where(cols == a[:, None], jnp.inf, d2m), axis=1)
    S, v = ref.cluster_sum_ref(x, a, k)
    sse = jax.ops.segment_sum(d1, a, num_segments=k)
    return a, d1, d2nd, S, v, sse


def _nested_kernel(x_ref, c_ref, cn_ref, ap_ref, keep_ref, dk_ref,
                   lbk_ref, vm_ref, a_ref, d_ref, lb_ref, s_ref, v_ref,
                   sse_ref, *, k: int):
    """One tile of the fused NESTED round (see `fused_nested_round_pallas`).

    The Hamerly bound DECISIONS arrive pre-made as the ``keep`` mask —
    the kernel only executes them, so the growth/bound schedule is
    identical between backends by construction. For kept rows the
    retained distance/bound (dk/lbk) pass straight through; everyone
    still pays the scores matmul because the dense nested path refreshes
    the second-closest bound for all rows each round.
    """
    n_idx = pl.program_id(0)

    x = x_ref[...].astype(jnp.float32)            # (bn, d)
    c = c_ref[...].astype(jnp.float32)            # (kp, d) VMEM-resident
    cn = cn_ref[...].astype(jnp.float32)          # (kp,) +inf on pads
    ap = ap_ref[...]                              # (bn,) prev assignment
    keep = keep_ref[...] != 0                     # settled: keep a_prev
    vm = vm_ref[...] != 0                         # valid (un-padded) rows

    # Full squared distances — the REF expression (xn - 2x·c + cn,
    # clamped), not the partial-distance trick of `_round_kernel`: label
    # parity with the ref round path is the contract here, and the two
    # expressions round differently at ties.
    xn = jnp.sum(x * x, axis=1, keepdims=True)
    dot = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    d2m = jnp.maximum(xn - 2.0 * dot + cn[None, :], 0.0)

    af = jnp.argmin(d2m, axis=1).astype(jnp.int32)
    b1 = jnp.min(d2m, axis=1)
    cols = jax.lax.broadcasted_iota(jnp.int32, d2m.shape, 1)
    b2 = jnp.min(jnp.where(cols == af[:, None], jnp.inf, d2m), axis=1)
    d1 = jnp.sqrt(b1)
    d2 = jnp.sqrt(b2)

    a_new = jnp.where(vm, jnp.where(keep, ap, af), -1)
    d_new = jnp.where(vm, jnp.where(keep, dk_ref[...], d1), 0.0)
    lb_new = jnp.where(vm, jnp.where(keep, lbk_ref[...], d2), 0.0)
    a_ref[...] = a_new
    d_ref[...] = d_new
    lb_ref[...] = lb_new

    # delta-S/v for already-seen points (rounds._delta_sv semantics),
    # folded into ONE matmul via a signed coefficient matrix: +1 at the
    # new cluster for joins, -1 at the old cluster for leaves. Masked
    # rows (a_new == -1) and grid pads carry zero coefficients, so no
    # post-hoc pad correction is needed.
    seen = ap >= 0
    changed = seen & (a_new != ap)
    w_rm = jnp.where(changed, 1.0, 0.0)
    w_add = jnp.where((changed | ~seen) & (a_new >= 0), 1.0, 0.0)
    add_oh = (cols == jnp.clip(a_new, 0, k - 1)[:, None]).astype(
        jnp.float32)
    rm_oh = (cols == jnp.clip(ap, 0, k - 1)[:, None]).astype(jnp.float32)
    coeff = w_add[:, None] * add_oh - w_rm[:, None] * rm_oh   # (bn, kp)
    s_part = jax.lax.dot_general(coeff, x, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    v_part = jnp.sum(coeff, axis=0)
    sse_part = jnp.sum(add_oh * (d_new * d_new)[:, None], axis=0)

    @pl.when(n_idx == 0)
    def _init():
        s_ref[...] = s_part
        v_ref[...] = v_part
        sse_ref[...] = sse_part

    @pl.when(n_idx != 0)
    def _acc():
        s_ref[...] += s_part
        v_ref[...] += v_part
        sse_ref[...] += sse_part


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def fused_nested_round_pallas(x: jax.Array, c: jax.Array,
                              a_prev: jax.Array, settled: jax.Array,
                              d_keep: jax.Array, lb_keep: jax.Array,
                              valid: jax.Array, *, bn: int = 256,
                              interpret: bool = False):
    """Fused nested-prefix round: assign + Hamerly keep-select +
    delta-S/v + sse in ONE pass over x.

    Inputs beyond (x, c): the previous assignment, the pre-computed
    ``settled`` mask (rows whose Hamerly s/2 / lower bound proved the
    assignment cannot change), the retained EUCLIDEAN distance and
    decayed lower bound for settled rows, and the valid-row mask.

    Returns (a_new, d_new, lb_new, dS, dv, sse): post-mask assignments
    (-1 on invalid rows), euclidean distance to the assigned centroid,
    the refreshed second-closest lower bound, the signed delta cluster
    sums/counts for seen points, and per-cluster sse of active members.
    """
    n, d = x.shape
    k = c.shape[0]
    kp = k + (-k % 128)
    cf = c.astype(jnp.float32)
    cn = jnp.sum(cf ** 2, axis=1)
    if kp != k:
        cf = jnp.pad(cf, ((0, kp - k), (0, 0)))
        cn = jnp.pad(cn, (0, kp - k), constant_values=jnp.inf)
    n_pad = -n % bn
    settled = settled.astype(jnp.int32)
    valid = valid.astype(jnp.int32)
    if n_pad:
        # pad rows: a_prev=-1 (unseen) + valid=0 ⇒ every coefficient and
        # sse term is zero; outputs are sliced off below.
        x = jnp.pad(x, ((0, n_pad), (0, 0)))
        a_prev = jnp.pad(a_prev, (0, n_pad), constant_values=-1)
        settled = jnp.pad(settled, (0, n_pad), constant_values=1)
        d_keep = jnp.pad(d_keep, (0, n_pad))
        lb_keep = jnp.pad(lb_keep, (0, n_pad))
        valid = jnp.pad(valid, (0, n_pad))
    np_ = x.shape[0]

    kernel = functools.partial(_nested_kernel, k=k)
    a, dn, lb, S, v, sse = pl.pallas_call(
        kernel,
        grid=(np_ // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((kp, d), lambda i: (0, 0)),
            pl.BlockSpec((kp,), lambda i: (0,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((kp, d), lambda i: (0, 0)),
            pl.BlockSpec((kp,), lambda i: (0,)),
            pl.BlockSpec((kp,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), jnp.int32),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
            jax.ShapeDtypeStruct((kp, d), jnp.float32),
            jax.ShapeDtypeStruct((kp,), jnp.float32),
            jax.ShapeDtypeStruct((kp,), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(x, cf, cn, a_prev, settled, d_keep, lb_keep, valid)
    return a[:n], dn[:n], lb[:n], S[:k], v[:k], sse[:k]


def fused_nested_round_ref(x: jax.Array, c: jax.Array, a_prev: jax.Array,
                           settled: jax.Array, d_keep: jax.Array,
                           lb_keep: jax.Array, valid: jax.Array):
    """Pure-jnp oracle mirroring the ref round path op for op."""
    from repro.kernels import ref

    k = c.shape[0]
    af, d1sq, d2sq = ref.assign_top2_ref(x, c)
    d1 = jnp.sqrt(jnp.maximum(d1sq, 0.0))
    d2 = jnp.sqrt(jnp.maximum(d2sq, 0.0))
    settled = settled.astype(bool)
    valid = valid.astype(bool)
    a_new = jnp.where(valid, jnp.where(settled, a_prev, af),
                      -1).astype(jnp.int32)
    d_new = jnp.where(valid, jnp.where(settled, d_keep, d1), 0.0)
    lb_new = jnp.where(valid, jnp.where(settled, lb_keep, d2), 0.0)
    seen = a_prev >= 0
    changed = seen & (a_new != a_prev)
    w_rm = jnp.where(changed, 1.0, 0.0).astype(jnp.float32)
    w_add = jnp.where((changed | ~seen) & (a_new >= 0),
                      1.0, 0.0).astype(jnp.float32)
    S_rm, v_rm = ref.cluster_sum_ref(x, jnp.clip(a_prev, 0, k - 1), k,
                                     weights=w_rm)
    S_add, v_add = ref.cluster_sum_ref(x, jnp.clip(a_new, 0, k - 1), k,
                                       weights=w_add)
    sse = jax.ops.segment_sum(d_new * d_new, jnp.clip(a_new, 0, k - 1),
                              num_segments=k)
    return (a_new, d_new, lb_new, S_add - S_rm, v_add - v_rm, sse)
