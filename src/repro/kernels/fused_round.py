"""Fused k-means round kernel: assign + cluster-sum in ONE pass over X.

The paper's assignment step followed by the S/v/sse accumulation reads X
twice when expressed as separate ops (and XLA-CPU materialises another
3-5 staged intermediates — measured 1.8 TB vs the 0.27 TB single-pass
floor on kmeans_xl; EXPERIMENTS.md §Perf). On TPU the whole round fits a
single Pallas kernel:

  * the full centroid block C (k, d) stays VMEM-resident (k=4096, d=1024
    bf16 = 8 MiB against ~128 MiB VMEM),
  * grid over point tiles (sequential): each (bn, d) X tile is read from
    HBM exactly once; the MXU computes scores = X·Cᵀ; the VPU folds
    top-2 (argmin via one-hot max trick) and accumulates
        S += onehotᵀ·X       (MXU)
        v += Σ onehot, sse += Σ d²
    into revisited (k, d)/(k,) output blocks that never leave VMEM.

HBM traffic per round = |X| + |C| + |outputs| — the optimal single pass.
Distance identities: ||x-c||² = ||x||² - 2x·c + ||c||²; the scores matrix
only needs (-2x·c + ||c||²) for the argmin, ||x||² is added back on the
winning value only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.compat import CompilerParams


def _round_kernel(x_ref, c_ref, cn_ref, a_ref, d1_ref, d2_ref, s_ref,
                  v_ref, sse_ref, *, k: int):
    n_idx = pl.program_id(0)

    x = x_ref[...].astype(jnp.float32)            # (bn, d)
    c = c_ref[...].astype(jnp.float32)            # (k, d) VMEM-resident
    cn = cn_ref[...].astype(jnp.float32)          # (k,)

    xn = jnp.sum(x * x, axis=1, keepdims=True)    # (bn, 1)
    dot = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    # partial distance (no xn): argmin-equivalent, cheaper to fold
    pd = cn[None, :] - 2.0 * dot                  # (bn, k)

    b1 = jnp.min(pd, axis=1)
    a = jnp.argmin(pd, axis=1).astype(jnp.int32)
    cols = jax.lax.broadcasted_iota(jnp.int32, pd.shape, 1)
    b2 = jnp.min(jnp.where(cols == a[:, None], jnp.inf, pd), axis=1)

    d1 = jnp.maximum(b1 + xn[:, 0], 0.0)          # true squared distances
    d2 = jnp.maximum(b2 + xn[:, 0], 0.0)

    a_ref[...] = a
    d1_ref[...] = d1
    d2_ref[...] = d2

    onehot = (cols == a[:, None]).astype(jnp.float32)     # (bn, k)
    s_part = jax.lax.dot_general(onehot, x, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    v_part = jnp.sum(onehot, axis=0)
    sse_part = jnp.sum(onehot * d1[:, None], axis=0)

    @pl.when(n_idx == 0)
    def _init():
        s_ref[...] = s_part
        v_ref[...] = v_part
        sse_ref[...] = sse_part

    @pl.when(n_idx != 0)
    def _acc():
        s_ref[...] += s_part
        v_ref[...] += v_part
        sse_ref[...] += sse_part


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def fused_round_pallas(x: jax.Array, c: jax.Array, *, bn: int = 256,
                       interpret: bool = False):
    """One fused assignment+accumulation pass.

    x: (n, d), c: (k, d). Returns (a, d1_sq, d2_sq, S, v, sse) where S/v/
    sse are the per-cluster sums/counts/sse of THIS pass. n padded to bn;
    padded rows are masked out of the accumulators by the wrapper.
    """
    n, d = x.shape
    k = c.shape[0]
    n_pad = -n % bn
    cn = jnp.sum(c.astype(jnp.float32) ** 2, axis=1)
    if n_pad:
        x = jnp.pad(x, ((0, n_pad), (0, 0)))
    np_ = x.shape[0]

    kernel = functools.partial(_round_kernel, k=k)
    a, d1, d2, S, v, sse = pl.pallas_call(
        kernel,
        grid=(np_ // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), jnp.int32),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(x, c, cn)
    if n_pad:
        # padded rows were assigned to argmin over real centroids; remove
        # their contributions (they are all-zero rows: d1 = ||c_a||^2)
        pad_a = a[n:]
        pad_d1 = d1[n:]
        S = S.at[pad_a].add(-jnp.zeros((n_pad, d), jnp.float32))
        v = v.at[pad_a].add(-1.0)
        sse = sse.at[pad_a].add(-pad_d1)
    return a[:n], d1[:n], d2[:n], S, v, sse


def fused_round_ref(x: jax.Array, c: jax.Array):
    """Pure-jnp oracle for the fused round."""
    from repro.kernels import ref

    d2m = ref.pairwise_dist2(x, c)
    a = jnp.argmin(d2m, axis=1).astype(jnp.int32)
    d1 = jnp.min(d2m, axis=1)
    k = c.shape[0]
    cols = jnp.arange(k)[None, :]
    d2nd = jnp.min(jnp.where(cols == a[:, None], jnp.inf, d2m), axis=1)
    S, v = ref.cluster_sum_ref(x, a, k)
    sse = jax.ops.segment_sum(d1, a, num_segments=k)
    return a, d1, d2nd, S, v, sse
