"""Fused pairwise-distance + top-2 argmin Pallas TPU kernel.

The k-means assignment hot spot. For a tile of points the MXU computes the
``x @ c.T`` Gram block while the VPU fuses the ``|x|^2 - 2 x.c + |c|^2``
expansion and a running (min, 2nd-min, argmin) reduction carried across the
centroid grid dimension in the (revisited) output blocks.

Grid: (n_blocks, k_blocks) with the k dimension sequential ("arbitrary") so
output blocks act as accumulators; the point dimension is parallel.

BlockSpecs keep an (bn, d) X tile and a (bk, d) centroid tile resident in
VMEM; bn/bk default to MXU-aligned 256/128. d is kept whole per tile —
k-means dims (784/1024/2048) fit comfortably: a 256x2048 f32 tile is 2 MiB
against ~16 MiB VMEM.

Padded centroids carry +inf norms so they can never win the argmin; padded
points produce garbage rows that the wrapper slices off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.compat import CompilerParams

_NEG_BIG = float("inf")   # python literal: pallas kernels may not capture
                          # traced constants


def _assign_kernel(x_ref, c_ref, cn_ref, a_ref, d1_ref, d2_ref, *, bk: int):
    """One (i, k) grid step: fold centroid tile k into running top-2."""
    k_idx = pl.program_id(1)

    x = x_ref[...].astype(jnp.float32)             # (bn, d)
    c = c_ref[...].astype(jnp.float32)             # (bk, d)
    cn = cn_ref[...].astype(jnp.float32)           # (bk,)

    xn = jnp.sum(x * x, axis=1, keepdims=True)     # (bn, 1)
    dot = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)        # (bn, bk) on the MXU
    d2 = jnp.maximum(xn - 2.0 * dot + cn[None, :], 0.0)
    # padded centroids have cn = +inf -> d2 = +inf, never selected

    # top-2 within this centroid tile
    b1 = jnp.min(d2, axis=1)                                    # (bn,)
    bi = jnp.argmin(d2, axis=1).astype(jnp.int32) + k_idx * bk  # global idx
    col = jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1) + k_idx * bk
    d2_wo_min = jnp.where(col == bi[:, None], _NEG_BIG, d2)
    b2 = jnp.min(d2_wo_min, axis=1)

    @pl.when(k_idx == 0)
    def _init():
        a_ref[...] = bi
        d1_ref[...] = b1
        d2_ref[...] = b2

    @pl.when(k_idx != 0)
    def _fold():
        r1 = d1_ref[...]
        r2 = d2_ref[...]
        ri = a_ref[...]
        new1 = jnp.minimum(r1, b1)
        newi = jnp.where(b1 < r1, bi, ri)
        new2 = jnp.minimum(jnp.maximum(r1, b1), jnp.minimum(r2, b2))
        a_ref[...] = newi
        d1_ref[...] = new1
        d2_ref[...] = new2


@functools.partial(jax.jit, static_argnames=("bn", "bk", "interpret"))
def assign_top2_pallas(x: jax.Array, c: jax.Array, *, bn: int = 256,
                       bk: int = 128, interpret: bool = False):
    """(a, d1, d2) = fused nearest/2nd-nearest centroid search.

    x: (n, d); c: (k, d). Returns int32 (n,), f32 (n,), f32 (n,) with
    SQUARED distances. n is padded to bn, k to bk internally.
    """
    n, d = x.shape
    k = c.shape[0]
    n_pad = -n % bn
    k_pad = -k % bk

    cn = jnp.sum(c.astype(jnp.float32) ** 2, axis=1)
    if k_pad:
        c = jnp.pad(c, ((0, k_pad), (0, 0)))
        cn = jnp.pad(cn, (0, k_pad), constant_values=jnp.inf)
    if n_pad:
        x = jnp.pad(x, ((0, n_pad), (0, 0)))
    np_, kp = x.shape[0], c.shape[0]

    grid = (np_ // bn, kp // bk)
    kernel = functools.partial(_assign_kernel, bk=bk)
    a, d1, d2 = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bk,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), jnp.int32),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, c, cn)
    return a[:n], d1[:n], d2[:n]
