"""Training and serving step builders (the functions the launcher jits).

``make_train_step`` builds one SPMD program:
  batch (B_global, S) -> reshape (n_micro, B/n_micro, S) -> lax.scan of
  value_and_grad microbatches with f32 grad accumulation (remat'ed
  backbone) -> AdamW update.

Gradient reductions across data shards and FSDP all-gathers are inserted
by GSPMD from the parameter shardings; the scan-over-microbatches keeps
peak logits memory to one microbatch.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.optim import adamw


def shard_batch(batch: Dict[str, jax.Array], n_micro: int):
    def r(x):
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])
    return jax.tree.map(r, batch)


def make_train_step(cfg: ModelConfig, *, n_micro: int = 1,
                    opt_cfg: Optional[adamw.AdamWConfig] = None,
                    remat: bool = True, accum_dtype=jnp.float32):
    """``accum_dtype``: gradient-accumulation buffer dtype. f32 default;
    the launcher selects bf16 for >100B-param models where the extra
    2 bytes/param of accumulator doesn't fit HBM (documented trade-off —
    16 bf16 adds keep ~3 significand bits of headroom)."""
    opt_cfg = opt_cfg or adamw.AdamWConfig()

    def loss_fn(params, mb):
        return M.train_loss(params, mb, cfg, remat=remat)

    def train_step(params, opt_state: adamw.AdamWState,
                   batch: Dict[str, jax.Array]):
        mbs = shard_batch(batch, n_micro)

        def micro(carry, mb):
            acc, loss_sum = carry
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(a.dtype), acc, grads)
            return (acc, loss_sum + loss), None

        acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype),
                            params)
        (grads, loss_sum), _ = jax.lax.scan(
            micro, (acc0, jnp.zeros((), jnp.float32)), mbs)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) / n_micro,
                             grads)
        params2, opt2, om = adamw.update(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss_sum / n_micro, **om}
        return params2, opt2, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, *, cache_len: int):
    def prefill_step(params, batch):
        return M.prefill(params, batch, cfg, cache_len=cache_len)
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, token, cache):
        return M.decode_step(params, token, cache, cfg)
    return decode_step
