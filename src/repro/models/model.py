"""Unified model zoo: one scan-friendly decoder covering all six families.

Layers are stacked per *period position* and scanned over periods, so the
HLO stays one-period-sized regardless of depth (compile-time critical at
512 SPMD partitions):

  family    period   position structure
  dense      1       [attn + mlp]
  moe(all)   1       [attn + moe]
  moe(alt)   2       [attn + mlp, attn + moe]
  ssm        1       [mamba]
  hybrid     8       [attn|mamba at t==0|t>0; moe on odd t]   (jamba)
  encdec     1       encoder [bidir attn + mlp], decoder
                     [self attn + cross attn + mlp]           (whisper)
  vlm        1       dense decoder + patch-embedding prefix   (internvl2)

Entry points: init_params / train_logits_and_loss / prefill / decode.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# period structure
# --------------------------------------------------------------------------

def period_len(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.hybrid_period
    if cfg.moe is not None and cfg.moe.layout == "alternate":
        return 2
    return 1


def n_periods(cfg: ModelConfig) -> int:
    pl = period_len(cfg)
    assert cfg.n_layers % pl == 0, (cfg.n_layers, pl)
    return cfg.n_layers // pl


def pos_is_attn(cfg: ModelConfig, t: int) -> bool:
    return cfg.is_attention_layer(t)


def pos_is_moe(cfg: ModelConfig, t: int) -> bool:
    return cfg.is_moe_layer(t)


def pos_has_ffn(cfg: ModelConfig, t: int) -> bool:
    return cfg.family != "ssm"


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _init_position(key, cfg: ModelConfig, t: int) -> Params:
    """Params for one layer at period-position t."""
    ks = iter(jax.random.split(key, 8))
    d = cfg.d_model
    p: Params = {"ln1": jnp.ones((d,), L.PDTYPE)}
    if pos_is_attn(cfg, t):
        p["attn"] = L.init_attention(next(ks), cfg)
    else:
        p["mamba"] = L.init_mamba(next(ks), d, cfg.ssm)
    if cfg.family == "encdec":
        p["ln_x"] = jnp.ones((d,), L.PDTYPE)
        p["xattn"] = L.init_cross_attention(next(ks), cfg)
    if pos_has_ffn(cfg, t):
        p["ln2"] = jnp.ones((d,), L.PDTYPE)
        if pos_is_moe(cfg, t):
            p["moe"] = L.init_moe(next(ks), d, cfg.moe)
        else:
            p["mlp"] = L.init_mlp(next(ks), d, cfg.d_ff)
    return p


def _init_stacked(key, cfg: ModelConfig) -> Dict[str, Params]:
    """{pos_t: params stacked over periods} — scan xs."""
    np_, pl = n_periods(cfg), period_len(cfg)
    out = {}
    for t in range(pl):
        keys = jax.random.split(jax.random.fold_in(key, t), np_)
        out[str(t)] = jax.vmap(lambda k_: _init_position(k_, cfg, t))(keys)
    return out


def _init_encoder(key, cfg: ModelConfig) -> Params:
    """Whisper-style encoder stack (bidirectional, sinusoidal pos)."""
    enc_cfg = dataclasses.replace(cfg, attn_bias=False)
    np_ = cfg.encoder.n_layers
    keys = jax.random.split(key, np_)

    def one(k_):
        k1, k2 = jax.random.split(k_)
        d = cfg.d_model
        return {"ln1": jnp.ones((d,), L.PDTYPE),
                "attn": L.init_attention(k1, enc_cfg),
                "ln2": jnp.ones((d,), L.PDTYPE),
                "mlp": L.init_mlp(k2, d, cfg.d_ff)}

    return jax.vmap(one)(keys)


def init_params(key, cfg: ModelConfig) -> Params:
    ks = iter(jax.random.split(key, 8))
    d, v = cfg.d_model, cfg.vocab
    p: Params = {
        "embed": (jax.random.normal(next(ks), (v, d), jnp.float32)
                  * d ** -0.5).astype(L.PDTYPE),
        "blocks": _init_stacked(next(ks), cfg),
        "ln_f": jnp.ones((d,), L.PDTYPE),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(next(ks), d, v)
    if cfg.family == "encdec":
        p["encoder"] = _init_encoder(next(ks), cfg)
        if cfg.encoder.d_frontend != d:
            p["enc_in"] = L.dense_init(next(ks), cfg.encoder.d_frontend, d)
    return p


# --------------------------------------------------------------------------
# sinusoidal positions (whisper)
# --------------------------------------------------------------------------

def sinusoid(S: int, d: int) -> jax.Array:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, jnp.float32) * (-jnp.log(1e4) / d))
    pe = jnp.zeros((S, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe.astype(L.CDTYPE)


# --------------------------------------------------------------------------
# forward: full-sequence (train / prefill)
# --------------------------------------------------------------------------

def _layer_full(p: Params, x, cfg: ModelConfig, t: int, *, positions,
                enc_out, want_cache: bool):
    """One layer, full sequence. Returns (x, aux, cache_entry)."""
    aux = jnp.zeros((), jnp.float32)
    cache = {}
    use_rope = cfg.family != "encdec"
    if pos_is_attn(cfg, t):
        h, (k_, v_) = L.attention_fwd(p["attn"], L.rms_norm(
            x, p["ln1"], cfg.norm_eps), cfg, positions=positions,
            causal=True, use_rope=use_rope)
        x = x + h
        if want_cache:
            cache["kv"] = (k_, v_)
    else:
        h = L.mamba_fwd(p["mamba"], L.rms_norm(x, p["ln1"], cfg.norm_eps),
                        cfg.ssm, cfg.d_model,
                        return_state=want_cache)
        if want_cache:
            h, st = h
            cache["ssm"] = st
        x = x + h
    if cfg.family == "encdec":
        x = x + L.cross_attention_fwd(
            p["xattn"], L.rms_norm(x, p["ln_x"], cfg.norm_eps),
            L.cross_kv(p["xattn"], enc_out, cfg), cfg)
    if pos_has_ffn(cfg, t):
        h_in = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        if pos_is_moe(cfg, t):
            h, a = L.moe_fwd(p["moe"], h_in, cfg.moe)
            aux = aux + a
        else:
            h = L.mlp_fwd(p["mlp"], h_in)
        x = x + h
    return x, aux, cache


def backbone_full(params: Params, x, cfg: ModelConfig, *, positions,
                  enc_out=None, want_cache: bool = False,
                  remat: bool = True):
    """Scan the stacked blocks over a full sequence."""
    pl = period_len(cfg)

    def period_body(carry, pparams):
        x, aux = carry
        caches = {}
        for t in range(pl):
            x, a, c = _layer_full(pparams[str(t)], x, cfg, t,
                                  positions=positions, enc_out=enc_out,
                                  want_cache=want_cache)
            x = L.constrain(x, "dp", None, None)
            aux = aux + a
            if c:
                caches[str(t)] = c
        return (x, aux), caches

    body = period_body
    if remat:
        body = jax.checkpoint(
            period_body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                    params["blocks"])
    return x, aux, caches


def encode(params: Params, frames, cfg: ModelConfig):
    """Whisper encoder: precomputed frame embeddings -> context."""
    x = frames.astype(L.CDTYPE)
    if "enc_in" in params:
        x = x @ params["enc_in"]
    x = x + sinusoid(x.shape[1], cfg.d_model)[None]

    def body(x, p):
        h, _ = L.attention_fwd(
            p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps), cfg,
            positions=jnp.arange(x.shape[1])[None], causal=False,
            use_rope=False)
        x = x + h
        x = x + L.mlp_fwd(p["mlp"], L.rms_norm(x, p["ln2"], cfg.norm_eps))
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return x


def embed_inputs(params: Params, batch: Dict[str, jax.Array],
                 cfg: ModelConfig):
    """tokens (+ modality prefix) -> (x, positions, enc_out)."""
    tokens = batch["tokens"]
    x = params["embed"][tokens].astype(L.CDTYPE)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = encode(params, batch["frames"], cfg)
        x = x + sinusoid(x.shape[1], cfg.d_model)[None]
    if cfg.family == "vlm":
        # precomputed patch embeddings prefixed to the token sequence
        x = jnp.concatenate([batch["patches"].astype(L.CDTYPE), x], axis=1)
    S = x.shape[1]
    x = L.constrain(x, "dp", None, None)
    positions = jnp.arange(S)[None]
    return x, positions, enc_out


def logits_fn(params: Params, x, cfg: ModelConfig):
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return L.constrain((x @ w).astype(jnp.float32), "dp", None, "tp")


def train_loss(params: Params, batch: Dict[str, jax.Array],
               cfg: ModelConfig, *, remat: bool = True):
    """Token-mean cross entropy (+ MoE aux). labels==-100 masked out."""
    x, positions, enc_out = embed_inputs(params, batch, cfg)
    x, aux, _ = backbone_full(params, x, cfg, positions=positions,
                              enc_out=enc_out, remat=remat)
    if cfg.family == "vlm":   # strip the patch prefix before the LM loss
        x = x[:, batch["patches"].shape[1]:]
    logits = logits_fn(params, x, cfg)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    lab = jnp.maximum(labels, 0)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + 0.01 * aux, {"xent": loss, "aux": aux}


# --------------------------------------------------------------------------
# serving: prefill + decode
# --------------------------------------------------------------------------

def prefill(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            *, cache_len: int):
    """Run the prompt, return (last-token logits, decode cache).

    Attention K/V caches are allocated at ``cache_len`` and filled with the
    prompt prefix; SSM layers keep their (state, conv) carry.
    """
    x, positions, enc_out = embed_inputs(params, batch, cfg)
    B, S = x.shape[0], x.shape[1]
    x, _, caches = backbone_full(params, x, cfg, positions=positions,
                                 enc_out=enc_out, want_cache=True,
                                 remat=False)
    logits = logits_fn(params, x[:, -1:], cfg)

    out: Dict[str, Any] = {"pos": jnp.asarray(S, jnp.int32)}
    blocks = {}
    for t, c in caches.items():
        ent = {}
        if "kv" in c:
            k_, v_ = c["kv"]   # (n_periods, B, S, KV, Dh)
            pad = cache_len - S
            ent["k"] = jnp.pad(k_, ((0, 0), (0, 0), (0, pad), (0, 0),
                                    (0, 0)))
            ent["v"] = jnp.pad(v_, ((0, 0), (0, 0), (0, pad), (0, 0),
                                    (0, 0)))
        if "ssm" in c:
            ent["ssm"] = c["ssm"]["ssm"]
            ent["conv"] = c["ssm"]["conv"]
        blocks[t] = ent
    out["blocks"] = blocks
    if cfg.family == "encdec":
        out["enc_out"] = enc_out
    return logits, out


def make_decode_cache(cfg: ModelConfig, *, batch: int, cache_len: int,
                      dtype=L.CDTYPE) -> Dict[str, Any]:
    """Zero-initialised cache pytree (used for dry-run input specs)."""
    np_, pl = n_periods(cfg), period_len(cfg)
    blocks = {}
    for t in range(pl):
        ent: Dict[str, Any] = {}
        if pos_is_attn(cfg, t):
            shp = (np_, batch, cache_len, cfg.n_kv_heads, cfg.head_dim)
            ent["k"] = jnp.zeros(shp, dtype)
            ent["v"] = jnp.zeros(shp, dtype)
        else:
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            nh = d_in // s.head_dim
            gn = s.n_groups * s.d_state
            ent["ssm"] = jnp.zeros((np_, batch, nh, s.head_dim, s.d_state),
                                   jnp.float32)
            ent["conv"] = {
                "x": jnp.zeros((np_, batch, s.d_conv - 1, d_in), dtype),
                "bc": jnp.zeros((np_, batch, s.d_conv - 1, 2 * gn), dtype)}
        blocks[str(t)] = ent
    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32),
                             "blocks": blocks}
    if cfg.family == "encdec":
        cache["enc_out"] = jnp.zeros((batch, cfg.encoder.n_ctx,
                                      cfg.d_model), dtype)
    return cache


def decode_step(params: Params, token: jax.Array, cache: Dict[str, Any],
                cfg: ModelConfig):
    """One decode step. token: (B, 1) int32. Returns (logits, new cache)."""
    x = params["embed"][token].astype(L.CDTYPE)
    pos = cache["pos"]
    if cfg.family == "encdec":
        x = x + sinusoid_at(pos, cfg.d_model)[None, None]
    enc_out = cache.get("enc_out")
    pl = period_len(cfg)

    def period_body(x, inp):
        pparams, pcache = inp
        new_cache = {}
        for t in range(pl):
            p = pparams[str(t)]
            ent = pcache[str(t)]
            h_in = L.rms_norm(x, p["ln1"], cfg.norm_eps)
            if pos_is_attn(cfg, t):
                h, (k_, v_) = L.attention_decode_fwd(
                    p["attn"], h_in, cfg, k_cache=ent["k"],
                    v_cache=ent["v"], pos=pos,
                    use_rope=cfg.family != "encdec")
                new_cache[str(t)] = {"k": k_, "v": v_}
            else:
                h, st = L.mamba_decode_fwd(
                    p["mamba"], h_in, cfg.ssm, cfg.d_model,
                    {"ssm": ent["ssm"], "conv": ent["conv"]})
                new_cache[str(t)] = {"ssm": st["ssm"], "conv": st["conv"]}
            x = x + h
            if cfg.family == "encdec":
                x = x + L.cross_attention_fwd(
                    p["xattn"], L.rms_norm(x, p["ln_x"], cfg.norm_eps),
                    L.cross_kv(p["xattn"], enc_out, cfg), cfg)
            if pos_has_ffn(cfg, t):
                h_in2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
                if pos_is_moe(cfg, t):
                    h, _ = L.moe_fwd(p["moe"], h_in2, cfg.moe)
                else:
                    h = L.mlp_fwd(p["mlp"], h_in2)
                x = x + h
        return x, new_cache

    x, new_blocks = jax.lax.scan(period_body, x,
                                 (params["blocks"], cache["blocks"]))
    logits = logits_fn(params, x, cfg)
    new_cache = dict(cache)
    new_cache["blocks"] = new_blocks
    new_cache["pos"] = pos + 1
    return logits, new_cache


def sinusoid_at(pos: jax.Array, d: int) -> jax.Array:
    div = jnp.exp(jnp.arange(0, d, 2, jnp.float32) * (-jnp.log(1e4) / d))
    ang = pos.astype(jnp.float32) * div
    pe = jnp.zeros((d,), jnp.float32)
    pe = pe.at[0::2].set(jnp.sin(ang))
    pe = pe.at[1::2].set(jnp.cos(ang))
    return pe.astype(L.CDTYPE)
