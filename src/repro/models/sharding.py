"""GSPMD sharding rules for params, activations, caches and batches.

Baseline layout (MaxText-style TP + FSDP):
  * batch / tokens          -> data axes ("pod", "data")
  * attention heads, FFN hidden, experts, vocab -> "model" (TP / EP)
  * the non-TP dim of every weight additionally shards over "data" (FSDP,
    ZeRO-3 storage; XLA all-gathers per layer inside the scan)
  * per-arch fallback: archs whose head/expert counts don't divide the
    model axis (whisper-tiny: 6 heads) keep those weights TP-replicated —
    recorded by `tp_ok()`.

KV caches: batch -> data axes when divisible; KV heads -> "model" when
divisible, otherwise the SEQUENCE dim -> "model" (decode_attention is
written as reductions over S, so a sequence-sharded cache lowers to
flash-decoding partial-softmax all-reduces).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

TP = "model"


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != TP)


def axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def tp_ok(cfg: ModelConfig, mesh: Mesh) -> bool:
    """Can attention heads shard over the model axis for this arch?"""
    return cfg.n_heads % mesh.shape[TP] == 0


def kv_tp_ok(cfg: ModelConfig, mesh: Mesh) -> bool:
    return cfg.n_kv_heads % mesh.shape[TP] == 0


# --------------------------------------------------------------------------
# parameter rules
# --------------------------------------------------------------------------

def _leaf_rule(path: str, ndim: int, cfg: ModelConfig, mesh: Mesh,
               fsdp: str) -> P:
    """PartitionSpec for one param leaf. ``path`` is dot-joined key names.

    Stacked block params carry a leading period axis (never sharded) —
    handled by padding the rule with a leading None when ndim exceeds the
    base rank.
    """
    name = path.split(".")[-1]
    in_attn = ".attn." in path or path.endswith("attn") or ".xattn." in path
    attn_tp = TP if tp_ok(cfg, mesh) else None

    if name == "embed":
        return P(TP, None)                       # vocab-sharded rows
    if name == "lm_head":
        return P(fsdp, TP)
    if name == "enc_in":
        return P(None, fsdp)

    def stacked(*spec):
        return P(*([None] * (ndim - len(spec)) + list(spec)))

    if name in ("wq", "wk", "wv"):
        if in_attn:
            return stacked(fsdp, attn_tp)
        return stacked(fsdp, TP)                 # unreachable, safety
    if name == "wo":
        return stacked(attn_tp, fsdp)
    if name in ("bq", "bk", "bv"):
        return stacked(attn_tp)
    if name in ("w_gate", "w_up"):
        if ndim >= 3 and ".moe." in path:        # (L, E, D, F)
            return stacked(TP, fsdp, None)
        return stacked(fsdp, TP)
    if name == "w_down":
        if ndim >= 3 and ".moe." in path:        # (L, E, F, D)
            return stacked(TP, fsdp, None)
        return stacked(TP, fsdp)
    if name == "router":
        return stacked(fsdp, None)
    # mamba
    if name in ("wz", "wx"):
        return stacked(fsdp, TP)                 # d_inner over TP (heads)
    if name == "wdt":
        return stacked(fsdp, TP)                 # heads over TP
    if name in ("wB", "wC"):
        return stacked(fsdp, None)               # small shared groups
    if name == "conv_x":
        return stacked(None, TP)
    if name == "conv_bc":
        return stacked(None, None)
    if name in ("A_log", "D", "dt_bias"):
        return stacked(TP)
    if name == "norm":
        return stacked(TP)                       # (d_inner,) TP-sharded
    if name == "out_proj":
        return stacked(TP, fsdp)
    # norms / anything small: replicated
    return P(*([None] * ndim))


def _path_str(path) -> str:
    out = []
    for e in path:
        if isinstance(e, jax.tree_util.DictKey):
            out.append(str(e.key))
        else:
            out.append(str(e))
    return ".".join(out)


def param_specs(cfg: ModelConfig, mesh: Mesh, params_shape) -> Any:
    """PartitionSpec pytree for a params tree (arrays OR ShapeDtypeStructs).

    FSDP dim uses "data" (per-pod ZeRO-3); params stay replicated across
    "pod" so the cross-DCI traffic per step is one gradient all-reduce.
    """
    fsdp = "data" if "data" in mesh.axis_names else None

    def rule(path, leaf):
        spec = _leaf_rule(_path_str(path), leaf.ndim, cfg, mesh, fsdp)
        # divisibility guard: drop axes that don't divide
        fixed = []
        for dim, ax in enumerate(spec):
            if ax is None:
                fixed.append(None)
                continue
            size = axis_size(mesh, ax)
            fixed.append(ax if leaf.shape[dim] % size == 0 else None)
        return P(*fixed)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def param_shardings(cfg: ModelConfig, mesh: Mesh, params_shape) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(cfg, mesh, params_shape))


# --------------------------------------------------------------------------
# batch / activation / cache rules
# --------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, mesh: Mesh, batch_shape) -> Any:
    dp = data_axes(mesh)

    def rule(path, leaf):
        name = _path_str(path).split(".")[-1]
        bdim = leaf.shape[0]
        b_ax = dp if bdim % axis_size(mesh, dp) == 0 else None
        if name in ("tokens", "labels"):
            return P(b_ax, None)
        if name in ("frames", "patches"):
            return P(b_ax, None, None)
        return P(*([b_ax] + [None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map_with_path(rule, batch_shape)


def cache_specs(cfg: ModelConfig, mesh: Mesh, cache_shape) -> Any:
    dp = data_axes(mesh)
    dp_total = axis_size(mesh, dp)
    kv_on_tp = kv_tp_ok(cfg, mesh)

    def rule(path, leaf):
        pstr = _path_str(path)
        name = pstr.split(".")[-1]
        if name == "pos":
            return P()
        if name == "enc_out":                    # (B, ctx, D)
            b_ax = dp if leaf.shape[0] % dp_total == 0 else None
            return P(b_ax, None, None)
        if name in ("k", "v"):                   # (L, B, S, KV, Dh)
            b_ax = dp if leaf.shape[1] % dp_total == 0 else None
            if kv_on_tp:
                return P(None, b_ax, None, TP, None)
            return P(None, b_ax, TP, None, None)   # sequence-sharded
        if name == "ssm":                        # (L, B, nh, hd, N)
            b_ax = dp if leaf.shape[1] % dp_total == 0 else None
            nh_ax = TP if leaf.shape[2] % mesh.shape[TP] == 0 else None
            return P(None, b_ax, nh_ax, None, None)
        if name in ("x", "bc"):                  # conv state (L,B,w,C)
            b_ax = dp if leaf.shape[1] % dp_total == 0 else None
            c_ax = TP if (name == "x"
                          and leaf.shape[3] % mesh.shape[TP] == 0) else None
            return P(None, b_ax, None, c_ax)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def tree_shardings(mesh: Mesh, spec_tree) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def activation_spec(mesh: Mesh, cfg: ModelConfig) -> P:
    """(B, S, D) residual-stream constraint."""
    return P(data_axes(mesh), None, None)
