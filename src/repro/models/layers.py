"""Model-zoo building blocks: norms, RoPE, attention, MLP, MoE, Mamba2 SSD.

Everything is functional: ``init_*`` returns a params dict, ``*_fwd`` maps
(params, activations) -> activations. Params are stored bf16 (production
mixed precision); norms, softmax, SSD decays and loss run in f32.

Attention comes in three entry points:
  * ``flash_attention``   training/prefill: two-level chunked running-max
                          softmax (q-chunk scan over kv-chunk scan), peak
                          memory q_chunk x kv_chunk regardless of S.
  * ``decode_attention``  one new token against a (B, S, KV, Dh) cache;
                          written as reductions over the cache's S dim so
                          GSPMD turns a sequence-sharded cache into
                          flash-decoding-style partial-softmax collectives.
  * ``cross_attention``   enc-dec (whisper): full (non-causal) attention
                          against a precomputed encoder context.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

Params = Dict[str, Any]
PDTYPE = jnp.bfloat16   # parameter storage dtype
CDTYPE = jnp.bfloat16   # activation compute dtype


# --------------------------------------------------------------------------
# activation sharding constraints (no-ops outside a jax.set_mesh context)
# --------------------------------------------------------------------------

def _ambient_mesh():
    """The mesh visible here — abstract inside jit traces, else concrete."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is not None and not mesh.empty:
            return mesh
    except Exception:
        pass
    try:
        mesh = jax.sharding.get_mesh()
        if mesh is not None and not mesh.empty:
            return mesh
    except Exception:
        pass
    return None


def _mesh_axes() -> tuple:
    mesh = _ambient_mesh()
    return tuple(mesh.axis_names) if mesh is not None else ()


def dp_axes() -> tuple:
    return tuple(a for a in _mesh_axes() if a != "model")


def constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint against the ambient mesh.

    spec entries: "dp" -> the data axes tuple, "tp" -> "model" (dropped if
    the dim doesn't divide), None -> unsharded. No mesh set -> identity,
    so reduced-config smoke tests run unchanged on one device.
    """
    axes = _mesh_axes()
    if not axes:
        return x
    import numpy as _np
    mesh = _ambient_mesh()
    out = []
    for dim, s in enumerate(spec):
        if s == "dp":
            ax = dp_axes()
            size = int(_np.prod([mesh.shape[a] for a in ax]))
            out.append(ax if ax and x.shape[dim] % size == 0 else None)
        elif s == "tp":
            ok = "model" in axes and x.shape[dim] % mesh.shape["model"] == 0
            out.append("model" if ok else None)
        else:
            out.append(None)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*out))


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, *, scale: float | None = None,
               dtype=PDTYPE):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float
               ) -> jax.Array:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, Dh/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, cfg.q_dim()),
        "wk": dense_init(ks[1], d, cfg.kv_dim()),
        "wv": dense_init(ks[2], d, cfg.kv_dim()),
        "wo": dense_init(ks[3], cfg.q_dim(), d),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((cfg.q_dim(),), PDTYPE)
        p["bk"] = jnp.zeros((cfg.kv_dim(),), PDTYPE)
        p["bv"] = jnp.zeros((cfg.kv_dim(),), PDTYPE)
    return p


def _qkv(p: Params, x: jax.Array, cfg: ModelConfig):
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.attn_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    B, S = x.shape[0], x.shape[1]
    q = constrain(q.reshape(B, S, cfg.n_heads, cfg.head_dim),
                  "dp", None, "tp", None)
    k = constrain(k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim),
                  "dp", None, "tp", None)
    v = constrain(v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim),
                  "dp", None, "tp", None)
    return q, k, v


def _pick_chunk(S: int, target: int) -> int:
    """Largest divisor of S that is <= target (chunked-attention tiling)."""
    c = min(S, target)
    while S % c:
        c -= 1
    return c


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool, q_chunk: int = 512,
                    kv_chunk: int = 1024,
                    q_offset: jax.Array | int = 0) -> jax.Array:
    """Chunked attention with running-max softmax (flash pattern).

    q: (B, Sq, H, Dh); k/v: (B, Skv, KV, Dh) with H a multiple of KV (GQA).
    Peak score memory is q_chunk x kv_chunk per (batch, head).
    ``q_offset``: global position of q's first row (context parallelism).
    """
    B, Sq, H, Dh = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    q_chunk = _pick_chunk(Sq, q_chunk)
    kv_chunk = _pick_chunk(Skv, kv_chunk)
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    scale = Dh ** -0.5

    qc = q.reshape(B, nq, q_chunk, KV, G, Dh)
    kc = k.reshape(B, nk, kv_chunk, KV, Dh)
    vc = v.reshape(B, nk, kv_chunk, KV, Dh)

    def q_body(_, qi_and_chunk):
        qi, qx = qi_and_chunk               # qx: (B, q_chunk, KV, G, Dh)

        # remat: the backward recomputes each chunk's scores instead of
        # saving (q_chunk x kv_chunk) probability residuals per iteration
        # — this IS flash attention's memory story, fwd and bwd.
        @jax.checkpoint
        def kv_body(carry, ki_and_chunk):
            m_prev, l_prev, acc = carry
            ki, kx, vx = ki_and_chunk
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qx.astype(CDTYPE),
                           kx.astype(CDTYPE),
                           preferred_element_type=jnp.float32) * scale
            if causal:
                qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
                kpos = ki * kv_chunk + jnp.arange(kv_chunk)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            # guard fully-masked rows (m == -inf)
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p_ = jnp.exp(s - m_safe[..., None])
            p_ = jnp.where(jnp.isfinite(s), p_, 0.0)
            alpha = jnp.where(jnp.isfinite(m_prev),
                              jnp.exp(m_prev - m_safe), 0.0)
            l_new = l_prev * alpha + jnp.sum(p_, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p_.astype(CDTYPE),
                            vx.astype(CDTYPE),
                            preferred_element_type=jnp.float32)
            acc = acc * alpha[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, KV, G, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_chunk, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_body, (m0, l0, a0),
            (jnp.arange(nk), kc.swapaxes(0, 1), vc.swapaxes(0, 1)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # (B, KV, G, q_chunk, Dh) -> (B, q_chunk, KV, G, Dh)
        return None, out.transpose(0, 3, 1, 2, 4).astype(q.dtype)

    _, outs = jax.lax.scan(jax.checkpoint(q_body), None,
                           (jnp.arange(nq), qc.swapaxes(0, 1)))
    # outs: (nq, B, q_chunk, KV, G, Dh)
    out = outs.swapaxes(0, 1).reshape(B, Sq, H, Dh)
    return out


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array) -> jax.Array:
    """One-token attention against a cache.

    q: (B, 1, H, Dh); caches: (B, S, KV, Dh); pos: () current length.
    Written as reductions over S so a sequence-sharded cache lowers to
    partial-softmax all-reduces (flash-decoding) rather than a gather.
    """
    B, _, H, Dh = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = Dh ** -0.5
    qh = q.reshape(B, KV, G, Dh)
    s = jnp.einsum("bhgd,bshd->bhgs", qh.astype(CDTYPE),
                   k_cache.astype(CDTYPE),
                   preferred_element_type=jnp.float32) * scale
    mask = jnp.arange(S)[None, None, None, :] <= pos
    s = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(CDTYPE),
                     v_cache.astype(CDTYPE),
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, Dh).astype(q.dtype)


def _seqpar_flash(q, k, v, *, causal, q_chunk, kv_chunk, mesh):
    """Context-parallel attention for archs whose head count doesn't
    divide the model axis (llama3.2: 24 heads, whisper: 6, qwen1.5: 40):
    q is sharded over "model" on the SEQUENCE dim (full heads per shard),
    k/v replicated across it; each shard runs flash over its q rows with
    the correct global causal offset. Recovers the model axis for
    attention where head-parallelism can't — the alternative (replicated
    attention) wastes |model| x the FLOPs (measured 16x on llama3.2,
    useful_ratio 0.06; see EXPERIMENTS.md §Perf)."""
    from jax.sharding import PartitionSpec as P
    tp = mesh.shape["model"]
    S_loc = q.shape[1] // tp

    def body(qL, kF, vF):
        off = jax.lax.axis_index("model") * S_loc
        return flash_attention(qL, kF.astype(CDTYPE), vF.astype(CDTYPE),
                               causal=causal,
                               q_chunk=min(q_chunk, S_loc),
                               kv_chunk=kv_chunk, q_offset=off)

    # k/v enter as f32: their backward cotangent psum over "model" is then
    # an f32 all-reduce (XLA CPU's AllReducePromotion pass check-fails on
    # the bf16 one; on TPU either dtype is fine).
    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(None, "model", None, None), P(), P()),
        out_specs=P(None, "model", None, None),
        axis_names={"model"}, check_vma=False)(
        q, k.astype(jnp.float32), v.astype(jnp.float32))


def attention_fwd(p: Params, x: jax.Array, cfg: ModelConfig, *,
                  positions: jax.Array, causal: bool = True,
                  q_chunk: int = 512, kv_chunk: int = 1024,
                  use_rope: bool = True):
    """Full-sequence attention (train / prefill). Returns (out, (k, v))."""
    q, k, v = _qkv(p, x, cfg)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    mesh = _ambient_mesh()
    seqpar = (mesh is not None and "model" in mesh.axis_names
              and cfg.n_heads % mesh.shape["model"] != 0
              and q.shape[1] % mesh.shape["model"] == 0 and causal)
    if seqpar:
        o = _seqpar_flash(q, k, v, causal=causal, q_chunk=q_chunk,
                          kv_chunk=kv_chunk, mesh=mesh)
    else:
        o = flash_attention(q, k, v, causal=causal, q_chunk=q_chunk,
                            kv_chunk=kv_chunk)
    B, S = x.shape[0], x.shape[1]
    out = o.reshape(B, S, cfg.q_dim()) @ p["wo"]
    return constrain(out, "dp", None, None), (k, v)


def attention_decode_fwd(p: Params, x: jax.Array, cfg: ModelConfig, *,
                         k_cache: jax.Array, v_cache: jax.Array,
                         pos: jax.Array, use_rope: bool = True):
    """One-token attention step. x: (B, 1, D). Returns (out, new caches)."""
    q, k, v = _qkv(p, x, cfg)
    if use_rope:
        ppos = jnp.full((x.shape[0], 1), pos, jnp.int32)
        q = apply_rope(q, ppos, cfg.rope_theta)
        k = apply_rope(k, ppos, cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k.astype(k_cache.dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v.astype(v_cache.dtype), pos, axis=1)
    o = decode_attention(q, k_cache, v_cache, pos)
    out = o.reshape(x.shape[0], 1, cfg.q_dim()) @ p["wo"]
    return out, (k_cache, v_cache)


def init_cross_attention(key, cfg: ModelConfig) -> Params:
    return init_attention(key, dataclasses.replace(cfg, attn_bias=False))


def cross_attention_fwd(p: Params, x: jax.Array, enc_kv: Tuple[jax.Array,
                                                               jax.Array],
                        cfg: ModelConfig):
    """Decoder-side cross attention against precomputed encoder K/V."""
    B, S = x.shape[0], x.shape[1]
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k, v = enc_kv
    o = flash_attention(q, k, v, causal=False)
    return o.reshape(B, S, cfg.q_dim()) @ p["wo"]


def cross_kv(p: Params, enc_out: jax.Array, cfg: ModelConfig):
    """Precompute encoder-side K/V once per request (whisper serving)."""
    B, S = enc_out.shape[0], enc_out.shape[1]
    k = (enc_out @ p["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = (enc_out @ p["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    return k, v


# --------------------------------------------------------------------------
# dense MLP (SwiGLU)
# --------------------------------------------------------------------------

def init_mlp(key, d: int, f: int) -> Params:
    ks = jax.random.split(key, 3)
    return {"w_gate": dense_init(ks[0], d, f),
            "w_up": dense_init(ks[1], d, f),
            "w_down": dense_init(ks[2], f, d)}


def mlp_fwd(p: Params, x: jax.Array) -> jax.Array:
    g = jax.nn.silu((x @ p["w_gate"]).astype(jnp.float32)).astype(x.dtype)
    g = constrain(g, "dp", None, "tp")
    h = g * constrain(x @ p["w_up"], "dp", None, "tp")
    return constrain(h @ p["w_down"], "dp", None, None)


# --------------------------------------------------------------------------
# MoE (top-k router, capacity dispatch, EP over the "model" axis)
# --------------------------------------------------------------------------

def init_moe(key, d: int, moe: MoEConfig) -> Params:
    ks = jax.random.split(key, 4)
    e, f = moe.n_experts, moe.d_expert_ff

    def estack(k_, din, dout):
        return (jax.random.normal(k_, (e, din, dout), jnp.float32)
                * din ** -0.5).astype(PDTYPE)

    return {"router": dense_init(ks[0], d, e, dtype=jnp.float32),
            "w_gate": estack(ks[1], d, f),
            "w_up": estack(ks[2], d, f),
            "w_down": estack(ks[3], f, d)}


def moe_fwd(p: Params, x: jax.Array, moe: MoEConfig
            ) -> Tuple[jax.Array, jax.Array]:
    """Capacity-based top-k MoE. x: (B, S, D) -> (out, aux_loss).

    Under a production mesh this routes to ``_moe_fwd_ep`` — a manual
    (shard_map) expert-parallel dispatch in which every (data, model)
    device buckets ITS OWN data shard's tokens for ITS OWN expert shard
    entirely locally; the only cross-device traffic is the per-layer
    (T, D) combine psum over "model" plus the usual FSDP weight gathers.
    (The naive GSPMD lowering of the E-sharded scatter-add all-reduces
    whole (E, cap, D) buffers — measured 15.9 TB/device/step on
    qwen3-moe train_4k; see EXPERIMENTS.md §Perf.)

    Without a mesh (smoke tests) the dense single-device path runs.
    """
    mesh = _ambient_mesh()
    if mesh is not None and "model" in mesh.axis_names and x.shape[1] > 1:
        # S == 1 (decode) stays on the weight-stationary GSPMD path: EP's
        # per-layer FSDP weight gathers dwarf one token's expert compute
        # (measured 8.8x regression on qwen3 decode_32k; §Perf).
        tp = mesh.shape["model"]
        dp = dp_axes()
        import numpy as _np
        dp_total = int(_np.prod([mesh.shape[a] for a in dp])) if dp else 1
        if (moe.n_experts % tp == 0
                and x.shape[0] % dp_total == 0):
            return _moe_fwd_ep(p, x, moe, mesh)
    return _moe_fwd_dense(p, x, moe)


def _moe_fwd_dense(p: Params, x: jax.Array, moe: MoEConfig
                   ) -> Tuple[jax.Array, jax.Array]:
    """Single-device reference dispatch (GShard-style, sort-free)."""
    B, S, D = x.shape
    T = B * S
    E, K = moe.n_experts, moe.top_k
    cap = int(moe.capacity_factor * T * K / E + 0.999)
    xt = x.reshape(T, D)

    logits = (xt.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                   # (T, K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # rank of each (token, choice) within its expert, token-major order
    flat_e = top_e.reshape(T * K)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)      # (T*K, E)
    rank = (jnp.cumsum(onehot, axis=0) - onehot)             # exclusive
    rank = jnp.sum(rank * onehot, axis=-1)                   # (T*K,)
    valid = rank < cap
    slot = flat_e * cap + jnp.where(valid, rank, 0)

    x_rep = jnp.repeat(xt, K, axis=0)                        # (T*K, D)
    w = jnp.where(valid, top_p.reshape(T * K), 0.0)
    buf = jnp.zeros((E * cap, D), CDTYPE)
    buf = buf.at[slot].add(jnp.where(valid[:, None], x_rep, 0.0)
                           .astype(CDTYPE))
    buf = constrain(buf.reshape(E, cap, D), "tp", None, None)

    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"],
                               preferred_element_type=jnp.float32))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"],
                   preferred_element_type=jnp.float32)
    y = jnp.einsum("ecf,efd->ecd", (g * u).astype(CDTYPE), p["w_down"],
                   preferred_element_type=jnp.float32)       # (E, cap, D)
    y = constrain(y, "tp", None, None)

    y_tok = y.reshape(E * cap, D)[slot]                      # (T*K, D)
    out = jnp.sum((y_tok * w[:, None]).reshape(T, K, D), axis=1)

    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)                             # (E,)
    ce = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32),
                  axis=0)
    aux = E * jnp.sum(me * ce)
    out = constrain(out.reshape(B, S, D).astype(x.dtype), "dp", None, None)
    return out, aux


def _moe_fwd_ep(p: Params, x: jax.Array, moe: MoEConfig, mesh
                ) -> Tuple[jax.Array, jax.Array]:
    """Manual expert-parallel dispatch (see moe_fwd docstring).

    shard_map over ALL mesh axes: batch manual over the data axes, experts
    manual over "model". Each device buckets its local tokens for its
    local experts with a local capacity (cf * T_local * K / E per expert,
    the standard per-shard capacity semantics of EP systems), runs the
    expert FFNs on FSDP-gathered weights, and psums the combine over
    "model".
    """
    from jax.sharding import PartitionSpec as P

    dp = dp_axes()
    E, K = moe.n_experts, moe.top_k
    tp = mesh.shape["model"]
    e_loc = E // tp
    fsdp_ok = ("data" in mesh.axis_names
               and p["w_gate"].shape[1] % mesh.shape["data"] == 0
               and p["w_down"].shape[1] % mesh.shape["data"] == 0)
    # matches param_specs: (E -> model, dim1 -> data FSDP, dim2 -> None)
    w_spec = P("model", "data" if fsdp_ok else None, None)

    def body(xb, router, wg, wu, wd):
        B_loc, S, D = xb.shape
        T = B_loc * S
        cap = int(moe.capacity_factor * T * K / E + 0.999)
        xt = xb.reshape(T, D)
        if fsdp_ok:   # FSDP gather of this layer's local expert weights
            wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, "data", axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, "data", axis=1, tiled=True)

        logits = xt.astype(jnp.float32) @ router            # (T, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, K)              # (T, K)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

        e_lo = jax.lax.axis_index("model") * e_loc
        flat_e = top_e.reshape(T * K)
        loc = flat_e - e_lo                                  # local id
        mine = (loc >= 0) & (loc < e_loc)
        loc = jnp.where(mine, loc, 0)
        onehot = jax.nn.one_hot(loc, e_loc, dtype=jnp.int32) \
            * mine[:, None].astype(jnp.int32)                # (T*K, e_loc)
        rank = jnp.sum((jnp.cumsum(onehot, axis=0) - onehot) * onehot,
                       axis=-1)
        valid = mine & (rank < cap)
        slot = loc * cap + jnp.where(valid, rank, 0)

        w = jnp.where(valid, top_p.reshape(T * K), 0.0)
        x_rep = jnp.repeat(xt, K, axis=0)
        buf = jnp.zeros((e_loc * cap, D), CDTYPE)
        buf = buf.at[slot].add(
            jnp.where(valid[:, None], x_rep, 0.0).astype(CDTYPE))
        buf = buf.reshape(e_loc, cap, D)

        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg,
                                   preferred_element_type=jnp.float32))
        u = jnp.einsum("ecd,edf->ecf", buf, wu,
                       preferred_element_type=jnp.float32)
        y = jnp.einsum("ecf,efd->ecd", (g * u).astype(CDTYPE), wd,
                       preferred_element_type=jnp.float32)

        y_tok = y.reshape(e_loc * cap, D)[slot]              # (T*K, D)
        part = jnp.sum((y_tok * w[:, None]).reshape(T, K, D), axis=1)
        out = jax.lax.psum(part.astype(jnp.float32), "model")

        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32),
                      axis=0)
        aux = E * jnp.sum(me * ce)
        if dp:
            aux = jax.lax.pmean(aux, dp)
        return out.reshape(B_loc, S, D).astype(xb.dtype), aux

    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(dp if dp else None, None, None), P(None, None),
                  w_spec, w_spec, w_spec),
        out_specs=(P(dp if dp else None, None, None), P()),
        check_vma=False)(x, p["router"], p["w_gate"], p["w_up"],
                         p["w_down"])


# --------------------------------------------------------------------------
# Mamba2 (SSD — state-space duality, chunked scan)
# --------------------------------------------------------------------------

def init_mamba(key, d: int, ssm: SSMConfig) -> Params:
    """Mamba2 block params. The input projection is stored per COMPONENT
    (z, x, B, C, dt) rather than fused, so each output is cleanly
    TP-shardable (z/x/dt shard over heads on "model"; the small shared
    B/C group projections stay replicated)."""
    d_in = ssm.expand * d
    nh = d_in // ssm.head_dim
    gn = ssm.n_groups * ssm.d_state
    ks = jax.random.split(key, 8)
    return {
        "wz": dense_init(ks[0], d, d_in),
        "wx": dense_init(ks[1], d, d_in),
        "wB": dense_init(ks[2], d, gn),
        "wC": dense_init(ks[3], d, gn),
        "wdt": dense_init(ks[4], d, nh),
        "conv_x": (jax.random.normal(ks[5], (ssm.d_conv, d_in),
                                     jnp.float32) * 0.1).astype(PDTYPE),
        "conv_bc": (jax.random.normal(ks[6], (ssm.d_conv, 2 * gn),
                                      jnp.float32) * 0.1).astype(PDTYPE),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.ones((d_in,), PDTYPE),
        "out_proj": dense_init(ks[7], d_in, d),
    }


def _causal_conv(x: jax.Array, w: jax.Array, state: Optional[jax.Array]):
    """Depthwise causal conv, width d_conv. x: (B, L, C); w: (d_conv, C).

    Returns (y, new_state) where state is the trailing (d_conv-1) inputs.
    """
    dconv = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], dconv - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :]
            for i in range(dconv))
    new_state = xp[:, -(dconv - 1):]
    return jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype), new_state


def _ssd_proj(p: Params, u: jax.Array, ssm: SSMConfig, d: int,
              conv_state: Optional[Dict[str, jax.Array]]):
    """Project u -> (z, x, B, C, dt) and run the causal convs."""
    d_in = ssm.expand * d
    nh = d_in // ssm.head_dim
    gn = ssm.n_groups * ssm.d_state
    z = constrain(u @ p["wz"], "dp", None, "tp")
    xr = constrain(u @ p["wx"], "dp", None, "tp")
    bc = jnp.concatenate([u @ p["wB"], u @ p["wC"]], axis=-1)
    dt = constrain(u @ p["wdt"], "dp", None, "tp")
    cs_x = None if conv_state is None else conv_state["x"]
    cs_bc = None if conv_state is None else conv_state["bc"]
    xr, ns_x = _causal_conv(xr, p["conv_x"], cs_x)
    bc, ns_bc = _causal_conv(bc, p["conv_bc"], cs_bc)
    Bm, Cm = jnp.split(bc, 2, axis=-1)
    return z, xr, Bm, Cm, dt, d_in, nh, gn, {"x": ns_x, "bc": ns_bc}


def mamba_fwd(p: Params, u: jax.Array, ssm: SSMConfig, d: int,
              *, init_state=None, return_state: bool = False):
    """Chunked SSD forward. u: (B, L, D). L must divide by ssm.chunk.

    Scan over chunks: within a chunk the quadratic (Q x Q) dual form runs
    on the MXU; across chunks a (nh, hd, N) state carries the recurrence.
    """
    B, L, _ = u.shape
    Q = min(ssm.chunk, L)
    pad = -L % Q
    if pad:
        assert init_state is None, "chunk-pad + carried state unsupported"
        # FRONT-pad to a chunk multiple: zero inputs contribute nothing to
        # states or outputs (x=0 ⇒ dt·x·B = 0), and the initial state is
        # zero, so real-token outputs and the final state are unchanged.
        u = jnp.pad(u, ((0, 0), (pad, 0), (0, 0)))
        L = L + pad
    nc = L // Q
    conv_state = None if init_state is None else init_state["conv"]
    z, xs, Bm, Cm, dt, d_in, nh, gn, conv_out_state = \
        _ssd_proj(p, u, ssm, d, conv_state)
    hd, N, G = ssm.head_dim, ssm.d_state, ssm.n_groups

    xh = xs.reshape(B, nc, Q, nh, hd)
    Bh = Bm.reshape(B, nc, Q, G, N)
    Ch = Cm.reshape(B, nc, Q, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"]).reshape(B, nc, Q, nh)
    A = -jnp.exp(p["A_log"])                                  # (nh,)
    dA = dt * A[None, None, None, :]                          # (B,nc,Q,nh)
    # heads -> groups map
    hpg = nh // G

    def chunk_body(state, inp):
        xq, Bq, Cq, dtq, dAq = inp        # (B,Q,...)
        seg = jnp.cumsum(dAq, axis=1)                          # (B,Q,nh)
        tot = seg[:, -1:]                                      # (B,1,nh)
        # intra-chunk dual form
        Bg = jnp.repeat(Bq, hpg, axis=2)                       # (B,Q,nh,N)
        Cg = jnp.repeat(Cq, hpg, axis=2)
        Lmat = jnp.exp(seg[:, :, None, :] - seg[:, None, :, :])  # (B,Q,Q,nh)
        causal = jnp.tril(jnp.ones((Q, Q), bool))
        Lmat = jnp.where(causal[None, :, :, None], Lmat, 0.0)
        scores = jnp.einsum("bqhn,bshn->bqsh", Cg, Bg,
                            preferred_element_type=jnp.float32)
        scores = scores * Lmat * dtq[:, None, :, :]            # (B,Q,Q,nh)
        y_intra = jnp.einsum("bqsh,bshp->bqhp",
                             scores.astype(CDTYPE), xq,
                             preferred_element_type=jnp.float32)
        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum("bqhn,bhpn->bqhp", Cg.astype(CDTYPE),
                             state.astype(CDTYPE),
                             preferred_element_type=jnp.float32)
        y_inter = y_inter * jnp.exp(seg)[..., None]
        # new chunk state
        decay_in = jnp.exp(tot - seg) * dtq                    # (B,Q,nh)
        st_local = jnp.einsum("bqhp,bqhn,bqh->bhpn",
                              xq.astype(jnp.float32), Bg, decay_in,
                              preferred_element_type=jnp.float32)
        state = state * jnp.exp(tot)[:, 0, :, None, None] + st_local
        return state, (y_intra + y_inter)

    st0 = (jnp.zeros((B, nh, hd, N), jnp.float32) if init_state is None
           else init_state["ssm"])
    xc = xh.swapaxes(0, 1)
    state, ys = jax.lax.scan(
        chunk_body, st0,
        (xc, Bh.swapaxes(0, 1), Ch.swapaxes(0, 1), dt.swapaxes(0, 1),
         dA.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1).reshape(B, L, nh, hd)
    y = y + xh.reshape(B, L, nh, hd).astype(jnp.float32) \
        * p["D"][None, None, :, None]
    y = constrain(y.reshape(B, L, d_in).astype(u.dtype), "dp", None, "tp")
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["norm"], 1e-5)
    if pad:
        y = y[:, pad:]
    out = constrain(y @ p["out_proj"], "dp", None, None)
    if return_state:
        return out, {"ssm": state, "conv": conv_out_state}
    return out


def mamba_decode_fwd(p: Params, u: jax.Array, ssm: SSMConfig, d: int,
                     state: Dict[str, jax.Array]):
    """Single-token SSM step. u: (B, 1, D); state: {ssm, conv}."""
    B = u.shape[0]
    z, xs, Bm, Cm, dt, d_in, nh, gn, conv_state = \
        _ssd_proj(p, u, ssm, d, state["conv"])
    hd, N, G = ssm.head_dim, ssm.d_state, ssm.n_groups
    hpg = nh // G
    xh = xs.reshape(B, nh, hd)
    Bh = jnp.repeat(Bm.reshape(B, G, N), hpg, axis=1)         # (B,nh,N)
    Ch = jnp.repeat(Cm.reshape(B, G, N), hpg, axis=1)
    dtv = jax.nn.softplus(dt.astype(jnp.float32)
                          + p["dt_bias"]).reshape(B, nh)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dtv * A[None, :])                         # (B,nh)
    st = state["ssm"] * decay[:, :, None, None] \
        + jnp.einsum("bhp,bhn,bh->bhpn", xh.astype(jnp.float32), Bh, dtv)
    y = jnp.einsum("bhpn,bhn->bhp", st, Ch) \
        + xh.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B, 1, d_in).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["norm"], 1e-5)
    return y @ p["out_proj"], {"ssm": st, "conv": conv_state}
