"""Atomic, checksummed, keep-N checkpointing with elastic restore.

Layout per step:
    <dir>/step_000042/
        manifest.json     {step, time, keys -> {file, shape, dtype, crc}}
        arr_000.npy ...   one file per pytree leaf

Properties needed at 1000-node scale:
  * atomic: written to ``step_X.tmp-<pid>`` then os.rename'd — a crashed
    writer never corrupts the latest checkpoint;
  * checksummed: crc32 per leaf, verified on restore;
  * keep-N garbage collection;
  * elastic: leaves are stored UNSHARDED (gathered); restore re-shards
    onto whatever mesh/sharding tree the caller passes — pod counts can
    change between runs;
  * async: ``save(..., background=True)`` snapshots to host RAM
    synchronously and writes to disk on a worker thread (training
    continues during the disk write).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from pathlib import Path
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# numpy can't serialise ml_dtypes (bfloat16, fp8) natively — bit-cast
# through a same-width uint container and record the logical dtype.
_EXOTIC = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
           "float8_e5m2": np.uint8, "float8_e4m3": np.uint8}


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _key_strs(tree: Any):
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, _ in paths:
        out.append(jax.tree_util.keystr(path))
    return out


class CheckpointStore:
    def __init__(self, directory: str | Path, *, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # -- save ------------------------------------------------------------
    def save(self, step: int, tree: Any, *, background: bool = False):
        """Snapshot to host then write. Returns after snapshot if
        background=True (the disk write continues on a thread)."""
        host = jax.tree.map(lambda x: np.asarray(x), tree)
        if background:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()
        else:
            self._write(step, host)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any):
        leaves, _ = _flatten(host_tree)
        keys = _key_strs(host_tree)
        final = self.dir / f"step_{step:09d}"
        tmp = self.dir / f"step_{step:09d}.tmp-{os.getpid()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "time": time.time(), "leaves": {}}
        for i, (k, leaf) in enumerate(zip(keys, leaves)):
            fn = f"arr_{i:04d}.npy"
            arr = np.asarray(leaf)
            logical = str(arr.dtype)
            if logical in _EXOTIC:
                arr = arr.view(_EXOTIC[logical])
            np.save(tmp / fn, arr)
            manifest["leaves"][k] = {
                "file": fn, "shape": list(arr.shape),
                "dtype": str(arr.dtype), "logical_dtype": logical,
                "crc": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
            }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # -- restore ----------------------------------------------------------
    def steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.name.endswith(".tmp") or ".tmp-" in p.name:
                continue
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, tree_like: Any, *, step: Optional[int] = None,
                shardings: Any = None, verify: bool = True) -> Any:
        """Restore into the structure of ``tree_like``.

        ``shardings``: optional matching pytree of NamedSharding — leaves
        are device_put with them (elastic re-shard onto any mesh).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        keys = _key_strs(tree_like)
        leaves, treedef = _flatten(tree_like)
        shard_leaves = (_flatten(shardings)[0] if shardings is not None
                        else [None] * len(leaves))
        out = []
        for k, proto, sh in zip(keys, leaves, shard_leaves):
            ent = manifest["leaves"].get(k)
            if ent is None:
                raise KeyError(f"checkpoint missing leaf {k}")
            arr = np.load(d / ent["file"])
            if verify:
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
                if crc != ent["crc"]:
                    raise IOError(f"checksum mismatch for {k}")
            logical = ent.get("logical_dtype", ent["dtype"])
            if logical != str(arr.dtype) and logical in _EXOTIC:
                arr = arr.view(getattr(ml_dtypes, logical))
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)
