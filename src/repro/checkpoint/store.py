"""Atomic, checksummed, keep-N checkpointing with elastic restore.

Layout per step (the version token makes same-step overwrites atomic):
    <dir>/step_000000042.v<token>/
        manifest.json     {step, time, keys -> {file, shape, dtype, crc}}
        extra.json        optional JSON sidecar (loop metadata/manifest)
        arr_000.npy ...   one file per pytree leaf
    (unversioned ``step_000000042`` dirs from older writers stay
    readable; a versioned dir for the same step supersedes them.)

Properties needed at 1000-node scale:
  * atomic: written to a ``.tmp-<pid>`` dir then os.rename'd to a FRESH
    versioned final name — the previous checkpoint for the same step is
    only garbage-collected after the new one is fully on disk, so a
    crashed writer never corrupts OR loses the latest checkpoint;
  * checksummed: crc32 per leaf, verified on restore;
  * keep-N garbage collection (plus superseded same-step versions);
  * elastic: leaves are stored UNSHARDED (gathered); restore re-shards
    onto whatever mesh/sharding tree the caller passes — pod counts can
    change between runs;
  * async: ``save(..., background=True)`` snapshots to host RAM
    synchronously and writes to disk on a worker thread (training
    continues during the disk write).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

# numpy can't serialise ml_dtypes (bfloat16, fp8) natively — bit-cast
# through a same-width uint container and record the logical dtype.
_EXOTIC = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
           "float8_e5m2": np.uint8, "float8_e4m3": np.uint8}


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _key_strs(tree: Any):
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, _ in paths:
        out.append(jax.tree_util.keystr(path))
    return out


def _parse_step_dir(name: str) -> Optional[Tuple[int, int]]:
    """step_000000042[.v<token>] -> (step, version); None if not a
    (complete) checkpoint dir name. Unversioned legacy dirs sort as
    version -1 so any versioned rewrite supersedes them."""
    if ".tmp-" in name or not name.startswith("step_"):
        return None
    stem = name[len("step_"):]
    stem, _, ver = stem.partition(".v")
    try:
        return int(stem), (int(ver) if ver else -1)
    except ValueError:
        return None


# crashed-writer .tmp- dirs older than this are garbage-collected (a
# healthy writer renames its tmp away within one save)
_TMP_TTL_S = 300.0


class CheckpointStore:
    def __init__(self, directory: str | Path, *, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # -- save ------------------------------------------------------------
    def save(self, step: int, tree: Any, *, background: bool = False,
             extra: Optional[Dict[str, Any]] = None):
        """Snapshot to host then write. Returns after snapshot if
        background=True (the disk write continues on a thread).

        ``extra``: optional JSON-safe dict written as ``extra.json``
        inside the step dir (read back with `read_extra`)."""
        host = jax.tree.map(lambda x: np.asarray(x), tree)
        if background:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra), daemon=True)
            self._thread.start()
        else:
            self._write(step, host, extra)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any,
               extra: Optional[Dict[str, Any]] = None):
        leaves, _ = _flatten(host_tree)
        keys = _key_strs(host_tree)
        # fresh versioned final name: the atomic rename lands NEXT TO any
        # previous version of this step instead of over it, so a crash at
        # any point leaves the previous checkpoint intact
        token = time.time_ns()
        final = self.dir / f"step_{step:09d}.v{token}"
        tmp = self.dir / f"{final.name}.tmp-{os.getpid()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "time": time.time(), "leaves": {}}
        for i, (k, leaf) in enumerate(zip(keys, leaves)):
            fn = f"arr_{i:04d}.npy"
            arr = np.asarray(leaf)
            logical = str(arr.dtype)
            if logical in _EXOTIC:
                arr = arr.view(_EXOTIC[logical])
            np.save(tmp / fn, arr)
            manifest["leaves"][k] = {
                "file": fn, "shape": list(arr.shape),
                "dtype": str(arr.dtype), "logical_dtype": logical,
                "crc": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
            }
        if extra is not None:
            (tmp / "extra.json").write_text(json.dumps(extra, indent=1))
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        os.rename(tmp, final)
        self._gc()

    def _step_dirs(self) -> Dict[int, Path]:
        """Newest complete dir per step (versioned beats legacy)."""
        best: Dict[int, Tuple[int, Path]] = {}
        for p in self.dir.glob("step_*"):
            parsed = _parse_step_dir(p.name)
            if parsed is None or not (p / "manifest.json").exists():
                continue
            step, ver = parsed
            if step not in best or ver > best[step][0]:
                best[step] = (ver, p)
        return {s: p for s, (v, p) in best.items()}

    def _gc(self):
        dirs = self._step_dirs()
        # superseded versions of surviving steps
        for p in self.dir.glob("step_*"):
            parsed = _parse_step_dir(p.name)
            if parsed is None:
                continue
            step, _ = parsed
            if dirs.get(step) is not None and p != dirs[step]:
                shutil.rmtree(p, ignore_errors=True)
        # keep-N on steps
        steps = sorted(dirs)
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(dirs[s], ignore_errors=True)
        # crashed-writer tmp dirs: a failed rename leaves a fresh-named
        # .tmp- dir no later save will ever match — reap old ones here
        now = time.time()
        for p in self.dir.glob("*.tmp-*"):
            try:
                if now - p.stat().st_mtime > _TMP_TTL_S:
                    shutil.rmtree(p, ignore_errors=True)
            except OSError:
                pass

    def clear(self):
        """Remove every checkpoint (and tmp debris) in the directory."""
        self.wait()
        for p in self.dir.glob("step_*"):
            if _parse_step_dir(p.name) is not None or ".tmp-" in p.name:
                shutil.rmtree(p, ignore_errors=True)

    # -- restore ----------------------------------------------------------
    def steps(self):
        return sorted(self._step_dirs())

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def _dir_for(self, step: Optional[int]) -> Tuple[int, Path]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dirs().get(step)
        if d is None:
            raise FileNotFoundError(f"no checkpoint for step {step} in "
                                    f"{self.dir}")
        return step, d

    def read_extra(self, step: Optional[int] = None
                   ) -> Optional[Dict[str, Any]]:
        """The ``extra`` dict saved with the step (None if absent)."""
        _, d = self._dir_for(step)
        p = d / "extra.json"
        return json.loads(p.read_text()) if p.exists() else None

    def restore(self, tree_like: Any, *, step: Optional[int] = None,
                shardings: Any = None, verify: bool = True) -> Any:
        """Restore into the structure of ``tree_like``.

        ``shardings``: optional matching pytree of NamedSharding — leaves
        are device_put with them (elastic re-shard onto any mesh).
        """
        step, d = self._dir_for(step)
        manifest = json.loads((d / "manifest.json").read_text())
        keys = _key_strs(tree_like)
        leaves, treedef = _flatten(tree_like)
        shard_leaves = (_flatten(shardings)[0] if shardings is not None
                        else [None] * len(leaves))
        out = []
        for k, proto, sh in zip(keys, leaves, shard_leaves):
            ent = manifest["leaves"].get(k)
            if ent is None:
                raise KeyError(f"checkpoint missing leaf {k}")
            arr = np.load(d / ent["file"])
            if verify:
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
                if crc != ent["crc"]:
                    raise IOError(f"checksum mismatch for {k}")
            logical = ent.get("logical_dtype", ent["dtype"])
            if logical != str(arr.dtype) and logical in _EXOTIC:
                arr = arr.view(getattr(ml_dtypes, logical))
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)
