"""Qwen1.5 32B — dense, QKV bias, MHA (kv=heads).

[hf:Qwen/Qwen1.5-32B family] 64L d_model=5120 40H (kv=40) d_ff=27392
vocab=152064.
"""
from repro.configs.base import ModelConfig, replace

CONFIG = ModelConfig(
    arch_id="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab=152064,
    attn_bias=True,
)


def reduced() -> ModelConfig:
    return replace(CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                   head_dim=16, d_ff=128, vocab=512)
