"""Llama 3.2 3B — small llama3 dense model.

[hf:meta-llama/Llama-3.2-3B] 28L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=128256.
"""
from repro.configs.base import ModelConfig, replace

CONFIG = ModelConfig(
    arch_id="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=128256,
    rope_theta=500000.0,
)


def reduced() -> ModelConfig:
    return replace(CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                   head_dim=16, d_ff=128, vocab=512)
