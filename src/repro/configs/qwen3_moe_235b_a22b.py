"""Qwen3-MoE 235B-A22B — 128 experts, top-8, all layers MoE.

[hf:Qwen/Qwen3-30B-A3B family scaled] 94L d_model=4096 64H (GQA kv=4)
expert d_ff=1536 vocab=151936, MoE 128e top-8.
"""
from repro.configs.base import ModelConfig, MoEConfig, replace

CONFIG = ModelConfig(
    arch_id="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab=151936,
    moe=MoEConfig(n_experts=128, top_k=8, d_expert_ff=1536, layout="all"),
)


def reduced() -> ModelConfig:
    return replace(
        CONFIG,
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab=512,
        moe=MoEConfig(n_experts=8, top_k=2, d_expert_ff=64, layout="all"),
    )
