"""K-means workload configs — the paper's own experiments + scale-out.

kmeans_infmnist / kmeans_rcv1 mirror the paper's two datasets (RCV1 densified
to 2048 dims for the MXU path — see DESIGN.md §6).
kmeans_xl is the production-scale workload for the multi-pod dry-run:
2^30 points, d=1024, k=4096 with centroids sharded over the "model" axis.
"""
from repro.configs.base import KMeansConfig

KMEANS_INFMNIST = KMeansConfig(
    name="kmeans_infmnist", n_points=400_000, dim=784, k=50,
    algorithm="tb", rho=float("inf"), b0=5000, bounds="hamerly2",
)

KMEANS_RCV1 = KMeansConfig(
    name="kmeans_rcv1", n_points=781_265, dim=2048, k=50,
    algorithm="tb", rho=float("inf"), b0=5000, bounds="hamerly2",
)

KMEANS_XL = KMeansConfig(
    name="kmeans_xl", n_points=2**30, dim=1024, k=4096,
    algorithm="tb", rho=float("inf"), b0=2**20, bounds="hamerly2",
    shard_centroids=True,
)

KMEANS_WORKLOADS = {c.name: c for c in (KMEANS_INFMNIST, KMEANS_RCV1, KMEANS_XL)}
