"""Granite-3.0 1B-A400M MoE — 32 experts, top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base] 24L d_model=1024 16H (GQA kv=8)
expert d_ff=512 vocab=49155, MoE 32e top-8.
"""
from repro.configs.base import ModelConfig, MoEConfig, replace

CONFIG = ModelConfig(
    arch_id="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab=49155,
    moe=MoEConfig(n_experts=32, top_k=8, d_expert_ff=512, layout="all"),
)


def reduced() -> ModelConfig:
    return replace(
        CONFIG,
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab=512,
        moe=MoEConfig(n_experts=8, top_k=4, d_expert_ff=64, layout="all"),
    )
