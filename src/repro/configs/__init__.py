"""Architecture registry: ``get_config(arch_id)`` / ``get_reduced(arch_id)``.

Every assigned architecture is a selectable config (``--arch <id>``).
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import (ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K,
                                TRAIN_4K, KMeansConfig, ModelConfig,
                                ShapeConfig)
from repro.configs import (codeqwen1_5_7b, granite_moe_1b_a400m,
                           internvl2_76b, jamba_v0_1_52b, llama3_2_3b,
                           mamba2_2_7b, qwen1_5_32b, qwen3_moe_235b_a22b,
                           tinyllama_1_1b, whisper_tiny)
from repro.configs.kmeans_workloads import KMEANS_WORKLOADS

_MODULES = {
    "jamba-v0.1-52b": jamba_v0_1_52b,
    "whisper-tiny": whisper_tiny,
    "internvl2-76b": internvl2_76b,
    "qwen3-moe-235b-a22b": qwen3_moe_235b_a22b,
    "granite-moe-1b-a400m": granite_moe_1b_a400m,
    "tinyllama-1.1b": tinyllama_1_1b,
    "llama3.2-3b": llama3_2_3b,
    "codeqwen1.5-7b": codeqwen1_5_7b,
    "qwen1.5-32b": qwen1_5_32b,
    "mamba2-2.7b": mamba2_2_7b,
}

ARCHS: Dict[str, ModelConfig] = {k: m.CONFIG for k, m in _MODULES.items()}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def get_reduced(arch_id: str) -> ModelConfig:
    return _MODULES[arch_id].reduced()


def list_archs() -> List[str]:
    return list(ARCHS)


def shapes_for(cfg: ModelConfig) -> List[ShapeConfig]:
    """The shape cells this arch runs (long_500k only for sub-quadratic)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if not cfg.full_attention_only:
        out.append(LONG_500K)
    return out


def skipped_shapes_for(cfg: ModelConfig) -> List[ShapeConfig]:
    return [] if not cfg.full_attention_only else [LONG_500K]


def get_kmeans_config(name: str) -> KMeansConfig:
    return KMEANS_WORKLOADS[name]


__all__ = [
    "ARCHS", "ALL_SHAPES", "TRAIN_4K", "PREFILL_32K", "DECODE_32K",
    "LONG_500K", "get_config", "get_reduced", "list_archs", "shapes_for",
    "skipped_shapes_for", "get_kmeans_config", "KMEANS_WORKLOADS",
]
