"""Whisper-tiny — encoder-decoder audio transformer, conv frontend stubbed.

[arXiv:2212.04356] 4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865.
The conv frontend is a STUB: input_specs() provides precomputed frame
embeddings (B, 1536, 384). Encoder ctx padded 1500 -> 1536 for clean tiling.
"""
from repro.configs.base import EncoderConfig, ModelConfig, replace

CONFIG = ModelConfig(
    arch_id="whisper-tiny",
    family="encdec",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab=51865,
    encoder=EncoderConfig(n_layers=4, n_ctx=1536, d_frontend=384),
)


def reduced() -> ModelConfig:
    return replace(
        CONFIG,
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=512,
        encoder=EncoderConfig(n_layers=2, n_ctx=32, d_frontend=64),
    )
