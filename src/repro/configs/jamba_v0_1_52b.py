"""Jamba v0.1 52B — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.
Attention every 8th layer; MoE on alternating layers (16 experts, top-2).
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, replace

CONFIG = ModelConfig(
    arch_id="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=65536,
    moe=MoEConfig(n_experts=16, top_k=2, d_expert_ff=14336, layout="alternate"),
    ssm=SSMConfig(d_state=16, expand=2, d_conv=4, head_dim=64, chunk=256),
    hybrid_period=8,
    full_attention_only=False,   # hybrid: runs long_500k
)


def reduced() -> ModelConfig:
    return replace(
        CONFIG,
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
        moe=MoEConfig(n_experts=4, top_k=2, d_expert_ff=128, layout="alternate"),
        ssm=SSMConfig(d_state=8, expand=2, d_conv=4, head_dim=16, chunk=16),
        hybrid_period=2,
    )
