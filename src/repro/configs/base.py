"""Config dataclasses for the model zoo and the k-means engine.

Everything is a frozen dataclass so configs are hashable and can be used as
static args to jit'd builders.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert_ff: int
    # capacity factor: per-expert token capacity = tokens * top_k / n_experts * cf
    capacity_factor: float = 1.25
    # which layers are MoE; "all" | "alternate" (odd layers dense)
    layout: str = "all"


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    d_conv: int = 4
    head_dim: int = 64          # mamba2 SSD head size
    chunk: int = 256            # SSD chunk length
    n_groups: int = 1           # B/C groups


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec (whisper) / frontend-stub (vlm) archs."""
    n_layers: int = 0
    n_ctx: int = 0              # encoder context length (frames / patches)
    d_frontend: int = 0         # dim of the precomputed stub embeddings


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    # hybrid: one attention layer per `hybrid_period` layers (rest SSM)
    hybrid_period: int = 0
    attn_bias: bool = False     # qwen1.5-style QKV bias
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # set for archs whose quadratic attention makes long_500k infeasible
    full_attention_only: bool = True

    # ---- derived helpers -------------------------------------------------
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def is_attention_layer(self, layer_idx: int) -> bool:
        if self.family not in ("hybrid",):
            return self.family != "ssm"
        return layer_idx % self.hybrid_period == 0

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.moe is None:
            return False
        if self.moe.layout == "all":
            return True
        return layer_idx % 2 == 1  # alternate: odd layers MoE

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), used for 6ND."""
        d = self.d_model
        n = 0
        n += self.vocab * d                     # embed
        if not self.tie_embeddings:
            n += self.vocab * d                 # lm head
        for i in range(self.n_layers):
            if self.family == "ssm" or (self.family == "hybrid"
                                        and not self.is_attention_layer(i)):
                n += self._mamba_params()
            else:
                n += d * self.q_dim() + 2 * d * self.kv_dim() \
                     + self.q_dim() * d
                if self.attn_bias:
                    n += self.q_dim() + 2 * self.kv_dim()
            # mlp
            if self.is_moe_layer(i):
                m = self.moe
                n += m.n_experts * 3 * d * m.d_expert_ff + d * m.n_experts
            elif self.family != "ssm":
                n += 3 * d * self.d_ff
            n += 2 * d                           # norms
        if self.encoder is not None and self.encoder.n_layers:
            de = d
            per = 4 * de * de + 3 * de * self.d_ff + 2 * de
            n += self.encoder.n_layers * per
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        m = self.moe
        total = self.param_count()
        moe_layers = sum(1 for i in range(self.n_layers) if self.is_moe_layer(i))
        all_exp = moe_layers * m.n_experts * 3 * d * m.d_expert_ff
        act_exp = moe_layers * m.top_k * 3 * d * m.d_expert_ff
        return total - all_exp + act_exp

    def _mamba_params(self) -> int:
        s = self.ssm or SSMConfig()
        d = self.d_model
        d_in = s.expand * d
        nh = d_in // s.head_dim
        n = 0
        n += d * (2 * d_in + 2 * s.n_groups * s.d_state + nh)  # in_proj (z,x,B,C,dt)
        n += s.d_conv * (d_in + 2 * s.n_groups * s.d_state)    # conv over x,B,C
        n += nh * 2                                            # A_log, D
        n += d_in * d                                          # out_proj
        return n


@dataclass(frozen=True)
class KMeansConfig:
    """Workload config for the paper's technique."""
    name: str
    n_points: int
    dim: int
    k: int
    dtype: str = "float32"
    # engine knobs
    algorithm: str = "tb"       # lloyd | mb | mbf | gb | tb
    rho: float = float("inf")
    b0: int = 5000
    bounds: str = "hamerly2"    # none | elkan | hamerly2
    # distribution: shard centroids over "model" when k is large
    shard_centroids: bool = False


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode

    def __str__(self) -> str:  # pragma: no cover
        return f"{self.name}(L={self.seq_len},B={self.global_batch},{self.kind})"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def replace(cfg, **kw):
    return dataclasses.replace(cfg, **kw)
