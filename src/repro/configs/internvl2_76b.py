"""InternVL2-76B — VLM: InternViT frontend (stub) + InternLM2-like LM backbone.

[arXiv:2404.16821] 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
The vision frontend is a STUB: input_specs() provides precomputed patch
embeddings (B, 256, d_model) prepended to the token sequence.
"""
from repro.configs.base import EncoderConfig, ModelConfig, replace

CONFIG = ModelConfig(
    arch_id="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab=128256,
    encoder=EncoderConfig(n_layers=0, n_ctx=256, d_frontend=8192),
)


def reduced() -> ModelConfig:
    return replace(
        CONFIG,
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
        encoder=EncoderConfig(n_layers=0, n_ctx=8, d_frontend=64),
    )
