"""Mamba2 2.7B — attention-free SSM with SSD (state-space duality).

[arXiv:2405.21060] 64L d_model=2560 (attn-free) vocab=50280, ssm_state=128.
d_inner = 2*d_model = 5120, 80 SSD heads of size 64.
"""
from repro.configs.base import ModelConfig, SSMConfig, replace

CONFIG = ModelConfig(
    arch_id="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(d_state=128, expand=2, d_conv=4, head_dim=64, chunk=256),
    full_attention_only=False,   # attention-free: runs long_500k
)


def reduced() -> ModelConfig:
    return replace(
        CONFIG, n_layers=2, d_model=64, vocab=512,
        ssm=SSMConfig(d_state=8, expand=2, d_conv=4, head_dim=16, chunk=16),
    )
