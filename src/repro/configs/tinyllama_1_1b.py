"""TinyLlama 1.1B — llama2-architecture small dense model.

[arXiv:2401.02385] 22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.
"""
from repro.configs.base import ModelConfig, replace

CONFIG = ModelConfig(
    arch_id="tinyllama-1.1b",
    family="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=64,
    d_ff=5632,
    vocab=32000,
)


def reduced() -> ModelConfig:
    return replace(CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                   head_dim=16, d_ff=128, vocab=512)
