"""Jit trace counters for the round factories (retrace accounting).

The host loop compiles ONE executable per power-of-two (b, capacity)
bucket; a change that sneaks a per-round-varying value into the static
argument set (or rebuilds the jit wrapper each call) silently turns the
steady-state loop into a retrace-per-round loop — the fit still
converges, just ~100x slower at scale, which is exactly the regression
the paper's speedup claim cannot survive.

The round bodies call `record(site, **statics)` at their top. A jit'd
function's Python body runs exactly once per TRACE (cache misses only),
so the counter keyed on the bucket statics counts real traces: a bucket
traced twice, or a set of bucket keys that grows with the round count,
is a retrace bug. `repro.analysis.retrace` resets the counters, drives
a full growth schedule, and asserts traces == distinct invoked buckets.

Counting is a dict increment at trace time only — steady-state rounds
never touch it — so the hooks stay on unconditionally. Eager (non-jit)
calls of a round body also increment; audits bracket their own runs
with `snapshot()` / diffs, so unrelated eager activity cannot leak in.
"""
from __future__ import annotations

import threading
from collections import Counter
from typing import Dict, Tuple

_lock = threading.Lock()
_counts: Counter = Counter()

#: key: (site, sorted tuple of (static name, repr(value)))
TraceKey = Tuple[str, Tuple[Tuple[str, str], ...]]


def record(site: str, **statics) -> None:
    """Count one trace of ``site`` with the given static arguments.

    Called from inside round-function bodies, i.e. under jax tracing —
    values must not be inspected (they are tracers for the array args),
    so only the STATIC arguments belong here, rendered via repr.
    """
    key = (site, tuple(sorted((k, repr(v)) for k, v in statics.items())))
    with _lock:
        _counts[key] += 1


def snapshot() -> Dict[TraceKey, int]:
    """Current counts (copy) — diff two snapshots to scope one run."""
    with _lock:
        return dict(_counts)


def diff(before: Dict[TraceKey, int]) -> Dict[TraceKey, int]:
    """Traces recorded since ``before`` (a `snapshot()` result)."""
    with _lock:
        return {k: v - before.get(k, 0) for k, v in _counts.items()
                if v - before.get(k, 0) > 0}


def reset() -> None:
    with _lock:
        _counts.clear()
