"""Cross-cutting helpers shared by launchers, benchmarks and smokes."""
from repro.util import env  # noqa: F401
