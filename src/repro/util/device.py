"""Shared donated device-buffer writers (the out-of-core fill path).

THE donated per-device-piece segment writer used by every engine's
`_ensure_prefix`: a growing fit fills storage rows ``[filled, b)`` of
the device data buffer in bounded segments, and the buffer generation
must be updated IN PLACE — the whole point of the out-of-core plane is
that neither the host nor a device ever holds two copies of the data.

A shard_map'd update would be the obvious multi-device spelling, but on
CPU its donation does not reliably run in place — every segment write
then copies the whole (n, d) buffer, so filling the prefix holds two
buffer generations resident (~2x the data in RSS, measured in PR 6). A
plain jit over ONE device's piece does update in place, so engines
apply `piece_update` per addressable shard and reassemble the global
array (`jax.make_array_from_single_device_arrays`).

Keep every donated jit in the engine data path HERE: the donation
auditor (`repro.analysis.donation`) proves each site's donated operand
is actually aliased in the compiled executable — an unregistered
donation site elsewhere in the engines fails the audit, so the PR 6
copy class cannot silently return.
"""
from __future__ import annotations

import jax

#: (piece, segment, row) -> piece with segment written at ``row``;
#: donates (and on CPU/GPU aliases) the piece buffer.
piece_update = jax.jit(
    lambda Xs, seg, at: jax.lax.dynamic_update_slice(Xs, seg, (at, 0)),
    donate_argnums=0)
