"""Computation-environment configuration: the ONE place XLA_FLAGS is set.

Launchers, benchmark parents and their subprocess children all shape the
jax runtime the same three ways — force N host-platform devices, pick a
platform, flip precision/debug switches — and every one of them must do
it BEFORE jax initialises its backend (XLA reads the flags exactly
once). Scattering raw ``os.environ["XLA_FLAGS"] = ...`` assignments
around the tree made that ordering easy to break and the flag strings
easy to drift; this module owns both.

jax itself is imported lazily inside the functions that need it, so the
flag-setting helpers (`force_host_device_count`, `merge_xla_flags`) are
safe to call from a fresh interpreter before any jax import.
"""
from __future__ import annotations

import os
import sys
import warnings


def device_count_flag(n: int) -> str:
    """The complete XLA flag forcing ``n`` host-platform devices."""
    return f"--xla_force_host_platform_device_count={int(n)}"


def merge_xla_flags(*flags: str, env: dict | None = None) -> str:
    """Merge ``flags`` into XLA_FLAGS, replacing same-name flags in place.

    Existing flags whose ``--name`` part matches an incoming flag are
    replaced (last write wins); everything else is preserved, so a user's
    own XLA_FLAGS survive a launcher forcing the device count.
    ``env`` defaults to ``os.environ`` — pass a subprocess env dict to
    shape a child without touching this process.
    """
    env = os.environ if env is None else env
    incoming = {f.split("=", 1)[0]: f for f in flags}
    kept = [f for f in env.get("XLA_FLAGS", "").split()
            if f.split("=", 1)[0] not in incoming]
    merged = " ".join(kept + list(incoming.values()))
    env["XLA_FLAGS"] = merged
    return merged


def force_host_device_count(n: int, *, env: dict | None = None) -> None:
    """Force ``n`` host-platform devices (CPU dev meshes / smoke tests).

    Must run before jax initialises its backend; warns (rather than
    silently doing nothing) when a backend already exists in this
    process. With ``env`` given, shapes that dict for a subprocess
    instead — no ordering constraint applies there.
    """
    merge_xla_flags(device_count_flag(n), env=env)
    if env is None and _backend_initialized():
        warnings.warn(
            f"force_host_device_count({n}) after the jax backend "
            f"initialised has no effect; set it before any jax device "
            f"query (or spawn a fresh process)", RuntimeWarning,
            stacklevel=2)


def _backend_initialized() -> bool:
    if "jax" not in sys.modules:
        return False
    try:
        from jax._src import xla_bridge
        return bool(xla_bridge._backends)
    except Exception:       # private API moved — assume not initialised
        return False


def require_devices(n: int, *, local: bool = False) -> None:
    """Fail with the full remedy if fewer than ``n`` devices exist.

    ``local=True`` counts only THIS process's devices (the multihost
    initialiser validates per-process capacity; mesh builders validate
    the global total). Shared by `launch.mesh.make_host_mesh` and
    `launch.mesh.initialize_multihost` so the two error messages cannot
    drift.
    """
    import jax
    have = len(jax.local_devices() if local else jax.devices())
    if have < n:
        scope = "process-local " if local else ""
        raise RuntimeError(
            f"need {n} {scope}devices, have {have}; on a CPU host set "
            f"XLA_FLAGS={device_count_flag(n)} in the environment "
            f"BEFORE jax initialises (or run on a host with enough "
            f"accelerators)")


#: per-platform XLA flag shaping for the kernel launch path. The gpu
#: set follows jax's published performance-tips list; cpu/tpu currently
#: contribute nothing (Mosaic ignores XLA_FLAGS) but keep a slot so a
#: future platform tweak lands in exactly one place.
_KERNEL_FLAGS = {
    "gpu": ("--xla_gpu_triton_gemm_any=True",
            "--xla_gpu_enable_latency_hiding_scheduler=true"),
}


def apply_kernel_flags(platform: str, *, env: dict | None = None) -> str:
    """Shape XLA_FLAGS for kernel launches on ``platform``.

    Called from BOTH ends of the dispatch plane — `set_platform` (the
    launcher side, before jax initialises) and `kernels.plan
    .resolve_plan` (the engine side, when a fit resolves its
    `KernelPlan`) — so the flag set cannot drift between a launcher
    that configured the platform and a bare fit that did not. Merging
    replaces same-name flags in place, so repeated application is
    idempotent and a user's own XLA_FLAGS survive.
    """
    flags = _KERNEL_FLAGS.get(platform, ())
    if flags:
        return merge_xla_flags(*flags, env=env)
    e = os.environ if env is None else env
    return e.get("XLA_FLAGS", "")


def set_platform(platform: str = "cpu") -> None:
    """Pick the jax platform; also apply its kernel-launch XLA flags.

    Flags are merged (not overwritten) into XLA_FLAGS so a forced host
    device count set earlier survives.
    """
    import jax
    jax.config.update("jax_platform_name", platform)
    apply_kernel_flags(platform)


def jax_enable_x64(use_x64: bool) -> None:
    """Default float precision of jax arrays: 64-bit on/off."""
    import jax
    jax.config.update("jax_enable_x64", bool(use_x64))


def set_debug_nan(flag: bool) -> None:
    """Raise on NaN production (jax debugging flag)."""
    import jax
    jax.config.update("jax_debug_nans", bool(flag))
