"""Synthetic stand-ins for the paper's datasets (offline container).

* ``infmnist_like``  — dense 784-d: k* prototype "digits" (smooth random
  blobs) + per-sample smooth deformation fields + pixel noise, matching
  the generative recipe of Loosli et al.'s infinite-MNIST ("infinitely
  many deformations of the original digits").
* ``rcv1_like``      — tf-idf-ish documents: Zipfian feature popularity,
  log-normal document lengths, l2-normalised rows. Densified at reduced
  dimensionality for the MXU path (sparse kernels are out of scope for
  TPU; see DESIGN.md §6).
* ``lm_tokens``      — deterministic synthetic token stream for the LM
  trainer examples (Zipf unigram with short-range repetition structure).

All generators are seeded and chunked so multi-GB datasets stream without
holding intermediates.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def _prototypes(rng: np.random.Generator, k: int, side: int = 28
                ) -> np.ndarray:
    """Smooth random 'digit' prototypes on a side x side grid."""
    yy, xx = np.mgrid[0:side, 0:side].astype(np.float32) / side
    protos = np.zeros((k, side, side), np.float32)
    for i in range(k):
        n_strokes = rng.integers(2, 5)
        img = np.zeros((side, side), np.float32)
        for _ in range(n_strokes):
            cx, cy = rng.uniform(0.2, 0.8, 2)
            sx, sy = rng.uniform(0.05, 0.25, 2)
            th = rng.uniform(0, np.pi)
            dx, dy = xx - cx, yy - cy
            rx = dx * np.cos(th) + dy * np.sin(th)
            ry = -dx * np.sin(th) + dy * np.cos(th)
            img += np.exp(-(rx ** 2 / (2 * sx ** 2)
                            + ry ** 2 / (2 * sy ** 2)))
        protos[i] = img / max(img.max(), 1e-6)
    return protos


def infmnist_like(n: int, *, n_classes: int = 10, seed: int = 0,
                  side: int = 28, deform: float = 1.5,
                  noise: float = 0.05, chunk: int = 50_000) -> np.ndarray:
    """(n, side*side) f32 deformed-prototype images in [0, 1]."""
    rng = np.random.default_rng(seed)
    protos = _prototypes(rng, n_classes, side)
    yy, xx = np.mgrid[0:side, 0:side].astype(np.float32)
    out = np.empty((n, side * side), np.float32)
    for lo in range(0, n, chunk):
        hi = min(n, lo + chunk)
        m = hi - lo
        cls = rng.integers(0, n_classes, m)
        # smooth per-sample deformation: low-freq sin/cos displacement
        ph = rng.uniform(0, 2 * np.pi, (m, 4)).astype(np.float32)
        amp = rng.uniform(0, deform, (m, 2)).astype(np.float32)
        fx = (xx[None] + amp[:, 0, None, None]
              * np.sin(yy[None] / side * 2 * np.pi + ph[:, 0, None, None]))
        fy = (yy[None] + amp[:, 1, None, None]
              * np.sin(xx[None] / side * 2 * np.pi + ph[:, 1, None, None]))
        xi = np.clip(fx, 0, side - 1).astype(np.int32)
        yi = np.clip(fy, 0, side - 1).astype(np.int32)
        img = protos[cls][np.arange(m)[:, None, None], yi, xi]
        img += noise * rng.standard_normal((m, side, side)).astype(
            np.float32)
        out[lo:hi] = np.clip(img, 0, 1).reshape(m, -1)
    return out


def rcv1_like(n: int, *, dim: int = 2048, avg_nnz: int = 60,
              n_topics: int = 50, seed: int = 0,
              chunk: int = 50_000) -> np.ndarray:
    """(n, dim) f32 l2-normalised tf-idf-like rows (densified).

    Each document mixes a topic's Zipfian feature distribution with a
    global background, log-normal lengths — clusterable structure similar
    in spirit to RCV1's.
    """
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, dim + 1, dtype=np.float64)
    background = 1.0 / ranks ** 1.1
    topic_feats = np.stack([
        rng.permutation(dim)[:dim] for _ in range(n_topics)])
    out = np.empty((n, dim), np.float32)
    for lo in range(0, n, chunk):
        hi = min(n, lo + chunk)
        m = hi - lo
        topics = rng.integers(0, n_topics, m)
        lengths = np.maximum(
            5, rng.lognormal(np.log(avg_nnz), 0.6, m)).astype(np.int32)
        block = np.zeros((m, dim), np.float32)
        for i in range(m):
            t = topics[i]
            probs = background.copy()
            boost = topic_feats[t][: dim // 10]
            probs[boost] *= 20.0
            probs /= probs.sum()
            idx = rng.choice(dim, size=min(int(lengths[i]), dim),
                             replace=False, p=probs)
            tf = 1.0 + rng.standard_exponential(len(idx))
            block[i, idx] = tf.astype(np.float32)
        norms = np.linalg.norm(block, axis=1, keepdims=True)
        out[lo:hi] = block / np.maximum(norms, 1e-9)
    return out


def gaussian_blobs(n: int, *, k: int = 50, dim: int = 64,
                   spread: float = 5.0, seed: int = 0
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Simple mixture (data, true_centers) for tests."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, dim)).astype(np.float32) * spread
    X = (centers[rng.integers(0, k, n)]
         + rng.normal(size=(n, dim)).astype(np.float32))
    return X.astype(np.float32), centers


def lm_tokens(n_tokens: int, *, vocab: int, seed: int = 0,
              repeat_p: float = 0.3) -> np.ndarray:
    """Zipf unigram stream with short-range repetition (compressible)."""
    rng = np.random.default_rng(seed)
    base = rng.zipf(1.3, n_tokens).astype(np.int64)
    toks = (base % (vocab - 2)) + 1
    rep = rng.random(n_tokens) < repeat_p
    idx = np.maximum(np.arange(n_tokens) - rng.integers(1, 32, n_tokens), 0)
    toks[rep] = toks[idx[rep]]
    return toks.astype(np.int32)
