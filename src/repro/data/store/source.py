"""`StoredShardSource`: the nested-shard layout over an on-disk store.

The schedule property that makes out-of-core nested k-means cheap: round
t+1 reuses round t's prefix and only APPENDS, so if consecutive shuffle
positions live in consecutive chunks, the disk frontier advances
monotonically and every chunk is read about once per full-data pass.

A uniform row shuffle destroys that — each doubling's delta scatters
over ALL chunks, costing ~log2(n/b0) full passes. `store_permutation`
therefore shuffles at two levels: chunk ORDER uniformly, then rows
WITHIN each chunk — every shuffle prefix is a contiguous run of whole
chunks (plus one partial frontier chunk), while each point still lands
in the prefix with chunk-level randomness. The caveat is explicit: the
early batches are a by-chunk (not by-row) sample, so a store whose row
order correlates with content at chunk granularity (e.g. sorted by
label) should be written pre-shuffled.

The bit-parity contract with the in-memory engines: a store-backed fit
replays exactly the row sequence ``X[store_permutation(...)]`` — so
``fit(store, shuffle=True)`` equals ``fit(X[perm], shuffle=False)``
bitwise, which the smoke asserts on every backend.
"""
from __future__ import annotations

import zlib
from pathlib import Path
from typing import Dict, Union

import numpy as np

from repro.data.pipeline import ShardLayout, nested_shard_layout
from repro.data.store.reader import ChunkStore


def store_permutation(n: int, chunk_rows: int, seed: int, *,
                      shuffle: bool = True) -> np.ndarray:
    """Chunk-blocked shuffle of ``n`` rows (see module docstring)."""
    if not shuffle:
        return np.arange(n)
    rng = np.random.default_rng(seed)
    n_chunks = -(-n // chunk_rows) if n else 0
    order = rng.permutation(n_chunks)
    parts = []
    for ci in order:
        lo = int(ci) * chunk_rows
        hi = min(n, lo + chunk_rows)
        parts.append(lo + rng.permutation(hi - lo))
    return (np.concatenate(parts) if parts
            else np.arange(0))


def dataset_fingerprint(data) -> Dict[str, object]:
    """Content identity of a fit's dataset, for checkpoint manifests.

    Stores carry their index checksum (covers every chunk's crc32).
    In-memory arrays hash a bounded strided row sample — O(1) in the
    dataset size, computed on the CALLER's array before any shuffle so
    every engine (and every process) of the same fit agrees. Two
    same-shape arrays differing only off-sample collide, which the
    fail-loudly-on-the-wrong-dataset use case accepts.
    """
    if isinstance(data, ChunkStore):
        return data.fingerprint()
    X = np.asarray(data)
    n = int(X.shape[0])
    d = int(X.shape[1]) if X.ndim > 1 else 1
    step = max(1, n // 64)
    sample = np.ascontiguousarray(X[::step][:64])
    return {"kind": "array", "n": n, "d": d, "dtype": str(X.dtype),
            "crc": int(zlib.crc32(sample.tobytes()))}


class StoredShardSource:
    """`KMeansShardedSource` semantics, backed by a `ChunkStore`.

    Same surface (`n_valid` / `shard` / `shard_valid` / `global_prefix`)
    so the parity test can diff the two row-for-row; plus the streaming
    primitive the engines actually use: `block(shards, lo, hi)` fetches
    per-shard storage rows [lo, hi) for several shards in ONE pass over
    the covering chunks — on a round-robin layout those shards' rows
    interleave inside the same chunks, so fetching them together reads
    each chunk once instead of once per shard.
    """

    def __init__(self, store: Union[str, Path, ChunkStore], n_shards: int,
                 *, seed: int = 0, shuffle: bool = True,
                 cache_chunks: int = 8, prefetch_depth: int = 0):
        self.store = (store if isinstance(store, ChunkStore)
                      else ChunkStore(store, cache_chunks=cache_chunks,
                                      prefetch_depth=prefetch_depth))
        self._owns_store = not isinstance(store, ChunkStore)
        perm = store_permutation(self.store.n, self.store.chunk_rows,
                                 seed, shuffle=shuffle)
        self.layout: ShardLayout = nested_shard_layout(
            self.store.n, n_shards, seed=seed, perm=perm)
        self.n_shards = n_shards
        self.perm = self.layout.perm

    # -- KMeansShardedSource-parity surface ---------------------------------

    def n_valid(self, s: int) -> int:
        return int(self.layout.n_valid[s])

    def shard(self, s: int) -> np.ndarray:
        """Full storage slice of shard ``s`` (pads = copies of row 0)."""
        return self.block(np.asarray([s]), 0,
                          self.layout.rows_per_shard)[0]

    def shard_valid(self, s: int) -> np.ndarray:
        return self.shard(s)[: self.n_valid(s)]

    def global_prefix(self, b: int) -> np.ndarray:
        if b > self.store.n:
            raise ValueError(
                f"prefix size {b} exceeds the {self.store.n} real rows")
        return self.store.take(self.perm[:b])

    # -- streaming fetch (the engines' placement primitive) -----------------

    def block(self, shards: np.ndarray, lo: int, hi: int) -> np.ndarray:
        """(len(shards), hi-lo, d): storage rows [lo, hi) of each shard.

        Shard ``s`` storage row ``i`` holds shuffle position
        ``i * n_shards + s``; structural pads (positions >= n) map to
        store row 0, mirroring the in-memory engines' pad semantics.
        """
        shards = np.asarray(shards)
        pos = (np.arange(lo, hi)[:, None] * self.n_shards
               + shards[None, :]).ravel()
        orig = self.perm[pos]
        orig = np.where(orig < self.store.n, orig, 0)
        rows = self.store.take(orig)
        return np.ascontiguousarray(
            rows.reshape(hi - lo, len(shards), self.store.d)
            .transpose(1, 0, 2))

    def prefetch_positions(self, plo: int, phi: int) -> int:
        """Hint the store to warm the chunks covering shuffle positions
        [plo, phi) — the next prefix extension — in the background."""
        if phi <= plo:
            return 0
        orig = self.perm[plo:min(phi, len(self.perm))]
        orig = orig[orig < self.store.n]
        if not orig.size:
            return 0
        cis = np.unique(orig // self.store.chunk_rows)
        return self.store.prefetch(cis.tolist())

    # -- lifecycle ----------------------------------------------------------

    @property
    def metrics(self):
        return self.store.metrics

    def close(self) -> None:
        if self._owns_store:
            self.store.close()
