"""`ChunkStore`: chunk-at-a-time reader over the chunked on-disk format.

Reads go chunk-at-a-time through a small LRU cache: the nested schedule's
disk access is an append-only frontier (see `source.StoredShardSource`),
so a handful of cached chunks turns the per-round per-shard fetches into
exactly one load of each chunk per full-data pass. An optional
background prefetcher warms the cache with the chunks of the NEXT prefix
extension while the current round computes.

Every load is counted (`metrics`): the out-of-core benchmark gates on
``bytes_read <= ~1.1x`` one full pass, which is only honest if the store
itself does the accounting.
"""
from __future__ import annotations

import dataclasses
import json
import os
import queue
import threading
import zlib
from collections import OrderedDict
from pathlib import Path
from typing import Dict, Iterable, Union

import numpy as np

from repro.data.store.writer import DATA_NAME, FORMAT, INDEX_NAME


@dataclasses.dataclass
class StoreMetrics:
    """Cumulative read accounting for one `ChunkStore` handle."""
    chunk_loads: int = 0      # chunks decoded off the mapping
    bytes_read: int = 0       # bytes those loads touched
    cache_hits: int = 0       # chunk requests served from the LRU cache
    rows_served: int = 0      # rows returned by rows()/take()
    prefetched: int = 0       # chunk loads issued by the prefetcher

    def to_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class ChunkStore:
    """Read handle on a store directory written by `writer.StoreWriter`.

    ``cache_chunks`` bounds host memory at
    ``cache_chunks * chunk_rows * d * itemsize`` plus the (lazily paged)
    mapping. ``verify=True`` checks each chunk's crc32 on load — cheap
    insurance for resumable long fits. ``prefetch_depth > 0`` starts a
    daemon thread that loads requested chunks ahead of use; it only ever
    warms the cache, so results are bit-for-bit identical with it on or
    off.
    """

    def __init__(self, path: Union[str, Path], *, cache_chunks: int = 8,
                 verify: bool = False, prefetch_depth: int = 0):
        self.path = Path(path)
        index_file = self.path / INDEX_NAME
        if not index_file.exists():
            raise FileNotFoundError(
                f"{self.path} is not a chunk store (no {INDEX_NAME}); "
                f"build one with repro.data.store.writer")
        self.index = json.loads(index_file.read_text())
        if self.index.get("format") != FORMAT:
            raise ValueError(
                f"unsupported store format {self.index.get('format')!r} "
                f"at {self.path}; this reader speaks {FORMAT}")
        self.n = int(self.index["n"])
        self.d = int(self.index["d"])
        self.dtype = np.dtype(self.index["dtype"])
        self.chunk_rows = int(self.index["chunk_rows"])
        self.checksum = int(self.index["checksum"])
        self._chunks = self.index["chunks"]
        self.n_chunks = len(self._chunks)
        # pread-based loads (NOT a persistent memmap: mapped file pages
        # count toward the process RSS until the OS reclaims them, so a
        # memmap reader silently re-buffers the whole dataset in host
        # memory over a full pass — exactly what the store exists to
        # avoid; pread leaves the bytes in the kernel page cache)
        self._fd = os.open(self.path / self.index.get("data_file",
                                                      DATA_NAME),
                           os.O_RDONLY) if self.n else None
        self._row_bytes = self.d * self.dtype.itemsize
        self._verify = bool(verify)
        self._cache: OrderedDict[int, np.ndarray] = OrderedDict()
        self._cache_chunks = max(1, int(cache_chunks))
        self._lock = threading.RLock()
        self.metrics = StoreMetrics()
        self._prefetch_q: "queue.Queue[int] | None" = None
        self._prefetcher = None
        if prefetch_depth > 0:
            self._prefetch_q = queue.Queue(maxsize=int(prefetch_depth))
            self._prefetcher = threading.Thread(
                target=self._prefetch_loop, daemon=True,
                name=f"chunkstore-prefetch:{self.path.name}")
            self._prefetcher.start()

    # -- chunk access -------------------------------------------------------

    def chunk(self, ci: int) -> np.ndarray:
        """Chunk ``ci`` as a host array (LRU-cached; do not mutate)."""
        if not 0 <= ci < self.n_chunks:
            raise IndexError(f"chunk {ci} out of range "
                             f"[0, {self.n_chunks})")
        with self._lock:
            hit = self._cache.get(ci)
            if hit is not None:
                self._cache.move_to_end(ci)
                self.metrics.cache_hits += 1
                return hit
            arr = self._load(ci)
            self._cache[ci] = arr
            while len(self._cache) > self._cache_chunks:
                self._cache.popitem(last=False)
            return arr

    def _load(self, ci: int) -> np.ndarray:
        meta = self._chunks[ci]
        want = meta["rows"] * self._row_bytes
        buf = os.pread(self._fd, want,
                       ci * self.chunk_rows * self._row_bytes)
        if len(buf) != want:
            raise IOError(f"chunk {ci} of {self.path} is corrupt: "
                          f"short read ({len(buf)} of {want} bytes)")
        arr = np.frombuffer(buf, self.dtype).reshape(meta["rows"], self.d)
        if self._verify:
            crc = zlib.crc32(buf)
            if crc != meta["crc"]:
                raise IOError(
                    f"chunk {ci} of {self.path} is corrupt: crc "
                    f"{crc} != recorded {meta['crc']}")
        self.metrics.chunk_loads += 1
        self.metrics.bytes_read += arr.nbytes
        return arr

    # -- row access ---------------------------------------------------------

    def rows(self, lo: int, hi: int) -> np.ndarray:
        """Rows [lo, hi) in store order (crosses chunk boundaries)."""
        if not 0 <= lo <= hi <= self.n:
            raise IndexError(f"rows [{lo}, {hi}) out of [0, {self.n}]")
        out = np.empty((hi - lo, self.d), self.dtype)
        at = lo
        while at < hi:
            ci = at // self.chunk_rows
            base = ci * self.chunk_rows
            stop = min(hi, base + self._chunks[ci]["rows"])
            out[at - lo:stop - lo] = self.chunk(ci)[at - base:stop - base]
            at = stop
        self.metrics.rows_served += out.shape[0]
        return out

    def take(self, idx: np.ndarray) -> np.ndarray:
        """Rows at arbitrary store indices, loaded chunk-by-chunk."""
        idx = np.asarray(idx)
        if idx.size and (idx.min() < 0 or idx.max() >= self.n):
            raise IndexError(
                f"take indices out of [0, {self.n}): "
                f"[{idx.min()}, {idx.max()}]")
        out = np.empty((idx.size, self.d), self.dtype)
        ci_of = idx // self.chunk_rows
        for ci in np.unique(ci_of):
            m = ci_of == ci
            out[m] = self.chunk(int(ci))[idx[m] - int(ci) * self.chunk_rows]
        self.metrics.rows_served += out.shape[0]
        return out

    # -- prefetch -----------------------------------------------------------

    def prefetch(self, cis: Iterable[int]) -> int:
        """Request background loads; drops requests beyond the queue
        bound (prefetch is a hint, never a dependency). Returns how many
        were enqueued; 0 when no prefetcher is running."""
        if self._prefetch_q is None:
            return 0
        sent = 0
        for ci in cis:
            try:
                self._prefetch_q.put_nowait(int(ci))
                sent += 1
            except queue.Full:
                break
        return sent

    def _prefetch_loop(self) -> None:
        while True:
            ci = self._prefetch_q.get()
            if ci < 0:
                return
            with self._lock:
                cached = ci in self._cache
            if not cached:
                try:
                    self.chunk(ci)
                    with self._lock:
                        self.metrics.prefetched += 1
                except Exception:
                    pass        # the foreground read will raise properly

    # -- lifecycle ----------------------------------------------------------

    def fingerprint(self) -> Dict[str, object]:
        """Content identity for checkpoint manifests (see
        `source.dataset_fingerprint`): shape, dtype and the store-level
        checksum, which covers every chunk's crc32."""
        return {"kind": "store", "n": self.n, "d": self.d,
                "dtype": self.dtype.name, "crc": self.checksum}

    def close(self) -> None:
        if self._prefetch_q is not None:
            self._prefetch_q.put(-1)
            self._prefetcher.join(timeout=5)
            self._prefetch_q = None
        self._cache.clear()
        if self._fd is not None:
            os.close(self._fd)
            self._fd = None

    def __enter__(self) -> "ChunkStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __repr__(self) -> str:
        return (f"ChunkStore({str(self.path)!r}, n={self.n}, d={self.d}, "
                f"dtype={self.dtype.name}, chunks={self.n_chunks}x"
                f"{self.chunk_rows})")
