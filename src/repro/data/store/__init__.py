"""`repro.data.store` — the out-of-core chunked data plane.

A dataset too large for host RAM lives on disk as fixed-size row chunks
plus a JSON index (`writer.StoreWriter`); `reader.ChunkStore` memory-maps
it back with an LRU chunk cache, an optional background prefetcher and
read metrics; `source.StoredShardSource` composes the store with the
engines' `nested_shard_layout` so each process fetches exactly the
chunks covering its shards' next prefix extension per round — the
paper's "reuse old, append new" schedule turned into an append-only
disk-read frontier.
"""
from repro.data.store.reader import ChunkStore, StoreMetrics
from repro.data.store.source import (StoredShardSource, dataset_fingerprint,
                                     store_permutation)
from repro.data.store.writer import StoreWriter, write_store

__all__ = ["ChunkStore", "StoreMetrics", "StoreWriter", "StoredShardSource",
           "dataset_fingerprint", "store_permutation", "write_store"]
