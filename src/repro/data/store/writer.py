"""Chunked on-disk dataset writer (+ the `python -m` store-building CLI).

Format (one directory):

    data.bin     row-major raw bytes, chunk after chunk
    index.json   {"format", "n", "d", "dtype", "chunk_rows", "checksum",
                  "chunks": [{"offset", "rows", "crc"}, ...]}

All chunks hold exactly ``chunk_rows`` rows except a possibly-ragged
tail. Each chunk carries a crc32; the store-level ``checksum`` covers
the shape header plus every chunk crc, so it fingerprints the full
dataset content without a second pass over the bytes. The index is
written atomically (tmp + rename) at `close`, so a crashed writer never
leaves a readable-but-truncated store behind.

The writer is append-only and buffers at most one chunk: building a
store from a generator streams at O(chunk_rows * d) host memory no
matter how large the dataset.
"""
from __future__ import annotations

import argparse
import json
import os
import zlib
from pathlib import Path
from typing import Iterable, Optional, Union

import numpy as np

INDEX_NAME = "index.json"
DATA_NAME = "data.bin"
FORMAT = "repro.chunkstore/1"


def _header_checksum(n: int, d: int, dtype: str, chunk_rows: int,
                     chunk_crcs: Iterable[int]) -> int:
    payload = json.dumps([n, d, dtype, chunk_rows, list(chunk_crcs)])
    return zlib.crc32(payload.encode())


class StoreWriter:
    """Append-only chunked writer; context manager closing the index.

        with StoreWriter(path, d=64, chunk_rows=65536) as w:
            for block in blocks:        # any row counts, any order
                w.append(block)
        store = ChunkStore(path)
    """

    def __init__(self, path: Union[str, Path], *, d: int,
                 dtype: Union[str, np.dtype] = np.float32,
                 chunk_rows: int = 65536):
        if d < 1:
            raise ValueError(f"d must be >= 1, got {d}")
        if chunk_rows < 1:
            raise ValueError(f"chunk_rows must be >= 1, got {chunk_rows}")
        self.path = Path(path)
        self.path.mkdir(parents=True, exist_ok=True)
        self.d = int(d)
        self.dtype = np.dtype(dtype)
        self.chunk_rows = int(chunk_rows)
        self._f = open(self.path / DATA_NAME, "wb")
        self._buf: list[np.ndarray] = []
        self._buf_rows = 0
        self._chunks: list[dict] = []
        self._offset = 0
        self._n = 0
        self._closed = False

    def append(self, rows: np.ndarray) -> None:
        rows = np.ascontiguousarray(rows, dtype=self.dtype)
        if rows.ndim == 1:
            rows = rows[None, :]
        if rows.ndim != 2 or rows.shape[1] != self.d:
            raise ValueError(
                f"append expects (m, {self.d}) rows, got {rows.shape}")
        self._buf.append(rows)
        self._buf_rows += rows.shape[0]
        while self._buf_rows >= self.chunk_rows:
            block = np.concatenate(self._buf, axis=0)
            self._flush_chunk(block[:self.chunk_rows])
            rest = block[self.chunk_rows:]
            self._buf = [rest] if rest.shape[0] else []
            self._buf_rows = rest.shape[0]

    def _flush_chunk(self, arr: np.ndarray) -> None:
        raw = arr.tobytes()
        self._f.write(raw)
        self._chunks.append({"offset": self._offset, "rows": arr.shape[0],
                             "crc": zlib.crc32(raw)})
        self._offset += len(raw)
        self._n += arr.shape[0]

    def close(self) -> dict:
        """Flush the ragged tail and atomically publish the index."""
        if self._closed:
            return self._index
        if self._buf_rows:
            self._flush_chunk(np.concatenate(self._buf, axis=0))
            self._buf, self._buf_rows = [], 0
        self._f.flush()
        os.fsync(self._f.fileno())
        self._f.close()
        self._index = {
            "format": FORMAT,
            "n": self._n, "d": self.d, "dtype": self.dtype.name,
            "chunk_rows": self.chunk_rows, "data_file": DATA_NAME,
            "checksum": _header_checksum(
                self._n, self.d, self.dtype.name, self.chunk_rows,
                (c["crc"] for c in self._chunks)),
            "chunks": self._chunks,
        }
        tmp = self.path / (INDEX_NAME + ".tmp")
        tmp.write_text(json.dumps(self._index))
        os.replace(tmp, self.path / INDEX_NAME)
        self._closed = True
        return self._index

    def __enter__(self) -> "StoreWriter":
        return self

    def __exit__(self, *exc) -> None:
        if exc[0] is None:
            self.close()
        else:                        # crashed mid-write: no index => no store
            self._f.close()


def write_store(path: Union[str, Path], X: np.ndarray, *,
                chunk_rows: int = 65536,
                dtype: Optional[Union[str, np.dtype]] = None) -> Path:
    """One-call store build from an in-memory (or memmapped) array."""
    X = np.asarray(X)
    if X.ndim != 2:
        raise ValueError(f"write_store expects a 2-D array, got {X.shape}")
    with StoreWriter(path, d=X.shape[1], dtype=dtype or X.dtype,
                     chunk_rows=chunk_rows) as w:
        for lo in range(0, X.shape[0], chunk_rows):
            w.append(X[lo:lo + chunk_rows])
    return Path(path)


# --------------------------------------------------------------------------
# synthetic streaming sources (benchmarks + CLI)
# --------------------------------------------------------------------------

def blob_rows(n: int, *, dim: int, classes: int = 50, seed: int = 0,
              spread: float = 5.0, block: int = 0) -> np.ndarray:
    """One deterministic block of the infinite gaussian-blob stream.

    The mixture centers depend only on ``seed``; the samples of block
    ``i`` depend on ``(seed, i)`` — so a store of any size can be
    generated block-by-block at O(block) memory, and a validation set is
    just blocks from a disjoint index range.
    """
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(classes, dim)).astype(np.float32) * spread
    brng = np.random.default_rng((seed, block))
    cls = brng.integers(0, classes, n)
    return (centers[cls]
            + brng.normal(size=(n, dim)).astype(np.float32)
            ).astype(np.float32)


def write_synthetic_store(path: Union[str, Path], *, n: int, dim: int,
                          classes: int = 50, seed: int = 0,
                          spread: float = 5.0,
                          chunk_rows: int = 65536) -> Path:
    """Stream a gaussian-blob dataset of any size straight to disk."""
    with StoreWriter(path, d=dim, chunk_rows=chunk_rows) as w:
        block = 0
        done = 0
        while done < n:
            m = min(chunk_rows, n - done)
            w.append(blob_rows(m, dim=dim, classes=classes, seed=seed,
                               spread=spread, block=block))
            done += m
            block += 1
    return Path(path)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="Build a repro.data.store chunked dataset on disk")
    ap.add_argument("out", help="store directory to create")
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--from-npy", metavar="FILE",
                     help="convert a .npy array (memory-mapped: the "
                          "array is never loaded whole)")
    src.add_argument("--synthetic", choices=("blobs",),
                     help="stream a synthetic dataset (with --n/--dim)")
    ap.add_argument("--n", type=int, default=1_000_000,
                    help="rows for --synthetic")
    ap.add_argument("--dim", type=int, default=64,
                    help="columns for --synthetic")
    ap.add_argument("--classes", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chunk-rows", type=int, default=65536)
    ap.add_argument("--dtype", default=None,
                    help="cast rows to this dtype (default: keep)")
    args = ap.parse_args(argv)

    if args.from_npy:
        X = np.load(args.from_npy, mmap_mode="r")
        out = write_store(args.out, X, chunk_rows=args.chunk_rows,
                          dtype=args.dtype)
    else:
        out = write_synthetic_store(
            args.out, n=args.n, dim=args.dim, classes=args.classes,
            seed=args.seed, chunk_rows=args.chunk_rows)
    idx = json.loads((out / INDEX_NAME).read_text())
    print(f"wrote {idx['n']} x {idx['d']} {idx['dtype']} rows in "
          f"{len(idx['chunks'])} chunks of {idx['chunk_rows']} to {out} "
          f"(checksum {idx['checksum']})")


if __name__ == "__main__":
    main()
