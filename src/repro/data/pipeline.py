"""Data pipelines: nested-prefix k-means sharding + LM token batches.

`nested_shard_layout` is THE host-side description of how the mesh
engine places points: shuffle, structural tail padding to a multiple of
the shard count, and the interleave that makes the union of per-shard
prefixes equal the global shuffle prefix. `repro.api.engines.mesh._MeshRun`
and `KMeansShardedSource` both build on it, so the streaming source and
the device placement can never drift apart (tested for parity).

KMeansShardedSource: the nested-batch schedule needs each device shard to
hold a contiguous slice whose prefix-union equals the global shuffle
prefix. This class is the equivalent host-side iterator for streaming
datasets (points arrive in shuffle order, are round-robined to shards,
and each shard appends — so shard prefixes always reconstruct the global
prefix exactly, even under restart). When ``n % n_shards != 0`` the
source pads with structural tail rows exactly like the mesh engine
(PR 2 semantics): pads sit at the END of the shuffle, land on the tail
storage row of the high shards, and each shard's real rows stay
prefix-contiguous with a per-shard ``n_valid`` count.

LMBatches: deterministic, seekable token batches — ``state == (step,)``
so a restarted trainer resumes mid-epoch bit-identically.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.data import synthetic


@dataclasses.dataclass(frozen=True)
class ShardLayout:
    """How ``n_real`` rows land on ``n_shards`` nested-prefix shards.

    Attributes:
      n_real     caller's dataset size (structural pads excluded).
      n_shards   data shards.
      n_storage  padded total rows; always a multiple of ``n_shards``.
      perm       (n_storage,) global shuffle: shuffle position p holds
                 data row ``perm[p]``; positions >= n_real are the
                 identity tail of structural pads.
      pos        (n_storage,) inverse interleave: storage row
                 ``shard * (n_storage // n_shards) + i`` holds shuffle
                 position ``pos[...] == i * n_shards + shard``.
      n_valid    (n_shards,) real rows on each shard; real rows are the
                 prefix of the shard's storage slice.
    """
    n_real: int
    n_shards: int
    n_storage: int
    perm: np.ndarray
    pos: np.ndarray
    n_valid: np.ndarray

    @property
    def rows_per_shard(self) -> int:
        return self.n_storage // self.n_shards

    def shard_positions(self, s: int) -> np.ndarray:
        """Global-shuffle positions held by shard ``s``, storage order."""
        return np.arange(s, self.n_storage, self.n_shards)

    def orig_index(self) -> np.ndarray:
        """(n_storage,) original data row at each storage row (-1 = pad)."""
        orig = self.perm[self.pos]
        return np.where(orig < self.n_real, orig, -1)

    def shard_orig_rows(self, s: int) -> np.ndarray:
        """(rows_per_shard,) original data row at each storage row OF
        SHARD ``s``, in storage order (-1 = structural pad).

        This is the per-process placement primitive: a multihost
        process materialises only its own shards' rows —
        ``X[shard_orig_rows(s)]`` with pads mapped to ``X[0]`` — instead
        of the full padded permutation of the dataset.
        """
        r = self.rows_per_shard
        return self.orig_index()[s * r:(s + 1) * r]


def nested_shard_layout(n_real: int, n_shards: int, *, seed: int = 0,
                        shuffle: bool = True,
                        perm: Optional[np.ndarray] = None) -> ShardLayout:
    """The mesh engine's data placement, as pure host-side index math.

    Shuffle positions are dealt round-robin: shard ``s`` holds positions
    ``s::n_shards`` — so the union of per-shard prefixes of size
    ``b // n_shards`` IS the global shuffle prefix of size ``b``.
    Structural pads occupy positions ``n_real..n_storage-1`` (the end of
    the shuffle), hence the LAST storage row of the high shards; every
    shard's real rows stay prefix-contiguous and are counted by
    ``n_valid``.

    ``perm`` overrides the shuffle with a caller-supplied permutation of
    the ``n_real`` rows (the identity pad tail is appended here). The
    out-of-core `StoredShardSource` uses this to install its
    chunk-blocked shuffle while inheriting all pad/interleave semantics.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    pad = -n_real % n_shards
    n_storage = n_real + pad
    if perm is not None:
        perm = np.asarray(perm)
        if perm.shape != (n_real,):
            raise ValueError(
                f"perm must permute the {n_real} real rows, got shape "
                f"{perm.shape}")
        perm = np.concatenate([perm, np.arange(n_real, n_storage)])
    else:
        rng = np.random.default_rng(seed)
        perm = (np.concatenate([rng.permutation(n_real),
                                np.arange(n_real, n_storage)])
                if shuffle else np.arange(n_storage))
    pos = np.arange(n_storage).reshape(n_storage // n_shards, n_shards) \
        .T.ravel()
    n_valid = np.array([len(range(s, n_real, n_shards))
                        for s in range(n_shards)])
    return ShardLayout(n_real=n_real, n_shards=n_shards,
                       n_storage=n_storage, perm=perm, pos=pos,
                       n_valid=n_valid)


@dataclasses.dataclass
class KMeansShardedSource:
    """Round-robin shard assignment preserving the nested-prefix property.

    ``n % n_shards != 0`` is handled with the mesh engine's structural-
    pad semantics: `shard(s)` returns the full storage slice (pads are
    copies of ``X[0]`` at the tail), and ``n_valid(s)`` says how many
    leading rows are real — the same per-shard mask `_MeshRun` derives
    inside the sharded round.
    """
    X: np.ndarray
    n_shards: int
    seed: int = 0
    perm_override: Optional[np.ndarray] = None

    def __post_init__(self):
        n = self.X.shape[0]
        self.layout = nested_shard_layout(n, self.n_shards, seed=self.seed,
                                          perm=self.perm_override)
        pad = self.layout.n_storage - n
        self._Xp = (np.concatenate([self.X, np.repeat(self.X[:1], pad,
                                                      axis=0)])
                    if pad else self.X)
        self.perm = self.layout.perm

    def n_valid(self, s: int) -> int:
        """Real (non-pad) rows on shard ``s``; always a prefix."""
        return int(self.layout.n_valid[s])

    def shard(self, s: int) -> np.ndarray:
        """Shard s holds global-shuffle positions s::n_shards, in order.

        Rows past ``n_valid(s)`` are structural pads (copies of X[0]).
        """
        return self._Xp[self.perm[s::self.n_shards]]

    def shard_valid(self, s: int) -> np.ndarray:
        """Only the real rows of shard ``s`` (pads stripped)."""
        return self.shard(s)[: self.n_valid(s)]

    def global_prefix(self, b: int) -> np.ndarray:
        if b > self.X.shape[0]:
            raise ValueError(
                f"prefix size {b} exceeds the {self.X.shape[0]} real rows")
        return self.X[self.perm[:b]]


class LMBatches:
    """Seekable synthetic LM batches: (tokens, labels) of (B, S) int32."""

    def __init__(self, *, vocab: int, batch: int, seq: int,
                 n_tokens: int = 2_000_000, seed: int = 0):
        self.tokens = synthetic.lm_tokens(n_tokens, vocab=vocab, seed=seed)
        self.batch, self.seq = batch, seq
        self.per_step = batch * (seq + 1)
        self.n_steps = len(self.tokens) // self.per_step

    def __len__(self) -> int:
        return self.n_steps

    def at(self, step: int) -> Dict[str, np.ndarray]:
        i = (step % self.n_steps) * self.per_step
        chunk = self.tokens[i: i + self.per_step].reshape(
            self.batch, self.seq + 1)
        return {"tokens": chunk[:, :-1].astype(np.int32),
                "labels": chunk[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.at(step)
            step += 1
