"""Data pipelines: nested-prefix k-means sharding + LM token batches.

KMeansShardedSource: the nested-batch schedule needs each device shard to
hold a contiguous slice whose prefix-union equals the global shuffle
prefix — handled by the interleave in core.distributed.fit_distributed.
This module provides the equivalent host-side iterator for streaming
datasets (points arrive in shuffle order, are round-robined to shards,
and each shard appends — so shard prefixes always reconstruct the global
prefix exactly, even under restart).

LMBatches: deterministic, seekable token batches — ``state == (step,)``
so a restarted trainer resumes mid-epoch bit-identically.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.data import synthetic


@dataclasses.dataclass
class KMeansShardedSource:
    """Round-robin shard assignment preserving the nested-prefix property."""
    X: np.ndarray
    n_shards: int
    seed: int = 0

    def __post_init__(self):
        n = self.X.shape[0]
        if n % self.n_shards:
            raise ValueError((n, self.n_shards))
        rng = np.random.default_rng(self.seed)
        self.perm = rng.permutation(n)

    def shard(self, s: int) -> np.ndarray:
        """Shard s holds global-shuffle positions s::n_shards, in order."""
        return self.X[self.perm[s::self.n_shards]]

    def global_prefix(self, b: int) -> np.ndarray:
        return self.X[self.perm[:b]]


class LMBatches:
    """Seekable synthetic LM batches: (tokens, labels) of (B, S) int32."""

    def __init__(self, *, vocab: int, batch: int, seq: int,
                 n_tokens: int = 2_000_000, seed: int = 0):
        self.tokens = synthetic.lm_tokens(n_tokens, vocab=vocab, seed=seed)
        self.batch, self.seq = batch, seq
        self.per_step = batch * (seq + 1)
        self.n_steps = len(self.tokens) // self.per_step

    def __len__(self) -> int:
        return self.n_steps

    def at(self, step: int) -> Dict[str, np.ndarray]:
        i = (step % self.n_steps) * self.per_step
        chunk = self.tokens[i: i + self.per_step].reshape(
            self.batch, self.seq + 1)
        return {"tokens": chunk[:, :-1].astype(np.int32),
                "labels": chunk[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.at(step)
            step += 1
