"""The checker allowlist: sanctioned exceptions, one per line, with a
mandatory reason.

Format (``allowlist.txt`` next to this module)::

    <file>::<qualname>::<kind>::<detail-substring>  # <reason>

``file`` is repo-relative; ``qualname`` and ``kind`` match exactly or
are ``*``; ``detail-substring`` must occur in the violation's detail
(``*`` matches anything).  A line with no ``# reason`` is a parse
error — an exception nobody can justify is not an exception.

Matching is deliberately narrow: an entry keyed on file+qualname+kind
cannot blanket-silence a checker, and `unused_entries` lets the lint
fail on entries that no longer match anything, so the allowlist shrinks
when the code it excuses is fixed.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import List, Tuple

from repro.analysis.report import Violation

DEFAULT_PATH = Path(__file__).with_name("allowlist.txt")


@dataclasses.dataclass(frozen=True)
class Entry:
    file: str
    qualname: str
    kind: str
    substring: str
    reason: str
    lineno: int

    def matches(self, v: Violation) -> bool:
        return (self.file == v.file
                and self.qualname in ("*", v.qualname)
                and self.kind in ("*", v.kind)
                and (self.substring == "*" or self.substring in v.detail))


def load(path=None) -> List[Entry]:
    path = Path(path) if path is not None else DEFAULT_PATH
    entries: List[Entry] = []
    for lineno, raw in enumerate(path.read_text().splitlines(), start=1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        body, sep, reason = line.partition("#")
        reason = reason.strip()
        if not sep or not reason:
            raise ValueError(
                f"{path}:{lineno}: allowlist entry has no '# reason' — "
                f"every sanctioned exception must say why: {raw!r}")
        parts = [p.strip() for p in body.strip().split("::")]
        if len(parts) != 4 or not all(parts):
            raise ValueError(
                f"{path}:{lineno}: expected "
                f"'file::qualname::kind::substring  # reason', got {raw!r}")
        entries.append(Entry(*parts, reason=reason, lineno=lineno))
    return entries


def apply(violations: List[Violation], entries: List[Entry]
          ) -> Tuple[List[Violation], List[Entry]]:
    """(violations not excused, entries that excused at least one)."""
    used = set()
    kept = []
    for v in violations:
        hit = next((e for e in entries if e.matches(v)), None)
        if hit is None:
            kept.append(v)
        else:
            used.add(id(hit))
    return kept, [e for e in entries if id(e) in used]


def unused_entries(entries: List[Entry], used: List[Entry],
                   path=None) -> List[Violation]:
    """Stale allowlist entries, reported as violations themselves."""
    path = Path(path) if path is not None else DEFAULT_PATH
    used_ids = {id(e) for e in used}
    from repro.analysis.report import rel
    return [
        Violation(checker="lint", kind="stale-allowlist",
                  file=rel(path), line=e.lineno, qualname=e.qualname,
                  detail=(f"entry excuses nothing any more "
                          f"({e.file}::{e.qualname}::{e.kind}) — "
                          f"delete it"))
        for e in entries if id(e) not in used_ids]
