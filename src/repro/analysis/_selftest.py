"""Planted historical bug classes — the checkers' negative fixtures.

Each fixture replants a bug this repo actually shipped (and fixed), in
the exact shape a regression would take, so the selftests prove the
checkers still have teeth:

  * `LeakyRun` — the PR 2 class: a per-round schedule decision read off
    a live device scalar (branch + host coercion + ambient RNG).  The
    lint must flag its AST; the host-sync auditor must flag the sync at
    runtime with this file's line numbers.
  * `growing_update` / `replicated_smap_update` — the PR 6 class: a
    donated jit whose output cannot occupy the donated buffer (shape
    outgrows it / shard_map output replicated), so XLA silently copies.
  * `retrace_fixture_violations` — the rho-keyed retrace class: the
    same (b, capacity) bucket compiled once per round because a float
    hyperparameter rides in the jit key; plus an exact-need (non-pow2)
    capacity schedule.

This module is imported by the checkers' ``selftest()`` entry points
and by tests/test_analysis.py; it is NOT part of the production import
graph (importing it initialises jax).
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.report import Violation, rel
from repro.api.engines.local import _LocalRun, nested_jit

_HERE = rel(__file__)


# -- PR 2 class: device-scalar control flow ----------------------------------

class LeakyRun(_LocalRun):
    """A local run whose schedule leaks device state into host control
    flow — every pattern below is a planted lint/hostsync violation."""

    def nested_step(self, state, b, capacity):
        # branch + float() coercion on a live device scalar: one hidden
        # device->host sync per round, and divergent control flow on a
        # multi-process run
        if float(jnp.max(state.stats.p)) > 1e9:
            b = max(1, b // 2)
        return super().nested_step(state, b, capacity)

    def mb_step(self, state, fixed):
        # ambient entropy: processes draw different numbers
        if np.random.random() < 2.0:
            pass
        return super().mb_step(state, fixed)

    def eval_mse(self, state):
        # .item() on device state without derivation from HostRoundInfo
        _ = state.stats.sse.item(0)
        return super().eval_mse(state)


class LeakyEngine:
    def begin(self, X, config, *, X_val=None, init_C=None):
        return LeakyRun(X, config, X_val, init_C)


def leaky_line(marker: str) -> int:
    """1-based line of the first planted occurrence of ``marker``."""
    from pathlib import Path
    for i, line in enumerate(
            Path(__file__).read_text().splitlines(), start=1):
        if marker in line and "marker" not in line:
            return i
    raise AssertionError(f"marker {marker!r} not found in fixture")


def hostsync_fixture_violations(audit_backend) -> List[Violation]:
    found = audit_backend(backend="local",
                          engine_factory=lambda cfg: LeakyEngine())
    planted = [v for v in found if v.file == _HERE]
    if not planted:
        raise AssertionError(
            "hostsync selftest: the planted device-scalar branch "
            f"(PR 2 bug class) was NOT flagged; got only: "
            f"{[str(v) for v in found]}")
    return planted


# -- PR 6 class: donated-but-copying jits ------------------------------------

#: donation that XLA cannot honour: the output outgrows the donated
#: buffer, so every call silently copies.
growing_update = jax.jit(
    lambda Xs: jnp.concatenate([Xs, Xs[:1]], axis=0), donate_argnums=0)


def replicated_smap_update(mesh, axis: str = "data"):
    """The literal PR 6 spelling: a shard_map'd donated segment writer
    whose out_specs replicate — per-device output shape != donated
    piece shape, so aliasing is impossible and the whole buffer copies
    on every segment write."""
    from jax.sharding import PartitionSpec as P

    from repro.core.distributed import shard_map_compat

    def body(Xs, seg, at):
        upd = jax.lax.dynamic_update_slice(Xs, seg, (at, 0))
        return jax.lax.all_gather(upd, axis, axis=0, tiled=True)

    fn = shard_map_compat(body, mesh=mesh,
                          in_specs=(P(axis), P(axis), P()),
                          out_specs=P())
    return jax.jit(fn, donate_argnums=0)


def donation_fixture_violations(audit_donated_jit) -> List[Violation]:
    line = leaky_line("jnp.concatenate([Xs, Xs[:1]]")
    found = audit_donated_jit(
        growing_update, (np.zeros((256, 16), np.float32),), donated=(0,),
        file=_HERE, line=line, qualname="growing_update")
    if len(jax.devices()) > 1:
        mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        fn = replicated_smap_update(mesh)
        found += audit_donated_jit(
            fn, (np.zeros((256, 16), np.float32),
                 np.ones((64, 16), np.float32),
                 jnp.zeros((), jnp.int32)),
            donated=(0,), file=_HERE,
            line=leaky_line("def replicated_smap_update"),
            qualname="replicated_smap_update")
    if not found:
        raise AssertionError(
            "donation selftest: the planted copying donation (PR 6 bug "
            "class) was NOT flagged")
    return found


# -- retrace class: per-round cache keys -------------------------------------

def retrace_fixture_violations(trace_violations, lattice_violations
                               ) -> List[Violation]:
    from repro.core.state import init_state
    from repro.util import tracecount

    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(64, 4)).astype(np.float32))
    state = init_state(X, 4)

    # rho drifting per round keys the jit cache: same (b, capacity)
    # bucket, a fresh executable every round — the historical regression
    invoked = []
    before = tracecount.snapshot()
    for rho in (1.90, 1.91, 1.92):
        nested_jit(X, state, b=32, rho=rho, bounds="hamerly2",
                   capacity=16, use_shalf=True, plan=None)
        invoked.append((32, 16))
    diff = tracecount.diff(before)
    found = trace_violations(
        diff, invoked, "nested_round", site_file=_HERE,
        site_line=leaky_line("for rho in (1.90, 1.91, 1.92)"),
        qualname="retrace_fixture[rho-keyed]")

    # exact-need capacity: off the pow2 lattice, one executable per
    # distinct need value — unbounded cache growth
    found += lattice_violations(
        [(32, 24), (48, None)], 32, 64, site_file=_HERE,
        site_line=leaky_line("[(32, 24), (48, None)]"),
        qualname="retrace_fixture[off-lattice]")
    if not [v for v in found if v.kind == "retrace"]:
        raise AssertionError(
            "retrace selftest: the planted rho-keyed retrace was NOT "
            "flagged")
    if not [v for v in found if v.kind == "off-lattice-bucket"]:
        raise AssertionError(
            "retrace selftest: the planted off-lattice schedule was "
            "NOT flagged")
    return found
