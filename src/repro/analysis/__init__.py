"""`repro.analysis` — invariant checkers for the control plane.

The repo's correctness story rests on invariants that ordinary unit
tests cannot see: the host loop's control flow must be bit-identical on
every process (PR 5), the steady-state loop must not sync device state
to the host outside the sanctioned crossings, the jit cache must hold
exactly one executable per power-of-two schedule bucket, and every
donated buffer in the engine data path must actually be aliased by the
compiled executable.  Each of these has been violated by a real
historical bug class in this codebase; this package turns each one into
a mechanical check:

  lint       static AST pass over `repro.api.loop` and the engines —
             flags per-round branches, host coercions and RNG draws
             that do not derive from the psum-reduced `HostRoundInfo`
             scalars, the resolved `FitConfig`, or the sanctioned
             `run` primitives (`replicated_lint`).
  hostsync   runs a small fit per backend under a device->host
             interceptor (plus `jax.transfer_guard`) scoped by
             `repro.api.loop.LoopAudit` — any sync outside the
             sanctioned scopes is a violation with the caller's
             file:line (`hostsync`).
  retrace    runs a full growth schedule and counts ACTUAL jit traces
             via `repro.util.tracecount` — every (b, capacity) bucket
             must trace at most once and sit on the pow2 lattice
             (`retrace`).
  donation   proves every `donate_argnums` jit in the engine data path
             aliases its donated operand in the compiled executable —
             via `memory_analysis()` and buffer-pointer identity
             (`donation`).

Run them all: ``python -m repro.analysis all`` (see `__main__`).  Each
checker also has a ``selftest`` that replants the historical bug class
(device-scalar branch, rho-keyed retrace, copying donation) and asserts
the checker still catches it.  Sanctioned exceptions live in
`allowlist.txt` next to this file — every entry carries a reason and
stale entries fail the lint, so the exception surface stays auditable.

Everything here is import-light: importing the package or the lint
touches no jax; the runtime auditors import jax lazily so the CLI can
force a host device count first (`repro.util.env`).
"""
from __future__ import annotations

from repro.analysis.report import Violation

__all__ = ["Violation"]
