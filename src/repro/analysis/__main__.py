"""``python -m repro.analysis`` — run the invariant checkers.

Usage::

    python -m repro.analysis lint                  # AST lint (no jax)
    python -m repro.analysis hostsync retrace      # runtime auditors
    python -m repro.analysis all                   # everything
    python -m repro.analysis all --selftest        # planted-bug teeth check
    python -m repro.analysis hostsync --backends local,mesh,xl

Exit status 0 iff every requested check is clean (or, with
``--selftest``, iff every checker still flags its planted historical
bug class).  The runtime checkers need multiple devices for the mesh/xl
backends, so the host device count is forced BEFORE jax initialises —
which is why this module must stay the process entry point and must not
import jax at module scope.
"""
from __future__ import annotations

import argparse
import sys
from typing import List

CHECKS = ("lint", "hostsync", "retrace", "donation")
RUNTIME_CHECKS = {"hostsync", "retrace", "donation"}


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="invariant checkers: replicated-control-flow lint, "
                    "host-sync / retrace / donation auditors")
    p.add_argument("checks", nargs="*", default=["all"],
                   choices=list(CHECKS) + ["all"],
                   help="which checkers to run (default: all)")
    p.add_argument("--backends", default="local,mesh,xl",
                   help="comma-separated backends for the runtime "
                        "auditors (default: local,mesh,xl)")
    p.add_argument("--devices", type=int, default=4,
                   help="host device count to force for multi-device "
                        "backends (default: 4)")
    p.add_argument("--allowlist", default=None,
                   help="alternate allowlist file for the lint")
    p.add_argument("--trace-dir", default=None,
                   help="attach a repro.obs FitObserver to the hostsync "
                        "audits (per-backend subdirectories) — gates "
                        "that tracing adds no device->host syncs")
    p.add_argument("--selftest", action="store_true",
                   help="instead of auditing the tree, replant each "
                        "checker's historical bug class and FAIL if it "
                        "is no longer flagged")
    args = p.parse_args(argv)

    checks = list(CHECKS) if "all" in args.checks else \
        [c for c in CHECKS if c in args.checks]
    backends = [b.strip() for b in args.backends.split(",") if b.strip()]

    if set(checks) & RUNTIME_CHECKS:
        # must precede any jax import in this process
        from repro.util.env import force_host_device_count
        force_host_device_count(args.devices)

    failures = 0
    for check in checks:
        violations = _run_check(check, args, backends)
        if args.selftest:
            # a selftest SUCCEEDS by producing violations (the planted
            # bug was caught); _run_check raises when teeth are lost
            print(f"[{check}] selftest: planted bug class flagged "
                  f"({len(violations)} finding(s))")
            for v in violations:
                print(f"    {v}")
            continue
        if violations:
            failures += len(violations)
            print(f"[{check}] FAIL — {len(violations)} violation(s):")
            for v in sorted(violations,
                            key=lambda v: (v.file, v.line, v.kind)):
                print(f"    {v}")
        else:
            scope = (f" (backends: {', '.join(backends)})"
                     if check in ("hostsync", "retrace") else "")
            print(f"[{check}] OK{scope}")
    if failures:
        print(f"\n{failures} violation(s); see "
              f"src/repro/analysis/allowlist.txt for how sanctioned "
              f"exceptions are recorded")
        return 1
    return 0


def _run_check(check: str, args, backends: List[str]):
    if check == "lint":
        from repro.analysis import replicated_lint
        if args.selftest:
            from repro.analysis.report import repo_root
            fixture = (repo_root()
                       / "src/repro/analysis/_selftest.py")
            found = replicated_lint.lint_file(fixture, mode="engine")
            kinds = {v.kind for v in found}
            missing = ({"branch", "host-coercion", "rng-draw"}
                       - kinds)
            if missing:
                raise AssertionError(
                    f"lint selftest: planted kinds not flagged: "
                    f"{sorted(missing)}")
            return found
        return replicated_lint.run(allowlist_path=args.allowlist)
    if check == "hostsync":
        from repro.analysis import hostsync
        if args.selftest:
            return hostsync.selftest()
        out = []
        for b in backends:
            # one subdirectory per backend: trace files are keyed by
            # process id, and every single-process audit here is pid 0
            td = (f"{args.trace_dir.rstrip('/')}/{b}"
                  if args.trace_dir else None)
            out.extend(hostsync.audit_backend(backend=b, trace_dir=td))
        return out
    if check == "retrace":
        from repro.analysis import retrace
        if args.selftest:
            return retrace.selftest()
        out = []
        for b in backends:
            out.extend(retrace.audit_backend(backend=b))
        return out
    if check == "donation":
        from repro.analysis import donation
        if args.selftest:
            return donation.selftest()
        return donation.run()
    raise ValueError(f"unknown check {check!r}")


if __name__ == "__main__":
    sys.exit(main())
