"""Host-sync auditor: run a real fit and prove the steady-state loop
performs ZERO device->host syncs outside the sanctioned crossings.

`repro.api.loop.run_loop` brackets every round with
`LoopAudit.round_scope()` and each sanctioned crossing with
`sanctioned_scope(what)` (round_info / eval_mse / sync_flag /
checkpoint).  `HostSyncAudit` subclasses that seam: inside a round and
outside a sanctioned scope, any device->host materialisation is
recorded as a violation with the CALLER's file:line.

Two detection layers, because one is blind on CPU:

  * `jax.transfer_guard_device_to_host("disallow")` — authoritative on
    accelerators, but CPU jax arrays are zero-copy views of host
    memory, so d2h "transfers" never fire there;
  * a Python-level interceptor patched onto the runtime array type's
    conversion surface (``_value``/``__float__``/``__int__``/
    ``__bool__``/``__index__``/``item``/``tolist``/``__array__``) —
    this is how every host coercion in Python-land actually lands
    (``float(x)``, ``np.asarray(x)``, ``if x:``), and it works on
    every platform.  Tracers are a different type, so jit tracing is
    never intercepted.

The audited fit runs AFTER an identical unaudited warm-up fit, so every
bucket executable is already compiled and the audit sees the steady
state, not compilation. Host->device transfers are left ungated: data
growth legitimately places new rows mid-fit (`_ensure_prefix`).

The historical bug class (PR 2): a schedule decision read off a live
device scalar per round — correct results, but every round stalled the
dispatch pipeline.  `selftest()` replants it and asserts the auditor
still catches it.
"""
from __future__ import annotations

import contextlib
import traceback
from pathlib import Path
from typing import List, Optional

from repro.analysis.report import Violation, rel, repo_root
from repro.api.loop import LoopAudit

#: conversion surface intercepted on the runtime array type.
_HOOKS = ("__float__", "__int__", "__bool__", "__index__", "item",
          "tolist", "__array__")


class HostSyncAudit(LoopAudit):
    """Records unsanctioned device->host syncs instead of raising, so
    one audited fit reports every violation site at once."""

    def __init__(self, label: str = "fit"):
        self.label = label
        self.violations: List[Violation] = []
        self._in_round = 0
        self._sanctioned = 0

    # -- LoopAudit seam ------------------------------------------------------

    @contextlib.contextmanager
    def round_scope(self):
        import jax
        self._in_round += 1
        try:
            with jax.transfer_guard_device_to_host("disallow"):
                yield
        finally:
            self._in_round -= 1

    @contextlib.contextmanager
    def sanctioned_scope(self, what: str):
        import jax
        self._sanctioned += 1
        try:
            with jax.transfer_guard_device_to_host("allow"):
                yield
        finally:
            self._sanctioned -= 1

    # -- interceptor plumbing ------------------------------------------------

    @property
    def active(self) -> bool:
        return self._in_round > 0 and self._sanctioned == 0

    def notify(self, kind: str) -> None:
        if not self.active:
            return
        file, line, qual, snippet = _caller_site()
        v = Violation(checker="hostsync", kind=f"d2h-{kind}",
                      file=file, line=line, qualname=qual,
                      detail=(f"unsanctioned device->host sync in the "
                              f"steady-state loop ({self.label}): "
                              f"{snippet}"))
        if v not in self.violations:
            self.violations.append(v)

    @contextlib.contextmanager
    def installed(self):
        _active.append(self)
        _ensure_patched()
        try:
            yield self
        finally:
            _active.remove(self)
            if not _active:
                _unpatch()


_active: List[HostSyncAudit] = []
_saved = {}


def _caller_site():
    """Deepest stack frame inside this repo (and outside this module):
    the code that triggered the sync."""
    here = str(Path(__file__).resolve())
    root = str(repo_root())
    for f in reversed(traceback.extract_stack()):
        fn = str(Path(f.filename).resolve()) if f.filename else ""
        if fn == here or "/jax/" in fn or "/numpy/" in fn:
            continue
        if fn.startswith(root):
            return (rel(fn), f.lineno, f.name,
                    (f.line or "").strip() or "<unknown>")
    return ("<outside-repo>", 0, "?", "?")


def _notify_all(kind: str) -> None:
    for audit in _active:
        audit.notify(kind)


def _array_type():
    import jax
    import numpy as np

    return type(jax.device_put(np.zeros(())))


def _ensure_patched() -> None:
    if _saved:
        return
    cls = _array_type()
    for name in _HOOKS:
        orig = getattr(cls, name, None)
        if orig is None:
            continue

        def wrapper(self, *a, __orig=orig, __kind=name, **kw):
            _notify_all(__kind.strip("_"))
            return __orig(self, *a, **kw)

        _saved[name] = orig
        setattr(cls, name, wrapper)
    # numpy reaches CPU array memory through the `_value` property
    # (np.asarray / device_get), bypassing __array__ — intercept it too
    prop = getattr(cls, "_value", None)
    if isinstance(prop, property) and prop.fget is not None:
        orig_fget = prop.fget

        def fget(self, __orig=orig_fget):
            _notify_all("value")
            return __orig(self)

        _saved["_value"] = prop
        setattr(cls, "_value", property(fget, prop.fset, prop.fdel))


def _unpatch() -> None:
    if not _saved:
        return
    cls = _array_type()
    for name, orig in _saved.items():
        setattr(cls, name, orig)
    _saved.clear()


# -- audit driver ------------------------------------------------------------

def audit_backend(backend: str = "local", *, n: int = 2048, d: int = 8,
                  k: int = 8, seed: int = 0, engine_factory=None,
                  trace_dir: Optional[str] = None,
                  kernel_backend: Optional[str] = None,
                  bounds: str = "hamerly2") -> List[Violation]:
    """Warm up, then run one audited fit on ``backend``; returns the
    unsanctioned-sync violations. ``engine_factory`` overrides engine
    construction (the selftest injects a leaky engine). ``trace_dir``
    attaches a `repro.obs.FitObserver` to the AUDITED fit — proving the
    observability plane adds no device->host syncs of its own (the
    PR 8 acceptance gate: hostsync stays green with tracing on).
    ``kernel_backend`` forces the kernel plan ("pallas" proves the fused
    dispatch adds no syncs — `scripts/smoke_kernels.py`); ``bounds``
    selects the bound family (`scripts/smoke_bounds.py` proves the
    exponion geometry rebuild syncs nothing)."""
    import numpy as np

    from repro.api.config import FitConfig
    from repro.api.engines import make_engine
    from repro.api.loop import run_loop
    from repro.analysis.retrace import _mesh_for

    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d)).astype(np.float32)
    X_val = rng.normal(size=(256, d)).astype(np.float32)
    config = FitConfig(k=k, b0=max(2 * k, n // 32), seed=seed,
                       backend=backend, max_rounds=24, eval_every=4,
                       capacity_floor=32, bounds=bounds,
                       kernel_backend=kernel_backend).resolve(n)

    def fit(audit: Optional[HostSyncAudit], obs=None):
        if engine_factory is not None:
            engine = engine_factory(config)
        else:
            engine = make_engine(config, mesh=_mesh_for(backend, config))
        run = engine.begin(X, config, X_val=X_val)
        return run_loop(run, config, audit=audit, obs=obs)

    fit(None)                       # compile every bucket un-audited
    obs = None
    if trace_dir is not None:
        import jax

        from repro.obs import FitObserver
        obs = FitObserver(trace_dir, process_id=jax.process_index(),
                          k=k, d=d, meta={"backend": backend,
                                          "audit": "hostsync"})
    audit = HostSyncAudit(label=f"backend={backend}")
    try:
        with audit.installed():
            fit(audit, obs=obs)
    finally:
        if obs is not None:
            obs.close()
    return audit.violations


def selftest() -> List[Violation]:
    """Replant the PR 2 bug class (per-round branch on a live device
    scalar) and assert the auditor flags it at the planted file:line."""
    from repro.analysis import _selftest as fx
    return fx.hostsync_fixture_violations(audit_backend)
