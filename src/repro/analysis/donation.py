"""Donation auditor: prove every donated jit in the engine data path
actually ALIASES its donated operand in the compiled executable.

`donate_argnums` is a request, not a guarantee: XLA silently falls back
to a copy whenever it cannot line the output up with the donated buffer
(shape/layout mismatch, replicated shard_map outputs, cross-device
moves).  The historical bug class (PR 6): a shard_map'd donated
`dynamic_update_slice` that copied the WHOLE data buffer on every
segment write — the out-of-core fill path held two generations of the
dataset resident and the "bounded host memory" claim was silently
false, with no test failing.

This auditor closes that hole twice over:

  * statically, it scans the engine sources for `donate_argnums` /
    `donate_argnames` call sites and requires each to be REGISTERED
    here with an executable audit — a new donated jit that nobody
    proved aliasing fails the check (`unregistered-donation`);
  * dynamically, each registered site is lowered and compiled on
    representative shapes and must show the compiled
    `memory_analysis().alias_size_in_bytes` covering the donated bytes
    (`not-aliased`); on older runtimes without `memory_analysis`, the
    fallback proof is pointer identity — the output occupying the
    donated input's buffer (only a fallback: with a warm buffer pool
    the runtime can satisfy a compiled alias from a recycled buffer, so
    identity would be order-dependent).  Donation warnings raised
    during execution are violations too.

Everything jax-related is imported lazily so the CLI can force a host
device count first.
"""
from __future__ import annotations

import ast
import warnings
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.report import Violation, rel, repo_root

#: files whose donated jits are in the engine data path (audited set).
SCAN_GLOBS = ("src/repro/util/device.py", "src/repro/api/engines/*.py")

DONATE_KEYWORDS = {"donate_argnums", "donate_argnames"}


# -- static scan -------------------------------------------------------------

def scan_sites(root: Optional[Path] = None
               ) -> List[Tuple[str, int, str]]:
    """(repo-relative file, line, qualname) of every donate_* jit call
    in the scanned globs. ``qualname`` is the name the jit is bound to
    (assignment target / enclosing def), the registry key."""
    root = root or repo_root()
    paths: List[Path] = []
    for pattern in SCAN_GLOBS:
        paths.extend(sorted(root.glob(pattern)))
    sites: List[Tuple[str, int, str]] = []
    for path in paths:
        tree = ast.parse(path.read_text(), filename=str(path))
        # map every donate call to its nearest binding name
        parents: Dict[int, ast.AST] = {}
        for node in ast.walk(tree):
            for child in ast.iter_child_nodes(node):
                parents[id(child)] = node
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call)
                    and any(k.arg in DONATE_KEYWORDS
                            for k in node.keywords)):
                continue
            name = "<anonymous>"
            cur: Optional[ast.AST] = node
            while cur is not None:
                up = parents.get(id(cur))
                if isinstance(up, ast.Assign) and up.targets and \
                        isinstance(up.targets[0], ast.Name):
                    name = up.targets[0].id
                    break
                if isinstance(up, (ast.FunctionDef,
                                   ast.AsyncFunctionDef)):
                    name = up.name
                    break
                cur = up
            sites.append((rel(path), node.lineno, name))
    return sites


# -- executable audits -------------------------------------------------------

def audit_donated_jit(fn, args: Sequence, donated: Sequence[int], *,
                      file: str, line: int, qualname: str,
                      static_kwargs: Optional[dict] = None
                      ) -> List[Violation]:
    """Prove ``fn`` (a jitted callable) aliases its donated positional
    args for these representative ``args``. Returns violations; empty
    means the donation is real."""
    import jax
    import numpy as np

    static_kwargs = static_kwargs or {}
    out: List[Violation] = []
    placed = [a if isinstance(a, jax.Array) else jax.device_put(a)
              for a in args]
    donated_bytes = sum(int(np.asarray(placed[i]).nbytes)
                        for i in donated)

    compiled = jax.jit(fn).lower(*placed, **static_kwargs).compile() \
        if not hasattr(fn, "lower") else \
        fn.lower(*placed, **static_kwargs).compile()
    alias_bytes = None
    try:
        alias_bytes = int(compiled.memory_analysis().alias_size_in_bytes)
    except Exception:
        pass                       # older runtimes: pointer check below
    if alias_bytes is not None and alias_bytes < donated_bytes:
        out.append(Violation(
            checker="donation", kind="not-aliased", file=file, line=line,
            qualname=qualname,
            detail=(f"compiled executable aliases {alias_bytes} bytes "
                    f"but {donated_bytes} bytes were donated — the "
                    f"donated operand is being COPIED")))

    # pointer identity: the output must occupy the donated input's
    # buffer(s). Re-place fresh inputs (the lowered call above did not
    # consume them, but stay independent of that detail).
    placed = [a if isinstance(a, jax.Array) else jax.device_put(a)
              for a in args]
    try:
        in_ptrs = {p for i in donated
                   for p in _buffer_ptrs(placed[i])}
    except Exception:
        in_ptrs = set()            # backend without buffer pointers
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        result = fn(*placed, **static_kwargs)
    donation_warnings = [w for w in caught
                         if "donated" in str(w.message).lower()]
    for w in donation_warnings:
        out.append(Violation(
            checker="donation", kind="donation-unused", file=file,
            line=line, qualname=qualname,
            detail=f"runtime refused the donation: {w.message}"))
    # pointer identity is the FALLBACK proof, for runtimes whose
    # compiled executables expose no memory_analysis. When the compiled
    # alias map already covers the donated bytes, a runtime pointer
    # mismatch is allocator noise, not a copy in the program: a warm
    # buffer pool (any fit run earlier in the process) can satisfy the
    # alias by handing the output a recycled same-size buffer, so
    # requiring identity there makes the audit order-dependent.
    if in_ptrs and alias_bytes is None:
        leaves = jax.tree.leaves(result)
        out_ptrs = {p for leaf in leaves for p in _buffer_ptrs(leaf)}
        if not (in_ptrs & out_ptrs) and not donation_warnings:
            out.append(Violation(
                checker="donation", kind="not-aliased", file=file,
                line=line, qualname=qualname,
                detail=("output buffers do not reuse the donated "
                        "input's memory (pointer identity failed)")))
    return out


def _buffer_ptrs(arr) -> List[int]:
    return [s.data.unsafe_buffer_pointer()
            for s in arr.addressable_shards]


def _audit_piece_update() -> List[Violation]:
    """The shared out-of-core segment writer: repro.util.device."""
    import numpy as np
    from repro.util import device as D

    site = _site_of("src/repro/util/device.py", "piece_update")
    rng = np.random.default_rng(0)
    Xs = np.zeros((4096, 64), np.float32)
    seg = rng.normal(size=(512, 64)).astype(np.float32)
    return audit_donated_jit(
        D.piece_update, (Xs, seg, np.int32(1024)), donated=(0,),
        file=site[0], line=site[1], qualname="piece_update")


def _site_of(file: str, qualname: str) -> Tuple[str, int]:
    for f, line, name in scan_sites():
        if f == file and name == qualname:
            return f, line
    return file, 1


#: every donated jit the static scan may find, mapped to the audit that
#: proves it. Adding a donated jit to the data path REQUIRES adding an
#: audit here — that is the point.
REGISTRY = {
    ("src/repro/util/device.py", "piece_update"): _audit_piece_update,
}


def run() -> List[Violation]:
    violations: List[Violation] = []
    seen_keys = set()
    for file, line, name in scan_sites():
        key = (file, name)
        seen_keys.add(key)
        audit = REGISTRY.get(key)
        if audit is None:
            violations.append(Violation(
                checker="donation", kind="unregistered-donation",
                file=file, line=line, qualname=name,
                detail=("donated jit with no registered aliasing audit "
                        "— register it in repro.analysis.donation."
                        "REGISTRY with a proof it runs in place")))
    for key, audit in REGISTRY.items():
        if key in seen_keys:
            violations.extend(audit())
        else:
            violations.append(Violation(
                checker="donation", kind="stale-registry",
                file=key[0], line=1, qualname=key[1],
                detail="registered donation site no longer exists"))
    return violations


def selftest() -> List[Violation]:
    """Replant the PR 6 bug class and assert the audit still sees it:
    a donated update whose output CANNOT alias the donated buffer."""
    from repro.analysis import _selftest as fx
    return fx.donation_fixture_violations(audit_donated_jit)
