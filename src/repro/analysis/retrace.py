"""Retrace auditor: actual jit traces == analytic pow2 bucket count.

The schedule's speed rests on a compilation contract: round executables
are keyed ONLY by the power-of-two lattice the growth controller walks —
b doubling from b0, capacity in {None} | pow2 — so a full fit compiles
a handful of executables and every steady-state round is a cache hit.
The historical bug class: a float hyperparameter (rho) or an
exact-need capacity sneaking into the jit key, retracing EVERY round —
fits that "work" but spend their wall clock in XLA.

`repro.util.tracecount` hooks the round bodies (`core.rounds.
nested_round`, `core.distributed_xl.xl_nested_round`): a jitted
function's Python body runs exactly once per cache miss, so the counter
counts REAL traces, keyed by the round statics.  The auditor runs a
full growth schedule per backend, records which (b, capacity) buckets
the loop invoked (overflow retries included), and asserts:

  retrace             a (b, capacity) bucket traced more than once —
                      something off-lattice (rho, shapes, flags) is
                      keying the cache
  unexpected-trace    a trace for a bucket the schedule never invoked
  off-lattice-bucket  an invoked bucket off the pow2 lattice (b not in
                      the b0-doubling chain, capacity not a power of
                      two below b)

Missing traces are NOT violations: the jit cache is process-global, so
a bucket another fit already compiled legitimately traces zero times
here.  The dangerous direction is only ever MORE traces than buckets.
"""
from __future__ import annotations

import inspect
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.report import Violation, rel

Bucket = Tuple[int, Optional[int]]


def _parse_bucket(statics: Tuple[Tuple[str, str], ...]) -> Bucket:
    d = dict(statics)
    b = int(d["b"])
    cap = d.get("capacity", "None")
    return b, (None if cap == "None" else int(cap))


def _is_pow2(x: int) -> bool:
    return x > 0 and (x & (x - 1)) == 0


def lattice_violations(invoked: Sequence[Bucket], b0: int, b_max: int,
                       *, site_file: str, site_line: int, qualname: str
                       ) -> List[Violation]:
    chain = set()
    b = max(1, b0)
    while True:
        chain.add(min(b, b_max))
        if b >= b_max:
            break
        b *= 2
    out = []
    for bb, cap in sorted(set(invoked),
                          key=lambda t: (t[0], t[1] or 0)):
        bad_b = bb not in chain
        bad_cap = cap is not None and (not _is_pow2(cap) or cap >= bb)
        if bad_b or bad_cap:
            what = []
            if bad_b:
                what.append(f"b={bb} not on the b0={b0} doubling chain")
            if bad_cap:
                what.append(f"capacity={cap} not a pow2 below b")
            out.append(Violation(
                checker="retrace", kind="off-lattice-bucket",
                file=site_file, line=site_line, qualname=qualname,
                detail="; ".join(what)))
    return out


def trace_violations(diff: Dict, invoked: Sequence[Bucket], site: str, *,
                     site_file: str, site_line: int, qualname: str
                     ) -> List[Violation]:
    """Compare actual traces (a `tracecount.diff`) against the invoked
    buckets. Multiple distinct trace keys for one bucket == something
    besides (b, capacity) keys the cache — the rho-retrace class."""
    per_bucket: Dict[Bucket, int] = {}
    keys_of: Dict[Bucket, List] = {}
    for (s, statics), n in diff.items():
        if s != site:
            continue
        bucket = _parse_bucket(statics)
        per_bucket[bucket] = per_bucket.get(bucket, 0) + n
        keys_of.setdefault(bucket, []).append(dict(statics))
    invoked_set = set(invoked)
    out: List[Violation] = []
    for bucket, n in sorted(per_bucket.items(),
                            key=lambda t: (t[0][0], t[0][1] or 0)):
        b, cap = bucket
        if n > 1:
            varying = {k for d in keys_of[bucket] for k in d
                       if len({str(x.get(k)) for x in keys_of[bucket]})
                       > 1}
            out.append(Violation(
                checker="retrace", kind="retrace",
                file=site_file, line=site_line, qualname=qualname,
                detail=(f"bucket (b={b}, capacity={cap}) traced {n}x "
                        f"in one fit"
                        + (f" — cache keyed by {sorted(varying)}"
                           if varying else ""))))
        if bucket not in invoked_set:
            out.append(Violation(
                checker="retrace", kind="unexpected-trace",
                file=site_file, line=site_line, qualname=qualname,
                detail=(f"traced bucket (b={b}, capacity={cap}) that "
                        f"the schedule never invoked")))
    return out


def _round_site(backend: str):
    """(tracecount site name, file, line, qualname) of the round body
    that compiles for this backend."""
    if backend == "xl":
        from repro.core import distributed_xl as m
        fn, site = m.xl_nested_round, "xl_nested_round"
    else:
        from repro.core import rounds as m
        fn, site = m.nested_round, "nested_round"
    return (site, rel(inspect.getsourcefile(fn)),
            fn.__code__.co_firstlineno, site)


def audit_backend(backend: str = "local", *, n: int = 4096, d: int = 8,
                  k: int = 8, seed: int = 0,
                  kernel_backend: str = None,
                  bounds: str = "hamerly2") -> List[Violation]:
    """Run one full growth schedule on ``backend`` and check the trace
    contract. Multi-device backends need the CLI's forced host device
    count (see `repro.analysis.__main__`). ``kernel_backend`` forces a
    kernel plan ("pallas" proves the fused dispatch keeps one trace per
    bucket — `scripts/smoke_kernels.py` runs exactly that); ``bounds``
    selects the bound family (exponion's per-round geometry rebuild
    must not mint extra traces — `scripts/smoke_bounds.py`)."""
    import numpy as np

    from repro.api.config import FitConfig
    from repro.api.engines import make_engine
    from repro.api.loop import run_loop
    from repro.util import tracecount

    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d)).astype(np.float32)
    config = FitConfig(k=k, b0=max(2 * k, n // 64), seed=seed,
                       backend=backend, max_rounds=40,
                       capacity_floor=32, bounds=bounds,
                       kernel_backend=kernel_backend).resolve(n)
    engine = make_engine(config, mesh=_mesh_for(backend, config))
    run = engine.begin(X, config)

    invoked: List[Bucket] = []
    inner_step = run.nested_step

    def logged_step(state, b, capacity):
        invoked.append((b, capacity))
        return inner_step(state, b, capacity)

    run.nested_step = logged_step
    b0_local, b_max = run.b, run.b_max
    before = tracecount.snapshot()
    run_loop(run, config)
    diff = tracecount.diff(before)

    site, site_file, site_line, qual = _round_site(backend)
    qual = f"{qual}[backend={backend}]"
    out = trace_violations(diff, invoked, site, site_file=site_file,
                           site_line=site_line, qualname=qual)
    out.extend(lattice_violations(invoked, b0_local, b_max,
                                  site_file=site_file,
                                  site_line=site_line, qualname=qual))
    return out


def _mesh_for(backend: str, config):
    if backend not in ("mesh", "xl", "multihost"):
        return None
    import jax

    devices = jax.devices()
    if backend == "xl":
        m = 2 if len(devices) % 2 == 0 and len(devices) > 1 else 1
        shape = (len(devices) // m, m)
        return jax.make_mesh(shape, (config.data_axes[0],
                                     config.model_axis))
    if backend == "multihost":
        return None     # the engine builds its own flat mesh
    return jax.make_mesh((len(devices),), config.data_axes)


def selftest() -> List[Violation]:
    """Replant the historical rho-keyed retrace and an exact-need
    (non-pow2) capacity schedule; the checker must flag both."""
    from repro.analysis import _selftest as fx
    return fx.retrace_fixture_violations(trace_violations,
                                         lattice_violations)
