"""Shared reporting types for the `repro.analysis` checkers.

A checker produces a list of `Violation`s; the CLI formats them as
``file:line: [checker/kind] qualname: detail`` so editors and CI logs
can jump straight to the site.  Paths are repo-relative.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import List


def repo_root() -> Path:
    """The repository root (this file lives at src/repro/analysis/)."""
    return Path(__file__).resolve().parents[3]


def rel(path) -> str:
    """``path`` repo-relative when possible, as a posix string."""
    p = Path(path).resolve()
    try:
        return p.relative_to(repo_root()).as_posix()
    except ValueError:
        return p.as_posix()


@dataclasses.dataclass(frozen=True)
class Violation:
    """One diagnosed invariant break.

    checker   which checker produced it (lint/hostsync/retrace/donation)
    kind      the violation class within that checker (e.g. "branch",
              "host-coercion", "rng-draw", "retrace", "not-aliased")
    file      repo-relative path of the offending site
    line      1-based line number
    qualname  enclosing function/method (or audit site name)
    detail    one-line human diagnosis (source snippet, counts, bytes)
    """
    checker: str
    kind: str
    file: str
    line: int
    qualname: str
    detail: str

    def __str__(self) -> str:
        return (f"{self.file}:{self.line}: [{self.checker}/{self.kind}] "
                f"{self.qualname}: {self.detail}")


def render(violations: List[Violation]) -> str:
    return "\n".join(str(v) for v in sorted(
        violations, key=lambda v: (v.file, v.line, v.kind)))
