"""Replicated-control-flow lint (pure AST; imports no jax).

Checks the PR 5 invariant mechanically: on a multi-process run every
process executes `repro.api.loop.run_loop` over its own host state with
no consensus protocol, so every per-round decision must derive from
values that are bit-identical on every process BY CONSTRUCTION:

  * the `HostRoundInfo` landed by `fetch_round_info` (psum-reduced
    device scalars — same bits everywhere),
  * the resolved `FitConfig` and engine statics (seed-determined),
  * the sanctioned replication primitives `run.sync_flag` /
    `run.resolve_resume` (coordinator decides, everyone obeys).

Anything else — a live device value, the wall clock, a filesystem read,
an unseeded RNG draw — is process-local: a branch on it can diverge, a
host coercion of it is also a hidden device sync per round.  The lint
walks the per-round code regions and flags three violation kinds:

  branch         an if/while/ternary/assert/comprehension condition
                 whose value does not derive from the safe roots
  host-coercion  float()/int()/bool()/np.asarray()/jax.device_get()/
                 .item()/.tolist() applied to a non-derived value (a
                 per-round device->host sync outside `fetch_round_info`)
  rng-draw       any RNG call in per-round code (sanctioned streams are
                 allowlisted with the seed-derivation argument)

Scope — where "per-round" code lives:

  * `run_loop` in api/loop.py: the bodies of its top-level for/while
    statements plus its nested helper functions (executed every round);
    one-time setup/teardown code is out of scope by design.
  * the per-round methods of every engine class in api/engines/*.py:
    nested_step / lloyd_step / mb_step / eval_mse / sync_flag /
    _ensure_prefix / _fetch / _fetch_block.

The derivation analysis is a fixpoint over local assignments: a name is
safe iff every assignment to it is a safe expression.  Safe expressions
are literals, module-level names, config/run/self statics (minus the
device-state attributes), array METADATA attributes (.shape/.sharding/
`.addressable_shards` — same on every process), sanctioned sanitizer
calls, and safe-rooted arithmetic.  Device-module calls (jax.*/jnp.*),
wall-clock calls (time.*) and method calls on runtime objects are
unsafe.  `x is None` presence tests are always safe — they read
structure, not device values.

The lint is intentionally conservative: a new unsafe-looking site is a
finding even if benign, and the fix is either to derive it from
`RoundInfo` or to add an `allowlist.txt` entry WITH A REASON.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.report import Violation, rel, repo_root

# -- policy ------------------------------------------------------------------

#: engine methods that run in the steady-state loop (directly or via a
#: sanctioned scope); everything else on an engine is begin/end-of-fit.
PER_ROUND_METHODS = {
    "nested_step", "lloyd_step", "mb_step", "eval_mse", "sync_flag",
    "_ensure_prefix", "_fetch", "_fetch_block",
}

#: attributes of `run`/`self` that ARE live device state (unsafe);
#: every other run/self attribute is an engine static by contract.
RUN_UNSAFE_ATTRS = {"state", "_Xd", "_Xv"}

#: parameters that carry device state into per-round methods.
UNSAFE_PARAM_NAMES = {"state", "new_state", "arr", "stats", "X", "Xs", "seg"}

#: calls whose result is process-replicated even though the root module
#: is otherwise unsafe (cluster topology statics).
SAFE_QUALIFIED_CALLS = {
    "jax.process_count", "jax.process_index", "jax.device_count",
    "jax.local_device_count",
}

#: module roots whose call results are device values (branching on them
#: would sync) or host-local entropy (wall clock).
DEVICE_MODULE_ROOTS = {"jax", "jnp"}
UNSAFE_MODULE_ROOTS = {"time"}

#: calls that sanitise an unsafe value into a replicated host value.
SANITIZER_METHODS = {"sync_flag", "resolve_resume"}   # on run/self
SANITIZER_FUNCS = {"fetch_round_info"}                # bare names

#: array/sharding metadata: identical on every process regardless of
#: the array's safety (structure, not contents).
METADATA_ATTRS = {
    "shape", "dtype", "ndim", "size", "nbytes", "sharding", "device",
    "index", "is_fully_addressable", "is_fully_replicated",
    "addressable_shards", "axis_names",
}

#: builtins that are safe when their arguments are safe.
SAFE_BUILTINS = {
    "float", "int", "bool", "str", "min", "max", "abs", "len", "sorted",
    "sum", "round", "tuple", "list", "dict", "set", "range", "enumerate",
    "zip", "isinstance", "type", "getattr", "hasattr", "repr", "divmod",
    "next", "iter", "map", "filter", "all", "any",
}

#: method names safe to call on safe objects (pure container reads and
#: (de)serialisers of host dicts/records).
SAFE_METHODS = {
    "get", "items", "keys", "values", "copy", "to_dict", "from_dict",
    "as_posix", "bit_length", "startswith", "endswith", "split", "strip",
}

#: builtins whose result is process-replicated no matter the argument:
#: they read type/shape structure, not device contents.
METADATA_BUILTINS = {"isinstance", "len", "type"}

#: host coercions (device->host syncs when applied to device values).
COERCION_BUILTINS = {"float", "int", "bool"}
COERCION_NP_ATTRS = {"asarray", "array"}
COERCION_METHODS = {"item", "tolist"}

#: RNG fingerprints: any dotted-path segment in here marks a draw.
RNG_SEGMENTS = {"rng", "_rng", "random"}
RNG_FUNCS = {"default_rng"}


# -- small AST helpers -------------------------------------------------------

def _dotted(node: ast.AST) -> Optional[List[str]]:
    """['np', 'random', 'default_rng'] for np.random.default_rng; None
    when the chain is not rooted at a plain Name."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return None


def _snippet(source: str, node: ast.AST) -> str:
    seg = ast.get_source_segment(source, node) or type(node).__name__
    seg = " ".join(seg.split())
    return seg if len(seg) <= 88 else seg[:85] + "..."


# -- derivation environment --------------------------------------------------

@dataclasses.dataclass
class _Env:
    """name -> list of value-expressions assigned to it (fixpoint input);
    `safety` is the fixpoint output. ``parent`` chains a nested helper
    to its enclosing function's environment (closure reads)."""
    assigns: Dict[str, List[Optional[ast.AST]]]
    safety: Dict[str, bool]
    parent: Optional["_Env"] = None

    def is_local(self, name: str) -> bool:
        return (name in self.assigns
                or (self.parent is not None
                    and self.parent.is_local(name)))

    def safe(self, name: str) -> bool:
        # names never bound locally resolve outward: the enclosing
        # function first, then module scope — functions, classes,
        # imports, constants are safe as VALUES (their calls are
        # judged separately).
        if name in self.safety:
            return self.safety[name]
        if self.parent is not None:
            return self.parent.safe(name)
        return True


def _bind(env: Dict[str, List[Optional[ast.AST]]],
          target: ast.AST, value: Optional[ast.AST]) -> None:
    if isinstance(target, ast.Name):
        env.setdefault(target.id, []).append(value)
    elif isinstance(target, (ast.Tuple, ast.List)):
        elts = target.elts
        if (isinstance(value, (ast.Tuple, ast.List))
                and len(value.elts) == len(elts)):
            for t, v in zip(elts, value.elts):
                _bind(env, t, v)
        else:
            for t in elts:
                _bind(env, t, value)
    elif isinstance(target, ast.Starred):
        _bind(env, target.value, value)
    # attribute/subscript targets: safety of self._x reads is governed
    # by the RUN_UNSAFE_ATTRS policy, not by local flow.


class _Sentinel(ast.AST):
    """Stands in for 'definitely safe' / 'definitely unsafe' bindings."""
    def __init__(self, safe: bool):
        self.safe = safe


def _walk_own_scope(func: ast.FunctionDef):
    """Walk ``func``'s body without descending into nested function or
    lambda scopes (their locals must not leak into this env); the
    nested def/lambda node itself IS yielded so its name gets bound."""
    stack = list(func.body)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def build_env(func: ast.FunctionDef,
              parent: Optional[_Env] = None) -> _Env:
    """Collect ``func``'s own local bindings (nested helpers get their
    own child env via ``parent``) and solve the safety fixpoint."""
    assigns: Dict[str, List[Optional[ast.AST]]] = {}
    args = func.args
    for a in (list(args.posonlyargs) + list(args.args)
              + list(args.kwonlyargs)
              + ([args.vararg] if args.vararg else [])
              + ([args.kwarg] if args.kwarg else [])):
        assigns.setdefault(a.arg, []).append(
            _Sentinel(a.arg not in UNSAFE_PARAM_NAMES))
    for node in _walk_own_scope(func):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                _bind(assigns, t, node.value)
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            _bind(assigns, node.target, node.value)
        elif isinstance(node, ast.AugAssign):
            # x += v : final safety = old AND safety(v); the fixpoint
            # ANDs contributions, so recording v alone is exact.
            _bind(assigns, node.target, node.value)
        elif isinstance(node, ast.NamedExpr):
            _bind(assigns, node.target, node.value)
        elif isinstance(node, (ast.For, ast.AsyncFor)):
            _bind(assigns, node.target, node.iter)
        elif isinstance(node, ast.comprehension):
            _bind(assigns, node.target, node.iter)
        elif isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                if item.optional_vars is not None:
                    _bind(assigns, item.optional_vars, item.context_expr)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            assigns.setdefault(node.name, []).append(_Sentinel(True))
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for alias in node.names:
                name = (alias.asname or alias.name).split(".")[0]
                assigns.setdefault(name, []).append(_Sentinel(True))
        elif isinstance(node, ast.ExceptHandler) and node.name:
            assigns.setdefault(node.name, []).append(_Sentinel(True))

    env = _Env(assigns=assigns, safety={n: True for n in assigns},
               parent=parent)
    for _ in range(len(assigns) + 2):       # monotone: converges
        changed = False
        for name, values in assigns.items():
            ok = all(_expr_safe(v, env) if not isinstance(v, _Sentinel)
                     else v.safe
                     for v in values if v is not None)
            if ok != env.safety[name]:
                env.safety[name] = ok
                changed = True
        if not changed:
            break
    return env


# -- expression safety -------------------------------------------------------

def _is_sanitizer(func: ast.AST) -> bool:
    if isinstance(func, ast.Name):
        return func.id in SANITIZER_FUNCS
    if isinstance(func, ast.Attribute):
        return (isinstance(func.value, ast.Name)
                and func.value.id in ("run", "self")
                and func.attr in SANITIZER_METHODS)
    return False


def _is_rng(func: ast.AST) -> bool:
    parts = _dotted(func)
    if parts is None:
        return False
    return (bool(set(parts) & RNG_SEGMENTS)
            or parts[-1] in RNG_FUNCS)


def _call_safe(call: ast.Call, env: _Env) -> bool:
    func = call.func
    if _is_sanitizer(func):
        return True
    if _is_rng(func):
        return False
    parts = _dotted(func)
    args_safe = (all(_expr_safe(a, env) for a in call.args)
                 and all(_expr_safe(k.value, env) for k in call.keywords))
    if parts is not None:
        qual = ".".join(parts)
        if qual in SAFE_QUALIFIED_CALLS:
            return True
        root = parts[0]
        if root in DEVICE_MODULE_ROOTS or root in UNSAFE_MODULE_ROOTS:
            return False
        if len(parts) == 1:
            # bare name: builtin / module-level function / local callable
            if root in METADATA_BUILTINS:
                return True       # reads structure, never device values
            if root in SAFE_BUILTINS:
                return args_safe
            if env.is_local(root):
                return env.safe(root) and args_safe
            return args_safe      # module-level def/import
        # dotted: method/function on some object
        if root in ("run", "self"):
            return False          # non-sanctioned engine method result
        if env.is_local(root):
            # method on a runtime object (store.latest_step(), ...)
            return (env.safe(root) and parts[-1] in SAFE_METHODS
                    and args_safe)
        # module- or class-rooted helper (np.unique, math.isfinite,
        # Telemetry.from_dict, multihost_utils.broadcast_one_to_all)
        return args_safe
    # calls on computed receivers: self._store.take(...).astype(...)
    if isinstance(func, ast.Attribute):
        return (func.attr in SAFE_METHODS and _expr_safe(func.value, env)
                and args_safe)
    return False


def _expr_safe(node: Optional[ast.AST], env: _Env) -> bool:
    if node is None:
        return True
    if isinstance(node, _Sentinel):
        return node.safe
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Name):
        return env.safe(node.id)
    if isinstance(node, ast.Attribute):
        if node.attr in METADATA_ATTRS:
            return True
        if (isinstance(node.value, ast.Name)
                and node.value.id in ("run", "self")):
            return node.attr not in RUN_UNSAFE_ATTRS
        return _expr_safe(node.value, env)
    if isinstance(node, ast.Call):
        return _call_safe(node, env)
    if isinstance(node, ast.Compare):
        # presence tests read structure, never device values
        if (all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops)
                and all(isinstance(c, ast.Constant) and c.value is None
                        for c in node.comparators)):
            return True
        return (_expr_safe(node.left, env)
                and all(_expr_safe(c, env) for c in node.comparators))
    if isinstance(node, (ast.BoolOp,)):
        return all(_expr_safe(v, env) for v in node.values)
    if isinstance(node, ast.BinOp):
        return _expr_safe(node.left, env) and _expr_safe(node.right, env)
    if isinstance(node, ast.UnaryOp):
        return _expr_safe(node.operand, env)
    if isinstance(node, ast.IfExp):
        return (_expr_safe(node.test, env) and _expr_safe(node.body, env)
                and _expr_safe(node.orelse, env))
    if isinstance(node, ast.Subscript):
        return _expr_safe(node.value, env) and _expr_safe(node.slice, env)
    if isinstance(node, ast.Slice):
        return (_expr_safe(node.lower, env) and _expr_safe(node.upper, env)
                and _expr_safe(node.step, env))
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        return all(_expr_safe(e, env) for e in node.elts)
    if isinstance(node, ast.Dict):
        return (all(_expr_safe(k, env) for k in node.keys if k is not None)
                and all(_expr_safe(v, env) for v in node.values))
    if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp)):
        return (_expr_safe(node.elt, env)
                and all(_expr_safe(g.iter, env)
                        and all(_expr_safe(i, env) for i in g.ifs)
                        for g in node.generators))
    if isinstance(node, ast.DictComp):
        return (_expr_safe(node.key, env) and _expr_safe(node.value, env)
                and all(_expr_safe(g.iter, env) for g in node.generators))
    if isinstance(node, ast.JoinedStr):
        return all(_expr_safe(v, env) for v in node.values)
    if isinstance(node, ast.FormattedValue):
        return _expr_safe(node.value, env)
    if isinstance(node, (ast.Lambda, ast.Starred)):
        return True
    return False          # unknown node kind: conservative


# -- region scanning ---------------------------------------------------------

@dataclasses.dataclass
class _Region:
    qualname: str
    stmts: List[ast.stmt]
    env: _Env


def _scan_region(region: _Region, source: str, path: str
                 ) -> List[Violation]:
    out: List[Violation] = []
    env = region.env

    def flag(kind: str, node: ast.AST, what: ast.AST) -> None:
        out.append(Violation(
            checker="lint", kind=kind, file=path, line=node.lineno,
            qualname=region.qualname, detail=_snippet(source, what)))

    seen: Set[int] = set()
    for stmt in region.stmts:
        for node in ast.walk(stmt):
            if id(node) in seen:
                continue
            seen.add(id(node))
            if isinstance(node, (ast.If, ast.While)):
                if not _expr_safe(node.test, env):
                    flag("branch", node, node.test)
            elif isinstance(node, ast.IfExp):
                if not _expr_safe(node.test, env):
                    flag("branch", node, node.test)
            elif isinstance(node, ast.Assert):
                if not _expr_safe(node.test, env):
                    flag("branch", node, node.test)
            elif isinstance(node, ast.comprehension):
                for cond in node.ifs:
                    if not _expr_safe(cond, env):
                        flag("branch", cond, cond)
            elif isinstance(node, ast.Call):
                if _is_rng(node.func):
                    flag("rng-draw", node, node)
                    continue
                f = node.func
                coercing = False
                obj: Optional[ast.AST] = None
                if (isinstance(f, ast.Name)
                        and f.id in COERCION_BUILTINS
                        and not env.is_local(f.id)):
                    coercing = any(not _expr_safe(a, env)
                                   for a in node.args)
                elif isinstance(f, ast.Attribute):
                    parts = _dotted(f)
                    if (parts and parts[0] in ("np", "numpy")
                            and f.attr in COERCION_NP_ATTRS):
                        coercing = any(not _expr_safe(a, env)
                                       for a in node.args)
                    elif (parts and parts[0] in ("jax",)
                          and f.attr == "device_get"):
                        coercing = any(not _expr_safe(a, env)
                                       for a in node.args)
                    elif f.attr in COERCION_METHODS:
                        obj = f.value
                        coercing = not _expr_safe(obj, env)
                if coercing:
                    flag("host-coercion", node, node)
    return out


# -- scope extraction --------------------------------------------------------

def _loop_regions(tree: ast.Module) -> List[_Region]:
    """Regions for run_loop: the bodies of its top-level for/while
    loops (the round loop) plus its nested helpers, which execute every
    round and close over the loop's locals."""
    out: List[_Region] = []
    for node in tree.body:
        if isinstance(node, ast.FunctionDef) and node.name == "run_loop":
            outer = build_env(node)
            for stmt in node.body:
                if isinstance(stmt, (ast.For, ast.While)):
                    out.append(_Region("run_loop", list(stmt.body),
                                       outer))
                elif isinstance(stmt, ast.FunctionDef):
                    out.append(_Region(
                        f"run_loop.{stmt.name}", list(stmt.body),
                        build_env(stmt, parent=outer)))
    return out


def _engine_regions(tree: ast.Module) -> List[_Region]:
    out: List[_Region] = []
    for node in tree.body:
        if isinstance(node, ast.ClassDef):
            for item in node.body:
                if (isinstance(item, ast.FunctionDef)
                        and item.name in PER_ROUND_METHODS):
                    out.append(_Region(f"{node.name}.{item.name}",
                                       list(item.body), build_env(item)))
    return out


def lint_file(path, mode: str) -> List[Violation]:
    """Lint one file. ``mode``: "loop" (run_loop regions) or "engine"
    (per-round methods of every class)."""
    path = Path(path)
    source = path.read_text()
    tree = ast.parse(source, filename=str(path))
    regions = (_loop_regions(tree) if mode == "loop"
               else _engine_regions(tree))
    relpath = rel(path)
    violations: List[Violation] = []
    for region in regions:
        violations.extend(_scan_region(region, source, relpath))
    return violations


def default_files() -> List[Tuple[Path, str]]:
    root = repo_root()
    files: List[Tuple[Path, str]] = [
        (root / "src/repro/api/loop.py", "loop")]
    for p in sorted((root / "src/repro/api/engines").glob("*.py")):
        if p.name != "__init__.py":
            files.append((p, "engine"))
    return files


def run(files: Optional[Iterable[Tuple[Path, str]]] = None,
        allowlist_path=None, check_stale: bool = True
        ) -> List[Violation]:
    """Lint the control plane; returns unexcused violations (plus stale
    allowlist entries when ``check_stale``)."""
    from repro.analysis import allowlist as al
    found: List[Violation] = []
    for path, mode in (files if files is not None else default_files()):
        found.extend(lint_file(path, mode))
    entries = al.load(allowlist_path)
    kept, used = al.apply(found, entries)
    if check_stale:
        kept.extend(al.unused_entries(entries, used, allowlist_path))
    return kept
