"""ShapeDtypeStruct stand-ins for every model input (no allocation).

The dry-run lowers against these; the same builders serve the smoke tests
(who turn them into real arrays at reduced scale).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import layers as L
from repro.models import model as M
from repro.optim import adamw

SDS = jax.ShapeDtypeStruct


def token_split(cfg: ModelConfig, S: int) -> Tuple[int, int]:
    """(prefix_len, token_len): VLM reserves a patch prefix inside S."""
    if cfg.family == "vlm":
        p = cfg.encoder.n_ctx
        return p, S - p
    return 0, S


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig
                      ) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    p, st = token_split(cfg, S)
    batch = {"tokens": SDS((B, st), jnp.int32),
             "labels": SDS((B, st), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = SDS((B, cfg.encoder.n_ctx,
                               cfg.encoder.d_frontend), L.CDTYPE)
    if cfg.family == "vlm":
        batch["patches"] = SDS((B, p, cfg.d_model), L.CDTYPE)
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig
                        ) -> Dict[str, Any]:
    b = train_batch_specs(cfg, shape)
    b.pop("labels")
    return b


def decode_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """token + KV/SSM cache ShapeDtypeStructs for one decode step."""
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(functools.partial(
        M.make_decode_cache, cfg, batch=B, cache_len=S))
    return {"token": SDS((B, 1), jnp.int32), "cache": cache}


def abstract_params(cfg: ModelConfig):
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(functools.partial(M.init_params, cfg=cfg), key)


def abstract_opt_state(params_shape):
    return jax.eval_shape(adamw.init, params_shape)


def materialize(tree, seed: int = 0):
    """Turn a spec tree into real arrays (smoke tests, reduced configs)."""
    leaves, treedef = jax.tree.flatten(tree)
    key = jax.random.PRNGKey(seed)
    out = []
    for i, leaf in enumerate(leaves):
        k = jax.random.fold_in(key, i)
        if jnp.issubdtype(leaf.dtype, jnp.integer):
            out.append(jax.random.randint(k, leaf.shape, 0, 128,
                                          leaf.dtype))
        else:
            out.append(jax.random.normal(k, leaf.shape, jnp.float32)
                       .astype(leaf.dtype))
    return jax.tree.unflatten(treedef, out)
