"""Batched serving driver: prefill + greedy decode loop.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch tinyllama-1.1b --reduced --batch 4 --prompt-len 32 \
        --gen 16
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import model as M
from repro.train import step as tstep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.list_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    rng = np.random.default_rng(args.seed)
    B, P = args.batch, args.prompt_len
    cache_len = P + args.gen + (cfg.encoder.n_ctx
                                if cfg.family == "vlm" else 0)

    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, P)))}
    if cfg.family == "encdec":
        batch["frames"] = jnp.zeros(
            (B, cfg.encoder.n_ctx, cfg.encoder.d_frontend), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patches"] = jnp.zeros(
            (B, cfg.encoder.n_ctx, cfg.d_model), jnp.bfloat16)

    prefill = jax.jit(tstep.make_prefill_step(cfg, cache_len=cache_len))
    decode = jax.jit(tstep.make_decode_step(cfg), donate_argnums=(2,))

    t0 = time.time()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)

    out = [np.asarray(tok)]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.concatenate(out, axis=1)
    print(f"{args.arch}: prefill {B}x{P} in {t_prefill * 1e3:.1f}ms; "
          f"{args.gen - 1} decode steps in {t_decode * 1e3:.1f}ms "
          f"({B * (args.gen - 1) / max(t_decode, 1e-9):.0f} tok/s)")
    print("generated token ids (row 0):", gen[0].tolist())


if __name__ == "__main__":
    main()
