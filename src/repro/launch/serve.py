"""Batched serving driver: prefill + greedy decode loop.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch tinyllama-1.1b --reduced --batch 4 --prompt-len 32 \
        --gen 16

With ``--codebook K`` the server also maintains a k-means VQ codebook
over the token-embedding table, served through `repro.serve`: the
codebook is fitted once at startup (checkpointable with
``--checkpoint-dir`` / ``--save-every``, resumable with ``--resume``)
and then wrapped in a `ClusterService` — every served batch's
embeddings are INGESTED, not folded inline, so the background refresher
keeps the codebook fresh while decode traffic reads versioned snapshots
without ever waiting on a `partial_fit`. Decode output is tagged with
its codebook cell.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.api import CheckpointConfig, FitConfig, NestedKMeans
from repro.models import model as M
from repro.serve import ClusterService, IngestQueue
from repro.train import step as tstep


def build_codebook(E, k: int, seed: int, *,
                   checkpoint_dir: str | None = None,
                   save_every: int = 20,
                   resume: bool = False,
                   backend: str = "local",
                   trace_dir: str | None = None) -> NestedKMeans:
    """Fit the embedding codebook through the unified api.

    ``E`` is the data to cluster: an in-memory ``(n, d)`` array (the
    embedding table), or an on-disk `repro.data.store` chunk store —
    a directory path or an open `ChunkStore` — for embedding corpora
    bigger than host memory. Store-backed fits stream the nested prefix
    from disk on any backend; everything downstream (checkpointing,
    resume, the local hand-off) is identical.

    With ``checkpoint_dir`` the fit checkpoints its full loop state
    every ``save_every`` rounds and (``resume=True``) continues a killed
    fit bit-identically instead of restarting. ``resume`` without a
    checkpoint dir is a loud error — silently refitting from scratch is
    exactly what a resuming operator does not want.

    ``trace_dir`` attaches a `repro.obs.FitObserver` to the fit: every
    round's scalars, span timings and roofline utilization land as
    JSONL under the directory (`python -m repro.obs summarize DIR`).

    ``backend`` selects the execution engine for the FIT: "local"
    (default), "mesh" (points sharded over the host devices), "xl"
    (points AND centroids sharded — the large-k regime) or "multihost"
    (the mesh engine across jax.distributed processes). The mesh is
    built over whatever devices are visible; checkpoints restore
    elastically across backends, so a fit checkpointed locally resumes
    sharded and vice versa. The returned estimator is always a LOCAL
    one — a sharded fit's outcome is adopted onto the local engine so
    downstream serving streams without rebuilding a sharded layout per
    micro-batch (partial_fit itself runs on any backend now).
    """
    if resume and not checkpoint_dir:
        raise ValueError(
            "--resume needs --checkpoint-dir: there is nowhere to "
            "resume from without a checkpoint store")
    from pathlib import Path

    from repro.data.store import ChunkStore
    if isinstance(E, (str, Path)):
        E = ChunkStore(E)
    n = E.n if isinstance(E, ChunkStore) else E.shape[0]
    ck = (CheckpointConfig(checkpoint_dir=checkpoint_dir,
                           save_every=save_every)
          if checkpoint_dir else None)
    mesh = None
    if backend in ("mesh", "xl"):
        import math
        n_dev = len(jax.devices())
        # widest model axis both the device count and k divide by —
        # degrading to m=1 (centroids unsharded) only when unavoidable,
        # and loudly, since an operator asked for xl to SHARD k
        m = math.gcd(n_dev, k) if backend == "xl" else 1
        if backend == "xl" and m == 1 and n_dev > 1:
            print(f"warning: backend='xl' cannot shard k={k} over "
                  f"{n_dev} devices (gcd 1); centroids stay replicated "
                  f"(equivalent to backend='mesh')")
        mesh = jax.make_mesh((n_dev // m, m), ("data", "model"))
    cfg = FitConfig(k=k, algorithm="tb", rho=float("inf"),
                    b0=min(2 * k, n), bounds="hamerly2",
                    max_rounds=200, seed=seed, checkpoint=ck,
                    backend=backend, data_axes=("data",),
                    model_axis="model", trace_dir=trace_dir)
    km = NestedKMeans(cfg, mesh=mesh)
    km.fit(E, resume=resume)
    if backend != "local":
        # hand the sharded outcome to a local estimator, so downstream
        # serving streams without standing up a sharded layout per
        # micro-batch. Only the (k, d)-sized cluster stats are pulled —
        # km.stats_ is host-reachable on every backend (multihost fits
        # gather them through the engine at fit time); gathering the
        # row-sharded per-point arrays would concentrate the whole
        # dataset's state on one device for nothing.
        import dataclasses
        out = km.outcome_
        stats = jax.tree.map(lambda x: jnp.asarray(np.asarray(x)),
                             km.stats_)
        out = dataclasses.replace(
            out, state=dataclasses.replace(out.state, stats=stats))
        km = NestedKMeans(dataclasses.replace(cfg, backend="local"))
        km.adopt(out)
    return km


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.list_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--codebook", type=int, default=0, metavar="K",
                    help="maintain a K-cell VQ codebook over the "
                         "embedding table via repro.serve")
    ap.add_argument("--codebook-store", default=None, metavar="DIR",
                    help="fit the codebook from this on-disk "
                         "repro.data.store chunk store instead of the "
                         "embedding table (its d must equal the model's "
                         "embedding dim; the fit streams from disk)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="checkpoint the codebook fit in-loop here")
    ap.add_argument("--save-every", type=int, default=20,
                    help="codebook checkpoint cadence in host rounds")
    ap.add_argument("--resume", action="store_true",
                    help="resume a killed codebook fit from "
                         "--checkpoint-dir (error without it)")
    ap.add_argument("--codebook-backend", default="local",
                    choices=("local", "mesh", "xl", "multihost"),
                    help="execution engine for the codebook fit: local "
                         "| mesh (points sharded) | xl (points + "
                         "centroids sharded, for large K) | multihost "
                         "(jax.distributed processes)")
    ap.add_argument("--trace-dir", default=None,
                    help="write repro.obs structured traces of the "
                         "codebook fit here (inspect with `python -m "
                         "repro.obs summarize DIR`)")
    args = ap.parse_args()

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    rng = np.random.default_rng(args.seed)
    B, P = args.batch, args.prompt_len
    cache_len = P + args.gen + (cfg.encoder.n_ctx
                                if cfg.family == "vlm" else 0)

    service = None
    E = None
    if args.codebook:
        E = np.asarray(params["embed"], np.float32)
        t0 = time.time()
        source = args.codebook_store or E
        codebook = build_codebook(source, args.codebook, args.seed,
                                  checkpoint_dir=args.checkpoint_dir,
                                  save_every=args.save_every,
                                  resume=args.resume,
                                  backend=args.codebook_backend,
                                  trace_dir=args.trace_dir)
        what = (f"store {args.codebook_store}" if args.codebook_store
                else f"{E.shape} embeddings")
        print(f"codebook: k={args.codebook} over {what} "
              f"in {time.time() - t0:.2f}s "
              f"(rounds={codebook.n_rounds_}, "
              f"converged={codebook.converged_})")
        # background refresh: served embeddings are queued, folded in by
        # the refresher thread, and published as versioned snapshots;
        # dedup on token id keeps each embedding's contribution unique
        service = ClusterService(
            codebook, micro_batch=256, flush_after_s=0.05,
            queue=IngestQueue(max_rows=4096, dedup=True)).start()
    elif args.resume or args.checkpoint_dir or args.trace_dir:
        ap.error("--checkpoint-dir/--resume/--trace-dir only apply to "
                 "the codebook fit; pass --codebook K")

    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, P)))}
    if cfg.family == "encdec":
        batch["frames"] = jnp.zeros(
            (B, cfg.encoder.n_ctx, cfg.encoder.d_frontend), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patches"] = jnp.zeros(
            (B, cfg.encoder.n_ctx, cfg.d_model), jnp.bfloat16)

    prefill = jax.jit(tstep.make_prefill_step(cfg, cache_len=cache_len))
    decode = jax.jit(tstep.make_decode_step(cfg), donate_argnums=(2,))

    t0 = time.time()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)

    out = [np.asarray(tok)]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok))
        if service is not None:
            # stream the served embeddings toward the refresher; token
            # ids double as dedup keys ("each sample exactly once")
            ids = np.asarray(tok).ravel()
            service.ingest(E[ids], ids=ids.tolist())
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.concatenate(out, axis=1)
    print(f"{args.arch}: prefill {B}x{P} in {t_prefill * 1e3:.1f}ms; "
          f"{args.gen - 1} decode steps in {t_decode * 1e3:.1f}ms "
          f"({B * (args.gen - 1) / max(t_decode, 1e-9):.0f} tok/s)")
    print("generated token ids (row 0):", gen[0].tolist())

    if service is not None:
        # tag output tokens with their codebook cell (router/dedup view)
        cells = service.predict(E[gen[0]])
        print("codebook cells  (row 0):", cells.tolist())
        service.stop()               # final flush of the ingest queue
        m = service.export_metrics()
        snap = service.snapshot
        print(f"codebook service: {m['refresh']['count']} background "
              f"refreshes over {m['refresh']['rows']} embeddings, "
              f"snapshot v{snap.version} "
              f"(deduped={m['queue']['deduped']}, "
              f"batch MSE {snap.batch_mse:.5f})")


if __name__ == "__main__":
    main()
