"""Batched serving driver: prefill + greedy decode loop.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch tinyllama-1.1b --reduced --batch 4 --prompt-len 32 \
        --gen 16

With ``--codebook K`` the server also maintains a k-means VQ codebook
over the token-embedding table through `repro.api` (the unified
estimator surface): the codebook is fitted once at startup and then
*streamed* — every served batch's embeddings are folded in with
`NestedKMeans.partial_fit`, the serving-path primitive for keeping a
router/dedup codebook fresh under live traffic. Decode output is tagged
with its codebook cell.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.api import CheckpointConfig, FitConfig, NestedKMeans
from repro.models import model as M
from repro.train import step as tstep


def build_codebook(E: np.ndarray, k: int, seed: int, *,
                   checkpoint_dir: str | None = None,
                   resume: bool = False) -> NestedKMeans:
    """Fit the embedding-table codebook through the unified api.

    With ``checkpoint_dir`` the fit checkpoints its full loop state
    in-loop and (``resume=True``) continues a killed fit bit-identically
    instead of restarting.
    """
    ck = (CheckpointConfig(checkpoint_dir=checkpoint_dir, save_every=20)
          if checkpoint_dir else None)
    km = NestedKMeans(FitConfig(k=k, algorithm="tb", rho=float("inf"),
                                b0=min(2 * k, E.shape[0]),
                                bounds="hamerly2", max_rounds=200,
                                seed=seed, checkpoint=ck))
    km.fit(E, resume=resume and ck is not None)
    return km


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.list_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--codebook", type=int, default=0, metavar="K",
                    help="maintain a K-cell VQ codebook over the "
                         "embedding table via repro.api")
    args = ap.parse_args()

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    rng = np.random.default_rng(args.seed)
    B, P = args.batch, args.prompt_len
    cache_len = P + args.gen + (cfg.encoder.n_ctx
                                if cfg.family == "vlm" else 0)

    codebook = None
    if args.codebook:
        E = np.asarray(params["embed"], np.float32)
        t0 = time.time()
        codebook = build_codebook(E, args.codebook, args.seed)
        print(f"codebook: k={args.codebook} over {E.shape} embeddings "
              f"in {time.time() - t0:.2f}s "
              f"(rounds={codebook.n_rounds_}, "
              f"converged={codebook.converged_})")

    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, P)))}
    if cfg.family == "encdec":
        batch["frames"] = jnp.zeros(
            (B, cfg.encoder.n_ctx, cfg.encoder.d_frontend), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patches"] = jnp.zeros(
            (B, cfg.encoder.n_ctx, cfg.d_model), jnp.bfloat16)

    prefill = jax.jit(tstep.make_prefill_step(cfg, cache_len=cache_len))
    decode = jax.jit(tstep.make_decode_step(cfg), donate_argnums=(2,))

    t0 = time.time()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)

    out = [np.asarray(tok)]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.concatenate(out, axis=1)
    print(f"{args.arch}: prefill {B}x{P} in {t_prefill * 1e3:.1f}ms; "
          f"{args.gen - 1} decode steps in {t_decode * 1e3:.1f}ms "
          f"({B * (args.gen - 1) / max(t_decode, 1e-9):.0f} tok/s)")
    print("generated token ids (row 0):", gen[0].tolist())

    if codebook is not None:
        E = np.asarray(params["embed"], np.float32)
        # tag output tokens with their codebook cell (router/dedup view)
        cells = codebook.predict(E[gen[0]])
        print("codebook cells  (row 0):", cells.tolist())
        # streaming refinement: fold this batch's served embeddings in
        served = E[np.unique(gen)]
        codebook.partial_fit(served)
        rec = codebook.telemetry_[-1]
        print(f"codebook partial_fit: +{rec.b} embeddings, "
              f"{rec.n_changed} reassigned, batch MSE {rec.batch_mse:.5f}")


if __name__ == "__main__":
    main()
