import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any other import: jax locks the device count on first
#   init. 512 placeholder host devices stand in for the production pods.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces, in artifacts/dryrun/<cell>.json:
  * memory_analysis()  — per-device bytes (proves the cell fits HBM)
  * cost_analysis()    — per-device HLO FLOPs / bytes
  * collective wire bytes parsed from the partitioned HLO
  * the three-term roofline (repro.roofline.analysis)

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--kmeans]
  python -m repro.launch.dryrun --arch ... --shape ... --dump-hlo f.txt
"""
import argparse
import functools
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import input_specs as ispec
from repro.launch.mesh import make_production_mesh
from repro.models import sharding as shd
from repro.optim import adamw
from repro.roofline import analysis as ra
from repro.roofline import hlo_cost
from repro.train import step as tstep

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _mesh_tag(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "pod16x16"


def n_micro_for(cfg: ModelConfig, shape: ShapeConfig, mesh) -> int:
    """One sequence per data shard per microbatch."""
    dp = shd.axis_size(mesh, shd.data_axes(mesh))
    return max(1, shape.global_batch // dp)


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """Build (jitted fn, abstract args, in_shardings) for one cell."""
    params_s = ispec.abstract_params(cfg)
    pshard = shd.param_shardings(cfg, mesh, params_s)

    if shape.kind == "train":
        batch_s = ispec.train_batch_specs(cfg, shape)
        bshard = shd.tree_shardings(
            mesh, shd.batch_specs(cfg, mesh, batch_s))
        opt_s = ispec.abstract_opt_state(params_s)
        oshard = adamw.AdamWState(
            mu=shd.param_shardings(cfg, mesh, opt_s.mu),
            nu=shd.param_shardings(cfg, mesh, opt_s.nu),
            count=jax.NamedSharding(mesh, jax.sharding.PartitionSpec()))
        fn = tstep.make_train_step(
            cfg, n_micro=n_micro_for(cfg, shape, mesh),
            accum_dtype=(jnp.bfloat16 if cfg.param_count() > 1e11
                         else jnp.float32))
        args = (params_s, opt_s, batch_s)
        in_sh = (pshard, oshard, bshard)
        tokens = shape.global_batch * shape.seq_len
        model_flops = ra.model_flops_train(cfg.active_param_count(), tokens)
    elif shape.kind == "prefill":
        batch_s = ispec.prefill_batch_specs(cfg, shape)
        bshard = shd.tree_shardings(
            mesh, shd.batch_specs(cfg, mesh, batch_s))
        fn = tstep.make_prefill_step(cfg, cache_len=shape.seq_len)
        args = (params_s, batch_s)
        in_sh = (pshard, bshard)
        tokens = shape.global_batch * shape.seq_len
        model_flops = ra.model_flops_fwd(cfg.active_param_count(), tokens)
    else:  # decode
        dec = ispec.decode_specs(cfg, shape)
        cshard = shd.tree_shardings(
            mesh, shd.cache_specs(cfg, mesh, dec["cache"]))
        dp = shd.data_axes(mesh)
        tok_ax = dp if shape.global_batch % shd.axis_size(mesh, dp) == 0 \
            else None
        tshard = jax.NamedSharding(
            mesh, jax.sharding.PartitionSpec(tok_ax, None))
        fn = tstep.make_decode_step(cfg)
        args = (params_s, dec["token"], dec["cache"])
        in_sh = (pshard, tshard, cshard)
        tokens = shape.global_batch            # one token per sequence
        model_flops = ra.model_flops_fwd(cfg.active_param_count(), tokens)

    return fn, args, in_sh, model_flops


def run_cell(arch: str, shape: ShapeConfig, *, multi_pod: bool,
             out_dir: Path = ARTIFACTS, dump_hlo: str | None = None,
             tag: str = "") -> dict:
    cfg = configs.get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = f"{arch}__{shape.name}__{_mesh_tag(multi_pod)}{tag}"
    t0 = time.time()
    rec: dict = {"cell": cell, "arch": arch, "shape": shape.name,
                 "mesh": list(mesh.shape.values()),
                 "axes": list(mesh.axis_names), "kind": shape.kind}
    try:
        fn, args, in_sh, model_flops = lower_cell(cfg, shape, mesh)
        # donate params/opt (train) or cache (decode): the updated state
        # aliases the input buffers, as the real launcher runs it
        donate = (0, 1) if shape.kind == "train" else \
            (2,) if shape.kind == "decode" else ()
        with jax.set_mesh(mesh):
            lowered = jax.jit(fn, in_shardings=in_sh,
                              donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        # analytic per-device storage floor from the input shardings
        # (CPU BufferAssignment ignores donation and keeps separate
        # input+output copies, so memory_analysis() overstates steady
        # state for donated train/decode steps — both views recorded).
        def _dev_bytes(leaf, sh):
            n = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
            if hasattr(sh, "spec"):
                for dim, ax in enumerate(sh.spec):
                    if ax is None:
                        continue
                    axes = ax if isinstance(ax, tuple) else (ax,)
                    size = int(np.prod([mesh.shape[a] for a in axes]))
                    if leaf.shape[dim] % size == 0:
                        n //= size
            return n
        storage = sum(
            _dev_bytes(l, s) for l, s in zip(
                jax.tree.leaves(args), jax.tree.leaves(
                    in_sh, is_leaf=lambda x: hasattr(x, "spec"))))
        try:
            mem = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "peak_bytes": int(mem.argument_size_in_bytes
                                  + mem.temp_size_in_bytes),
                "storage_bytes_analytic": storage,
                "source": "memory_analysis",
            }
        except Exception as e:  # CPU backend may not implement it
            rec["memory"] = {"storage_bytes_analytic": storage,
                             "peak_bytes": None,
                             "source": f"analytic({type(e).__name__})"}

        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        if dump_hlo:
            Path(dump_hlo).write_text(hlo)
        # loop-aware per-device costs (XLA's cost_analysis counts while
        # bodies once; hlo_cost multiplies through trip counts)
        hc = hlo_cost.analyze(hlo)
        coll = ra.parse_collectives(hlo)   # static (per-occurrence) view
        flops, hbm = hc.flops, hc.bytes
        n_chips = len(jax.devices())
        roof = ra.roofline_terms(flops, hbm, hc.wire,
                                 model_flops=model_flops / n_chips)
        rec.update({
            "ok": True,
            "t_lower_s": round(t_lower, 2),
            "t_compile_s": round(t_compile, 2),
            "flops_per_device": flops,
            "hbm_bytes_per_device": hbm,
            "wire_bytes_per_device": hc.wire,
            "xla_cost_analysis": {"flops": float(cost.get("flops", 0.0)),
                                  "bytes": float(cost.get("bytes accessed",
                                                          0.0))},
            "collectives": hc.wire_by_kind,
            "collective_counts": coll.counts,
            "model_flops_per_device": model_flops / n_chips,
            "roofline": {
                "compute_s": roof.compute_s,
                "memory_s": roof.memory_s,
                "collective_s": roof.collective_s,
                "bottleneck": roof.bottleneck,
                "useful_ratio": roof.useful_ratio,
                "roofline_fraction": roof.roofline_fraction(),
            },
        })
    except Exception as e:
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{cell}.json").write_text(json.dumps(rec, indent=1))
    status = "OK " if rec.get("ok") else "FAIL"
    roofstr = ""
    if rec.get("ok"):
        r = rec["roofline"]
        m = rec.get("memory", {})
        peak = m.get("peak_bytes")
        roofstr = (f" comp={r['compute_s']:.3g}s mem={r['memory_s']:.3g}s"
                   f" coll={r['collective_s']:.3g}s -> {r['bottleneck']}"
                   + (f" | peak/dev={peak / 1e9:.2f}GB" if peak else "")
                   + f" flops/dev={rec['flops_per_device']:.3g}")
    print(f"[{status}] {cell}{roofstr}", flush=True)
    return rec


def run_kmeans_cell(name: str, *, multi_pod: bool,
                    out_dir: Path = ARTIFACTS) -> dict:
    """Dry-run of the paper's own technique at production scale."""
    from repro.core import rounds as kr
    from repro.core import distributed as kd
    from repro.core.state import KMeansState, ClusterStats, PointState

    kcfg = configs.get_kmeans_config(name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = f"{name}__round__{_mesh_tag(multi_pod)}"
    dp_axes = tuple(a for a in mesh.axis_names if a != "model")
    n_dp = shd.axis_size(mesh, dp_axes)
    t0 = time.time()
    rec: dict = {"cell": cell, "arch": name, "shape": "round",
                 "mesh": list(mesh.shape.values()),
                 "axes": list(mesh.axis_names), "kind": "kmeans"}
    try:
        N, d, k = kcfg.n_points, kcfg.dim, kcfg.k
        N += -N % n_dp                   # structural tail padding
        n_local = N // n_dp
        b_local = max(1, min(kcfg.b0 * 64, N) // n_dp)
        if kcfg.shard_centroids:
            # optimized production round: pure DP over every axis,
            # C replicated (see distributed.make_dp_round docstring).
            n_chips_all = len(jax.devices())
            N += -N % n_chips_all
            fn = kd.make_dp_round(mesh, rho=kcfg.rho)
            args = (jax.ShapeDtypeStruct((N, d), jnp.float32),
                    jax.ShapeDtypeStruct((k, d), jnp.float32))
            lowered = fn.lower(*args)
            # single-X-pass Pallas traffic model (the TPU execution path;
            # interpret-mode lowering can't appear in CPU HLO):
            n_loc = N // n_chips_all
            rec["pallas_analytic"] = {
                "hbm_bytes": n_loc * d * 4 + k * d * 4 * 3 + n_loc * 12,
                # scores dot (2ndk) + one-hot S accumulation dot (2ndk):
                # the dense round's honest MXU cost is 4ndk. In nested
                # steady state the S term shrinks to changed points only
                # (delta updates) and bounds prune the scores dot.
                "flops": 4.0 * n_loc * d * k + 4.0 * n_loc * k,
                "note": "fused_round kernel: X once + C + outputs",
            }
        else:
            fn = kd.make_sharded_round(
                mesh, dp_axes, b_local=b_local, rho=kcfg.rho,
                bounds=kcfg.bounds, capacity=max(256, b_local // 4))
            state = jax.eval_shape(functools.partial(
                _abstract_kmeans_state, n=N, d=d, k=k))
            args = (jax.ShapeDtypeStruct((N, d), jnp.float32), state)
            lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        hc = hlo_cost.analyze(compiled.as_text())
        flops, hbm = hc.flops, hc.bytes
        # useful work: one fused assign round = 2 b d k / chips flops
        n_chips = len(jax.devices())
        b_glob = N if kcfg.shard_centroids else b_local * n_dp
        model_flops = 2.0 * b_glob * d * k / n_chips
        roof = ra.roofline_terms(flops, hbm, hc.wire,
                                 model_flops=model_flops)
        if "pallas_analytic" in rec:
            pa = rec["pallas_analytic"]
            pr = ra.roofline_terms(pa["flops"], pa["hbm_bytes"], hc.wire,
                                   model_flops=model_flops)
            pa["roofline"] = {
                "compute_s": pr.compute_s, "memory_s": pr.memory_s,
                "collective_s": pr.collective_s,
                "bottleneck": pr.bottleneck,
                "roofline_fraction": pr.roofline_fraction(),
            }
        try:
            mem = compiled.memory_analysis()
            peak = int(mem.argument_size_in_bytes
                       + mem.temp_size_in_bytes)
        except Exception:
            peak = None
        rec.update({
            "ok": True, "t_lower_s": round(t_lower, 2),
            "t_compile_s": round(t_compile, 2),
            "flops_per_device": flops, "hbm_bytes_per_device": hbm,
            "wire_bytes_per_device": hc.wire,
            "collectives": hc.wire_by_kind,
            "model_flops_per_device": model_flops,
            "memory": {"peak_bytes": peak},
            "roofline": {
                "compute_s": roof.compute_s, "memory_s": roof.memory_s,
                "collective_s": roof.collective_s,
                "bottleneck": roof.bottleneck,
                "useful_ratio": roof.useful_ratio,
                "roofline_fraction": roof.roofline_fraction(),
            },
        })
    except Exception as e:
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{cell}.json").write_text(json.dumps(rec, indent=1))
    print(f"[{'OK ' if rec.get('ok') else 'FAIL'}] {cell}", flush=True)
    return rec


def _abstract_kmeans_state(n: int, d: int, k: int):
    from repro.core.state import ClusterStats, KMeansState, PointState
    return KMeansState(
        stats=ClusterStats(C=jnp.zeros((k, d), jnp.float32),
                           S=jnp.zeros((k, d), jnp.float32),
                           v=jnp.zeros((k,), jnp.float32),
                           sse=jnp.zeros((k,), jnp.float32),
                           p=jnp.zeros((k,), jnp.float32)),
        points=PointState(a=jnp.zeros((n,), jnp.int32),
                          d=jnp.zeros((n,), jnp.float32),
                          lb=jnp.zeros((n,), jnp.float32)),
        elkan=None, round=jnp.zeros((), jnp.int32))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--kmeans", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--dump-hlo", default=None)
    ap.add_argument("--out", default=str(ARTIFACTS))
    ap.add_argument("--skip-existing", action="store_true",
                    help="skip cells whose artifact JSON already has ok=true")
    args = ap.parse_args()
    out = Path(args.out)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    def done(cell: str) -> bool:
        f = out / f"{cell}.json"
        if not (args.skip_existing and f.exists()):
            return False
        try:
            return json.loads(f.read_text()).get("ok", False)
        except Exception:
            return False

    n_fail = 0
    if args.kmeans:
        for name in configs.KMEANS_WORKLOADS:
            for mp in meshes:
                if done(f"{name}__round__{_mesh_tag(mp)}"):
                    continue
                rec = run_kmeans_cell(name, multi_pod=mp, out_dir=out)
                n_fail += 0 if rec.get("ok") else 1
    if args.all:
        for arch in configs.list_archs():
            cfg = configs.get_config(arch)
            for shape in configs.shapes_for(cfg):
                for mp in meshes:
                    if done(f"{arch}__{shape.name}__{_mesh_tag(mp)}"):
                        continue
                    rec = run_cell(arch, shape, multi_pod=mp, out_dir=out)
                    n_fail += 0 if rec.get("ok") else 1
    elif args.arch:
        shape = {s.name: s for s in configs.ALL_SHAPES}[args.shape]
        for mp in meshes:
            rec = run_cell(args.arch, shape, multi_pod=mp, out_dir=out,
                           dump_hlo=args.dump_hlo)
            n_fail += 0 if rec.get("ok") else 1
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
