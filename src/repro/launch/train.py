"""LM training CLI — any assigned arch, reduced or full config.

    PYTHONPATH=src python -m repro.launch.train \
        --arch tinyllama-1.1b --reduced --steps 50 --batch 8 --seq 128 \
        --ckpt-dir /tmp/run1

Reduced configs actually train on this CPU box; full configs are meant
for the production mesh (this CLI still runs them if you have the
hardware — the step function is the same one the dry-run compiles).
Checkpoints save asynchronously every ``--ckpt-every`` steps and training
resumes from the latest checkpoint if the directory is non-empty
(fault-tolerant restart). ``--codebook K`` additionally clusters the
token-embedding table through `repro.api` at the end of the run — a
cheap geometry probe (codebook occupancy / VQ error) of what training
did to the embedding space.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint.store import CheckpointStore
from repro.data.pipeline import LMBatches
from repro.models import model as M
from repro.optim import adamw
from repro.train import step as tstep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    choices=configs.list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--n-micro", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", "--checkpoint-dir", dest="ckpt_dir",
                    default=None,
                    help="checkpoint directory (LM training state; the "
                         "--codebook fit checkpoints in-loop under "
                         "<dir>/codebook)")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="restore the latest checkpoint in --ckpt-dir "
                         "before training / the codebook fit "
                         "(--no-resume starts fresh)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--codebook", type=int, default=0, metavar="K",
                    help="cluster the trained embedding table into K "
                         "cells via repro.api and report VQ stats")
    ap.add_argument("--codebook-store", default=None, metavar="DIR",
                    help="fit the codebook from this on-disk "
                         "repro.data.store chunk store instead of the "
                         "embedding table (its d must equal the "
                         "model's embedding dim); the VQ probe still "
                         "reports the table's occupancy under it")
    ap.add_argument("--codebook-backend", default="local",
                    choices=("local", "mesh", "xl", "multihost"),
                    help="engine for the codebook fit: local | mesh "
                         "(points sharded over the visible devices) | "
                         "xl (points + centroids sharded — large K) | "
                         "multihost (jax.distributed processes)")
    ap.add_argument("--trace-dir", default=None,
                    help="write repro.obs structured traces of the "
                         "codebook fit here (inspect with `python -m "
                         "repro.obs summarize DIR`)")
    args = ap.parse_args()

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    if cfg.family in ("encdec", "vlm"):
        print(f"note: {args.arch} needs modality inputs; using zero "
              "frame/patch stubs for the synthetic-token run")

    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"{args.arch} ({'reduced' if args.reduced else 'FULL'}): "
          f"{n_params:,} params")

    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=10,
                                decay_steps=max(args.steps, 100))
    train_step = jax.jit(tstep.make_train_step(
        cfg, n_micro=args.n_micro, opt_cfg=opt_cfg), donate_argnums=(0, 1))
    opt = adamw.init(params)

    data = LMBatches(vocab=cfg.vocab, batch=args.batch, seq=args.seq,
                     seed=args.seed)
    store = CheckpointStore(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if store and args.resume and store.latest_step() is not None:
        start = store.latest_step()
        restored = store.restore({"params": params, "opt": opt})
        params, opt = restored["params"], restored["opt"]
        print(f"resumed from checkpoint at step {start}")

    def to_batch(b):
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (args.batch, cfg.encoder.n_ctx, cfg.encoder.d_frontend),
                jnp.bfloat16)
        if cfg.family == "vlm":
            p = cfg.encoder.n_ctx
            batch["tokens"] = batch["tokens"][:, :-0 or None][:, p:] \
                if batch["tokens"].shape[1] > p else batch["tokens"]
            batch["labels"] = batch["labels"][:, p:] \
                if batch["labels"].shape[1] > p else batch["labels"]
            batch["patches"] = jnp.zeros((args.batch, p, cfg.d_model),
                                         jnp.bfloat16)
        return batch

    t0 = time.time()
    for step in range(start, args.steps):
        params, opt, m = train_step(params, opt, to_batch(data.at(step)))
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(m['loss']):.4f} "
                  f"lr {float(m['lr']):.2e} "
                  f"gnorm {float(m['grad_norm']):.3f} "
                  f"({(time.time() - t0):.1f}s)")
        if store and step and step % args.ckpt_every == 0:
            store.save(step, {"params": params, "opt": opt},
                       background=True)
    if store:
        store.save(args.steps, {"params": params, "opt": opt})
        store.wait()
        print(f"final checkpoint at step {args.steps}")

    if args.codebook:
        from repro.launch.serve import build_codebook
        E = np.asarray(params["embed"], np.float32)
        # the k-means fit checkpoints in-loop (run_loop saves the full
        # growth-schedule state) and resumes if a prior run was killed
        ckpt_dir = (f"{args.ckpt_dir}/codebook" if args.ckpt_dir
                    else None)
        # --resume here is opportunistic ("continue if a checkpoint
        # exists"), so only request it when there is a store to resume
        # from — build_codebook errors loudly on resume without one
        km = build_codebook(args.codebook_store or E, args.codebook,
                            args.seed, checkpoint_dir=ckpt_dir,
                            resume=args.resume and ckpt_dir is not None,
                            backend=args.codebook_backend,
                            trace_dir=args.trace_dir)
        sizes = np.bincount(km.predict(E), minlength=args.codebook)
        print(f"embedding codebook (k={args.codebook}): "
              f"VQ-MSE {-km.score(E) / E.shape[0]:.6f} "
              f"occupancy min={sizes.min()} max={sizes.max()} "
              f"empty={int((sizes == 0).sum())}")


if __name__ == "__main__":
    main()
