"""Production mesh builders.

A FUNCTION (never a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before any jax
initialisation.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = 1
    for s in shape:
        n *= s
    if len(jax.devices()) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(jax.devices())}; set "
            "XLA_FLAGS=--xla_force_host_platform_device_count")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
