"""Production mesh builders + jax.distributed initialisation helpers.

FUNCTIONS (never module-level constants) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before any jax
initialisation, and `initialize_multihost` must configure the CPU
collectives implementation before the backend comes up.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax

# the flag construction and device validation live in repro.util.env
# (shared with benchmark/smoke subprocess children); re-exported here
# because this module has always been their import point
from repro.util.env import device_count_flag, require_devices  # noqa: F401


def _make_mesh(shape, axes):
    """`jax.make_mesh` with Auto axis types where the jax version has
    them (>= 0.5); plain mesh on 0.4.x, which lacks AxisType."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = 1
    for s in shape:
        n *= s
    require_devices(n)
    return _make_mesh(shape, axes)


# --------------------------------------------------------------------------
# multi-process (jax.distributed)
# --------------------------------------------------------------------------

def distributed_initialized() -> bool:
    """True once `jax.distributed.initialize` has run in this process."""
    try:
        from jax._src import distributed
        return distributed.global_state.coordinator_address is not None
    except Exception:            # private API moved — assume not up
        return False


def initialize_multihost(*, coordinator_address: str, num_processes: int,
                         process_id: int,
                         local_devices: Optional[Sequence[int]] = None,
                         expect_local_devices: Optional[int] = None
                         ) -> None:
    """Stand up this process's membership in a jax.distributed cluster.

    Call BEFORE anything queries jax devices: on CPU the collectives
    implementation (gloo) must be configured before the backend
    initialises, and forcing host device counts (see
    `device_count_flag`) only works pre-initialisation. Process 0 at
    ``coordinator_address`` doubles as the coordination service — a dev
    cluster is just N local processes pointed at one localhost port
    (see scripts/smoke_multihost.py).

    ``expect_local_devices`` validates, post-init, that this process
    sees that many devices of its own (the shared `require_devices`
    helper, so the remedy message matches `make_host_mesh`'s).
    """
    if distributed_initialized():
        return
    try:
        # CPU backends cross processes via gloo; harmless elsewhere
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:
        pass                     # jax without the option (gpu/tpu-only)
    kwargs = {}
    if local_devices is not None:
        kwargs["local_device_ids"] = list(local_devices)
    jax.distributed.initialize(coordinator_address=coordinator_address,
                               num_processes=num_processes,
                               process_id=process_id, **kwargs)
    if expect_local_devices is not None:
        require_devices(expect_local_devices, local=True)


def ensure_multihost_initialized(config) -> None:
    """Initialise jax.distributed from a `FitConfig`'s coordinator
    fields (no-op when they are unset or the cluster is already up)."""
    if getattr(config, "coordinator_address", None) is None:
        return
    initialize_multihost(coordinator_address=config.coordinator_address,
                         num_processes=config.num_processes,
                         process_id=config.process_id)


def make_multihost_mesh(data_axes=("data",)):
    """One flat data axis over EVERY device of EVERY process.

    The multihost engine row-shards points over this mesh and keeps the
    cluster stats replicated; with one process this is exactly the mesh
    engine's layout, which is what makes the two bit-identical there.
    """
    data_axes = tuple(data_axes)
    if len(data_axes) != 1:
        raise ValueError(
            f"make_multihost_mesh builds one flat data axis; got "
            f"data_axes={data_axes!r} (pass a mesh to MultiHostEngine "
            f"for multi-axis layouts)")
    return _make_mesh((jax.device_count(),), data_axes)
