"""`FitObserver` — the concrete obs sink a traced fit writes through.

`api.loop.ObsSink` is the *seam*: a no-op base class `run_loop` and the
engines call unconditionally. This module is the *implementation* wired
in when a trace directory is configured: every round's host-landed
scalars go to a `SpanTracer` JSONL stream, a `MetricsRegistry`
aggregates counters/gauges/histograms for scraping, and a `WorkModel`
prices each round against the roofline bound.

The observer is deliberately **duck-typed** (it does not import
`api.loop`): the obs package stays jax-free, so readers and CLIs run on
machines with no accelerator stack — and importing it can never
provoke a device sync. The flip side is a hard contract: every value
handed to `round_end` is ALREADY host-landed plain Python
(`HostRoundInfo` fields, `time.perf_counter` floats, `StoreMetrics`
dicts, `util.tracecount` snapshots). The observer never sees a jax
array, which is what keeps the hostsync auditor silent with tracing on
— `tests/test_obs.py` asserts exactly that.
"""
from __future__ import annotations

import contextlib
import json
import math
import time
from pathlib import Path
from typing import Any, Dict, Optional, Union

from repro.obs.efficiency import WorkModel
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import OBS_SCHEMA, SpanTracer
from repro.util import tracecount


def _safe(v):
    """JSON-safe scalar: non-finite floats become None (strict parsers
    reject bare NaN), everything else passes through."""
    if isinstance(v, float) and not math.isfinite(v):
        return None
    return v


class FitObserver:
    """Observability sink for one fit (one per process on multihost).

    Satisfies the `api.loop.ObsSink` duck-type surface — ``span`` /
    ``count`` / ``round_end`` / ``fit_end`` / ``close`` — and writes:

      * ``trace-p<pid>-<seq>.jsonl``  — the span/event stream;
      * ``metrics-p<pid>.json``       — the registry export, at close.

    ``k``/``d`` enable the roofline `WorkModel`; without them the
    observer still traces rounds, just without priced work or the
    utilization gauge. ``bounds`` selects the model's work unit:
    elkan/exponion rounds count individual pair distances in
    ``n_recomputed`` (annulus scans, not full k rows), and pricing them
    as k-scans would overstate the work by exactly the pruning factor.
    """

    def __init__(self, trace_dir: Union[str, Path], *, process_id: int = 0,
                 k: Optional[int] = None, d: Optional[int] = None,
                 bounds: Optional[str] = None,
                 meta: Optional[Dict[str, Any]] = None,
                 registry: Optional[MetricsRegistry] = None,
                 rotate_bytes: int = 8 << 20):
        self.tracer = SpanTracer(trace_dir, process_id=process_id,
                                 rotate_bytes=rotate_bytes)
        self.registry = registry if registry is not None else \
            MetricsRegistry()
        self.work = (WorkModel.for_bounds(k, d, bounds or "hamerly2")
                     if k and d else None)
        self._closed = False
        self._tc_before = tracecount.snapshot()
        self._store_before: Dict[str, Any] = {}
        r = self.registry
        self._rounds = r.counter("fit_rounds", "completed loop rounds")
        self._kscans = r.counter(
            "fit_kscans", "points that paid a full k-centroid scan")
        self._retraces = r.counter(
            "fit_jit_traces", "jit traces observed during the fit")
        self._round_s = r.histogram(
            "fit_round_seconds", "per-round wall time", unit="s")
        self._g_kscans = r.gauge(
            "fit_kscans_per_s", "last round's achieved k-scan rate")
        self._g_bytes = r.gauge(
            "fit_bytes_per_s", "last round's achieved HBM byte rate")
        self._g_util = r.gauge(
            "fit_roofline_utilization",
            "last round's bound_s / wall_s vs the roofline model")
        self._g_b = r.gauge("fit_b_global", "current global nested batch")
        attrs = dict(meta or {})
        attrs.update(obs_schema=OBS_SCHEMA, k=k, d=d)
        self.tracer.event("fit_start", **attrs)

    # -- the ObsSink duck-type surface ---------------------------------------

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        t0 = time.monotonic()
        with self.tracer.span(name, **attrs):
            yield
        self.registry.histogram(f"fit_{name}_seconds",
                                f"{name} span wall time",
                                unit="s").record(time.monotonic() - t0)

    def count(self, name: str, n: int = 1) -> None:
        self.registry.counter(f"fit_{name}",
                              f"{name} occurrences").inc(n)
        self.tracer.event(name, n=n)

    def round_end(self, round: int, hinfo, *, dt_s: float, t_work: float,
                  b_global: int, capacity: Optional[int],
                  quiet_rounds: int, algorithm: str,
                  val_mse: Optional[float] = None,
                  store: Optional[Dict[str, Any]] = None) -> None:
        """Record one completed round from already-host-landed scalars."""
        attrs: Dict[str, Any] = {
            "round": int(round), "algorithm": algorithm,
            "dt_s": float(dt_s), "t_work": float(t_work),
            "b_global": int(b_global), "capacity": capacity,
            "quiet_rounds": int(quiet_rounds),
            "batch_mse": _safe(float(hinfo.batch_mse)),
            "n_changed": int(hinfo.n_changed),
            "n_active": int(hinfo.n_active),
            "grow": bool(hinfo.grow), "overflow": bool(hinfo.overflow),
            "r_median": _safe(float(hinfo.r_median)),
            "p_max": _safe(float(hinfo.p_max)),
            "kscans": int(hinfo.n_recomputed),
            "val_mse": _safe(float(val_mse)) if val_mse is not None
                       else None,
        }
        if self.work is not None:
            w = self.work.round_work(hinfo.n_recomputed, dt_s)
            attrs.update(work_unit=w.unit,
                         dist_evals=w.dist_evals, flops=w.flops,
                         bytes=int(w.hbm_bytes),
                         bound_s=_safe(w.bound_s),
                         bottleneck=w.bottleneck,
                         utilization=_safe(w.utilization))
            if dt_s > 0.0:
                self._g_kscans.set(w.kscans / dt_s)
                self._g_bytes.set(w.hbm_bytes / dt_s)
            if w.utilization is not None:
                self._g_util.set(w.utilization)
        if store:
            delta = {f"store_{key}": v - self._store_before.get(key, 0)
                     for key, v in store.items()
                     if isinstance(v, (int, float))}
            self._store_before = dict(store)
            attrs.update(delta)
        traced = tracecount.diff(self._tc_before)
        if traced:
            self._tc_before = tracecount.snapshot()
            n_traces = sum(traced.values())
            self._retraces.inc(n_traces)
            for (site, statics), n in sorted(traced.items()):
                self.tracer.event(
                    "jit_trace", site=site, n=n,
                    statics={name: v for name, v in statics})
            attrs["jit_traces"] = n_traces
        self._rounds.inc()
        self._kscans.inc(int(hinfo.n_recomputed))
        self._round_s.record(dt_s)
        self._g_b.set(float(b_global))
        self.tracer.event("round", **attrs)

    def fit_end(self, **summary) -> None:
        self.tracer.event("fit_end",
                          **{k: _safe(v) for k, v in summary.items()})
        self.tracer.flush()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        path = (self.tracer.dir /
                f"metrics-p{self.tracer.process_id:05d}.json")
        with open(path, "w", encoding="utf-8") as f:
            json.dump(self.registry.to_dict(), f, indent=2, sort_keys=True)
            f.write("\n")
        self.tracer.close()

    def __enter__(self) -> "FitObserver":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
