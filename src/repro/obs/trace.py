"""Structured span tracing: rotating JSONL event logs + a merge reader.

One `SpanTracer` per process writes one stream of JSON-lines events to
``trace-p<process_id>-<seq>.jsonl`` files under a trace directory,
rotating to a fresh file whenever the current one crosses
``rotate_bytes``. On a multihost fit every process traces its OWN host
loop into its own files (the control flow is replicated, the wall time
is not — per-process skew is exactly what the reader exposes); the
merge reader (`read_events`) reassembles the directory into one
time-ordered stream.

Event records share a common envelope::

    {"schema": 1, "pid": 0, "id": 17, "ts": 0.0312, ...}

  * ``ph: "meta"``  — one per file: schema version, wall-clock epoch
    (``wall0``) so per-process monotonic offsets can be aligned.
  * ``ph: "span"``  — a timed region, written at span EXIT: ``ts`` is
    the start offset, ``dur_s`` the duration, ``parent`` the id of the
    enclosing span (None at top level). Spans nest per-thread.
  * ``ph: "event"`` — a point event (a round record, a jit retrace)
    attributed to the current thread's open span, if any.

Timestamps come from the monotonic clock (offsets from tracer
construction), so a suspended laptop or an NTP step can never make a
span negative. Writes take one lock and one buffered ``write`` per
record; nothing here touches jax or device memory — the tracer is safe
to call from inside the host loop's transfer-guarded round scope.
"""
from __future__ import annotations

import contextlib
import json
import threading
import time
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional, Union

#: bump when the event envelope changes shape; readers refuse newer
#: schemas rather than mis-parse them.
OBS_SCHEMA = 1

_FILE_PREFIX = "trace-p"


def trace_file_name(process_id: int, seq: int) -> str:
    return f"{_FILE_PREFIX}{process_id:05d}-{seq:04d}.jsonl"


class SpanTracer:
    """Thread-safe JSONL span/event writer for one process."""

    def __init__(self, trace_dir: Union[str, Path], *, process_id: int = 0,
                 rotate_bytes: int = 8 << 20):
        if rotate_bytes < 4096:
            raise ValueError(f"rotate_bytes must be >= 4096, got "
                             f"{rotate_bytes}")
        self.dir = Path(trace_dir)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.process_id = int(process_id)
        self.rotate_bytes = rotate_bytes
        self._t0 = time.monotonic()
        self._wall0 = time.time()
        self._lock = threading.Lock()
        self._local = threading.local()       # per-thread span stack
        self._next_id = 0
        self._seq = 0
        self._file = None
        self._file_bytes = 0
        self._closed = False
        self._open_next_file()

    # -- writer internals ---------------------------------------------------

    def _open_next_file(self) -> None:
        if self._file is not None:
            self._file.close()
        path = self.dir / trace_file_name(self.process_id, self._seq)
        self._seq += 1
        self._file = open(path, "w", encoding="utf-8")
        self._file_bytes = 0
        self._write({"schema": OBS_SCHEMA, "pid": self.process_id,
                     "id": self._take_id(), "ts": self._now(),
                     "ph": "meta", "wall0": self._wall0})

    def _take_id(self) -> int:
        i = self._next_id
        self._next_id += 1
        return i

    def _now(self) -> float:
        return time.monotonic() - self._t0

    def _write(self, obj: Dict[str, Any]) -> None:
        line = json.dumps(obj, separators=(",", ":"),
                          default=_json_default) + "\n"
        self._file.write(line)
        self._file_bytes += len(line)

    def _emit(self, obj: Dict[str, Any]) -> None:
        with self._lock:
            if self._closed:
                return
            obj.setdefault("id", self._take_id())
            self._write(obj)
            if self._file_bytes >= self.rotate_bytes:
                self._open_next_file()

    def _stack(self) -> List[int]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    # -- public API ---------------------------------------------------------

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        """Time a region; the record is written when the region exits."""
        stack = self._stack()
        with self._lock:
            sid = self._take_id()
        parent = stack[-1] if stack else None
        stack.append(sid)
        t0 = self._now()
        try:
            yield sid
        finally:
            dur = self._now() - t0
            stack.pop()
            rec = {"schema": OBS_SCHEMA, "pid": self.process_id,
                   "id": sid, "ts": t0, "ph": "span", "name": name,
                   "parent": parent, "dur_s": dur}
            if attrs:
                rec["attrs"] = attrs
            self._emit(rec)

    def event(self, name: str, **attrs) -> None:
        """A point event, attributed to this thread's open span."""
        stack = self._stack()
        rec = {"schema": OBS_SCHEMA, "pid": self.process_id,
               "ts": self._now(), "ph": "event", "name": name,
               "parent": stack[-1] if stack else None}
        if attrs:
            rec["attrs"] = attrs
        self._emit(rec)

    def flush(self) -> None:
        with self._lock:
            if self._file is not None and not self._closed:
                self._file.flush()

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            if self._file is not None:
                self._file.close()
                self._file = None

    def __enter__(self) -> "SpanTracer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _json_default(obj):
    """Last-resort encoder: never let a numpy scalar (or anything else
    JSON-foreign) kill the trace stream mid-fit. ``item()`` (the numpy
    scalar unboxing protocol) preserves int-ness; the float fallback
    must come before int, or float-like values would silently truncate."""
    item = getattr(obj, "item", None)
    if callable(item):
        try:
            v = item()
            if isinstance(v, (bool, int, float, str)):
                return v
        except (TypeError, ValueError):
            pass
    for cast in (float, int):
        try:
            return cast(obj)
        except (TypeError, ValueError):
            continue
    return repr(obj)


# -- reader ------------------------------------------------------------------

def trace_files(trace_dir: Union[str, Path],
                process_id: Optional[int] = None) -> List[Path]:
    """The trace files of a directory, in (process, sequence) order."""
    pat = (f"{_FILE_PREFIX}*.jsonl" if process_id is None
           else f"{_FILE_PREFIX}{process_id:05d}-*.jsonl")
    return sorted(Path(trace_dir).glob(pat))


def read_events(trace_dir: Union[str, Path],
                process_id: Optional[int] = None) -> List[Dict[str, Any]]:
    """Merge every per-process file into one time-ordered event list.

    Events are ordered by wall-clock time: each file's ``meta`` record
    carries the process's wall epoch, so per-process monotonic offsets
    from different hosts interleave correctly (up to host clock skew).
    A schema newer than this reader understands is a loud error, not a
    silent mis-parse.
    """
    files = trace_files(trace_dir, process_id)
    if not files:
        raise FileNotFoundError(
            f"{trace_dir} holds no trace files ({_FILE_PREFIX}*.jsonl)")
    out: List[Dict[str, Any]] = []
    wall0: Dict[int, float] = {}
    for path in files:
        with open(path, encoding="utf-8") as f:
            for lineno, line in enumerate(f, 1):
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError as e:
                    raise ValueError(
                        f"{path}:{lineno}: corrupt trace line: {e}"
                        ) from None
                schema = rec.get("schema")
                if schema is not None and schema > OBS_SCHEMA:
                    raise ValueError(
                        f"{path}:{lineno}: trace schema {schema} is newer "
                        f"than this reader (understands <= {OBS_SCHEMA})")
                if rec.get("ph") == "meta":
                    wall0[rec.get("pid", 0)] = float(rec.get("wall0", 0.0))
                out.append(rec)
    out.sort(key=lambda r: (wall0.get(r.get("pid", 0), 0.0)
                            + float(r.get("ts", 0.0)),
                            r.get("pid", 0), r.get("id", 0)))
    return out


def tail_events(trace_dir: Union[str, Path], n: int = 20
                ) -> List[Dict[str, Any]]:
    """The last ``n`` merged events (cheap follower for live fits)."""
    return read_events(trace_dir)[-n:]


def summarize(events: Iterable[Dict[str, Any]]) -> Dict[str, Any]:
    """Aggregate a merged event stream into one JSON-safe summary.

    Round-level scalars (k-scans, bytes, retraces) are aggregated from
    the LOWEST process id only: `RoundInfo` is psum-reduced before it
    lands, so every process reports the same global values and summing
    across processes would multiply the work by the process count.
    Span timings are aggregated per process — wall time is the one
    thing replication does NOT make identical.
    """
    events = list(events)
    pids = sorted({e.get("pid", 0) for e in events})
    lead = pids[0] if pids else 0
    rounds_by_pid = {p: 0 for p in pids}
    summary: Dict[str, Any] = {
        "schema": OBS_SCHEMA, "processes": pids,
        "rounds": 0, "kscans_total": 0, "dist_evals_total": 0,
        "bytes_total": 0, "overflow_retries": 0, "jit_traces": 0,
        "round_s_total": 0.0, "max_b_global": 0,
        "utilization_last": None, "val_mse_last": None,
        "spans": {},
    }
    spans: Dict[str, Dict[str, Any]] = {}
    for e in events:
        pid = e.get("pid", 0)
        name = e.get("name")
        attrs = e.get("attrs", {}) or {}
        if e.get("ph") == "span":
            key = f"p{pid}:{name}"
            s = spans.setdefault(key, {"count": 0, "total_s": 0.0,
                                       "max_s": 0.0})
            d = float(e.get("dur_s", 0.0))
            s["count"] += 1
            s["total_s"] += d
            s["max_s"] = max(s["max_s"], d)
            continue
        if e.get("ph") != "event":
            continue
        if name == "round":
            rounds_by_pid[pid] = rounds_by_pid.get(pid, 0) + 1
            if pid != lead:
                continue
            summary["rounds"] += 1
            summary["kscans_total"] += int(attrs.get("kscans", 0))
            summary["dist_evals_total"] += int(attrs.get("dist_evals", 0))
            summary["bytes_total"] += int(attrs.get("bytes", 0))
            summary["round_s_total"] += float(attrs.get("dt_s", 0.0))
            summary["max_b_global"] = max(summary["max_b_global"],
                                          int(attrs.get("b_global", 0)))
            if attrs.get("utilization") is not None:
                summary["utilization_last"] = attrs["utilization"]
            if attrs.get("val_mse") is not None:
                summary["val_mse_last"] = attrs["val_mse"]
        elif name == "jit_trace" and pid == lead:
            summary["jit_traces"] += int(attrs.get("n", 1))
        elif name == "overflow_retry" and pid == lead:
            summary["overflow_retries"] += 1
    summary["rounds_by_process"] = rounds_by_pid
    summary["spans"] = {k: {**v, "mean_s": v["total_s"] / v["count"]}
                        for k, v in sorted(spans.items())}
    if summary["rounds"]:
        summary["round_s_mean"] = (summary["round_s_total"]
                                   / summary["rounds"])
        if summary["round_s_total"] > 0:
            summary["kscans_per_s"] = (summary["kscans_total"]
                                       / summary["round_s_total"])
    return summary
