"""repro.obs — structured tracing, unified metrics, roofline telemetry.

One observability plane for the whole stack:

  * `SpanTracer` / `read_events` / `summarize` (``trace.py``) —
    rotating JSONL span+event logs, per-process on multihost, with a
    merge reader and a ``python -m repro.obs`` CLI;
  * `MetricsRegistry` / `Counter` / `Gauge` / `Histogram`
    (``metrics.py``) — the registry generalized out of serve/metrics,
    with JSON and Prometheus-text exporters (`ServeMetrics` lives here
    now; ``repro.serve.metrics`` re-exports it);
  * `WorkModel` (``efficiency.py``) — per-round achieved k-scans/s and
    bytes/s against the ``roofline/analysis`` bound, exported as a live
    utilization gauge;
  * `FitObserver` (``sink.py``) — the concrete sink behind
    ``FitConfig(trace_dir=...)`` that the host loop's `ObsSink` seam
    writes through.

The package imports NO jax and NO numpy: attaching it to the host loop
cannot provoke a device sync (the hostsync auditor verifies this on
every backend), and the reader CLI runs anywhere Python does.
"""
from repro.obs.efficiency import FLOPS_PER_DIST, RoundWork, WorkModel
from repro.obs.metrics import (Counter, Gauge, Histogram, LatencyHistogram,
                               MetricsRegistry, ServeMetrics)
from repro.obs.sink import FitObserver
from repro.obs.trace import (OBS_SCHEMA, SpanTracer, read_events, summarize,
                             tail_events, trace_files)

__all__ = [
    "OBS_SCHEMA", "SpanTracer", "read_events", "summarize", "tail_events",
    "trace_files",
    "Counter", "Gauge", "Histogram", "LatencyHistogram", "MetricsRegistry",
    "ServeMetrics",
    "WorkModel", "RoundWork", "FLOPS_PER_DIST",
    "FitObserver",
]
