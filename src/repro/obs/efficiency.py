"""Roofline-aware efficiency: achieved work per round vs the bound.

The natural unit of work depends on the bound family, and
``RoundInfo.n_recomputed`` is counted in that family's unit:

  * ``unit="kscan"`` (bounds none / hamerly2): one point scanned
    against all ``k`` centroids — n_recomputed counts the points whose
    bounds failed and paid a full distance pass (the quantity Newling &
    Fleuret's bounds papers track as *the* scaling signal).
  * ``unit="pair"`` (bounds elkan / exponion): one (point, centroid)
    pair distance — these families prune WITHIN the row (elkan's
    per-pair bound test, exponion's annular candidate set), so pricing
    their counter as full k-scans would overstate the work by the very
    factor the family exists to save.

From ``(k, d)`` the costs are

  * FLOPs:      ``3 * d`` per pair distance (one fused mul-add +
                 compare per dim; a k-scan is ``k`` pairs);
  * HBM bytes:  ``4 * d``  per scanning point (stream the f32 row
                 once; the centroid block is k*d*4 ONCE per round, not
                 per point). In pair units the row stream is estimated
                 at one row per ``k`` pairs — exact when rows scan the
                 full k, an overestimate (conservative bound) when the
                 annulus is small.

`WorkModel` prices a round with ``roofline/analysis.roofline_terms``
(TPU v5e peak model) and turns the measured wall time into a
**utilization** fraction — achieved / attainable, given the round's own
arithmetic intensity. This is the live gauge the ROADMAP's "as fast as
the hardware allows" north star is measured by: a CPU fit reads a few
percent; the Pallas hot-path PR is expected to move it, and now has an
in-tree number to move.

Plain Python + the jax-free roofline module — safe to import anywhere,
including inside the transfer-guarded host loop.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.roofline.analysis import Roofline, roofline_terms

#: FLOPs per (point, centroid, dim): diff, square (fused mul-add), and
#: the running-min compare amortised across dims.
FLOPS_PER_DIST = 3.0

#: bytes per f32 element streamed from memory.
F32_BYTES = 4


#: bound family -> the unit its ``n_recomputed`` counter is measured in
BOUNDS_WORK_UNIT = {
    "none": "kscan",
    "hamerly2": "kscan",
    "elkan": "pair",
    "exponion": "pair",
}


@dataclasses.dataclass(frozen=True)
class RoundWork:
    """Priced work of one round: counts, the bound, and utilization."""
    kscans: int            # full-k-scan equivalents (exact in kscan
                           # units; ceil(pairs / k) in pair units)
    dist_evals: int        # (point, centroid) pair distance evals
    flops: float
    hbm_bytes: float
    bound_s: float         # roofline lower bound for this much work
    bottleneck: str        # "compute" | "memory" | "collective"
    dt_s: Optional[float] = None
    utilization: Optional[float] = None   # bound_s / dt_s, in [0, ~1]
    unit: str = "kscan"    # what n_recomputed counted ("kscan" | "pair")


class WorkModel:
    """Prices nested rounds for a fixed ``(k, d)`` problem shape.

    ``unit`` declares what the rounds' ``n_recomputed`` counts:
    "kscan" (none/hamerly2 — points times full k) or "pair"
    (elkan/exponion — individual pair distances). Use `for_bounds` to
    pick the unit from a fit's bound family.
    """

    def __init__(self, k: int, d: int, unit: str = "kscan"):
        if k < 1 or d < 1:
            raise ValueError(f"WorkModel needs k, d >= 1, got k={k} d={d}")
        if unit not in ("kscan", "pair"):
            raise ValueError(f"unknown work unit {unit!r}")
        self.k = int(k)
        self.d = int(d)
        self.unit = unit

    @classmethod
    def for_bounds(cls, k: int, d: int, bounds: str) -> "WorkModel":
        """The model whose unit matches a bound family's counter."""
        return cls(k, d, unit=BOUNDS_WORK_UNIT.get(bounds, "kscan"))

    def pair_evals(self, n_recomputed: int) -> int:
        """``n_recomputed`` converted to pair-distance evaluations."""
        n = max(0, int(n_recomputed))
        return n * self.k if self.unit == "kscan" else n

    def flops(self, n_recomputed: int) -> float:
        return FLOPS_PER_DIST * self.d * self.pair_evals(n_recomputed)

    def hbm_bytes(self, n_recomputed: int) -> float:
        # each scanning row streams once; the centroid block streams
        # once per round regardless of how many points scan it. In pair
        # units the row count is estimated at ceil(pairs / k) — exact
        # for full-row scans, conservative for small annuli.
        n = max(0, int(n_recomputed))
        rows = n if self.unit == "kscan" else -(-n // self.k)
        return F32_BYTES * (rows * self.d + self.k * self.d)

    def roofline(self, n_recomputed: int) -> Roofline:
        return roofline_terms(self.flops(n_recomputed),
                              self.hbm_bytes(n_recomputed), 0.0)

    def round_work(self, n_recomputed: int,
                   dt_s: Optional[float] = None) -> RoundWork:
        """Price a round; with ``dt_s`` also compute utilization."""
        n = max(0, int(n_recomputed))
        rl = self.roofline(n)
        bound = rl.step_time_s()
        util = None
        if dt_s is not None and dt_s > 0.0:
            util = bound / dt_s
        kscans = n if self.unit == "kscan" else -(-n // self.k)
        return RoundWork(kscans=kscans, dist_evals=self.pair_evals(n),
                         flops=rl.flops, hbm_bytes=rl.hbm_bytes,
                         bound_s=bound, bottleneck=rl.bottleneck,
                         dt_s=dt_s, utilization=util, unit=self.unit)
