"""Roofline-aware efficiency: achieved work per round vs the bound.

The unit of work is the **k-scan**: one point scanned against all ``k``
centroids. A nested round's k-scan count is exactly
``RoundInfo.n_recomputed`` — the points whose bounds failed and paid a
full distance pass (the quantity Newling & Fleuret's bounds papers
track as *the* scaling signal). From ``(k, d)`` a k-scan costs

  * FLOPs:      ``3 * d * k``   (one fused mul-add + compare per dim
                 per centroid, the standard distance-kernel count);
  * HBM bytes:  ``4 * d``       (stream the f32 row once; the centroid
                 block is k*d*4 ONCE per round, not per point).

`WorkModel` prices a round with ``roofline/analysis.roofline_terms``
(TPU v5e peak model) and turns the measured wall time into a
**utilization** fraction — achieved / attainable, given the round's own
arithmetic intensity. This is the live gauge the ROADMAP's "as fast as
the hardware allows" north star is measured by: a CPU fit reads a few
percent; the Pallas hot-path PR is expected to move it, and now has an
in-tree number to move.

Plain Python + the jax-free roofline module — safe to import anywhere,
including inside the transfer-guarded host loop.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.roofline.analysis import Roofline, roofline_terms

#: FLOPs per (point, centroid, dim): diff, square (fused mul-add), and
#: the running-min compare amortised across dims.
FLOPS_PER_DIST = 3.0

#: bytes per f32 element streamed from memory.
F32_BYTES = 4


@dataclasses.dataclass(frozen=True)
class RoundWork:
    """Priced work of one round: counts, the bound, and utilization."""
    kscans: int            # points that paid a full k-centroid scan
    dist_evals: int        # kscans * k (point-centroid distance evals)
    flops: float
    hbm_bytes: float
    bound_s: float         # roofline lower bound for this much work
    bottleneck: str        # "compute" | "memory" | "collective"
    dt_s: Optional[float] = None
    utilization: Optional[float] = None   # bound_s / dt_s, in [0, ~1]


class WorkModel:
    """Prices nested rounds for a fixed ``(k, d)`` problem shape."""

    def __init__(self, k: int, d: int):
        if k < 1 or d < 1:
            raise ValueError(f"WorkModel needs k, d >= 1, got k={k} d={d}")
        self.k = int(k)
        self.d = int(d)

    def flops(self, n_recomputed: int) -> float:
        return FLOPS_PER_DIST * self.d * self.k * n_recomputed

    def hbm_bytes(self, n_recomputed: int) -> float:
        # each recomputed row streams once; the centroid block streams
        # once per round regardless of how many points scan it
        return F32_BYTES * (n_recomputed * self.d + self.k * self.d)

    def roofline(self, n_recomputed: int) -> Roofline:
        return roofline_terms(self.flops(n_recomputed),
                              self.hbm_bytes(n_recomputed), 0.0)

    def round_work(self, n_recomputed: int,
                   dt_s: Optional[float] = None) -> RoundWork:
        """Price a round; with ``dt_s`` also compute utilization."""
        n = max(0, int(n_recomputed))
        rl = self.roofline(n)
        bound = rl.step_time_s()
        util = None
        if dt_s is not None and dt_s > 0.0:
            util = bound / dt_s
        return RoundWork(kscans=n, dist_evals=n * self.k,
                         flops=rl.flops, hbm_bytes=rl.hbm_bytes,
                         bound_s=bound, bottleneck=rl.bottleneck,
                         dt_s=dt_s, utilization=util)
