"""``python -m repro.obs`` — read traced fits from the command line.

  summarize DIR     one JSON summary of a trace directory (rounds,
                    k-scans, span timings, retraces, utilization)
  tail DIR [-n N]   the last N merged events, one JSON line each
  merge DIR [-o F]  merge per-process files into one time-ordered
                    JSONL stream (stdout or -o FILE)

Pure reader: imports no jax, touches no devices — safe on a login node
while the fit is still running (files are line-buffered JSONL; a
partial final line is a loud error only if the writer died mid-line).
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.trace import read_events, summarize, tail_events


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="summarize / tail / merge repro trace directories")
    sub = p.add_subparsers(dest="cmd", required=True)

    ps = sub.add_parser("summarize", help="aggregate a trace directory")
    ps.add_argument("trace_dir")

    pt = sub.add_parser("tail", help="last N merged events")
    pt.add_argument("trace_dir")
    pt.add_argument("-n", type=int, default=20, metavar="N")

    pm = sub.add_parser("merge",
                        help="merged time-ordered JSONL event stream")
    pm.add_argument("trace_dir")
    pm.add_argument("-o", "--out", default=None,
                    help="write to FILE instead of stdout")

    args = p.parse_args(argv)
    try:
        if args.cmd == "summarize":
            print(json.dumps(summarize(read_events(args.trace_dir)),
                             indent=2, sort_keys=True))
        elif args.cmd == "tail":
            for e in tail_events(args.trace_dir, args.n):
                print(json.dumps(e, separators=(",", ":")))
        elif args.cmd == "merge":
            events = read_events(args.trace_dir)
            out = (open(args.out, "w", encoding="utf-8")
                   if args.out else sys.stdout)
            try:
                for e in events:
                    out.write(json.dumps(e, separators=(",", ":")) + "\n")
            finally:
                if args.out:
                    out.close()
    except (FileNotFoundError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
