"""Unified metrics: counters, gauges, log-bucket histograms, one registry.

Generalized out of ``serve/metrics.py`` (which now re-exports from
here): the same `LatencyHistogram` the serving plane has always used is
the registry's `Histogram` with ``unit="s"``, and `ServeMetrics` keeps
its exact public surface and ``to_dict()`` schema while writing through
a `MetricsRegistry` underneath — so the fit loop, the data store and the
serving plane all export through the same two formats:

  * ``registry.to_dict()``      — JSON-safe nested dict;
  * ``registry.to_prometheus()``— Prometheus text exposition format
    (``# TYPE`` lines, cumulative histogram buckets, ``_sum``/``_count``).

Everything here is plain Python + ``math`` — no jax, no numpy — so
importing it can never provoke a device sync, and the obs plane stays
usable from reader CLIs on machines with no accelerator stack at all.
"""
from __future__ import annotations

import math
import re
import threading
from typing import Dict, Iterable, Optional, Tuple


class Counter:
    """Monotone counter."""

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: inc({n}) is negative")
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._value


class Gauge:
    """Last-write-wins instantaneous value."""

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Log-spaced histogram with percentile estimates from bucket edges.

    Buckets span ``lo`` upward at ``base``-factor spacing (defaults:
    1 µs at 1.12x — ~240 buckets to 100 s), so a percentile read is
    within one bucket factor (~12%) of the true value — fine for
    dashboards; benchmarks that assert on ratios keep their own exact
    sample arrays. ``unit`` suffixes the ``to_dict()`` keys: with the
    default ``unit="s"`` the export is byte-identical to the historical
    ``serve.metrics.LatencyHistogram`` (count / mean_s / p50_s / p99_s /
    max_s).
    """

    BASE = 1.12
    LO = 1e-6

    def __init__(self, name: str = "", help: str = "", *,
                 base: float = BASE, lo: float = LO, unit: str = "s"):
        if not (base > 1.0):
            raise ValueError(f"histogram base must be > 1, got {base}")
        if not (lo > 0.0):
            raise ValueError(f"histogram lo must be > 0, got {lo}")
        self.name = name
        self.help = help
        self.base = base
        self.lo = lo
        self.unit = unit
        self._lock = threading.Lock()
        self.counts: Dict[int, int] = {}
        self.n = 0
        self.total = 0.0
        self.max = 0.0

    def record(self, value: float) -> None:
        b = 0 if value <= self.lo else \
            int(math.log(value / self.lo, self.base)) + 1
        with self._lock:
            self.counts[b] = self.counts.get(b, 0) + 1
            self.n += 1
            self.total += value
            if value > self.max:
                self.max = value

    def percentile(self, q: float) -> float:
        """Upper edge of the bucket holding quantile ``q`` (0..1)."""
        with self._lock:
            if not self.n:
                return float("nan")
            rank = q * (self.n - 1)
            seen = 0
            for b in sorted(self.counts):
                seen += self.counts[b]
                if seen > rank:
                    return self.lo * self.base ** b
            return self.max

    def to_dict(self) -> dict:
        u = f"_{self.unit}" if self.unit else ""
        with self._lock:
            n, total, mx = self.n, self.total, self.max
        return {
            "count": n,
            f"mean{u}": total / n if n else float("nan"),
            f"p50{u}": self.percentile(0.50),
            f"p99{u}": self.percentile(0.99),
            f"max{u}": mx,
        }

    def bucket_edges(self) -> Iterable[Tuple[float, int]]:
        """(upper_edge, count) per OCCUPIED bucket, ascending."""
        with self._lock:
            items = sorted(self.counts.items())
        for b, c in items:
            yield self.lo * self.base ** b, c


#: historical name, kept as the canonical alias for latency use.
LatencyHistogram = Histogram


_NAME_SANITIZE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    out = _NAME_SANITIZE.sub("_", name)
    if not out or out[0].isdigit():
        out = "_" + out
    return out


def _prom_num(v: float) -> str:
    if isinstance(v, float):
        if math.isnan(v):
            return "NaN"
        if math.isinf(v):
            return "+Inf" if v > 0 else "-Inf"
    return repr(v) if isinstance(v, float) else str(v)


class MetricsRegistry:
    """Get-or-create registry of named metrics with two exporters.

    ``counter`` / ``gauge`` / ``histogram`` return the existing metric
    when the name is already registered (so instrumentation sites never
    need to coordinate creation) and raise on a type clash rather than
    silently mixing semantics under one name.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, object] = {}

    def _get_or_create(self, name: str, cls, factory):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = factory()
            elif not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r} is a {type(m).__name__}, not a "
                    f"{cls.__name__}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(name, Counter,
                                   lambda: Counter(name, help))

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(name, Gauge, lambda: Gauge(name, help))

    def histogram(self, name: str, help: str = "", *,
                  base: float = Histogram.BASE, lo: float = Histogram.LO,
                  unit: str = "s") -> Histogram:
        return self._get_or_create(
            name, Histogram,
            lambda: Histogram(name, help, base=base, lo=lo, unit=unit))

    def metrics(self) -> Dict[str, object]:
        with self._lock:
            return dict(self._metrics)

    def to_dict(self) -> dict:
        """JSON-safe export, grouped by metric kind."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for name, m in sorted(self.metrics().items()):
            if isinstance(m, Counter):
                out["counters"][name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name] = m.value
            else:
                out["histograms"][name] = m.to_dict()
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (one scrape payload)."""
        lines = []
        for name, m in sorted(self.metrics().items()):
            pname = _prom_name(name)
            if isinstance(m, Counter):
                if m.help:
                    lines.append(f"# HELP {pname} {m.help}")
                lines.append(f"# TYPE {pname} counter")
                lines.append(f"{pname} {m.value}")
            elif isinstance(m, Gauge):
                if m.help:
                    lines.append(f"# HELP {pname} {m.help}")
                lines.append(f"# TYPE {pname} gauge")
                lines.append(f"{pname} {_prom_num(m.value)}")
            elif isinstance(m, Histogram):
                if m.help:
                    lines.append(f"# HELP {pname} {m.help}")
                lines.append(f"# TYPE {pname} histogram")
                cum = 0
                for edge, count in m.bucket_edges():
                    cum += count
                    lines.append(
                        f'{pname}_bucket{{le="{_prom_num(edge)}"}} {cum}')
                with m._lock:
                    n, total = m.n, m.total
                lines.append(f'{pname}_bucket{{le="+Inf"}} {n}')
                lines.append(f"{pname}_sum {_prom_num(float(total))}")
                lines.append(f"{pname}_count {n}")
        return "\n".join(lines) + ("\n" if lines else "")


class ServeMetrics:
    """Counters + histograms for one `ClusterService`.

    Same public surface and byte-identical ``to_dict()`` schema as the
    historical ``serve.metrics.ServeMetrics``; the storage underneath is
    a `MetricsRegistry` (pass one in to co-export serving metrics with
    the rest of a process's obs plane, e.g. over ``to_prometheus()``).
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.registry = registry if registry is not None else \
            MetricsRegistry()
        self._lock = threading.Lock()
        r = self.registry
        self._predict_requests = r.counter(
            "serve_predict_requests", "predict() calls")
        self._predict_rows = r.counter(
            "serve_predict_rows", "rows labelled by predict()")
        self._refreshes = r.counter(
            "serve_refreshes", "background refresh cycles")
        self._refresh_rows = r.counter(
            "serve_refresh_rows", "rows folded in by refreshes")
        self._escalations = r.counter(
            "serve_refresh_escalations", "drift-triggered full re-fits")
        self._ingest_calls = r.counter(
            "serve_ingest_calls", "ingest() calls")
        self.predict_latency = r.histogram(
            "serve_predict_latency", "predict() wall seconds", unit="s")
        self.refresh_latency = r.histogram(
            "serve_refresh_latency", "refresh cycle wall seconds",
            unit="s")

    # historical attribute surface (plain ints before the registry port)

    @property
    def predict_requests(self) -> int:
        return self._predict_requests.value

    @property
    def predict_rows(self) -> int:
        return self._predict_rows.value

    @property
    def refreshes(self) -> int:
        return self._refreshes.value

    @property
    def refresh_rows(self) -> int:
        return self._refresh_rows.value

    @property
    def escalations(self) -> int:
        return self._escalations.value

    @property
    def ingest_calls(self) -> int:
        return self._ingest_calls.value

    # -- recording -----------------------------------------------------------

    def observe_predict(self, seconds: float, rows: int) -> None:
        with self._lock:
            self._predict_requests.inc()
            self._predict_rows.inc(rows)
            self.predict_latency.record(seconds)

    def observe_refresh(self, seconds: float, rows: int) -> None:
        with self._lock:
            self._refreshes.inc()
            self._refresh_rows.inc(rows)
            self.refresh_latency.record(seconds)

    def observe_escalation(self) -> None:
        with self._lock:
            self._escalations.inc()

    def observe_ingest(self) -> None:
        with self._lock:
            self._ingest_calls.inc()

    # -- export --------------------------------------------------------------

    def to_dict(self, *, queue_stats: Optional[dict] = None,
                snapshot=None) -> dict:
        """JSON-safe export; pass the queue/snapshot for their gauges."""
        with self._lock:
            out = {
                "predict": {"requests": self.predict_requests,
                            "rows": self.predict_rows,
                            "latency": self.predict_latency.to_dict()},
                "refresh": {"count": self.refreshes,
                            "rows": self.refresh_rows,
                            "escalations": self.escalations,
                            "latency": self.refresh_latency.to_dict()},
                "ingest_calls": self.ingest_calls,
            }
        if queue_stats is not None:
            out["queue"] = dict(queue_stats)
        if snapshot is not None:
            out["snapshot"] = {"version": snapshot.version,
                               "age_s": snapshot.age_s(),
                               "n_rounds": snapshot.n_rounds,
                               "batch_mse": snapshot.batch_mse}
        return out
