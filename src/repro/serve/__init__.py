"""repro.serve — streaming clustering service over `repro.api`.

    from repro.api import FitConfig, NestedKMeans
    from repro.serve import ClusterService

    svc = ClusterService(NestedKMeans(FitConfig(k=50)),
                         micro_batch=2048).start()
    svc.ingest(stream_rows)          # any size, even < k
    labels = svc.predict(X)          # lock-free, never blocked by refresh
    svc.stop()

The first package in the repo designed for concurrent callers: readers
answer from immutable versioned `CodebookSnapshot`s swapped atomically,
producers feed a bounded `IngestQueue` (block / drop-oldest / reservoir
backpressure, optional per-point dedup), and one background refresher
thread drains the queue through `NestedKMeans.partial_fit` — escalating
to a full checkpointed re-`fit` when the batch-MSE trend says the
codebook has drifted. `ServeMetrics.to_dict()` exports it all for the
bench harness.
"""
from repro.serve.metrics import LatencyHistogram, ServeMetrics
from repro.serve.queue import POLICIES, IngestQueue
from repro.serve.service import ClusterService
from repro.serve.snapshot import (CodebookSnapshot, SnapshotRef,
                                  codebook_checksum)

__all__ = [
    "ClusterService", "IngestQueue", "POLICIES",
    "CodebookSnapshot", "SnapshotRef", "codebook_checksum",
    "ServeMetrics", "LatencyHistogram",
]
