"""`ClusterService`: non-blocking streaming clustering over `partial_fit`.

Threading model — exactly one writer, lock-free readers:

  producers ──put──▶ IngestQueue ──get_batch──▶ refresher thread
                                                    │ partial_fit
                                                    ▼
         predict()/transform() ◀──atomic load── SnapshotRef.publish

The refresher drains micro-batches through the estimator's (thread-safe)
`partial_fit` and publishes a fresh immutable `CodebookSnapshot` after
every refresh. `predict` loads the current snapshot once and never takes
a lock, so codebook refreshes — even a full escalated re-`fit` — never
stall serving traffic; readers just keep answering from the previous
snapshot until the next one is swapped in.

Staleness / drift guardrails (Schwartzman, arXiv:2304.00419 motivates
watching the mini-batch objective trend): the service tracks the
batch-MSE of recent refreshes against the best level it has seen. When
the trend exceeds ``drift_factor`` for ``drift_window`` consecutive
refreshes, the codebook has drifted away from the stream and incremental
updates are no longer trusted: the service escalates to a full
(checkpointed, killable+resumable) `fit` over its retained history
reservoir — still on the refresher thread, with predict traffic served
from the last snapshot throughout.
"""
from __future__ import annotations

import threading
import time
from typing import Optional, Sequence

import numpy as np

from repro.api.estimator import NestedKMeans, NotFittedError
from repro.serve.metrics import ServeMetrics
from repro.serve.queue import IngestQueue
from repro.serve.snapshot import CodebookSnapshot, SnapshotRef


class ClusterService:
    """Serve `predict` while a background refresher folds the stream in.

    Args:
      estimator    a `NestedKMeans`; may be unfitted — the first refresh
                   happens once the queue has accumulated >= k rows (the
                   queue lifts the first-batch >= k constraint out of
                   producers, who may ingest any number of rows at a
                   time). ANY backend works: `partial_fit` routes each
                   micro-batch through the estimator's engine, so a
                   mesh/xl/multihost-backed codebook refreshes sharded
                   while predict keeps serving from snapshots.
      queue        optional pre-built `IngestQueue` (policy, bounds).
      micro_batch  refresh batch size the refresher aims for; steady
                   traffic drains in exactly this shape, so every
                   refresh reuses one jitted executable.
      flush_after_s  max time a sub-``micro_batch`` remainder may wait
                   before being flushed through a (shape-recompiling)
                   short refresh.
      drift_window / drift_factor   escalation trigger (see module doc).
      history_rows reservoir of past ingested rows retained for
                   escalation; 0 disables drift escalation.
    """

    def __init__(self, estimator: NestedKMeans, *,
                 queue: Optional[IngestQueue] = None,
                 micro_batch: int = 4096,
                 flush_after_s: float = 0.25,
                 drift_window: int = 8,
                 drift_factor: float = 2.0,
                 history_rows: int = 0,
                 seed: int = 0,
                 metrics: Optional[ServeMetrics] = None):
        if micro_batch < 1:
            raise ValueError(f"micro_batch must be >= 1, got {micro_batch}")
        self._km = estimator
        self.queue = queue or IngestQueue(
            max_rows=max(4 * micro_batch, estimator.config.k), seed=seed)
        self.metrics = metrics or ServeMetrics()
        self.micro_batch = micro_batch
        self.flush_after_s = flush_after_s
        self.drift_window = drift_window
        self.drift_factor = drift_factor
        self._ref = SnapshotRef()
        self._version = 0
        # serialises publishers: the refresher vs a user-thread
        # escalate(); readers never touch this lock
        self._pub_lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None
        # drift state
        self._mse_best: Optional[float] = None
        self._mse_bad_streak = 0
        # escalation history reservoir
        self._history_rows = history_rows
        self._history: list = []
        self._history_seen = 0
        self._rng = np.random.default_rng(seed)

        try:
            self._publish()              # estimator already fitted
        except NotFittedError:
            # only an UNFITTED estimator ever needs a first >= k batch;
            # a fitted one streams any size from the start
            if estimator.config.k > self.queue.max_rows:
                raise ValueError(
                    f"queue max_rows={self.queue.max_rows} can never "
                    f"accumulate the >= k={estimator.config.k} rows "
                    f"the first refresh needs") from None

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "ClusterService":
        if self._thread is not None:
            raise RuntimeError("service already started")
        self._thread = threading.Thread(target=self._refresh_loop,
                                        name="codebook-refresher",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self, *, drain: bool = True) -> None:
        """Stop ingesting, halt the refresher; optionally flush the tail.

        ``drain=True`` folds whatever the queue still holds through one
        last refresh (skipped if the codebook never initialised and the
        remainder is < k rows, or if the refresher died — diagnosing
        the death beats refreshing through possibly poisoned input).
        """
        self.queue.close()
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._last_error is not None:
            raise RuntimeError(
                "codebook refresher died") from self._last_error
        if drain:
            self._drain_remainder()

    def __enter__(self) -> "ClusterService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop(drain=exc == (None, None, None))

    # -- producer / reader API ----------------------------------------------

    def ingest(self, X, ids: Optional[Sequence] = None,
               timeout: Optional[float] = None) -> int:
        """Offer rows to the refresher; returns rows accepted."""
        self.metrics.observe_ingest()
        return self.queue.put(X, ids=ids, timeout=timeout)

    @property
    def snapshot(self) -> Optional[CodebookSnapshot]:
        """The current published snapshot (None before first refresh)."""
        return self._ref.load()

    def _require_snapshot(self) -> CodebookSnapshot:
        snap = self._ref.load()
        if snap is None:
            raise NotFittedError(
                "no codebook snapshot published yet — ingest >= k rows "
                "(or construct the service over a fitted estimator)")
        return snap

    def predict(self, X) -> np.ndarray:
        """Nearest-cell ids from the current snapshot. Never blocks on a
        refresh."""
        snap = self._require_snapshot()
        t0 = time.perf_counter()
        out = snap.predict(X)
        self.metrics.observe_predict(time.perf_counter() - t0,
                                     int(out.shape[0]))
        return out

    def transform(self, X) -> np.ndarray:
        snap = self._require_snapshot()
        t0 = time.perf_counter()
        out = snap.transform(X)
        self.metrics.observe_predict(time.perf_counter() - t0,
                                     int(out.shape[0]))
        return out

    def staleness_s(self) -> float:
        """Age of the snapshot readers are currently being served."""
        return self._require_snapshot().age_s()

    def export_metrics(self) -> dict:
        """JSON-safe metrics incl. queue depth + snapshot gauges."""
        return self.metrics.to_dict(queue_stats=self.queue.stats(),
                                    snapshot=self._ref.load())

    # -- the refresher -------------------------------------------------------

    def _fitted(self) -> bool:
        return self._ref.load() is not None

    def _refresh_loop(self) -> None:
        k = self._km.config.k
        while not self._stop.is_set():
            try:
                if not self._fitted():
                    # first refresh: must see >= k rows in one batch —
                    # sub-k contributions keep accumulating until then
                    batch = self.queue.get_batch(
                        max(self.micro_batch, k), min_rows=k,
                        timeout=self.flush_after_s, allow_short=False)
                else:
                    batch = self.queue.get_batch(
                        self.micro_batch, min_rows=self.micro_batch,
                        timeout=self.flush_after_s)
                if batch is None:
                    continue
                self._refresh(batch[0])
            except BaseException as e:     # noqa: BLE001 — keep serving
                self._last_error = e
                # wake + fail blocked producers loudly instead of
                # letting them wait on a refresher that no longer exists
                self.queue.close()
                return

    def _refresh(self, rows: np.ndarray) -> None:
        t0 = time.perf_counter()
        self._remember(rows)
        self._km.partial_fit(rows)
        self._publish()
        self.metrics.observe_refresh(time.perf_counter() - t0,
                                     int(rows.shape[0]))
        self._check_drift()

    def _publish(self) -> None:
        with self._pub_lock:
            exported = self._km.export_codebook()
            self._version += 1
            self._ref.publish(CodebookSnapshot.create(
                self._version, exported,
                kernel_backend=self._km.config.kernel_backend))

    def _drain_remainder(self) -> None:
        k = self._km.config.k
        while True:
            if not self._fitted():
                # the first batch must carry >= k rows in one piece;
                # allow_short=False leaves a sub-k tail buffered
                # instead of popping rows only to abandon them
                batch = self.queue.get_batch(
                    max(self.micro_batch, k), min_rows=k, timeout=0,
                    allow_short=False)
            else:
                batch = self.queue.get_batch(self.micro_batch, timeout=0)
            if batch is None:
                return
            self._refresh(batch[0])

    # -- drift / escalation --------------------------------------------------

    def _remember(self, rows: np.ndarray) -> None:
        """Reservoir-sample drained rows for a later escalated refit."""
        if not self._history_rows:
            return
        for r in rows:
            self._history_seen += 1
            if len(self._history) < self._history_rows:
                self._history.append(r)
            else:
                j = int(self._rng.integers(0, self._history_seen))
                if j < self._history_rows:
                    self._history[j] = r

    def _check_drift(self) -> None:
        mse = self._km.telemetry_[-1].batch_mse
        if mse is None or not np.isfinite(mse):
            return
        if self._mse_best is None or mse < self._mse_best:
            self._mse_best = mse
            self._mse_bad_streak = 0
            return
        if mse > self.drift_factor * self._mse_best:
            self._mse_bad_streak += 1
        else:
            self._mse_bad_streak = 0
        if (self._history_rows and
                self._mse_bad_streak >= self.drift_window):
            self.escalate()

    def escalate(self, *, resume: bool = False) -> None:
        """Full re-`fit` over the history reservoir, on the CALLING
        thread (the refresher, for automatic drift escalation).

        Readers keep answering from the last snapshot for the whole fit.
        With ``estimator.config.checkpoint`` set the refit checkpoints
        in-loop, so a killed escalation is itself resumable —
        ``resume=True`` continues such an interrupted refit instead of
        restarting it.
        """
        if not self._history:
            raise RuntimeError(
                "escalation needs history_rows > 0 (no retained data)")
        X = np.stack(self._history)
        self.metrics.observe_escalation()
        self._km.fit(X, resume=resume and
                     self._km.config.checkpoint is not None)
        self._publish()
        self._mse_best = None
        self._mse_bad_streak = 0
