"""Immutable, versioned codebook snapshots for lock-free readers.

A `CodebookSnapshot` is the unit of publication in `repro.serve`: the
refresher thread builds a NEW snapshot from the estimator's
`export_codebook()` and swaps it into a single reference
(`SnapshotRef.publish`). Reader threads load that reference once per
request — a plain attribute read, atomic under the interpreter — and
then work exclusively on the immutable snapshot they got. There is no
reader lock, and a reader can never observe a half-updated codebook:
either it sees the old snapshot or the new one, both internally
consistent (the `checksum` field lets tests and paranoid callers verify
exactly that).

The predict/transform closures are module-level jitted functions over
``(X, C)`` — NOT per-snapshot jits — so successive snapshots of the same
``(k, d)`` reuse one compiled executable and publishing stays O(copy).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


@partial(jax.jit, static_argnames=("backend",))
def _predict_jit(X, C, *, backend: Optional[str]):
    a, d1, _ = ops.assign_top2(X, C, backend=backend)
    return a, d1


@jax.jit
def _transform_jit(X, C):
    d2 = ref.pairwise_dist2(X, C)
    return jnp.sqrt(jnp.maximum(d2, 0.0))


def codebook_checksum(centroids: np.ndarray, counts: np.ndarray,
                      version: int) -> float:
    """Order-independent fingerprint binding (version, C, v) together.

    float64 sums are cheap, deterministic for a fixed array, and any
    torn mix of two snapshots' buffers changes the value with
    overwhelming probability (the version term keeps two refreshes that
    happen to share centroids distinguishable).
    """
    return float(np.sum(centroids, dtype=np.float64)
                 + 0.5 * np.sum(counts, dtype=np.float64)
                 + 1e-3 * version)


@dataclasses.dataclass(frozen=True)
class CodebookSnapshot:
    """One published codebook: centroids + counts + inference closures.

    ``version`` is assigned by the publisher and strictly increases;
    ``created_at`` is a `time.monotonic` stamp (age, not wall time).
    Arrays are read-only numpy views — mutating them raises.
    """
    version: int
    centroids: np.ndarray        # (k, d) float32, read-only
    counts: np.ndarray           # (k,)  float32, read-only
    n_rounds: int                # estimator rounds folded in so far
    batch_mse: float             # last refresh's batch MSE
    created_at: float            # time.monotonic at publication
    checksum: float              # codebook_checksum(C, v, version)
    kernel_backend: Optional[str] = None

    @classmethod
    def create(cls, version: int, exported: dict, *,
               kernel_backend: Optional[str] = None) -> "CodebookSnapshot":
        """Build from `NestedKMeans.export_codebook()` output."""
        C = np.ascontiguousarray(exported["centroids"], dtype=np.float32)
        v = np.ascontiguousarray(exported["counts"], dtype=np.float32)
        C.setflags(write=False)
        v.setflags(write=False)
        return cls(version=version, centroids=C, counts=v,
                   n_rounds=int(exported["n_rounds"]),
                   batch_mse=float(exported["batch_mse"]),
                   created_at=time.monotonic(),
                   checksum=codebook_checksum(C, v, version),
                   kernel_backend=kernel_backend)

    # -- shape ---------------------------------------------------------------

    @property
    def k(self) -> int:
        return self.centroids.shape[0]

    @property
    def dim(self) -> int:
        return self.centroids.shape[1]

    def age_s(self) -> float:
        return time.monotonic() - self.created_at

    def verify(self) -> bool:
        """Recompute the checksum — False would mean a torn read."""
        return self.checksum == codebook_checksum(
            self.centroids, self.counts, self.version)

    # -- inference (pure reads, safe from any thread) ------------------------

    def predict(self, X) -> np.ndarray:
        """Nearest-centroid index for each row of ``X``."""
        a, _ = _predict_jit(jnp.asarray(X), jnp.asarray(self.centroids),
                            backend=self.kernel_backend)
        return np.asarray(a)

    def predict_with_distance(self, X):
        """(labels, euclidean distance to the assigned centroid)."""
        a, d1 = _predict_jit(jnp.asarray(X), jnp.asarray(self.centroids),
                             backend=self.kernel_backend)
        return np.asarray(a), np.asarray(np.sqrt(np.maximum(d1, 0.0)))

    def transform(self, X) -> np.ndarray:
        """Euclidean distance of each row to every centroid: (n, k)."""
        return np.asarray(_transform_jit(jnp.asarray(X),
                                         jnp.asarray(self.centroids)))


class SnapshotRef:
    """The single mutable cell readers poll: atomic swap, monotone version.

    `publish` is called by ONE writer (the refresher); `load` by any
    number of readers. The version check on publish turns an accidental
    second writer into a loud error instead of a silently regressing
    snapshot stream.
    """

    def __init__(self):
        self._snap: Optional[CodebookSnapshot] = None

    def load(self) -> Optional[CodebookSnapshot]:
        return self._snap

    def publish(self, snap: CodebookSnapshot) -> None:
        cur = self._snap
        if cur is not None and snap.version <= cur.version:
            raise ValueError(
                f"snapshot version must be monotone: {snap.version} after "
                f"{cur.version} (two writers?)")
        self._snap = snap   # atomic reference swap
