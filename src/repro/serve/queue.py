"""Bounded ingest buffer between producers and the codebook refresher.

Producers hand in arbitrarily small row batches (`put`); the refresher
drains micro-batches (`get_batch`) sized for the jit cache. This is
where the estimator's "first `partial_fit` batch must have >= k rows"
constraint is lifted out of callers: the queue simply accumulates sub-k
contributions until the refresher's ``min_rows`` is reachable.

Backpressure policies when the buffer is full:
  block        `put` waits (optionally up to ``timeout``) for space —
               lossless, producers feel the pressure.
  drop-oldest  evict the oldest buffered rows to make room — bounded
               staleness, newest data always gets in.
  reservoir    uniform reservoir sample over every row EVER offered —
               the buffer converges to an unbiased sample of the stream.

Dedup: with ``dedup=True`` each `put` may carry per-row ids; a row whose
id was already accepted is dropped. This preserves the paper's nested
invariant — each sample contributes to the S/v statistics exactly once —
across at-least-once delivery from upstream producers.
"""
from __future__ import annotations

import threading
from collections import OrderedDict, deque
from typing import Optional, Sequence, Tuple

import numpy as np

POLICIES = ("block", "drop-oldest", "reservoir")


class IngestQueue:
    """Thread-safe bounded row buffer with pluggable backpressure.

    Rows are stored per point (id, row) so every policy — eviction,
    reservoir replacement, dedup — operates on single samples, matching
    the "one sample = one contribution" accounting of the nested
    algorithm.
    """

    def __init__(self, *, max_rows: int = 65536, policy: str = "block",
                 dedup: bool = False, seen_cap: int = 1 << 20,
                 seed: int = 0):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; expected one of "
                             f"{POLICIES}")
        if max_rows < 1:
            raise ValueError(f"max_rows must be >= 1, got {max_rows}")
        self.max_rows = max_rows
        self.policy = policy
        self.dedup = dedup
        self._seen: "OrderedDict[object, None]" = OrderedDict()
        self._seen_cap = seen_cap
        self._rng = np.random.default_rng(seed)
        self._buf: deque = deque()      # of (id_or_None, (d,) float32 row)
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._not_full = threading.Condition(self._lock)
        self._closed = False
        # counters (read via stats())
        self._offered = 0
        self._accepted = 0
        self._dropped_full = 0      # rejected: buffer full (block timeout)
        self._evicted = 0           # drop-oldest / reservoir replacement
        self._deduped = 0
        self._drained = 0
        self._peak_depth = 0        # high-water mark (obs manifests)

    # -- producer side -------------------------------------------------------

    def put(self, X, ids: Optional[Sequence] = None,
            timeout: Optional[float] = None) -> int:
        """Offer rows; returns how many were ACCEPTED into the buffer.

        ``ids`` (optional, required for dedup to bite) must be one
        hashable id per row. Under ``policy="block"`` a full buffer
        waits up to ``timeout`` seconds (forever if None) for space;
        rows that still don't fit are rejected and counted.
        """
        X = np.asarray(X, dtype=np.float32)
        if X.ndim == 1:
            X = X[None, :]
        if ids is not None and len(ids) != X.shape[0]:
            raise ValueError(f"{len(ids)} ids for {X.shape[0]} rows")
        accepted = 0
        with self._lock:
            if self._closed:
                raise RuntimeError("put() on a closed IngestQueue")
            for i in range(X.shape[0]):
                self._offered += 1
                pid = ids[i] if ids is not None else None
                if self.dedup and pid is not None and pid in self._seen:
                    self._deduped += 1
                    continue
                if not self._make_room(timeout):
                    self._dropped_full += 1
                    continue
                # ids are remembered only once the row is actually
                # accepted, so a rejected row may be redelivered later
                # without tripping the dedup
                if self.dedup and pid is not None:
                    self._remember(pid)
                self._buf.append((pid, X[i]))
                self._accepted += 1
                accepted += 1
            if len(self._buf) > self._peak_depth:
                self._peak_depth = len(self._buf)
            if accepted:
                self._not_empty.notify_all()
        return accepted

    def _remember(self, pid) -> None:
        self._seen[pid] = None
        if len(self._seen) > self._seen_cap:
            self._seen.popitem(last=False)

    def _evict(self, idx: int) -> None:
        """Drop a buffered row; forget its id so that an evicted sample
        can be REdelivered — it never reached the statistics, and 'each
        sample contributes exactly once' must not decay to 'zero times'.
        Lock held."""
        pid, _ = self._buf[idx]
        del self._buf[idx]
        if pid is not None:
            self._seen.pop(pid, None)
        self._evicted += 1

    def _make_room(self, timeout: Optional[float]) -> bool:
        """Ensure space for one row per the policy. Lock held."""
        if len(self._buf) < self.max_rows:
            return True
        if self.policy == "drop-oldest":
            self._evict(0)
            return True
        if self.policy == "reservoir":
            # classic reservoir over the _offered stream: keep the new
            # row with probability max_rows / offered, replacing a
            # uniformly random resident; otherwise drop it.
            j = int(self._rng.integers(0, self._offered))
            if j < self.max_rows:
                self._evict(j)
                return True
            return False
        # block
        ok = self._not_full.wait_for(
            lambda: self._closed or len(self._buf) < self.max_rows,
            timeout=timeout)
        if self._closed:
            # fail the BLOCKED producer loudly too — returning 0 here
            # would silently drop every batch after a refresher death
            raise RuntimeError(
                "IngestQueue closed while a producer was blocked on it")
        return bool(ok) and len(self._buf) < self.max_rows

    # -- consumer side -------------------------------------------------------

    def get_batch(self, max_rows: int, *, min_rows: int = 1,
                  timeout: Optional[float] = None, allow_short: bool = True
                  ) -> Optional[Tuple[np.ndarray, list]]:
        """Drain up to ``max_rows`` rows once >= ``min_rows`` are buffered.

        Waits up to ``timeout`` for ``min_rows``; on timeout returns
        whatever is buffered (possibly fewer than ``min_rows`` — a
        flush), or None if the buffer is empty. With
        ``allow_short=False`` a sub-``min_rows`` buffer is left in place
        and None is returned instead (used for the first refresh, which
        must see >= k rows). A closed queue drains whatever remains
        regardless of ``min_rows`` (unless ``allow_short=False``), then
        returns None. Result is ``(rows (n, d) float32, ids list)``.
        """
        with self._lock:
            self._not_empty.wait_for(
                lambda: self._closed or len(self._buf) >= min_rows,
                timeout=timeout)
            if not self._buf:
                return None
            if not allow_short and len(self._buf) < min_rows:
                return None
            n = min(max_rows, len(self._buf))
            items = [self._buf.popleft() for _ in range(n)]
            self._drained += n
            self._not_full.notify_all()
        ids = [pid for pid, _ in items]
        return np.stack([row for _, row in items]), ids

    @property
    def depth(self) -> int:
        with self._lock:
            return len(self._buf)

    @property
    def peak_depth(self) -> int:
        """Deepest the buffer has ever been. Deliberately NOT part of
        `stats()`: the stats dict is embedded verbatim in the serving
        JSON exports, whose schema stays byte-compatible; obs manifests
        read the high-water mark from here instead."""
        with self._lock:
            return self._peak_depth

    def close(self) -> None:
        """Reject future puts; wake every waiter. Buffered rows remain
        drainable."""
        with self._lock:
            self._closed = True
            self._not_empty.notify_all()
            self._not_full.notify_all()

    @property
    def closed(self) -> bool:
        return self._closed

    def stats(self) -> dict:
        with self._lock:
            return {
                "policy": self.policy, "max_rows": self.max_rows,
                "depth": len(self._buf), "offered": self._offered,
                "accepted": self._accepted,
                "dropped_full": self._dropped_full,
                "evicted": self._evicted, "deduped": self._deduped,
                "drained": self._drained,
            }
