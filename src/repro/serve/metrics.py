"""Serving metrics — now a thin shim over `repro.obs.metrics`.

`LatencyHistogram` and `ServeMetrics` were generalized into the shared
observability registry (`repro.obs.metrics`) so the serving plane, the
fit loop and the data store export through one metrics surface (JSON +
Prometheus text). The classes keep their historical names, public
attributes and byte-identical ``to_dict()`` schema; import from either
module — this one stays for existing callers.
"""
from repro.obs.metrics import LatencyHistogram, ServeMetrics

__all__ = ["LatencyHistogram", "ServeMetrics"]
