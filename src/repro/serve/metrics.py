"""Serving metrics: counters, gauges and latency histograms, JSON-safe.

`ServeMetrics` is the single sink `ClusterService` writes into; its
`to_dict()` is what `benchmarks/serve_latency.py` and operators scrape.
Everything is guarded by one small lock — the hot-path cost is two dict
updates per request, negligible next to a predict dispatch.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, Optional


class LatencyHistogram:
    """Log-spaced latency histogram (seconds) with percentile estimates.

    Buckets span 1 µs .. ~100 s at 1.12x spacing (~240 buckets), so a
    percentile read from bucket edges is within ~12% of the true value —
    fine for dashboards; benchmarks that assert on ratios keep their own
    exact sample arrays.
    """

    BASE = 1.12
    LO = 1e-6

    def __init__(self):
        self.counts: Dict[int, int] = {}
        self.n = 0
        self.total = 0.0
        self.max = 0.0

    def record(self, seconds: float) -> None:
        b = 0 if seconds <= self.LO else \
            int(math.log(seconds / self.LO, self.BASE)) + 1
        self.counts[b] = self.counts.get(b, 0) + 1
        self.n += 1
        self.total += seconds
        if seconds > self.max:
            self.max = seconds

    def percentile(self, q: float) -> float:
        """Upper edge of the bucket holding quantile ``q`` (0..1)."""
        if not self.n:
            return float("nan")
        rank = q * (self.n - 1)
        seen = 0
        for b in sorted(self.counts):
            seen += self.counts[b]
            if seen > rank:
                return self.LO * self.BASE ** b
        return self.max

    def to_dict(self) -> dict:
        return {
            "count": self.n,
            "mean_s": self.total / self.n if self.n else float("nan"),
            "p50_s": self.percentile(0.50),
            "p99_s": self.percentile(0.99),
            "max_s": self.max,
        }


class ServeMetrics:
    """Counters + histograms for one `ClusterService`."""

    def __init__(self):
        self._lock = threading.Lock()
        self.predict_requests = 0
        self.predict_rows = 0
        self.refreshes = 0
        self.refresh_rows = 0
        self.escalations = 0
        self.ingest_calls = 0
        self.predict_latency = LatencyHistogram()
        self.refresh_latency = LatencyHistogram()

    # -- recording -----------------------------------------------------------

    def observe_predict(self, seconds: float, rows: int) -> None:
        with self._lock:
            self.predict_requests += 1
            self.predict_rows += rows
            self.predict_latency.record(seconds)

    def observe_refresh(self, seconds: float, rows: int) -> None:
        with self._lock:
            self.refreshes += 1
            self.refresh_rows += rows
            self.refresh_latency.record(seconds)

    def observe_escalation(self) -> None:
        with self._lock:
            self.escalations += 1

    def observe_ingest(self) -> None:
        with self._lock:
            self.ingest_calls += 1

    # -- export --------------------------------------------------------------

    def to_dict(self, *, queue_stats: Optional[dict] = None,
                snapshot=None) -> dict:
        """JSON-safe export; pass the queue/snapshot for their gauges."""
        with self._lock:
            out = {
                "predict": {"requests": self.predict_requests,
                            "rows": self.predict_rows,
                            "latency": self.predict_latency.to_dict()},
                "refresh": {"count": self.refreshes,
                            "rows": self.refresh_rows,
                            "escalations": self.escalations,
                            "latency": self.refresh_latency.to_dict()},
                "ingest_calls": self.ingest_calls,
            }
        if queue_stats is not None:
            out["queue"] = dict(queue_stats)
        if snapshot is not None:
            out["snapshot"] = {"version": snapshot.version,
                               "age_s": snapshot.age_s(),
                               "n_rounds": snapshot.n_rounds,
                               "batch_mse": snapshot.batch_mse}
        return out
