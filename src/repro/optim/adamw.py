"""AdamW with f32 moments, decoupled weight decay and cosine schedule.

Optimizer states mirror the parameter sharding (FSDP'd over "data", TP
over "model"), so per-device optimizer memory is params/shards * 8 bytes.
Params may be stored bf16; the update math runs in f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    grad_clip: float = 1.0


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros),
                      count=jnp.zeros((), jnp.int32))


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) \
        * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(params, grads, state: AdamWState, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    count = state.count + 1
    lr = schedule(cfg, count)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def leaf(p, g, m, n):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        n = cfg.b2 * n + (1 - cfg.b2) * g * g
        upd = (m / b1c) / (jnp.sqrt(n / b2c) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (upd + cfg.weight_decay * pf * (p.ndim > 1))
        return pf.astype(p.dtype), m, n

    out = jax.tree.map(leaf, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, AdamWState(new_mu, new_nu, count), metrics
