"""int8 gradient compression with error feedback (cross-pod all-reduce).

At 512+ chips the cross-pod (DCI) gradient all-reduce is the slowest
collective; quantising to int8 with per-tensor scales cuts its volume 4x
(f32 accumulate) / 2x (bf16). Error feedback keeps the quantisation noise
unbiased over steps: the residual e_t is added back before the next
quantisation, so the *sum* of transmitted grads converges to the true sum
(Karimireddy et al., 2019).

Usage inside a shard_map'ed train step over the "pod" axis:

    q, scale, new_err = encode(g + err)
    q_sum = jax.lax.psum(q.astype(jnp.int32), "pod")
    g_hat = decode(q_sum, jax.lax.pmax(scale, "pod"))
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def encode(g: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(int8 quantised, per-tensor scale, error-feedback residual)."""
    gf = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(gf)) / 127.0
    safe = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(gf / safe), -127, 127).astype(jnp.int8)
    err = gf - q.astype(jnp.float32) * safe
    return q, scale, err


def decode(q_sum: jax.Array, scale: jax.Array) -> jax.Array:
    return q_sum.astype(jnp.float32) * jnp.maximum(scale, 1e-30)


def compressed_psum(tree: Any, err_tree: Any, axis: str):
    """Error-feedback int8 psum of a grad pytree over ``axis``.

    Returns (psum'ed f32 grads, new error-feedback tree). Scales use the
    axis-max so all shards decode identically.
    """
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        # shared scale (pmax: one scalar per tensor on the wire) so every
        # shard decodes the identical sum
        s = jax.lax.pmax(jnp.max(jnp.abs(gf)) / 127.0, axis)
        safe = jnp.maximum(s, 1e-30)
        q = jnp.clip(jnp.round(gf / safe), -127, 127)
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis)
        err = gf - q * safe
        return decode(q_sum, s), err

    out = jax.tree.map(one, tree, err_tree)
    g_new = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    e_new = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return g_new, e_new


def init_error(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
