"""Loop-driven centroid-sharded nested rounds (the kmeans_xl engine core).

`core.distributed.make_xl_round` is a stateless dense round: every call
re-assigns every point against fresh S/v. This module is the
nested-prefix counterpart that `repro.api.engines.xl.XLEngine` drives
through the shared host loop (`run_loop`): per-shard prefix batching
with ``n_valid`` masking, previously-seen-point delta S/v, Hamerly
bounding, growth, overflow retry and checkpointing — the full Alg. 6/9
schedule at centroid counts too large to replicate.

Layout (extends DESIGN.md §3 with a sharded model dimension):
  * points row-sharded over ``data_axes`` exactly like the mesh engine
    (`data.pipeline.nested_shard_layout` placement; the union of
    per-shard prefixes of size b is the global shuffle prefix), and
    REPLICATED over ``model_axis``.
  * cluster stats sharded over ``model_axis``: each model shard owns the
    (k_local, d) slice of C/S and the (k_local,) slices of v/sse/p,
    replicated over the data axes.
  * assignment: each model shard scans its k-slice with the fused top-2
    kernel; the per-shard (d1, d2, idx) triples are all-gathered over
    ``model_axis`` and tree-folded (`assign_top2_sharded`), so ``a``
    holds GLOBAL centroid indices and is replica-consistent over model.
  * delta S/v: the local batch rows are split into ``m`` chunks, one per
    model shard; each shard computes full-k partial sums over ITS row
    chunk only (an m-fold FLOP cut versus every shard summing every
    row), then one psum_scatter over ``model_axis`` simultaneously
    reduces the chunks and scatters the k-slices — each k-shard receives
    exactly its own slice — and a psum over ``data_axes`` completes the
    global delta. sse refreshes the same way.
  * the growth controller needs global per-cluster stats: the tiny
    (k_local,) vectors v/sse/p are all-gathered over ``model_axis`` and
    fed to `controller.should_grow` with the CONFIG's rho.

Bit-compatibility: on a 1-device model axis every collective here
collapses to the identity and each compute step mirrors
`rounds.nested_round` operation for operation, so an XLEngine fit on a
single-model-shard mesh reproduces the MeshEngine (and, at one data
shard, the LocalEngine) bit for bit — tested in scripts/smoke_xl.py.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import controller, rounds
from repro.core.distributed import (_fold_top2, assign_top2_sharded,
                                    per_shard_n_valid, shard_map_compat)
from repro.core.rounds import _euclid
from repro.core.state import (ClusterStats, ElkanBounds, KMeansState,
                              PointState, RoundInfo, centroid_update)
from repro.kernels import ops, ref
from repro.util import tracecount


# --------------------------------------------------------------------------
# sharded building blocks
# --------------------------------------------------------------------------

def _dist_to_assigned_sharded(x: jax.Array, C_local: jax.Array,
                              a: jax.Array, k_offset: jax.Array,
                              model_axis: str) -> jax.Array:
    """Exact euclidean distance of each point to its assigned centroid.

    The assigned centroid of a point may live on any model shard: each
    shard computes the distance for the points whose GLOBAL assignment
    falls in its k-slice and contributes zero for the rest, and one psum
    over ``model_axis`` assembles the full vector. Never-assigned points
    (``a == -1``) fall outside every slice and come back 0.0 — their
    lanes are dead (``seen`` gates every use downstream).
    """
    k_local = C_local.shape[0]
    a_loc = a - k_offset
    own = (a_loc >= 0) & (a_loc < k_local)
    Cg = C_local[jnp.clip(a_loc, 0, k_local - 1)]
    d2 = jnp.sum((x.astype(jnp.float32) - Cg) ** 2, axis=1)
    return _euclid(jax.lax.psum(jnp.where(own, d2, 0.0), model_axis))


def _half_intercentroid_sharded(C_local: jax.Array, model_axis: str,
                                m: int) -> jax.Array:
    """Hamerly's s(j)/2 for every GLOBAL j, from per-shard k-slices.

    Ring reduction: the (k_local, d) centroid blocks rotate around the
    model axis; at each of the m-1 steps every shard folds the visiting
    block's distances into its running per-centroid minimum. Peak
    memory stays O(k_local * d) — the full (k, d) codebook is never
    materialised on any device, which is the engine's reason to exist.
    (min is exact, so the partitioned fold equals a dense row min bit
    for bit.) The resulting (k_local,) vectors are all-gathered into
    the full (k,) threshold table every shard needs for the bound test.
    """
    k_local = C_local.shape[0]
    # own block first, self-distance masked by global index
    d2_own = ref.pairwise_dist2(C_local, C_local)
    eye = jnp.arange(k_local)
    d2_own = d2_own.at[eye, eye].set(jnp.inf)
    best = jnp.min(d2_own, axis=1)
    block = C_local
    perm = [(i, (i + 1) % m) for i in range(m)]
    for _ in range(m - 1):
        block = jax.lax.ppermute(block, model_axis, perm)
        best = jnp.minimum(best,
                           jnp.min(ref.pairwise_dist2(C_local, block),
                                   axis=1))
    s_half_loc = 0.5 * _euclid(best)
    return jax.lax.all_gather(s_half_loc, model_axis, tiled=True)  # (k,)


def _fold_min_idx(da, ia, db, ib):
    """Combine two (min, argmin) pairs; ties take the LOWER global index
    (associative + commutative, so the fold order cannot change the
    winner — and it matches `jnp.argmin`'s first-minimum rule on the
    unsharded row)."""
    take_b = (db < da) | ((db == da) & (ib < ia))
    return jnp.minimum(da, db), jnp.where(take_b, ib, ia)


def _assign_elkan_xl(x, state, a_prev, valid, *, k_local: int,
                     k_offset, model_axis: str):
    """`rounds._assign_elkan` with the k column sharded over the model
    axis: each shard holds the (b, k_local) slice of the lower-bound
    matrix l and of its C/p slices, runs the bound test locally, and the
    per-shard (min, argmin) candidates are tree-folded into the global
    assignment. Bit-compatible with the local path on a 1-shard model
    axis (every collective collapses to the identity)."""
    C_local = state.stats.C
    seen = a_prev >= 0
    l_dec = state.elkan.l[:x.shape[0]] - state.stats.p[None, :]  # eq. (4)
    d_a = _dist_to_assigned_sharded(x, C_local, a_prev, k_offset,
                                    model_axis)

    d_all = _euclid(ref.pairwise_dist2(x, C_local))     # (b, k_local)
    cols = k_offset + jnp.arange(k_local)[None, :]      # GLOBAL indices
    own = cols == a_prev[:, None]
    compute = (l_dec < d_a[:, None]) & ~own             # bound test
    compute = compute | ~seen[:, None]                  # new pts: all k
    if valid is not None:
        compute = compute & valid[:, None]

    l_new = jnp.where(compute, d_all, l_dec)
    cand = jnp.where(compute, d_all, jnp.inf)
    cand = jnp.where(own & seen[:, None], d_a[:, None], cand)
    # local winner carries its GLOBAL index; fold across model shards
    a_loc = (jnp.argmin(cand, axis=1).astype(jnp.int32) + k_offset)
    d_loc = jnp.min(cand, axis=1)
    ds = jax.lax.all_gather(d_loc, model_axis)          # (m, b)
    ias = jax.lax.all_gather(a_loc, model_axis)
    while ds.shape[0] > 1:
        half = ds.shape[0] // 2
        d, ia = _fold_min_idx(ds[:half], ias[:half],
                              ds[half:2 * half], ias[half:2 * half])
        if ds.shape[0] % 2:            # odd: carry the tail row over
            d = jnp.concatenate([d, ds[2 * half:]])
            ia = jnp.concatenate([ia, ias[2 * half:]])
        ds, ias = d, ia
    a_new, d_new = ias[0].astype(jnp.int32), ds[0]
    # pair computations across the whole k row + the per-point d_a's
    # (pads are never seen, so they add nothing to the second term)
    n_comp = jax.lax.psum(jnp.sum(compute.astype(jnp.int32)),
                          model_axis) \
        + jnp.sum(seen.astype(jnp.int32))
    return a_new, d_new, None, n_comp, jnp.asarray(False), l_new


def _exponion_geom_xl(C_local: jax.Array, model_axis: str, m: int,
                      k_offset: jax.Array):
    """Exponion geometry from per-shard k-slices: (B, s).

    ``B`` is this shard's (k, k_local) block of the inter-centroid
    distance matrix — rows are GLOBAL anchors, columns are the LOCAL
    centroids — assembled with the same ring ppermute as
    `_half_intercentroid_sharded`, so peak memory stays O(k^2 / m) per
    shard (never the full k x k table). ``s`` is the full (k,) nearest-
    other-centroid table (min over local columns, pmin over the model
    axis) — one structure feeds both the Hamerly threshold (s/2) and the
    annulus radius (2*d_a + s), exactly like the local `ExponionGeom`.

    The own diagonal of B is set to an EXACT zero: the anchor must
    always pass its own ``<= R`` test (the matmul distance form can
    leave rounding dust there), which is what makes the union of
    per-shard candidate sets a superset of the exact global annulus.
    """
    k_local = C_local.shape[0]
    k = k_local * m
    ax = jax.lax.axis_index(model_axis)
    cols = jnp.arange(k_local)
    own_rows = k_offset + cols

    B = jnp.zeros((k, k_local), jnp.float32)
    block = C_local
    perm = [(i, (i + 1) % m) for i in range(m)]
    for step in range(m):
        # after `step` rotations this shard holds the block that
        # originated on shard (ax - step) % m — its rows of B
        d_blk = _euclid(ref.pairwise_dist2(block, C_local))
        src = jax.lax.rem(ax - step + m, m)
        B = jax.lax.dynamic_update_slice(
            B, d_blk, (src * k_local, jnp.int32(0)))
        if step < m - 1:
            block = jax.lax.ppermute(block, model_axis, perm)

    B = B.at[own_rows, cols].set(0.0)
    masked = B.at[own_rows, cols].set(jnp.inf)
    s = jax.lax.pmin(jnp.min(masked, axis=1), model_axis)      # (k,)
    return B, s


def _assign_exponion_xl(x, state, a_prev, valid, *, k_local: int,
                        k_offset, model_axis: str, m: int,
                        use_shalf: bool):
    """`rounds._assign_exponion` with the centroids model-sharded.

    Each shard tests its local centroid columns against the EXACT
    annulus (``B[anchor] <= R``) — there is no global sorted neighbour
    table across shards, so each shard counts its block's members
    directly; the union of per-shard candidate sets is the exact
    annulus plus full rows for unseen points, the same set the local
    path's ``rank < m_exact`` mask selects, so labels, centroids, the
    stored lb AND the ``n_recomputed`` pair count are all bit-equal to
    the local/mesh exponion (and labels/centroids to ``bounds="none"``).

    Degenerate rings: when k/m leaves fewer than 4 local centroid
    columns, an annulus test cannot beat scanning the row it would need
    to test — fall back to the elkan-style full local scan for failing
    points and skip building B entirely (the s table still comes from
    the ring reduction for the Hamerly threshold).

    Per-shard (min, 2nd-min, global argmin) triples are tree-folded
    with `distributed._fold_top2` (lowest-global-index tie-break), so
    the fold matches `jnp.argmin` on the unsharded row.
    """
    C_local = state.stats.C
    k = k_local * m
    b = x.shape[0]
    seen = a_prev >= 0
    degenerate = k_local < 4

    p_max = jax.lax.pmax(jnp.max(state.stats.p), model_axis)
    d_a = _dist_to_assigned_sharded(x, C_local, a_prev, k_offset,
                                    model_axis)
    if degenerate:
        B = None
        s_half = _half_intercentroid_sharded(C_local, model_axis, m)
    else:
        B, s = _exponion_geom_xl(C_local, model_axis, m, k_offset)
        s_half = 0.5 * s
    settled, lb_dec, d_a, _n_need = rounds._hamerly_settled(
        x, state, a_prev, valid, use_shalf=use_shalf, p_max=p_max,
        d_assigned=d_a, s_half=s_half)
    needs = ~settled

    if degenerate:
        scan = jnp.broadcast_to(needs[:, None], (b, k_local))
    else:
        anchor = jnp.clip(a_prev, 0, k - 1)
        R = 2.0 * d_a + s[anchor]
        scan = needs[:, None] & ((B[anchor] <= R[:, None])
                                 | ~seen[:, None])
    if valid is not None:
        scan = scan & valid[:, None]

    # candidate top-2 in SQUARED space (the units `assign_top2_sharded`
    # folds in — identical values and tie-breaks), sqrt after the fold
    cand = jnp.where(scan, ref.pairwise_dist2(x, C_local), jnp.inf)
    a_col = jnp.argmin(cand, axis=1).astype(jnp.int32)
    a_loc = a_col + k_offset                         # GLOBAL index
    d1_loc = jnp.min(cand, axis=1)
    rest = jnp.where(jnp.arange(k_local)[None, :] == a_col[:, None],
                     jnp.inf, cand)
    d2_loc = jnp.min(rest, axis=1)

    d1s = jax.lax.all_gather(d1_loc, model_axis)     # (m, b)
    d2s = jax.lax.all_gather(d2_loc, model_axis)
    ias = jax.lax.all_gather(a_loc, model_axis)
    while d1s.shape[0] > 1:
        half = d1s.shape[0] // 2
        d1, d2, ia = _fold_top2(
            d1s[:half], d2s[:half], ias[:half],
            d1s[half:2 * half], d2s[half:2 * half], ias[half:2 * half])
        if d1s.shape[0] % 2:           # odd: carry the tail row over
            d1 = jnp.concatenate([d1, d1s[2 * half:]])
            d2 = jnp.concatenate([d2, d2s[2 * half:]])
            ia = jnp.concatenate([ia, ias[2 * half:]])
        d1s, d2s, ias = d1, d2, ia
    a_f, d1, d2 = (ias[0].astype(jnp.int32), _euclid(d1s[0]),
                   _euclid(d2s[0]))

    a_new = jnp.where(settled, a_prev, a_f)
    d_new = jnp.where(settled, d_a, d1)
    lb_new = jnp.where(settled, lb_dec, d2)
    # pair accounting (elkan convention): scanned pairs + the per-seen-
    # point d_a refresh (pads are never seen, so they add nothing)
    n_comp = jax.lax.psum(jnp.sum(scan.astype(jnp.int32)), model_axis) \
        + jnp.sum(seen.astype(jnp.int32))
    return a_new, d_new, lb_new, n_comp, jnp.asarray(False), None


def _chunk_rows(arrs, *, m: int, model_axis: str):
    """Deal the batch rows into ``m`` chunks, one per model shard.

    Rows are padded up to a multiple of ``m`` (the pad weights are zero,
    so padded rows contribute nothing) and model shard i takes chunk i.
    This is what makes the psum_scatter reduction below also an m-fold
    FLOP cut: every shard only cluster-sums b/m rows.
    """
    b = arrs[0].shape[0]
    chunk = -(-b // m)
    pad = m * chunk - b
    ax = jax.lax.axis_index(model_axis)
    out = []
    for a in arrs:
        if pad:
            widths = ((0, pad),) + ((0, 0),) * (a.ndim - 1)
            a = jnp.pad(a, widths)
        out.append(jax.lax.dynamic_slice_in_dim(a, ax * chunk, chunk, 0))
    return out


def _delta_sv_xl(x, a_prev, a_new, k: int, *, m: int, model_axis: str,
                 data_axes: Tuple[str, ...], plan):
    """The nested S/v delta, reduced straight onto the k-shards.

    Weights follow `rounds._delta_sv` (remove expired, add current;
    ``a_new == -1`` rows contribute nothing). Each model shard computes
    full-k partials over its row chunk, then psum_scatter over
    ``model_axis`` reduces the m chunks AND scatters the k-slices in one
    collective — each k-shard only ever materialises its own
    (k_local, d) slice of the delta — and psum over ``data_axes``
    completes the cross-shard sum.
    """
    seen = a_prev >= 0
    changed = seen & (a_new != a_prev)
    w_rm = jnp.where(changed, 1.0, 0.0).astype(jnp.float32)
    w_add = jnp.where((changed | ~seen) & (a_new >= 0), 1.0, 0.0) \
        .astype(jnp.float32)
    ap = jnp.clip(a_prev, 0, k - 1)
    an = jnp.clip(a_new, 0, k - 1)
    x_c, ap_c, an_c, w_rm_c, w_add_c = _chunk_rows(
        [x, ap, an, w_rm, w_add], m=m, model_axis=model_axis)
    S_rm, v_rm = ops.cluster_sum(x_c, ap_c, k, weights=w_rm_c, plan=plan)
    S_add, v_add = ops.cluster_sum(x_c, an_c, k, weights=w_add_c,
                                   plan=plan)
    dS = jax.lax.psum_scatter(S_add - S_rm, model_axis,
                              scatter_dimension=0, tiled=True)
    dv = jax.lax.psum_scatter(v_add - v_rm, model_axis,
                              scatter_dimension=0, tiled=True)
    if data_axes:
        dS, dv = jax.lax.psum((dS, dv), data_axes)
    return dS, dv


def _refresh_sse_xl(d_act, a_act, k: int, *, m: int, model_axis: str,
                    data_axes: Tuple[str, ...]):
    """sse(j) over active members for this shard's k-slice (exact)."""
    d_c, a_c = _chunk_rows([d_act, jnp.clip(a_act, 0, k - 1)], m=m,
                           model_axis=model_axis)
    sse_full = jax.ops.segment_sum(d_c * d_c, a_c, num_segments=k)
    sse = jax.lax.psum_scatter(sse_full, model_axis,
                               scatter_dimension=0, tiled=True)
    if data_axes:
        sse = jax.lax.psum(sse, data_axes)
    return sse


# --------------------------------------------------------------------------
# the nested XL round
# --------------------------------------------------------------------------

def xl_nested_round(X: jax.Array, state: KMeansState, *, b: int,
                    rho: float, bounds: str, m: int,
                    data_axes: Tuple[str, ...], model_axis: str,
                    capacity: Optional[int] = None, use_shalf: bool = True,
                    plan=None,
                    n_valid: Optional[jax.Array] = None
                    ) -> Tuple[KMeansState, RoundInfo]:
    """One gb/tb round over the per-shard prefix ``X[:b]``, k sharded.

    The centroid-sharded mirror of `rounds.nested_round`: ``state.stats``
    leaves hold this model shard's k-slice while ``state.points`` hold
    this data shard's rows (with GLOBAL assignment indices); ``b`` is the
    per-data-shard prefix and ``n_valid`` caps it against the shard's
    real rows exactly as in the mesh engine. Supports ``bounds`` "none"
    (gb: exhaustive sharded top-2 each round) and "hamerly2" (tb: exact-
    refresh upper bound + decayed second-nearest lower bound, with the
    threshold's s(j)/2 table built from all-gathered per-shard slices,
    and the same capacity compaction / overflow-retry contract as the
    local round), "elkan" (paper-faithful per-(i, j) bounds with
    the l matrix's k column sharded over the model axis —
    `_assign_elkan_xl`) and "exponion" (annular candidate pruning with
    the inter-centroid geometry built from ring-rotated centroid
    slices — `_assign_exponion_xl`). RoundInfo is replica-consistent on
    every device.
    """
    # trace accounting (see repro.util.tracecount): one count per jit
    # trace, keyed on the intended executable-cache statics (the plan is
    # constant per fit — a new static key, never a new bucket)
    tracecount.record("xl_nested_round", b=b, capacity=capacity, rho=rho,
                      bounds=bounds, plan=plan)
    k_local = state.stats.C.shape[0]
    k = k_local * m
    C_local = state.stats.C
    ax_m = jax.lax.axis_index(model_axis)
    k_offset = ax_m * k_local

    x = X[:b]
    a_prev = state.points.a[:b]
    valid = None if n_valid is None else jnp.arange(b) < n_valid

    def assign_fn(xs):
        return assign_top2_sharded(xs, C_local, model_axis=model_axis,
                                   k_offset=k_offset, plan=plan)

    # a pallas plan routes the dense shapes through the single-pass
    # fused kernel — but only at m == 1, where every model-axis
    # collective (psum_scatter, all_gather, pmax) is the identity and
    # the local k-slice IS the full centroid block. At m > 1 the
    # sharded per-op kernels below remain the dispatch target.
    fused = (plan is not None and plan.backend == "pallas" and m == 1
             and (bounds == "none"
                  or (bounds == "hamerly2"
                      and (capacity is None or capacity >= b))))
    fused_acc = None

    # the bound/compaction schedule itself lives ONLY in rounds.py; this
    # engine injects the four quantities that need model-axis
    # collectives, so the local and sharded paths cannot drift apart
    if fused:
        p_max = (jax.lax.pmax(jnp.max(state.stats.p), model_axis)
                 if bounds == "hamerly2" else None)
        a_new, d_new, lb2, n_rec, overflow, fused_acc = \
            rounds._fused_dense_round(x, state, a_prev, valid,
                                      bounds=bounds, use_shalf=use_shalf,
                                      plan=plan, p_max=p_max)
        l_new = None
    elif bounds == "none":
        a_new, d_new, lb2, n_rec, overflow, _ = rounds._assign_exhaustive(
            x, state, a_prev, valid, assign_top2_fn=assign_fn)
        l_new = None
    elif bounds == "hamerly2":
        p_max = jax.lax.pmax(jnp.max(state.stats.p), model_axis)
        d_a = _dist_to_assigned_sharded(x, C_local, a_prev, k_offset,
                                        model_axis)
        s_half = (_half_intercentroid_sharded(C_local, model_axis, m)
                  if use_shalf else None)
        a_new, d_new, lb2, n_rec, overflow, _ = rounds._assign_hamerly2(
            x, state, a_prev, valid, capacity=capacity,
            use_shalf=use_shalf, plan=plan,
            p_max=p_max, d_assigned=d_a, s_half=s_half,
            assign_top2_fn=assign_fn)
        l_new = None
    elif bounds == "elkan":
        a_new, d_new, lb2, n_rec, overflow, l_new = _assign_elkan_xl(
            x, state, a_prev, valid, k_local=k_local, k_offset=k_offset,
            model_axis=model_axis)
    elif bounds == "exponion":
        a_new, d_new, lb2, n_rec, overflow, l_new = _assign_exponion_xl(
            x, state, a_prev, valid, k_local=k_local, k_offset=k_offset,
            model_axis=model_axis, m=m, use_shalf=use_shalf)
    else:
        raise ValueError(f"unsupported bounds for the XL engine: "
                         f"{bounds!r} (use 'none', 'hamerly2', 'elkan' "
                         f"or 'exponion')")

    if valid is not None:
        a_new = jnp.where(valid, a_new, jnp.int32(-1))
        d_new = jnp.where(valid, d_new, 0.0)
        if lb2 is not None:
            lb2 = jnp.where(valid, lb2, 0.0)
        if l_new is not None:
            # pads keep a stable zero bound (their lanes are dead)
            l_new = jnp.where(valid[:, None], l_new, 0.0)

    if fused_acc is not None:
        # m == 1: the fused accumulators are already full-k; the model
        # psum_scatter would be the identity, only the data psum remains
        dS, dv, sse = fused_acc
        if data_axes:
            dS, dv, sse = jax.lax.psum((dS, dv, sse), data_axes)
    else:
        dS, dv = _delta_sv_xl(x, a_prev, a_new, k, m=m,
                              model_axis=model_axis, data_axes=data_axes,
                              plan=plan)
        sse = _refresh_sse_xl(d_new, a_new, k, m=m, model_axis=model_axis,
                              data_axes=data_axes)
    mse_num = jnp.sum(d_new * d_new)
    mse_den = (jnp.asarray(b, jnp.float32) if valid is None
               else jnp.sum(valid.astype(jnp.float32)))
    n_changed = jnp.sum(((a_prev >= 0) & (a_new != a_prev))
                        .astype(jnp.int32))
    n_active = (jnp.asarray(b, jnp.int32) if valid is None
                else jnp.sum(valid.astype(jnp.int32)))
    n_rec = n_rec.astype(jnp.int32)
    overflow = overflow.astype(jnp.int32)
    if data_axes:
        (mse_num, mse_den, n_changed, n_active, n_rec, overflow) = \
            jax.lax.psum((mse_num, mse_den, n_changed, n_active, n_rec,
                          overflow), data_axes)

    stats = dataclasses.replace(state.stats, S=state.stats.S + dS,
                                v=state.stats.v + dv, sse=sse)
    stats = centroid_update(stats)           # per-slice: C <- S/v, p

    # growth decision on the GLOBAL per-cluster stats (tiny vectors)
    v_all = jax.lax.all_gather(stats.v, model_axis, tiled=True)
    sse_all = jax.lax.all_gather(stats.sse, model_axis, tiled=True)
    p_all = jax.lax.all_gather(stats.p, model_axis, tiled=True)
    grow, r_med = controller.should_grow(sse_all, v_all, p_all, rho)

    points = dataclasses.replace(
        state.points,
        a=state.points.a.at[:b].set(a_new),
        d=state.points.d.at[:b].set(d_new))
    if lb2 is not None:
        points = dataclasses.replace(
            points, lb=points.lb.at[:b].set(lb2))
    elkan = state.elkan
    if l_new is not None:
        elkan = ElkanBounds(l=state.elkan.l.at[:b].set(l_new))

    info = RoundInfo(
        batch_mse=mse_num / jnp.maximum(mse_den, 1.0),
        n_changed=n_changed, n_recomputed=n_rec, n_active=n_active,
        overflow=overflow.astype(jnp.bool_), grow=grow, r_median=r_med,
        p_max=jax.lax.pmax(jnp.max(stats.p), model_axis))
    new_state = dataclasses.replace(state, stats=stats, points=points,
                                    elkan=elkan, round=state.round + 1)
    return new_state, info


# --------------------------------------------------------------------------
# shard_map factory + placement helpers
# --------------------------------------------------------------------------

def xl_state_specs(data_axes: Tuple[str, ...], model_axis: str,
                   *, elkan: bool = False):
    """PartitionSpec pytree of the XL engine's KMeansState layout.

    ``elkan``: include the per-(i, j) lower-bound matrix, rows sharded
    with the points and the k column sharded with the centroids.
    """
    row = P(data_axes)
    stats = ClusterStats(C=P(model_axis, None), S=P(model_axis, None),
                         v=P(model_axis), sse=P(model_axis),
                         p=P(model_axis))
    points = PointState(a=row, d=row, lb=row)
    el = ElkanBounds(l=P(data_axes, model_axis)) if elkan else None
    return KMeansState(stats=stats, points=points, elkan=el, round=P())


@functools.lru_cache(maxsize=None)
def make_xl_nested_round(mesh: Mesh, data_axes: Tuple[str, ...], *,
                         model_axis: str = "model", b_local: int,
                         rho: float, bounds: str = "hamerly2",
                         capacity: Optional[int] = None,
                         use_shalf: bool = True,
                         n_real: Optional[int] = None,
                         plan=None):
    """jit(shard_map(xl_nested_round)) for one (b_local, capacity) bucket.

    The centroid-sharded analogue of `distributed.make_sharded_round`:
    same static-key bucketing (the host loop compiles one executable per
    power-of-two (b, capacity) pair), same per-shard ``n_valid``
    derivation from ``n_real`` — plus the model-axis stat sharding.
    ``plan`` (the fit's resolved `KernelPlan`) joins the lru_cache key.
    """
    state_specs = xl_state_specs(data_axes, model_axis,
                                 elkan=(bounds == "elkan"))
    info_specs = RoundInfo(**{f.name: P() for f in
                              dataclasses.fields(RoundInfo)})
    sizes = tuple(int(mesh.shape[a]) for a in data_axes)
    n_shards = 1
    for s in sizes:
        n_shards *= s
    m = int(mesh.shape[model_axis])

    def fn(Xs, st):
        n_valid = per_shard_n_valid(data_axes, sizes, n_shards, n_real)
        return xl_nested_round(
            Xs, st, b=b_local, rho=rho, bounds=bounds, m=m,
            data_axes=data_axes, model_axis=model_axis, capacity=capacity,
            use_shalf=use_shalf, plan=plan, n_valid=n_valid)

    shardmapped = shard_map_compat(
        fn, mesh=mesh, in_specs=(P(data_axes, None), state_specs),
        out_specs=(state_specs, info_specs))
    return jax.jit(shardmapped)


def shard_state_xl(state: KMeansState, mesh: Mesh,
                   data_axes: Tuple[str, ...],
                   model_axis: str) -> KMeansState:
    """Place a host state onto the mesh with the XL engine's layout.

    The placement is derived from `xl_state_specs` — the ONE statement
    of the layout, shared with the shard_map in/out specs and the
    elastic-restore shardings (PartitionSpec is a pytree leaf, so the
    spec tree zips directly against the state).
    """
    return jax.tree.map(
        lambda x, spec: jax.device_put(x, NamedSharding(mesh, spec)),
        state, xl_state_specs(data_axes, model_axis))
