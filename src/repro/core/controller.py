"""The paper's dynamic batch-growth controller (Algorithm 6).

``sigma_C(j) = sqrt(sse(j) / (v(j) (v(j)-1)))`` estimates the stochastic
error of centroid j's position; ``p(j)`` is the progress it made last
round. The batch doubles when the median ratio sigma_C/p reaches rho:
noise dominates progress -> more data is needed (anti-overfitting); while
progress dominates noise the current batch is still informative
(anti-redundancy).

Degenerate cases, following the paper:
  * ``p(j) == 0``        -> ratio +inf (cluster j finished moving).
  * ``v(j) <= 1``        -> ratio +inf (no variance estimate possible; the
                             cluster obviously needs more data).
  * ``rho == inf``       -> doubles iff the median ratio is +inf, i.e. MORE
                             THAN HALF the centroids did not move (gb-inf /
                             tb-inf; see DESIGN.md on the Alg. 10/11 typo).

"median" is the lower median ``sorted[(k-1)//2]`` so that with k even and
exactly half the ratios infinite the batch does NOT double ("more than
half" is strict in the paper's prose).
"""
from __future__ import annotations

import jax.numpy as jnp


def sigma_c(sse: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Per-cluster stochastic-error estimate; +inf where v <= 1.

    The v(v-1) denominator is substituted (never clamped) where the
    estimate is undefined: for 1 < v < 2 the true denominator is in
    (0, 2), and clamping it up to 1.0 would silently DEFLATE sigma_C for
    exactly the small-count clusters the paper's balancing argument
    needs an honest noise estimate for. The `where`-inside-`where` keeps
    the v <= 1 lanes division-safe without distorting any live lane.
    """
    denom = v * (v - 1.0)
    safe = jnp.where(v > 1.0, denom, 1.0)
    return jnp.where(v > 1.0, jnp.sqrt(sse / safe), jnp.inf)


def growth_ratios(sse: jnp.ndarray, v: jnp.ndarray,
                  p: jnp.ndarray) -> jnp.ndarray:
    sig = sigma_c(sse, v)
    return jnp.where(p > 0.0, sig / jnp.maximum(p, 1e-30), jnp.inf)


def lower_median(x: jnp.ndarray) -> jnp.ndarray:
    k = x.shape[0]
    return jnp.sort(x)[(k - 1) // 2]


def should_grow(sse: jnp.ndarray, v: jnp.ndarray, p: jnp.ndarray,
                rho: float):
    """(grow: bool scalar, r: median ratio). rho may be float('inf')."""
    r = lower_median(growth_ratios(sse, v, p))
    # r >= inf is True only when r == inf -> the rho=inf degenerate case
    # (doubling iff >half the centroids are unchanged) falls out for free.
    return r >= rho, r
