"""One-round update functions for every algorithm in the paper.

Each function is pure (state in -> state out) and jit-friendly with the
batch size ``b`` (and recompute ``capacity``) STATIC — the host driver
compiles one executable per power-of-two bucket (see driver.py). All are
exact: bound tests only ever *skip provably-unnecessary* work, so every
algorithm produces identical assignments to its exhaustive counterpart.

Algorithms (paper naming):
  * ``lloyd_round``         Lloyd's algorithm (full batch, fresh means).
  * ``mb_round``            Sculley's Mini-Batch (App. A.1 S/v form).
  * ``mbf_round``           mb-f: Mini-Batch with contamination removal.
  * ``nested_round``        gb-rho / tb-rho family on the nested prefix:
      bounds="none"       -> gb (exhaustive assignment each round)
      bounds="hamerly2"   -> tb, TPU-native two-bound + capacity compaction
      bounds="elkan"      -> tb, paper-faithful per-(i,j) lower bounds
      bounds="exponion"   -> tb, Hamerly test + annular candidate pruning
                             (Newling & Fleuret) for large k
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import controller
from repro.core.state import (ElkanBounds, ExponionGeom, KMeansState,
                              RoundInfo, build_exponion_geom,
                              centroid_update)
from repro.kernels import ops, ref
from repro.kernels.plan import KernelPlan
from repro.util import tracecount


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def _euclid(d2: jax.Array) -> jax.Array:
    return jnp.sqrt(jnp.maximum(d2, 0.0))


def _dist_to_assigned(x: jax.Array, C: jax.Array, a: jax.Array) -> jax.Array:
    """Exact euclidean distance of each point to its assigned centroid."""
    Cg = C[jnp.clip(a, 0, C.shape[0] - 1)]
    return _euclid(jnp.sum((x.astype(jnp.float32) - Cg) ** 2, axis=1))


def _half_intercentroid(C: jax.Array) -> jax.Array:
    """Hamerly's s(j): half the distance to the nearest other centroid."""
    d2 = ref.pairwise_dist2(C, C)
    k = C.shape[0]
    d2 = d2.at[jnp.arange(k), jnp.arange(k)].set(jnp.inf)
    return 0.5 * _euclid(jnp.min(d2, axis=1))


def _segment_scalar(vals: jax.Array, ids: jax.Array, k: int,
                    weights: jax.Array | None = None) -> jax.Array:
    if weights is not None:
        vals = vals * weights
    return jax.ops.segment_sum(vals, jnp.clip(ids, 0, k - 1), num_segments=k)


def _delta_sv(x: jax.Array, a_prev: jax.Array, a_new: jax.Array, k: int,
              plan: Optional[KernelPlan]):
    """The mb-f / nested S,v delta: remove expired, add current. Returns
    (dS, dv) so callers can psum the delta across data shards before
    applying it to the replicated stats. Rows with ``a_new == -1``
    (structural pads masked out of the active prefix) contribute
    nothing."""
    seen = a_prev >= 0
    changed = seen & (a_new != a_prev)
    w_rm = jnp.where(changed, 1.0, 0.0).astype(jnp.float32)
    w_add = jnp.where((changed | ~seen) & (a_new >= 0), 1.0, 0.0) \
        .astype(jnp.float32)
    a_new = jnp.clip(a_new, 0, k - 1)
    S_rm, v_rm = ops.cluster_sum(x, jnp.clip(a_prev, 0, k - 1), k,
                                 weights=w_rm, plan=plan)
    S_add, v_add = ops.cluster_sum(x, a_new, k, weights=w_add, plan=plan)
    return S_add - S_rm, v_add - v_rm


def _refresh_sse(d_act: jax.Array, a_act: jax.Array, k: int) -> jax.Array:
    """sse(j) = sum of d(i)^2 over active members (exact, no staleness)."""
    return _segment_scalar(d_act * d_act, a_act, k)


# --------------------------------------------------------------------------
# Lloyd
# --------------------------------------------------------------------------

def lloyd_round(X: jax.Array, state: KMeansState, *,
                plan: Optional[KernelPlan] = None
                ) -> Tuple[KMeansState, RoundInfo]:
    """Exact Lloyd iteration: full reassignment + fresh means."""
    k = state.stats.C.shape[0]
    n = X.shape[0]
    a_new, d1sq, _ = ops.assign_top2(X, state.stats.C, plan=plan)
    d = _euclid(d1sq)
    S, v = ops.cluster_sum(X, a_new, k, plan=plan)
    sse = _refresh_sse(d, a_new, k)
    stats = centroid_update(dataclasses.replace(
        state.stats, S=S, v=v, sse=sse))
    n_changed = jnp.sum((a_new != state.points.a).astype(jnp.int32))
    points = dataclasses.replace(state.points, a=a_new, d=d)
    info = RoundInfo(
        batch_mse=jnp.mean(d * d), n_changed=n_changed,
        n_recomputed=jnp.asarray(n, jnp.int32),
        n_active=jnp.asarray(n, jnp.int32),
        overflow=jnp.asarray(False), grow=jnp.asarray(False),
        r_median=jnp.asarray(jnp.inf, jnp.float32),
        p_max=jnp.max(stats.p))
    new_state = dataclasses.replace(state, stats=stats, points=points,
                                    round=state.round + 1)
    return new_state, info


# --------------------------------------------------------------------------
# Mini-Batch (Sculley) and mb-f
# --------------------------------------------------------------------------

def mb_round(X: jax.Array, idx: jax.Array, state: KMeansState, *,
             fixed: bool, plan: Optional[KernelPlan] = None
             ) -> Tuple[KMeansState, RoundInfo]:
    """One round of mb (Alg. 8 S/v form) or mb-f (Alg. 4, fixed=True).

    ``idx``: (b,) indices of this round's batch (driver cycles through a
    reshuffled permutation, per the paper's footnote 1 — no within-batch
    duplicates).
    """
    k = state.stats.C.shape[0]
    b = idx.shape[0]
    x = X[idx]
    a_new, d1sq, _ = ops.assign_top2(x, state.stats.C, plan=plan)
    d = _euclid(d1sq)

    if fixed:
        a_prev = state.points.a[idx]
        dS, dv = _delta_sv(x, a_prev, a_new, k, plan)
        stats = dataclasses.replace(state.stats, S=state.stats.S + dS,
                                    v=state.stats.v + dv)
        n_changed = jnp.sum(((a_prev >= 0) & (a_new != a_prev))
                            .astype(jnp.int32))
    else:
        # plain mb never removes: every (re)assignment accumulates forever
        S_add, v_add = ops.cluster_sum(x, a_new, k, plan=plan)
        stats = dataclasses.replace(state.stats, S=state.stats.S + S_add,
                                    v=state.stats.v + v_add)
        n_changed = jnp.asarray(b, jnp.int32)

    stats = centroid_update(stats)
    points = dataclasses.replace(
        state.points,
        a=state.points.a.at[idx].set(a_new),
        d=state.points.d.at[idx].set(d))
    info = RoundInfo(
        batch_mse=jnp.mean(d * d), n_changed=n_changed,
        n_recomputed=jnp.asarray(b, jnp.int32),
        n_active=jnp.asarray(b, jnp.int32),
        overflow=jnp.asarray(False), grow=jnp.asarray(False),
        r_median=jnp.asarray(jnp.inf, jnp.float32),
        p_max=jnp.max(stats.p))
    new_state = dataclasses.replace(state, stats=stats, points=points,
                                    round=state.round + 1)
    return new_state, info


def mbf_round(X, idx, state, *, plan=None):
    return mb_round(X, idx, state, fixed=True, plan=plan)


# --------------------------------------------------------------------------
# Nested (grow-batch) rounds: gb-rho / tb-rho
# --------------------------------------------------------------------------

def _assign_exhaustive(x, state, a_prev, valid, *, plan=None,
                       assign_top2_fn=None):
    """bounds='none': full top-2 for every active point.

    ``assign_top2_fn`` lets the centroid-sharded engine inject its
    collective top-2 (`distributed_xl`); the schedule stays identical.
    """
    if assign_top2_fn is None:
        a_new, d1sq, d2sq = ops.assign_top2(x, state.stats.C, plan=plan)
    else:
        a_new, d1sq, d2sq = assign_top2_fn(x)
    n_rec = (jnp.asarray(x.shape[0], jnp.int32) if valid is None
             else jnp.sum(valid.astype(jnp.int32)))
    return (a_new, _euclid(d1sq), _euclid(d2sq), n_rec,
            jnp.asarray(False), None)


def _hamerly_settled(x, state, a_prev, valid, *, use_shalf: bool,
                     p_max=None, d_assigned=None, s_half=None):
    """The Hamerly bound DECISIONS for one round's active slice.

    Factored out of `_assign_hamerly2` so the fused pallas round can
    reuse the decisions verbatim: whatever backend executes the
    assignment, the settled mask — and therefore the bound/compaction
    schedule — comes from this one function.

    Returns (settled, lb_dec, d_a, n_need).
    """
    C = state.stats.C
    b = x.shape[0]
    seen = a_prev >= 0
    if p_max is None:
        p_max = jnp.max(state.stats.p)
    lb_dec = state.points.lb[:b] - p_max
    d_a = (_dist_to_assigned(x, C, a_prev) if d_assigned is None
           else d_assigned)
    thresh = lb_dec
    if use_shalf:
        if s_half is None:
            s_half = _half_intercentroid(C)
        thresh = jnp.maximum(lb_dec, s_half[jnp.clip(a_prev, 0, None)])
    settled = seen & (d_a <= thresh)
    if valid is not None:
        # masked structural pads never need recompute; their outputs are
        # forced back to the never-assigned sentinel by the caller
        settled = settled | ~valid
    n_need = jnp.sum((~settled).astype(jnp.int32))
    return settled, lb_dec, d_a, n_need


def _fused_dense_round(x, state, a_prev, valid, *, bounds: str,
                       use_shalf: bool, plan: KernelPlan,
                       p_max=None, d_assigned=None, s_half=None):
    """Route the dense assignment through `ops.fused_nested_round`.

    One pass over x replaces the assign / delta-S/v / sse triple-read
    when the plan picked pallas. Only the DENSE shapes go here (gb, or
    tb with capacity covering the batch); the compacted tb path keeps
    the separate kernels because its gather/scatter breaks the
    single-sweep structure. Returns the `_assign_*` 6-tuple plus the
    fused (dS, dv, sse) accumulators via the normally-unused last slot.
    """
    b = x.shape[0]
    if bounds == "hamerly2":
        settled, lb_dec, d_a, n_rec = _hamerly_settled(
            x, state, a_prev, valid, use_shalf=use_shalf, p_max=p_max,
            d_assigned=d_assigned, s_half=s_half)
    else:                               # bounds == "none"
        settled = jnp.zeros((b,), jnp.bool_)
        lb_dec = jnp.zeros((b,), jnp.float32)
        d_a = jnp.zeros((b,), jnp.float32)
        n_rec = (jnp.asarray(b, jnp.int32) if valid is None
                 else jnp.sum(valid.astype(jnp.int32)))
    vmask = jnp.ones((b,), jnp.bool_) if valid is None else valid
    a_new, d_new, lb_new, dS, dv, sse = ops.fused_nested_round(
        x, state.stats.C, a_prev, settled, d_a, lb_dec, vmask, plan=plan)
    return (a_new, d_new, lb_new, n_rec.astype(jnp.int32),
            jnp.asarray(False), (dS, dv, sse))


def _assign_hamerly2(x, state, a_prev, valid, *, capacity: Optional[int],
                     use_shalf: bool, plan=None,
                     p_max=None, d_assigned=None, s_half=None,
                     assign_top2_fn=None):
    """TPU-native bounding: exact-refresh upper + decayed 2nd-nearest lower.

    Per round (active slice, all vectorised):
      1. lb' = lb - max_j p(j)                       (bound decay, eq. 4)
      2. d_a = ||x - C(a)|| exact for every point    (O(b d), negligible)
      3. settled iff d_a <= max(lb', s_half(a))      (Hamerly tests)
      4. the unsettled are COMPACTED into a ``capacity``-sized buffer and
         only that buffer hits the fused top-2 kernel — tile-level work
         elimination (the TPU adaptation of Elkan's per-scalar skip).
    Settled points keep their assignment with an EXACT distance (step 2),
    so sse / sigma_C stay exact. If more than ``capacity`` points need
    recompute the round reports overflow=True and the driver retries the
    same input state with a larger bucket — exactness is never sacrificed.
    ``capacity=None`` recomputes everything (used for b == capacity).

    The optional ``p_max`` / ``d_assigned`` / ``s_half`` /
    ``assign_top2_fn`` overrides exist for the centroid-sharded engine
    (`core.distributed_xl`), which precomputes these four quantities
    with model-axis collectives — the bound/compaction schedule itself
    lives ONLY here, so the engines cannot drift apart.
    """
    C = state.stats.C
    b = x.shape[0]
    if assign_top2_fn is None:
        def assign_top2_fn(xs):
            return ops.assign_top2(xs, C, plan=plan)
    settled, lb_dec, d_a, n_need = _hamerly_settled(
        x, state, a_prev, valid, use_shalf=use_shalf, p_max=p_max,
        d_assigned=d_assigned, s_half=s_half)
    needs = ~settled

    if capacity is None or capacity >= b:
        a_full, d1sq, d2sq = assign_top2_fn(x)
        d1, d2 = _euclid(d1sq), _euclid(d2sq)
        a_new = jnp.where(settled, a_prev, a_full)
        d_new = jnp.where(settled, d_a, d1)
        lb_new = jnp.where(settled, lb_dec, d2)
        return a_new, d_new, lb_new, n_need, jnp.asarray(False), None

    # compact-and-batch: unsettled points first (stable sort keeps order)
    order = jnp.argsort(jnp.where(needs, 0, 1), stable=True)
    idx_cap = order[:capacity]
    x_cap = x[idx_cap]
    a_cap, d1sq, d2sq = assign_top2_fn(x_cap)
    d1, d2 = _euclid(d1sq), _euclid(d2sq)

    # settled points carry the decayed bound + exact distance ...
    a_new = jnp.where(settled, a_prev, a_prev)   # placeholder, fixed below
    d_new = jnp.where(settled, d_a, state.points.d[:b])
    lb_new = jnp.where(settled, lb_dec, state.points.lb[:b])
    # ... and the recomputed buffer is scattered back (exact for every
    # entry, including any settled points that padded the buffer).
    a_new = a_new.at[idx_cap].set(a_cap)
    d_new = d_new.at[idx_cap].set(d1)
    lb_new = lb_new.at[idx_cap].set(d2)
    overflow = n_need > capacity
    return a_new, d_new, lb_new, jnp.minimum(n_need, capacity), overflow, None


def _assign_elkan(x, state, a_prev, valid, *, b: int):
    """Paper-faithful tb bounds (supp. Alg. 9/11): l(i,j), one per pair.

    Vectorised semantics (see DESIGN.md): all bound-passing distances are
    computed at once instead of serially; the final assignment is
    identical, and ``n_recomputed`` counts the pair-distance computations
    a serial implementation would have had to do (upper bound thereof).

    ``valid`` masks structural pad rows (mesh engines, N % n_shards
    != 0): their compute mask is forced off, so they never touch a
    distance, and the caller resets their outputs to the sentinel.
    """
    C = state.stats.C
    k = C.shape[0]
    seen = a_prev >= 0
    l_dec = state.elkan.l[:b] - state.stats.p[None, :]      # eq. (4)
    d_a = _dist_to_assigned(x, C, a_prev)

    d_all = _euclid(ref.pairwise_dist2(x, C))               # (b, k)
    cols = jnp.arange(k)[None, :]
    own = cols == a_prev[:, None]
    compute = (l_dec < d_a[:, None]) & ~own                 # bound test
    compute = compute | ~seen[:, None]                      # new pts: all k
    if valid is not None:
        compute = compute & valid[:, None]

    l_new = jnp.where(compute, d_all, l_dec)
    cand = jnp.where(compute, d_all, jnp.inf)
    cand = jnp.where(own & seen[:, None], d_a[:, None], cand)
    a_new = jnp.argmin(cand, axis=1).astype(jnp.int32)
    d_new = jnp.min(cand, axis=1)
    # + the d_a's (pads are never seen, so they add nothing here)
    n_comp = jnp.sum(compute.astype(jnp.int32)) \
        + jnp.sum(seen.astype(jnp.int32))
    return a_new, d_new, None, n_comp, jnp.asarray(False), l_new


def _assign_exponion(x, state, a_prev, valid, *, use_shalf: bool,
                     geom: Optional[ExponionGeom] = None,
                     p_max=None, d_assigned=None):
    """Annular candidate pruning (Newling & Fleuret's exponion).

    Reuses the Hamerly settled test verbatim (`_hamerly_settled`, with
    ``s/2`` read off the geometry table instead of recomputed); a point
    that FAILS the test scans only the centroids inside the ball of
    radius R = 2*d(x, c_a) + s(a) around its anchor — never the full k.

    Exactness: any centroid c_j outside the ball has
    d(x, c_j) >= d(c_a, c_j) - d(x, c_a) > R - u = u + s(a), while the
    anchor (distance u) and the anchor's nearest neighbour (distance
    <= u + s(a) by the triangle inequality) are ALWAYS candidates — so
    the candidate argmin is the true argmin (every centroid tied at the
    minimum satisfies d(c_a, c_j) <= 2u <= R, preserving the
    lowest-index tie-break of ``bounds="none"``) and the candidate
    second-minimum is the exact second-nearest distance, making the
    stored ``lb`` as tight as an exhaustive scan's. Boundary ties
    (d(c_a, c_j) == R exactly) are INCLUDED via a ``<=`` ring count.

    The candidate mask is the EXACT annulus (``rank < m_exact``) — the
    same set the centroid-sharded variant tests per slice, so the
    ``n_recomputed`` accounting is identical across backends. All
    shapes depend only on (b, k) — the ring count is a traced VALUE —
    so the retrace auditor's one-trace-per-(b, capacity) bucket
    contract is untouched. (The paper's log2-bucketed ring layout is a
    cache-locality play for scalar CPUs; on a vectorised backend the
    mask is free and padding the ring only inflates the honest count.)

    ``n_recomputed`` counts actual pair-distance evaluations (annulus
    scans + the per-seen-point d_a refresh), the elkan convention — NOT
    hamerly2's k-scan unit. `repro.obs.efficiency.WorkModel` prices the
    two units accordingly.

    The optional ``geom`` / ``p_max`` / ``d_assigned`` overrides exist
    for the centroid-sharded engine (`core.distributed_xl`), which
    builds the geometry from all-gathered centroid slices; the annulus
    schedule itself lives ONLY here.
    """
    C = state.stats.C
    k = C.shape[0]
    b = x.shape[0]
    if geom is None:
        geom = build_exponion_geom(C)
    seen = a_prev >= 0
    settled, lb_dec, d_a, _n_need = _hamerly_settled(
        x, state, a_prev, valid, use_shalf=use_shalf, p_max=p_max,
        d_assigned=d_assigned, s_half=0.5 * geom.s)
    needs = ~settled

    anchor = jnp.clip(a_prev, 0, k - 1)
    R = 2.0 * d_a + geom.s[anchor]
    rows = geom.dist[anchor]                                # (b, k) sorted
    m_exact = jnp.sum((rows <= R[:, None]).astype(jnp.int32), axis=1)
    ring = geom.rank[anchor] < m_exact[:, None]             # (b, k)
    scan = needs[:, None] & (ring | ~seen[:, None])         # new pts: all k
    if valid is not None:
        scan = scan & valid[:, None]

    # candidate top-2 in SQUARED space (the exact values and tie-break
    # order of `ops.assign_top2` on the full row), sqrt at the boundary
    d2_all = ref.pairwise_dist2(x, C)                       # (b, k)
    cand = jnp.where(scan, d2_all, jnp.inf)
    a_f = jnp.argmin(cand, axis=1).astype(jnp.int32)
    d1sq = jnp.take_along_axis(cand, a_f[:, None], axis=1)[:, 0]
    rest = jnp.where(jnp.arange(k)[None, :] == a_f[:, None], jnp.inf, cand)
    d1, d2 = _euclid(d1sq), _euclid(jnp.min(rest, axis=1))

    a_new = jnp.where(settled, a_prev, a_f)
    d_new = jnp.where(settled, d_a, d1)
    lb_new = jnp.where(settled, lb_dec, d2)
    # pair-distance accounting (elkan convention): every scanned
    # (point, centroid) pair + the d_a refresh of every seen point
    # (pads are never seen, so they add nothing)
    n_comp = jnp.sum(scan.astype(jnp.int32)) \
        + jnp.sum(seen.astype(jnp.int32))
    return a_new, d_new, lb_new, n_comp, jnp.asarray(False), None


def nested_round(X: jax.Array, state: KMeansState, *, b: int,
                 rho: float, bounds: str = "hamerly2",
                 capacity: Optional[int] = None, use_shalf: bool = True,
                 plan: Optional[KernelPlan] = None,
                 data_axes: Tuple[str, ...] = (),
                 n_valid: Optional[jax.Array] = None
                 ) -> Tuple[KMeansState, RoundInfo]:
    """One gb/tb round over the nested prefix ``X[:b]`` (b STATIC).

    Covers Alg. 7 (gb-rho), Alg. 9 (tb-rho) and their rho=inf degenerate
    forms (Alg. 10/11): previously-seen points are reassigned with delta
    S/v corrections, unseen points ``a(i) == -1`` enter the batch, the
    centroids move to S/v, and the controller votes on doubling b.

    ``data_axes``: when called inside shard_map with points sharded over
    these mesh axes, X/state.points are per-shard slices (b is the LOCAL
    prefix; the global batch is the union of shard prefixes), the S/v/sse
    deltas are psum-reduced so the replicated stats — and therefore the
    growth decision — stay bit-identical on every shard.

    ``n_valid``: optional per-call scalar capping the REAL rows of this
    slice. Rows at positions >= n_valid are structural pads: they are
    held out of the assignment (``a == -1``), contribute nothing to
    S/v/sse/mse, and are excluded from n_active/n_changed. This is how a
    shard whose real-row count is not a multiple of the shard count caps
    ``b`` against its own real rows while b stays a shared static.

    ``plan``: the fit's resolved `KernelPlan` (hashable, constant per
    fit — engines pass it as a jit STATIC). A pallas plan routes the
    dense gb/tb shapes through the single-pass fused kernel.
    """
    # trace accounting: this body runs once per jit trace; the statics
    # here ARE the intended executable-cache key (repro.analysis.retrace
    # asserts the trace count never exceeds the pow2 bucket count — the
    # plan is constant for a fit, so it widens no bucket)
    tracecount.record("nested_round", b=b, capacity=capacity, rho=rho,
                      bounds=bounds, plan=plan)
    k = state.stats.C.shape[0]
    x = X[:b]
    a_prev = state.points.a[:b]
    valid = None if n_valid is None else jnp.arange(b) < n_valid

    fused = (plan is not None and plan.backend == "pallas"
             and (bounds == "none"
                  or (bounds == "hamerly2"
                      and (capacity is None or capacity >= b))))
    fused_acc = None
    if fused:
        a_new, d_new, lb2, n_rec, overflow, fused_acc = \
            _fused_dense_round(x, state, a_prev, valid, bounds=bounds,
                               use_shalf=use_shalf, plan=plan)
        l_new = None
    elif bounds == "none":
        a_new, d_new, lb2, n_rec, overflow, l_new = _assign_exhaustive(
            x, state, a_prev, valid, plan=plan)
    elif bounds == "hamerly2":
        a_new, d_new, lb2, n_rec, overflow, l_new = _assign_hamerly2(
            x, state, a_prev, valid, capacity=capacity,
            use_shalf=use_shalf, plan=plan)
    elif bounds == "elkan":
        a_new, d_new, lb2, n_rec, overflow, l_new = \
            _assign_elkan(x, state, a_prev, valid, b=b)
    elif bounds == "exponion":
        a_new, d_new, lb2, n_rec, overflow, l_new = _assign_exponion(
            x, state, a_prev, valid, use_shalf=use_shalf)
    else:
        raise ValueError(f"unknown bounds {bounds!r}")

    if valid is not None:
        # idempotent on the fused path (the kernel already masked)
        a_new = jnp.where(valid, a_new, jnp.int32(-1))
        d_new = jnp.where(valid, d_new, 0.0)
        if lb2 is not None:
            lb2 = jnp.where(valid, lb2, 0.0)
        if l_new is not None:
            # pads keep a stable zero bound (their lanes are dead)
            l_new = jnp.where(valid[:, None], l_new, 0.0)

    if fused_acc is not None:
        dS, dv, sse = fused_acc
    else:
        dS, dv = _delta_sv(x, a_prev, a_new, k, plan)
        sse = _refresh_sse(d_new, a_new, k)
    mse_num = jnp.sum(d_new * d_new)
    mse_den = (jnp.asarray(b, jnp.float32) if valid is None
               else jnp.sum(valid.astype(jnp.float32)))
    n_changed = jnp.sum(((a_prev >= 0) & (a_new != a_prev))
                        .astype(jnp.int32))
    n_active = (jnp.asarray(b, jnp.int32) if valid is None
                else jnp.sum(valid.astype(jnp.int32)))
    n_rec = n_rec.astype(jnp.int32)
    overflow = overflow.astype(jnp.int32)
    if data_axes:
        (dS, dv, sse, mse_num, mse_den, n_changed, n_active, n_rec,
         overflow) = jax.lax.psum(
            (dS, dv, sse, mse_num, mse_den, n_changed, n_active, n_rec,
             overflow), data_axes)

    stats = dataclasses.replace(state.stats, S=state.stats.S + dS,
                                v=state.stats.v + dv, sse=sse)
    stats = centroid_update(stats)

    grow, r_med = controller.should_grow(stats.sse, stats.v, stats.p, rho)

    points = dataclasses.replace(
        state.points,
        a=state.points.a.at[:b].set(a_new),
        d=state.points.d.at[:b].set(d_new))
    if lb2 is not None:
        points = dataclasses.replace(points,
                                     lb=points.lb.at[:b].set(lb2))
    elkan = state.elkan
    if l_new is not None:
        elkan = ElkanBounds(l=state.elkan.l.at[:b].set(l_new))

    info = RoundInfo(
        batch_mse=mse_num / jnp.maximum(mse_den, 1.0), n_changed=n_changed,
        n_recomputed=n_rec, n_active=n_active,
        overflow=overflow.astype(jnp.bool_), grow=grow, r_median=r_med,
        p_max=jnp.max(stats.p))
    new_state = dataclasses.replace(state, stats=stats, points=points,
                                    elkan=elkan, round=state.round + 1)
    return new_state, info
