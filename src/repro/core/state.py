"""K-means engine state pytrees.

All state is functional (jax pytrees); the host driver threads it through
jit'd round functions. Distances are EUCLIDEAN (not squared) everywhere in
the state — the paper's bound arithmetic (l -= p, sse = sum d^2) is written
in euclidean distances; kernels return squared distances and the round
functions take the sqrt once per recomputation.

Conventions:
  * ``a == -1``  -> point never assigned (not yet in the nested batch).
  * ``v`` is float32 so it can feed the MXU cluster-sum kernel directly.
  * per-point arrays are allocated at full N; only the active prefix
    ``[:b]`` is ever touched by the nested algorithms (b is a static arg of
    the bucketed round functions).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


def _pytree_dataclass(cls):
    cls = dataclasses.dataclass(frozen=True)(cls)
    fields = [f.name for f in dataclasses.fields(cls)]
    jax.tree_util.register_dataclass(cls, data_fields=fields, meta_fields=[])
    return cls


@_pytree_dataclass
class ClusterStats:
    """Per-cluster running statistics shared by every algorithm."""
    C: jax.Array          # (k, d) f32 centroids
    S: jax.Array          # (k, d) f32 cumulative/current sums
    v: jax.Array          # (k,)  f32 assignment counts
    sse: jax.Array        # (k,)  f32 sum of squared distances of members
    p: jax.Array          # (k,)  f32 distance moved in last update


@_pytree_dataclass
class PointState:
    """Per-point state. Arrays are full-N; nested algorithms touch [:b]."""
    a: jax.Array          # (N,) int32 last assignment, -1 = never assigned
    d: jax.Array          # (N,) f32 distance at last (re)computation
    lb: jax.Array         # (N,) f32 lower bound on 2nd-nearest distance
                          #      (hamerly2 + exponion paths; ignored by
                          #      others — exponion shares hamerly2's
                          #      layout exactly, so sharding specs,
                          #      checkpoints and elastic resume treat the
                          #      two families identically)


@_pytree_dataclass
class ElkanBounds:
    """Paper-faithful per-(i, j) lower bounds (tb-rho reference path)."""
    l: jax.Array          # (N, k) f32


@_pytree_dataclass
class ExponionGeom:
    """Per-round inter-centroid geometry for ``bounds="exponion"``.

    Newling & Fleuret's annular pruning ("Fast K-Means with Accurate
    Bounds"): a point that fails its Hamerly test only scans centroids
    inside the ball of radius R = 2*d(x, c_a) + s(a) around its anchor
    c_a, where s(a) is the distance from the anchor to its nearest other
    centroid. This structure is rebuilt once per round from the current
    centroids — amortised O(k^2) per ROUND instead of O(k) per failing
    POINT — and is ephemeral (never checkpointed; every leaf shape
    depends only on the static k, so it adds no jit trace keys).

      order  (k, k) int32  per-anchor centroid indices sorted by
                           distance; ``order[j, 0] == j`` (self first).
      dist   (k, k) f32    the matching sorted euclidean distances
                           (``dist[j, 0] == 0``).
      rank   (k, k) int32  inverse permutation: ``rank[j, c]`` is the
                           sorted position of centroid c around anchor
                           j — the annulus test is ``rank < m`` for a
                           per-point ring count m.
      s      (k,)   f32    distance to the nearest OTHER centroid
                           (``dist[:, 1]``); ``s/2`` doubles as
                           Hamerly's s(j)/2 table, so one structure
                           feeds both the settled test and the annulus.
    """
    order: jax.Array
    dist: jax.Array
    rank: jax.Array
    s: jax.Array


def build_exponion_geom(C: jax.Array) -> ExponionGeom:
    """Sorted inter-centroid neighbour table for the exponion family."""
    from repro.kernels import ref

    k = C.shape[0]
    d2 = ref.pairwise_dist2(C, C)
    # the self-distance must sort first with an exact 0 (the matmul form
    # can leave rounding dust on the diagonal)
    d2 = d2.at[jnp.arange(k), jnp.arange(k)].set(0.0)
    dist_full = jnp.sqrt(jnp.maximum(d2, 0.0))
    order = jnp.argsort(dist_full, axis=1).astype(jnp.int32)
    dist = jnp.take_along_axis(dist_full, order, axis=1)
    rank = jnp.argsort(order, axis=1).astype(jnp.int32)
    if k > 1:
        s = dist[:, 1]
    else:
        s = jnp.zeros((k,), jnp.float32)
    return ExponionGeom(order=order, dist=dist, rank=rank, s=s)


@_pytree_dataclass
class KMeansState:
    stats: ClusterStats
    points: PointState
    elkan: Optional[ElkanBounds]
    round: jax.Array      # () int32


def init_state(X: jax.Array, k: int, *, bounds: str = "hamerly2",
               init_idx: jax.Array | None = None) -> KMeansState:
    """Paper initialisation: the first k points of the (pre-shuffled) data.

    ``init_idx`` overrides with explicit centroid row indices.
    """
    n, d = X.shape
    if init_idx is None:
        C0 = X[:k].astype(jnp.float32)
    else:
        C0 = X[init_idx].astype(jnp.float32)
    stats = ClusterStats(
        C=C0,
        S=jnp.zeros((k, d), jnp.float32),
        v=jnp.zeros((k,), jnp.float32),
        sse=jnp.zeros((k,), jnp.float32),
        p=jnp.zeros((k,), jnp.float32),
    )
    points = PointState(
        a=jnp.full((n,), -1, jnp.int32),
        d=jnp.zeros((n,), jnp.float32),
        lb=jnp.zeros((n,), jnp.float32),
    )
    elkan = ElkanBounds(l=jnp.zeros((n, k), jnp.float32)) \
        if bounds == "elkan" else None
    return KMeansState(stats=stats, points=points, elkan=elkan,
                       round=jnp.zeros((), jnp.int32))


@_pytree_dataclass
class RoundInfo:
    """Telemetry returned by every round function (all scalars)."""
    batch_mse: jax.Array        # mean d^2 over the active batch
    n_changed: jax.Array        # assignments that changed this round
    n_recomputed: jax.Array     # points whose distances were recomputed
    n_active: jax.Array         # active batch size (real rows only)
    overflow: jax.Array         # bool: capacity < points needing recompute
    grow: jax.Array             # bool: controller voted to double b
    r_median: jax.Array         # median sigma_C/p ratio (controller stat)
    p_max: jax.Array            # max centroid movement after the update
                                # (psum-consistent; the host convergence
                                # check reads this instead of re-syncing
                                # state.stats.p every round)


def centroid_update(stats: ClusterStats) -> ClusterStats:
    """C <- S/v (empty clusters keep their previous centroid); p <- ||dC||."""
    safe_v = jnp.maximum(stats.v, 1.0)
    C_new = jnp.where((stats.v > 0.0)[:, None], stats.S / safe_v[:, None],
                      stats.C)
    p = jnp.sqrt(jnp.sum((C_new - stats.C) ** 2, axis=1))
    return dataclasses.replace(stats, C=C_new, p=p)


@partial(jax.jit, static_argnames=("chunk",))
def full_mse(X: jax.Array, C: jax.Array, *, chunk: int = 65536) -> jax.Array:
    """Validation-set MSE: mean squared distance to nearest centroid.

    Chunked over points so huge validation sets never materialise an
    (n, k) distance matrix.
    """
    from repro.kernels import ref

    n = X.shape[0]
    pad = -n % chunk
    Xp = jnp.pad(X, ((0, pad), (0, 0))) if pad else X
    Xc = Xp.reshape(-1, chunk, X.shape[1])

    def body(carry, xc):
        d2 = ref.pairwise_dist2(xc, C)
        dmin = jnp.min(d2, axis=1)
        return carry + jnp.sum(dmin[: chunk]), None

    # mask padded rows out of the final chunk
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), Xc)
    if pad:
        d2_last = ref.pairwise_dist2(Xp[n:], C)
        total = total - jnp.sum(jnp.min(d2_last, axis=1))
    return total / n
