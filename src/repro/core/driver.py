"""Host-side k-means driver: bucketed jit, growth schedule, telemetry.

Data-dependent batch doubling cannot live inside one jit program, so the
driver runs a host loop over *bucketed* compiled rounds:

  * the active batch size ``b`` takes values ``b0 * 2^i`` (capped at N) —
    at most log2(N/b0) distinct shapes ever compile;
  * the hamerly2 recompute ``capacity`` is likewise a power-of-two bucket,
    chosen from the previous round's recompute count with 2x slack. A
    round whose bound-test demand exceeds its capacity returns
    ``overflow=True`` and is RETRIED from the same input state with a
    doubled bucket — exactness is never traded for speed.

Each (b, capacity) bucket compiles once; jit's cache keys on the static
args. Uniform static shapes double as straggler mitigation at scale: every
shard executes the identical SPMD program.

Wall-clock telemetry excludes validation MSE evaluation, matching the
paper's experimental protocol (§4.3).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rounds
from repro.core.state import KMeansState, full_mse, init_state

_nested_jit = jax.jit(
    rounds.nested_round,
    static_argnames=("b", "rho", "bounds", "capacity", "use_shalf",
                     "kernel_backend", "data_axes"))
_mb_jit = jax.jit(rounds.mb_round,
                  static_argnames=("fixed", "kernel_backend"))
_lloyd_jit = jax.jit(rounds.lloyd_round, static_argnames=("kernel_backend",))

ALGORITHMS = ("lloyd", "lloyd-elkan", "mb", "sgd", "mbf", "gb", "tb")


@dataclasses.dataclass
class FitResult:
    C: np.ndarray
    state: KMeansState
    telemetry: List[Dict[str, Any]]
    converged: bool
    algorithm: str

    @property
    def final_mse(self) -> float:
        for rec in reversed(self.telemetry):
            if rec.get("val_mse") is not None:
                return rec["val_mse"]
        return float("nan")


def _next_pow2(x: int) -> int:
    return 1 << max(0, int(x - 1).bit_length())


def _cap_bucket(need: int, b: int, floor: int = 1024) -> Optional[int]:
    """Power-of-two capacity with 2x slack; None == recompute everything."""
    cap = max(floor, _next_pow2(2 * max(need, 1)))
    return None if cap >= b else cap


def fit(X,
        k: int,
        *,
        algorithm: str = "tb",
        rho: float = float("inf"),
        b0: int = 5000,
        bounds: str = "hamerly2",
        X_val=None,
        max_rounds: int = 10_000,
        time_budget_s: float = float("inf"),
        seed: int = 0,
        eval_every: int = 10,
        use_shalf: bool = True,
        kernel_backend: Optional[str] = None,
        shuffle: bool = True,
        converge_patience: int = 2,
        on_round: Optional[Callable[[Dict[str, Any]], None]] = None,
        init_C: Optional[np.ndarray] = None,
        ) -> FitResult:
    """Run one of the paper's algorithms to convergence / budget.

    algorithm: lloyd | mb | sgd (= mb, b=1) | mbf | gb | tb.
    gb == tb with bounds="none". rho=inf gives gb-inf / tb-inf.
    Initialisation is the paper's: first k points of the shuffled data.
    """
    if algorithm not in ALGORITHMS:
        raise ValueError(f"unknown algorithm {algorithm!r}")
    rng = np.random.default_rng(seed)
    X = np.asarray(X)
    N = X.shape[0]
    perm = rng.permutation(N) if shuffle else np.arange(N)
    Xd = jnp.asarray(X[perm])
    Xv = jnp.asarray(X_val) if X_val is not None else None

    if algorithm == "sgd":
        algorithm, b0 = "mb", 1
    if algorithm == "lloyd-elkan":
        # Elkan-accelerated Lloyd == the nested engine started at b0=N
        # with the paper-faithful per-(i,j) bounds (exact, tests assert
        # identical minima to plain lloyd).
        algorithm, b0, bounds, rho = "tb", N, "elkan", float("inf")
    if algorithm == "gb":
        algorithm, bounds = "tb", "none"
    if algorithm in ("lloyd", "mb", "mbf"):
        bounds = "none"

    state = init_state(Xd, k, bounds=bounds)
    if init_C is not None:       # warm start (checkpoint restart)
        import dataclasses as _dc
        state = _dc.replace(state, stats=_dc.replace(
            state.stats, C=jnp.asarray(init_C, jnp.float32)))
    telemetry: List[Dict[str, Any]] = []
    t_work = 0.0          # cumulative compute time, eval excluded
    b = min(b0, N)
    capacity: Optional[int] = None
    mb_pos = 0
    mb_perm = rng.permutation(N)
    quiet_rounds = 0
    converged = False

    def record(info, extra=None):
        nonlocal telemetry
        rec = dict(
            round=len(telemetry), t=t_work, b=int(info.n_active),
            batch_mse=float(info.batch_mse),
            n_changed=int(info.n_changed),
            n_recomputed=int(info.n_recomputed),
            grow=bool(info.grow), r_median=float(info.r_median),
            val_mse=None)
        if extra:
            rec.update(extra)
        do_eval = (Xv is not None
                   and (len(telemetry) % eval_every == 0))
        if do_eval:
            rec["val_mse"] = float(full_mse(Xv, state.stats.C))
        telemetry.append(rec)
        if on_round:
            on_round(rec)
        return rec

    for _ in range(max_rounds):
        if t_work >= time_budget_s:
            break
        t0 = time.perf_counter()

        if algorithm == "lloyd":
            new_state, info = _lloyd_jit(Xd, state,
                                         kernel_backend=kernel_backend)
        elif algorithm in ("mb", "mbf"):
            if mb_pos + b > N:
                mb_perm = rng.permutation(N)
                mb_pos = 0
            idx = jnp.asarray(mb_perm[mb_pos:mb_pos + b])
            mb_pos += b
            new_state, info = _mb_jit(Xd, idx, state,
                                      fixed=(algorithm == "mbf"),
                                      kernel_backend=kernel_backend)
        else:  # tb family (incl. gb via bounds="none")
            while True:
                new_state, info = _nested_jit(
                    Xd, state, b=b, rho=rho, bounds=bounds,
                    capacity=capacity, use_shalf=use_shalf,
                    kernel_backend=kernel_backend)
                if not bool(info.overflow):
                    break
                capacity = (None if capacity is None or 2 * capacity >= b
                            else 2 * capacity)

        jax.block_until_ready(new_state.stats.C)
        t_work += time.perf_counter() - t0
        state = new_state
        record(info)

        if algorithm in ("tb",):
            if bounds == "hamerly2":
                need = int(info.n_recomputed)
                if bool(info.grow) and b < N:
                    # a doubling adds b new points that always need a full
                    # pass — start the grown bucket with full recompute
                    capacity = None
                else:
                    capacity = _cap_bucket(need, b)
            if bool(info.grow):
                b = min(2 * b, N)
            if (int(info.n_active) >= N and int(info.n_changed) == 0
                    and float(jnp.max(state.stats.p)) == 0.0):
                quiet_rounds += 1
                if quiet_rounds >= converge_patience:
                    converged = True
                    break
            else:
                quiet_rounds = 0
        elif algorithm == "lloyd":
            if int(info.n_changed) == 0:
                converged = True
                break

    # final validation point
    if Xv is not None:
        telemetry.append(dict(
            round=len(telemetry), t=t_work, b=b, batch_mse=None,
            n_changed=0, n_recomputed=0, grow=False, r_median=None,
            val_mse=float(full_mse(Xv, state.stats.C))))

    return FitResult(C=np.asarray(state.stats.C), state=state,
                     telemetry=telemetry, converged=converged,
                     algorithm=algorithm)
