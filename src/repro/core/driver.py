"""DEPRECATED single-host driver — thin shim over `repro.api`.

The host loop that used to live here (bucketed jit, growth schedule,
capacity bucketing, overflow retry, telemetry) moved to
`repro.api.loop.run_loop` + `LocalEngine`, where it is shared with the
shard_map backend. `fit()` keeps the historical kwargs signature and the
dict-based telemetry records so existing callers and tests keep working;
new code should use `repro.api.NestedKMeans` / `repro.api.fit`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core.state import KMeansState

__all__ = ["ALGORITHMS", "FitResult", "fit"]

# intentional copy of repro.api.config.ALGORITHMS (a module-level import
# would create a core <-> api cycle); keep the two literals in sync —
# tests/test_api.py asserts they match
ALGORITHMS = ("lloyd", "lloyd-elkan", "mb", "sgd", "mbf", "gb", "tb")


@dataclasses.dataclass
class FitResult:
    """Legacy result record (telemetry as plain dicts)."""
    C: np.ndarray
    state: KMeansState
    telemetry: List[Dict[str, Any]]
    converged: bool
    algorithm: str

    @property
    def final_mse(self) -> float:
        for rec in reversed(self.telemetry):
            if rec.get("val_mse") is not None:
                return rec["val_mse"]
        return float("nan")

    @classmethod
    def from_outcome(cls, out: "repro.api.FitOutcome",  # noqa: F821
                     algorithm: Optional[str] = None) -> "FitResult":
        return cls(C=out.C, state=out.state,
                   telemetry=[t.to_dict() for t in out.telemetry],
                   converged=out.converged,
                   algorithm=algorithm or out.algorithm)


def _next_pow2(x: int) -> int:      # kept for backward import compat
    return 1 << max(0, int(x - 1).bit_length())


def _cap_bucket(need: int, b: int, floor: int = 1024) -> Optional[int]:
    """Power-of-two capacity with 2x slack; None == recompute everything."""
    cap = max(floor, _next_pow2(2 * max(need, 1)))
    return None if cap >= b else cap


def fit(X,
        k: int,
        *,
        algorithm: str = "tb",
        rho: float = float("inf"),
        b0: int = 5000,
        bounds: str = "hamerly2",
        X_val=None,
        max_rounds: int = 10_000,
        time_budget_s: float = float("inf"),
        seed: int = 0,
        eval_every: int = 10,
        use_shalf: bool = True,
        kernel_backend: Optional[str] = None,
        shuffle: bool = True,
        converge_patience: int = 2,
        on_round: Optional[Callable[[Dict[str, Any]], None]] = None,
        init_C: Optional[np.ndarray] = None,
        ) -> FitResult:
    """Deprecated: build a `repro.api.FitConfig` and use `NestedKMeans`.

    Runs one of the paper's algorithms to convergence / budget through
    the unified engine loop. Semantics (and centroids) are bit-identical
    to the pre-api driver.
    """
    from repro import api

    config = api.FitConfig(
        k=k, algorithm=algorithm, rho=rho, b0=b0, bounds=bounds,
        max_rounds=max_rounds, time_budget_s=time_budget_s, seed=seed,
        eval_every=eval_every, use_shalf=use_shalf,
        kernel_backend=kernel_backend, shuffle=shuffle,
        converge_patience=converge_patience)
    cb = (lambda rec: on_round(rec.to_dict())) if on_round else None
    out = api.fit(X, config, X_val=X_val, init_C=init_C, on_round=cb)
    return FitResult.from_outcome(out)
