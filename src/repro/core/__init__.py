"""Nested Mini-Batch K-Means — the paper's contribution as a JAX module.

Public API:
    fit(X, k, algorithm=..., rho=..., ...)      host driver (single host)
    fit_distributed(...)                        shard_map multi-device
    nested_round / mb_round / lloyd_round       pure per-round functions
    init_state / KMeansState / full_mse         state utilities
"""
from repro.core.controller import should_grow, sigma_c
from repro.core.driver import ALGORITHMS, FitResult, fit
from repro.core.rounds import lloyd_round, mb_round, mbf_round, nested_round
from repro.core.state import (ClusterStats, ElkanBounds, KMeansState,
                              PointState, RoundInfo, full_mse, init_state)

__all__ = [
    "fit", "FitResult", "ALGORITHMS",
    "nested_round", "mb_round", "mbf_round", "lloyd_round",
    "init_state", "full_mse", "should_grow", "sigma_c",
    "KMeansState", "ClusterStats", "PointState", "ElkanBounds", "RoundInfo",
]
