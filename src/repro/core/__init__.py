"""Nested Mini-Batch K-Means — the paper's contribution as a JAX module.

NOTE: the public surface moved to `repro.api` (FitConfig + NestedKMeans
+ Engine backends). What remains here:
    nested_round / mb_round / lloyd_round       pure per-round functions
    init_state / KMeansState / full_mse         state utilities
    fit(...) / fit_distributed(...)             deprecation shims
"""
from repro.core.controller import should_grow, sigma_c
from repro.core.driver import ALGORITHMS, FitResult, fit
from repro.core.rounds import lloyd_round, mb_round, mbf_round, nested_round
from repro.core.state import (ClusterStats, ElkanBounds, KMeansState,
                              PointState, RoundInfo, full_mse, init_state)

__all__ = [
    "fit", "FitResult", "ALGORITHMS",
    "nested_round", "mb_round", "mbf_round", "lloyd_round",
    "init_state", "full_mse", "should_grow", "sigma_c",
    "KMeansState", "ClusterStats", "PointState", "ElkanBounds", "RoundInfo",
]
