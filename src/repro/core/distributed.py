"""Distributed nested mini-batch k-means: shard_map over the device mesh.

Layout (see DESIGN.md §3):
  * points row-sharded over the data axes (("pod","data") on the
    production mesh). Each shard holds a contiguous slice of the
    PRE-SHUFFLED dataset, so the nested-prefix property holds per shard
    and the global batch of size b is the union of per-shard prefixes of
    size b / n_shards.
  * cluster stats replicated — S/v/sse deltas are psum'ed inside the round
    (rounds.nested_round(data_axes=...)), making the stats, centroids and
    the growth decision bit-identical on every shard with no host
    round-trip.
  * for very large k (kmeans_xl: k=4096) the centroids are additionally
    sharded over "model": each model shard scans its k-slice with the
    fused top-2 kernel, the per-shard (d1, d2, idx) triples — 3 floats per
    point, tiny — are all-gathered over "model" and folded, and the S
    delta is psum_scatter'ed back to the k-shards.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import controller, rounds
from repro.core.state import (ClusterStats, ElkanBounds, KMeansState,
                              PointState, RoundInfo)
from repro.kernels import ops


def shard_map_compat(fn, *, mesh, in_specs, out_specs):
    """`jax.shard_map` across jax versions (with replication checks off).

    jax >= 0.6 exposes `jax.shard_map(..., check_vma=...)`; 0.4.x only
    has `jax.experimental.shard_map.shard_map(..., check_rep=...)`.
    """
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:
            return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


# --------------------------------------------------------------------------
# replicated-centroid engine (paper-scale k)
# --------------------------------------------------------------------------

def per_shard_n_valid(data_axes: Tuple[str, ...], sizes: Tuple[int, ...],
                      n_shards: int, n_real: Optional[int]):
    """This shard's real-row cap, derived INSIDE shard_map (or None).

    Linear shard index, row-major over ``data_axes`` — matches the slice
    order of NamedSharding(mesh, P(data_axes, None)). The up-to-
    ``n_shards - 1`` tail rows of a non-divisible ``n_real`` land on the
    low shards (PR 2 fix); shared by every sharded round factory so the
    tail-row semantics cannot drift between engines.
    """
    if n_real is None:
        return None
    idx = jnp.zeros((), jnp.int32)
    for ax, sz in zip(data_axes, sizes):
        idx = idx * sz + jax.lax.axis_index(ax)
    base, rem = divmod(n_real, n_shards)
    return base + (idx < rem).astype(jnp.int32)


@functools.lru_cache(maxsize=None)
def make_sharded_round(mesh: Mesh, data_axes: Tuple[str, ...], *,
                       b_local: int, rho: float, bounds: str = "hamerly2",
                       capacity: Optional[int] = None,
                       use_shalf: bool = True,
                       n_real: Optional[int] = None,
                       plan=None):
    """jit(shard_map(nested_round)) for one (b_local, capacity) bucket.

    ``plan``: the fit's resolved `kernels.plan.KernelPlan` — hashable,
    so it participates in this factory's lru_cache key exactly like the
    bucket statics do.

    ``n_real``: global count of real (non-pad) rows. When it is not a
    multiple of the shard count, the interleaved placement leaves the
    low shards holding one real row in their last storage slot and the
    high shards holding a structural pad there. Each shard derives its
    own real-row count from its linear index over ``data_axes`` and caps
    the active prefix against it (nested_round's ``n_valid``), so every
    real row — and no pad — enters the final full batch. ``None`` keeps
    the unmasked round (divisible N, and the dry-run cost model).
    """
    row = P(data_axes)
    pt_specs = PointState(a=row, d=row, lb=row)
    st_specs = ClusterStats(C=P(), S=P(), v=P(), sse=P(), p=P())
    # the per-(i, j) elkan lower bounds row-shard with the points (the
    # k column stays replicated like C); the n_valid mask keeps pad
    # rows out of the bound updates exactly as for hamerly2
    elkan_specs = (ElkanBounds(l=P(data_axes, None))
                   if bounds == "elkan" else None)
    state_specs = KMeansState(stats=st_specs, points=pt_specs,
                              elkan=elkan_specs, round=P())
    info_specs = RoundInfo(**{f.name: P() for f in
                              dataclasses.fields(RoundInfo)})

    sizes = tuple(int(mesh.shape[a]) for a in data_axes)
    n_shards = 1
    for s in sizes:
        n_shards *= s

    def fn(Xs, st):
        n_valid = per_shard_n_valid(data_axes, sizes, n_shards, n_real)
        return rounds.nested_round(
            Xs, st, b=b_local, rho=rho, bounds=bounds, capacity=capacity,
            use_shalf=use_shalf, plan=plan, data_axes=data_axes,
            n_valid=n_valid)

    shardmapped = shard_map_compat(
        fn, mesh=mesh, in_specs=(P(data_axes, None), state_specs),
        out_specs=(state_specs, info_specs))
    return jax.jit(shardmapped)


def shard_state(state: KMeansState, mesh: Mesh,
                data_axes: Tuple[str, ...]) -> KMeansState:
    """Place a host state onto the mesh with the engine's layout."""
    row = NamedSharding(mesh, P(data_axes))
    rep = NamedSharding(mesh, P())
    points = PointState(
        a=jax.device_put(state.points.a, row),
        d=jax.device_put(state.points.d, row),
        lb=jax.device_put(state.points.lb, row))
    stats = jax.tree.map(lambda x: jax.device_put(x, rep), state.stats)
    return KMeansState(stats=stats, points=points, elkan=None,
                       round=jax.device_put(state.round, rep))


def fit_distributed(X,
                    k: int,
                    mesh: Mesh,
                    *,
                    data_axes: Tuple[str, ...] = ("data",),
                    rho: float = float("inf"),
                    b0: int = 5000,
                    bounds: str = "hamerly2",
                    max_rounds: int = 1000,
                    seed: int = 0,
                    use_shalf: bool = True,
                    on_round=None):
    """DEPRECATED multi-device entry point — shim over `repro.api`.

    The sharded host loop that used to live here is now
    `repro.api.loop.run_loop` driving a `MeshEngine`; this wrapper
    keeps the historical signature and dict telemetry. Semantically
    identical to driver.fit(algorithm="tb") modulo the batch
    composition: the global batch is the union of equal per-shard
    prefixes of one global shuffle (vs a global prefix). Both are
    uniform samples; tests check single-shard equivalence exactly.
    """
    from repro import api

    config = api.FitConfig(
        k=k, algorithm="tb", rho=rho, b0=b0, bounds=bounds,
        max_rounds=max_rounds, seed=seed, use_shalf=use_shalf,
        backend="mesh", data_axes=tuple(data_axes),
        # the pre-api sharded loop used a smaller capacity floor and
        # declared convergence on the first quiet round
        capacity_floor=256, converge_patience=1)
    cb = (lambda rec: on_round(rec.to_dict())) if on_round else None
    out = api.fit(X, config, mesh=mesh, on_round=cb)
    from repro.core.driver import FitResult
    return FitResult.from_outcome(out, algorithm=f"tb-dist[{bounds}]")


# --------------------------------------------------------------------------
# sharded-centroid assignment (k over "model") — the kmeans_xl path
# --------------------------------------------------------------------------

def _fold_top2(d1a, d2a, ia, d1b, d2b, ib):
    """Combine two (min, 2nd-min, argmin) triples.

    Ties on the minimum break toward the LOWER global index, which makes
    the fold associative and commutative: tree folds, sequential folds
    and a single-device argmin over the concatenated centroids all pick
    the same winner, so shard count never changes an assignment.
    """
    take_b = (d1b < d1a) | ((d1b == d1a) & (ib < ia))
    new1 = jnp.minimum(d1a, d1b)
    newi = jnp.where(take_b, ib, ia)
    new2 = jnp.minimum(jnp.maximum(d1a, d1b), jnp.minimum(d2a, d2b))
    return new1, new2, newi


def assign_top2_sharded(x: jax.Array, C_local: jax.Array, *,
                        model_axis: str, k_offset: jax.Array,
                        backend: Optional[str] = None, plan=None):
    """Top-2 nearest over model-sharded centroids (inside shard_map).

    Each model shard scans its (k_local, d) slice, then the per-shard
    triples are all-gathered over ``model_axis`` (3 floats + 1 int per
    point per shard) and combined with a log-depth tree fold — the
    per-point reduction is O(log m) fold steps instead of the m-1 of a
    sequential left fold.

    Returns ``(a, d1_sq, d2_sq)`` with GLOBAL centroid indices and
    SQUARED distances — the exact units of `ops.assign_top2`, so the two
    are drop-in interchangeable and callers take one sqrt at the
    boundary. Ties on the minimum distance resolve to the lowest global
    index, matching `jnp.argmin` on the unsharded centroid block.
    """
    a_loc, d1_loc, d2_loc = ops.assign_top2(x, C_local, plan=plan,
                                            backend=backend)
    a_glob = a_loc + k_offset
    d1s = jax.lax.all_gather(d1_loc, model_axis)       # (m, b)
    d2s = jax.lax.all_gather(d2_loc, model_axis)
    ias = jax.lax.all_gather(a_glob, model_axis)
    while d1s.shape[0] > 1:
        half = d1s.shape[0] // 2
        d1, d2, ia = _fold_top2(
            d1s[:half], d2s[:half], ias[:half],
            d1s[half:2 * half], d2s[half:2 * half], ias[half:2 * half])
        if d1s.shape[0] % 2:           # odd: carry the tail row over
            d1 = jnp.concatenate([d1, d1s[2 * half:]])
            d2 = jnp.concatenate([d2, d2s[2 * half:]])
            ia = jnp.concatenate([ia, ias[2 * half:]])
        d1s, d2s, ias = d1, d2, ia
    return ias[0].astype(jnp.int32), d1s[0], d2s[0]


def xl_round_body(x, C_local, S_local, v_local, *, k: int,
                  data_axes: Tuple[str, ...], model_axis: str,
                  rho: float = float("inf")):
    """One production round with points sharded over data axes AND
    centroids sharded over the model axis (the kmeans_xl dry-run step).

    Stateless-bounds variant (first / dense round): exhaustive sharded
    top-2, fresh S/v via one-hot-matmul cluster sums reduced with
    psum(data) + psum_scatter(model). Returns the updated local centroid
    shard and telemetry. All returned distances (``d``, ``d2``) are
    EUCLIDEAN — `assign_top2_sharded` returns squared distances and this
    boundary takes the sqrt for both, so the output tuple never mixes
    units. ``rho`` is the growth-controller threshold (Alg. 6);
    ``float("inf")`` keeps the gb-inf/tb-inf degenerate rule.

    The loop-driven nested-prefix variant (delta S/v, bounds, n_valid
    masking) lives in `repro.core.distributed_xl.xl_nested_round`.
    """
    k_local = C_local.shape[0]
    ax_idx = jax.lax.axis_index(model_axis)
    k_offset = ax_idx * k_local

    a, d1, d2sq = assign_top2_sharded(x, C_local, model_axis=model_axis,
                                      k_offset=k_offset)
    d = jnp.sqrt(jnp.maximum(d1, 0.0))
    d2 = jnp.sqrt(jnp.maximum(d2sq, 0.0))

    # full-k local partials. x (and the folded a) are REPLICATED over the
    # model axis, so each model shard's partial already agrees across the
    # axis: slice out the local k-range for free, then psum only the
    # (k_local, d) slice over the data axes — the data all-reduce volume
    # drops by the model-axis size versus reducing full k everywhere.
    S_full, v_full = ops.cluster_sum(x, a, k)
    sse_full = jax.ops.segment_sum(d * d, a, num_segments=k)
    S_new = jax.lax.dynamic_slice_in_dim(S_full, k_offset, k_local, 0)
    v_new = jax.lax.dynamic_slice_in_dim(v_full, k_offset, k_local, 0)
    sse_new = jax.lax.dynamic_slice_in_dim(sse_full, k_offset, k_local, 0)
    S_new = jax.lax.psum(S_new, data_axes)
    v_new = jax.lax.psum(v_new, data_axes)
    sse_new = jax.lax.psum(sse_new, data_axes)

    safe_v = jnp.maximum(v_new, 1.0)
    C_new = jnp.where((v_new > 0.0)[:, None], S_new / safe_v[:, None],
                      C_local)
    p_local = jnp.sqrt(jnp.sum((C_new - C_local) ** 2, axis=1))
    # growth controller needs global per-cluster stats (tiny vectors)
    p_all = jax.lax.all_gather(p_local, model_axis, tiled=True)
    v_all = jax.lax.all_gather(v_new, model_axis, tiled=True)
    sse_all = jax.lax.all_gather(sse_new, model_axis, tiled=True)
    grow, r_med = controller.should_grow(sse_all, v_all, p_all, rho=rho)
    mse = jax.lax.psum(jnp.sum(d * d), data_axes) / \
        jax.lax.psum(jnp.asarray(x.shape[0], jnp.float32), data_axes)
    return C_new, S_new, v_new, a, d, d2, grow, r_med, mse


def dp_round_body(x, C, *, data_axes: Tuple[str, ...],
                  rho: float = float("inf"), use_pallas: bool = False):
    """Optimized production round: pure data parallelism, C replicated.

    For k up to ~10^4 the centroid block is VMEM-resident (k=4096 x
    d=1024 bf16 = 8 MiB), so sharding points over EVERY mesh axis and
    replicating C beats centroid sharding: assignment intensity is 2k
    FLOPs per 4 bytes of x — firmly compute-bound — and the only
    collective is the (k, d) psum of S/v/sse. On TPU the whole round is
    the fused single-X-pass Pallas kernel (kernels/fused_round.py).
    """
    if use_pallas:
        from repro.kernels.fused_round import fused_round_pallas
        a, d1, d2, S_loc, v_loc, sse_loc = fused_round_pallas(
            x, C, interpret=jax.default_backend() != "tpu")
    else:
        a, d1sq, _ = ops.assign_top2(x, C)
        d1 = d1sq
        S_loc, v_loc = ops.cluster_sum(x, a, C.shape[0])
        sse_loc = jax.ops.segment_sum(d1, a, num_segments=C.shape[0])
    d = jnp.sqrt(jnp.maximum(d1, 0.0))
    S = jax.lax.psum(S_loc, data_axes)
    v = jax.lax.psum(v_loc, data_axes)
    sse = jax.lax.psum(sse_loc, data_axes)
    safe_v = jnp.maximum(v, 1.0)
    C_new = jnp.where((v > 0.0)[:, None], S / safe_v[:, None], C)
    p = jnp.sqrt(jnp.sum((C_new - C) ** 2, axis=1))
    grow, r_med = controller.should_grow(sse, v, p, rho=rho)
    mse = jax.lax.psum(jnp.sum(d * d), data_axes) / jax.lax.psum(
        jnp.asarray(x.shape[0], jnp.float32), data_axes)
    return C_new, S, v, a, d, grow, r_med, mse


@functools.lru_cache(maxsize=None)
def make_dp_round(mesh: Mesh, *, rho: float = float("inf"),
                  use_pallas: bool = False):
    """jit(shard_map) data-parallel round over ALL mesh axes.

    ``rho`` is a static cache key like `make_sharded_round`'s: the
    config's threshold reaches the controller instead of a hardcoded
    ``float("inf")``.
    """
    axes = tuple(mesh.axis_names)
    fn = functools.partial(dp_round_body, data_axes=axes, rho=rho,
                           use_pallas=use_pallas)
    sm = shard_map_compat(
        fn, mesh=mesh,
        in_specs=(P(axes, None), P(None, None)),
        out_specs=(P(None, None), P(None, None), P(None),
                   P(axes), P(axes), P(), P(), P()))
    return jax.jit(sm)


@functools.lru_cache(maxsize=None)
def make_xl_round(mesh: Mesh, *, k: int,
                  data_axes: Tuple[str, ...] = ("data",),
                  model_axis: str = "model",
                  rho: float = float("inf")):
    """jit(shard_map) of the sharded-centroid production round.

    Kept as the centroid-sharded variant for k too large to replicate
    (k*d beyond VMEM, ~10^5+ centroids); for kmeans_xl (k=4096) the
    data-parallel ``make_dp_round`` dominates it — see §Perf. ``rho``
    is a static cache key threading the config's growth threshold to
    the controller. The loop-driven engine over this layout is
    `repro.api.engines.xl.XLEngine` (see `core.distributed_xl`)."""
    row = P(data_axes)
    kshard = P(model_axis)

    fn = functools.partial(xl_round_body, k=k, data_axes=data_axes,
                           model_axis=model_axis, rho=rho)
    sm = shard_map_compat(
        fn, mesh=mesh,
        in_specs=(P(data_axes, None), P(model_axis, None),
                  P(model_axis, None), kshard),
        out_specs=(P(model_axis, None), P(model_axis, None), kshard,
                   row, row, row, P(), P(), P()))
    return jax.jit(sm)
