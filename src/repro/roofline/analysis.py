"""Three-term roofline from a compiled dry-run artifact.

  compute term    = HLO_FLOPs / peak_FLOP/s          (per device)
  memory term     = HLO_bytes / HBM_bw               (per device)
  collective term = wire_bytes / link_bw             (per device)

HLO FLOPs / bytes come from ``compiled.cost_analysis()`` of the
POST-PARTITIONING module, i.e. they are already per-device. Collective
bytes are not in cost_analysis: we parse the partitioned HLO text and sum
estimated *wire* volume per op (ring algorithms, large-n approximation):

  all-gather        out_bytes              (each device receives ~out)
  reduce-scatter    in_bytes               (each device sends ~in)
  all-reduce        2 * out_bytes          (RS + AG phases)
  all-to-all        out_bytes
  collective-permute out_bytes             (one hop)

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (one link counted; conservative).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "f8e4m3fnuz": 1, "e4m3": 1, "e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _line_shapes(text: str):
    return [_shape_bytes(m.group(1), m.group(2))
            for m in _SHAPE_RE.finditer(text)]


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0
    by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    counts: Dict[str, int] = dataclasses.field(default_factory=dict)

    def add(self, kind: str, b: float):
        self.wire_bytes += b
        self.by_kind[kind] = self.by_kind.get(kind, 0.0) + b
        self.counts[kind] = self.counts.get(kind, 0) + 1


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum estimated wire bytes of every collective in partitioned HLO.

    Handles both sync ops and async `-start` forms (the `-done` halves
    carry no payload and are skipped). Shapes in post-SPMD HLO are
    per-device shapes, so the result is per-device wire volume.
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        rhs = ls.split("=", 1)[1]
        m = re.search(r"\b([a-z0-9-]+)\(", rhs)
        if not m:
            continue
        op = m.group(1)
        base = op.removesuffix("-start")
        if base not in _COLLECTIVES or op.endswith("-done"):
            continue
        shapes = _line_shapes(rhs)
        if not shapes:
            continue
        # The largest shape on the line is the full buffer being moved in
        # every case (AG output, RS input, AR in==out) — robust to the
        # tuple-shaped async `-start` forms.
        full = float(max(shapes))
        stats.add(base, 2.0 * full if base == "all-reduce" else full)
    return stats


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    wire_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: Optional[float] = None
    useful_ratio: Optional[float] = None

    def step_time_s(self) -> float:
        """Perfect-overlap lower bound: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def roofline_fraction(self) -> Optional[float]:
        """useful FLOPs / (chips-seconds at peak) — the MFU-style score."""
        if not self.model_flops:
            return None
        t = self.step_time_s()
        return (self.model_flops / PEAK_FLOPS) / t if t > 0 else None


def roofline_terms(flops: float, hbm_bytes: float, wire_bytes: float, *,
                   model_flops: Optional[float] = None) -> Roofline:
    c = flops / PEAK_FLOPS
    m = hbm_bytes / HBM_BW
    x = wire_bytes / LINK_BW
    dom = max((c, "compute"), (m, "memory"), (x, "collective"))[1]
    useful = (model_flops / flops) if (model_flops and flops) else None
    return Roofline(flops=flops, hbm_bytes=hbm_bytes, wire_bytes=wire_bytes,
                    compute_s=c, memory_s=m, collective_s=x,
                    bottleneck=dom, model_flops=model_flops,
                    useful_ratio=useful)


def model_flops_train(active_params: int, tokens: int) -> float:
    """6 N D (fwd 2ND + bwd 4ND), MoE: N = active params."""
    return 6.0 * active_params * tokens


def model_flops_fwd(active_params: int, tokens: int) -> float:
    return 2.0 * active_params * tokens
