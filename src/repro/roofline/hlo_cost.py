"""Loop-aware FLOP/byte counting over compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop BODY ONCE — with
scan-over-layers and scan-over-microbatches that undercounts by orders of
magnitude. This module re-derives per-device costs from the HLO text:

  * dot FLOPs = 2 * prod(output dims) * prod(lhs contracting dims),
  * fusion/dot HBM bytes = operand bytes + output bytes (fusions are
    XLA's unit of memory traffic),
  * while loops multiply their body cost by the trip count (parsed from
    the largest integer constant in the loop's condition computation —
    exact for jax.lax.scan/fori loops, which compare the induction
    variable against a literal),
  * fusions / calls / conditionals recurse through the call graph,
  * collective wire bytes likewise accumulate through loops (a psum
    inside a scan crosses the wire every iteration).

Results are per-device because post-partitioning shapes are per-device.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "f8e4m3fnuz": 1,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
# header: ``%name (args...) -> type {`` — args may contain nested parens
# (tuple-typed params), so only anchor on the leading %name(.
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(")
# first ``word(`` after the `=` is the op mnemonic (tuple types carry
# ``/*index=N*/`` comments, so don't try to span the type with a class)
_OP_RE = re.compile(r"\b([a-z][a-z0-9\-]*)\(")
_CALL_ATTRS = ("calls=", "to_apply=", "body=", "condition=",
               "branch_computations=")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _dims(s: str) -> List[int]:
    return [int(x) for x in s.split(",") if x]


def _shape_bytes(m: re.Match) -> int:
    dt, dims = m.group(1), _dims(m.group(2))
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims:
        n *= d
    return n * _DTYPE_BYTES[dt]


@dataclasses.dataclass
class OpCost:
    flops: float = 0.0
    bytes: float = 0.0
    wire: float = 0.0
    wire_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __iadd__(self, o: "OpCost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.wire += o.wire
        for k, v in o.wire_by_kind.items():
            self.wire_by_kind[k] = self.wire_by_kind.get(k, 0.0) + v
        return self

    def scaled(self, t: float) -> "OpCost":
        return OpCost(self.flops * t, self.bytes * t, self.wire * t,
                      {k: v * t for k, v in self.wire_by_kind.items()})


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    body: List[str] = []
    for line in text.splitlines():
        ls = line.strip()
        if cur is None:
            if ls.endswith("{"):
                m = _COMP_HDR.match(ls)
                if m:
                    cur = m.group(1)
                    body = []
        else:
            if ls == "}" or ls.startswith("}"):
                comps[cur] = body
                cur = None
            else:
                body.append(ls)
    return comps


_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_ARGS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")


def _line_def(line: str):
    """(name, type_str, rest) for a ``%name = type op(...)`` line."""
    m = _DEF_RE.match(line)
    if not m:
        return None
    return m.group(1), m.group(2)


def _operand_names(rest: str, op: str):
    """Names passed to op(...): optimized HLO prints names, not types."""
    i = rest.find(op + "(")
    if i < 0:
        return []
    depth = 0
    j = i + len(op)
    for j in range(i + len(op), len(rest)):
        ch = rest[j]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
    args = rest[i + len(op) + 1: j]
    return re.findall(r"%([\w.\-]+)", args)


def _dot_flops(line: str, table: Dict[str, Tuple[str, List[int]]]) -> float:
    shapes = list(_SHAPE_RE.finditer(line))
    if not shapes:
        return 0.0
    out_n = 1
    for d in _dims(shapes[0].group(2)):
        out_n *= d
    c = 1
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    ops = _operand_names(line, "dot")
    if m and ops and ops[0] in table:
        lhs_dims = table[ops[0]][1]
        for i in _dims(m.group(1)):
            if i < len(lhs_dims):
                c *= lhs_dims[i]
    return 2.0 * out_n * c


def _line_callees(line: str) -> List[str]:
    out = []
    for attr in _CALL_ATTRS:
        for m in re.finditer(re.escape(attr) + r"\{?%?([\w.\-]+)", line):
            name = m.group(1).rstrip(",}")
            out.append(name)
        # branch_computations={%a, %b}
    m = re.search(r"branch_computations=\{([^}]*)\}", line)
    if m:
        out.extend(x.strip().lstrip("%") for x in m.group(1).split(","))
    return out


def _trip_count(cond_body: List[str]) -> float:
    """Largest integer literal in the loop condition — exact for scans.

    jax.lax.scan / fori_loop conditions are ``compare(iter, constant(N)),
    direction=LT``. Capped to guard against sentinel constants.
    """
    best = 1
    for line in cond_body:
        if "constant(" not in line:
            continue
        for m in re.finditer(r"constant\((\d+)\)", line):
            v = int(m.group(1))
            if v < 10_000_000:
                best = max(best, v)
    return float(best)


_TRIP_CFG = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


_BYTES_OPS = frozenset((
    "copy", "copy-start", "transpose", "reshape", "broadcast",
    "concatenate", "slice", "dynamic-slice", "dynamic-update-slice",
    "reduce", "sort", "scatter", "gather", "pad", "convert",
    "select-and-scatter", "reduce-window", "add", "multiply", "subtract",
    "divide", "select", "exponential", "rsqrt", "tanh", "maximum",
    "minimum", "compare", "dot", "fusion"))


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps = _split_computations(hlo_text)
        self._memo: Dict[str, OpCost] = {}
        self._tables: Dict[str, Dict[str, Tuple[str, List[int]]]] = {}
        entry = None
        for line in hlo_text.splitlines():
            if line.startswith("ENTRY"):
                m = _COMP_HDR.match(line.strip())
                if m:
                    entry = m.group(1)
        self.entry = entry

    def _table(self, name: str) -> Dict[str, Tuple[str, List[int]]]:
        """name -> (dtype, dims) symbol table for one computation."""
        if name in self._tables:
            return self._tables[name]
        table: Dict[str, Tuple[str, List[int]]] = {}
        for line in self.comps.get(name, ()):
            d = _line_def(line)
            if not d:
                continue
            var, rest = d
            tm = _SHAPE_RE.match(rest)
            if tm:
                table[var] = (tm.group(1), _dims(tm.group(2)))
        self._tables[name] = table
        return table

    def _operand_bytes(self, rest: str, op: str, table) -> float:
        tot = 0.0
        for nm in _operand_names(rest, op):
            if nm in table:
                dt, dims = table[nm]
                n = 1
                for d in dims:
                    n *= d
                tot += n * _DTYPE_BYTES.get(dt, 0)
        return tot

    def _fusion_traffic(self, callee: str) -> float:
        """HBM traffic of one fusion execution, from its computation body.

        Parameters consumed ONLY through dynamic-slice/gather inside the
        fusion contribute the SLICE size, not the full buffer (scan stacks
        are read one layer at a time). A dynamic-update-slice root writes
        its update slice in place, not the whole aliased buffer.
        """
        key = f"traffic|{callee}"
        if key in self._memo:
            return self._memo[key].bytes
        body = self.comps.get(callee, ())
        table = self._table(callee)
        params: Dict[str, float] = {}
        alias: Dict[str, str] = {}           # view var -> root param
        view_src: Dict[str, str] = {}        # view var -> source var
        dus_update: Dict[str, float] = {}    # DUS var -> update bytes
        sliced_reads: Dict[str, float] = {}
        used_whole: Dict[str, bool] = {}
        root_bytes = 0.0
        _VIEW = ("bitcast", "reshape", "copy", "transpose", "convert")

        def _root_of(nm: str):
            return alias.get(nm, nm)

        def _producer(nm: str):
            seen = set()
            while nm in view_src and nm not in seen:
                seen.add(nm)
                nm = view_src[nm]
            return nm

        for line in body:
            d = _line_def(line)
            if not d:
                continue
            var, rest = d
            m = _OP_RE.search(rest)
            op = m.group(1) if m else ""
            if op == "parameter":
                tm = _SHAPE_RE.match(rest)
                if tm:
                    n = 1
                    for x in _dims(tm.group(2)):
                        n *= x
                    params[var] = n * _DTYPE_BYTES.get(tm.group(1), 0)
                    used_whole[var] = False
                    sliced_reads[var] = 0.0
                continue
            names = _operand_names(rest, op) if op else []
            out_b = 0.0
            tm = _SHAPE_RE.match(rest)
            if tm:
                n = 1
                for x in _dims(tm.group(2)):
                    n *= x
                out_b = n * _DTYPE_BYTES.get(tm.group(1), 0)
            # convert/copy count as views here: on CPU, XLA legalizes
            # bf16 through f32 reduce-precision roundtrips over WHOLE
            # buffers — artifacts that don't exist on the TPU target.
            if op in ("bitcast", "reshape", "transpose", "convert",
                      "copy", "reduce-precision") and len(names) == 1:
                view_src[var] = names[0]
                if _root_of(names[0]) in params:
                    alias[var] = _root_of(names[0])
                    continue
            if op == "dynamic-update-slice" and len(names) >= 2:
                upd = names[1]
                if upd in table:
                    dt, dims = table[upd]
                    n = 1
                    for x in dims:
                        n *= x
                    dus_update[var] = n * _DTYPE_BYTES.get(dt, 0)
                else:
                    dus_update[var] = out_b
            for i, nm in enumerate(names):
                p = _root_of(nm)
                if p not in params:
                    continue
                if op in ("dynamic-slice", "gather", "slice"):
                    sliced_reads[p] += out_b
                elif op == "dynamic-update-slice" and i == 0:
                    pass          # aliased in-place destination
                else:
                    used_whole[p] = True
            if line.lstrip().startswith("ROOT"):
                prod = _producer(var)
                if op == "dynamic-update-slice":
                    root_bytes = dus_update.get(var, out_b)
                elif prod in dus_update:
                    root_bytes = dus_update[prod]
                else:
                    root_bytes = out_b
        reads = sum(params[p] if used_whole[p] else
                    min(params[p], sliced_reads[p])
                    for p in params)
        total = reads + root_bytes
        self._memo[key] = OpCost(bytes=total)
        return total

    def cost(self) -> OpCost:
        if self.entry is None:
            return OpCost()
        return self.comp_cost(self.entry, in_fusion=False)

    def comp_cost(self, name: str, *, in_fusion: bool) -> OpCost:
        key = f"{name}|{in_fusion}"
        if key in self._memo:
            return self._memo[key]
        self._memo[key] = OpCost()           # cycle guard
        total = OpCost()
        table = self._table(name)
        for line in self.comps.get(name, ()):
            total += self.line_cost(line, table, in_fusion=in_fusion)
        self._memo[key] = total
        return total

    def line_cost(self, line: str, table, *, in_fusion: bool) -> OpCost:
        if " = " not in line:
            return OpCost()
        rest = line.split(" = ", 1)[1]
        m = _OP_RE.search(rest)
        if not m:
            return OpCost()
        op = m.group(1)
        c = OpCost()
        if op == "dot":
            c.flops += _dot_flops(line, table)
        elif op == "fusion":
            # fusion internals never touch HBM: recurse for FLOPs only;
            # boundary traffic is slice-aware (see _fusion_traffic)
            for cal in _line_callees(line):
                c += self.comp_cost(cal, in_fusion=True)
                if not in_fusion:
                    c.bytes += self._fusion_traffic(cal)
            return c
        elif op == "while":
            body = cond = None
            for attr, val in re.findall(r"(body|condition)=%?([\w.\-]+)",
                                        line):
                if attr == "body":
                    body = val.rstrip(",")
                else:
                    cond = val.rstrip(",")
            # XLA annotates resolved trip counts in backend_config —
            # exact; fall back to the condition-constant heuristic.
            mt = _TRIP_CFG.search(line)
            if mt:
                trips = float(mt.group(1))
            else:
                trips = _trip_count(self.comps.get(cond, [])) if cond \
                    else 1.0
            if body:
                c += self.comp_cost(body, in_fusion=False).scaled(trips)
            return c
        elif op in ("call", "conditional", "async-start"):
            for cal in _line_callees(line):
                c += self.comp_cost(cal, in_fusion=in_fusion)
            return c
        elif op.removesuffix("-start") in _COLLECTIVES \
                and not op.endswith("-done"):
            base = op.removesuffix("-start")
            out_b = [_shape_bytes(s) for s in _SHAPE_RE.finditer(rest)]
            in_b = self._operand_bytes(rest, op, table)
            full = max(max(out_b, default=0.0), in_b)
            if full:
                wire = 2.0 * full if base == "all-reduce" else full
                c.wire += wire
                c.wire_by_kind[base] = c.wire_by_kind.get(base, 0.0) + wire
                if not in_fusion:
                    c.bytes += full
            return c
        elif op == "convolution":
            shapes = list(_SHAPE_RE.finditer(rest))
            if shapes:
                out_n = 1
                for d in _dims(shapes[0].group(2)):
                    out_n *= d
                c.flops += 2.0 * out_n  # lower bound (kernel dims unknown)
        # memory traffic: outputs (on the line) + operands (symbol table)
        if not in_fusion and op in _BYTES_OPS:
            c.bytes += sum(_shape_bytes(s) for s in _SHAPE_RE.finditer(rest))
            c.bytes += self._operand_bytes(rest, op, table)
        return c


def analyze(hlo_text: str) -> OpCost:
    return HloCostModel(hlo_text).cost()
