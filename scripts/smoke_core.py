"""Quick dev smoke of the core engine (not a test)."""
import numpy as np

from repro.core import fit
from repro.core.state import full_mse
import jax.numpy as jnp

rng = np.random.default_rng(0)
k, d, n = 8, 16, 4000
centers = rng.normal(size=(k, d)) * 5
X = (centers[rng.integers(0, k, n)] + rng.normal(size=(n, d))).astype(np.float32)
Xv = (centers[rng.integers(0, k, 500)] + rng.normal(size=(500, d))).astype(np.float32)

for algo, kw in [
    ("lloyd", {}),
    ("mb", dict(b0=256)),
    ("mbf", dict(b0=256)),
    ("gb", dict(b0=256, rho=float("inf"))),
    ("tb", dict(b0=256, rho=float("inf"), bounds="hamerly2")),
    ("tb", dict(b0=256, rho=float("inf"), bounds="elkan")),
    ("tb", dict(b0=256, rho=100.0, bounds="hamerly2")),
]:
    res = fit(X, k, algorithm=algo, X_val=Xv, max_rounds=60, eval_every=5,
              seed=1, **kw)
    tail = [r for r in res.telemetry if r["val_mse"] is not None][-1]
    print(f"{algo:6s} {str(kw.get('bounds','')):9s} rho={kw.get('rho','-')}"
          f" rounds={len(res.telemetry):3d} conv={res.converged}"
          f" val_mse={tail['val_mse']:.4f}"
          f" recomputed_last={res.telemetry[-2]['n_recomputed']}")
print("inertia sanity (true centers):",
      float(full_mse(jnp.asarray(Xv), jnp.asarray(centers, jnp.float32))))
