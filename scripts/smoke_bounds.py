"""Bound-family end-to-end check (run via tests/test_bounds_smoke.py).

Gates the bounds="exponion" PR with 8 forced host devices:

  1. family parity per backend — exponion labels AND centroids are
     bit-equal to bounds="none" on local, mesh(4 data shards),
     xl(4 data x 2 model) and multihost, with N % n_shards != 0, plus
     the degenerate-ring fallback (k_local < 4 on a (1,8) XL mesh);
  2. cross-backend parity — the SAME exponion fit is bit-identical
     (labels, centroids, per-point bounds, telemetry minus wall-clock)
     across XL(1,1) vs local, XL(2,1) vs mesh(2,1) and mesh vs
     multihost: the annulus schedule lives only in core/rounds.py and
     the sharded variants test the exact same candidate set;
  3. kill-and-resume — an exponion mesh fit interrupted at round 9
     resumes bit-identically (the geometry table is rebuilt per round,
     never checkpointed), and the checkpoint restores elastically onto
     the LocalEngine;
  4. auditors stay green with exponion — retrace (local + xl: the
     per-round geometry rebuild mints no extra traces), hostsync
     (zero unsanctioned device->host syncs) and the replicated-control-
     flow lint.
"""
from repro.util.env import force_host_device_count
force_host_device_count(8)

import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.analysis import hostsync, replicated_lint, retrace
from repro.core.state import full_mse

rng = np.random.default_rng(0)
k, d, n = 64, 16, 4001                  # 4001 % 2/4/8 != 0: tail rows
centers = rng.normal(size=(k, d)) * 5
X = (centers[rng.integers(0, k, n)]
     + rng.normal(size=(n, d))).astype(np.float32)

cfg = api.FitConfig(k=k, algorithm="tb", b0=512, max_rounds=80, seed=1,
                    bounds="exponion", capacity_floor=256)


def family_parity(tag, cfg_e, mesh=None, X_=None):
    """exponion == none, bit-equal labels + centroids, same backend."""
    X_ = X if X_ is None else X_
    out_e = api.fit(X_, cfg_e, mesh=mesh)
    out_n = api.fit(X_, dataclasses.replace(cfg_e, bounds="none"),
                    mesh=mesh)
    assert out_e.converged and out_n.converged
    np.testing.assert_array_equal(out_e.labels, out_n.labels)
    np.testing.assert_array_equal(out_e.C, out_n.C)
    assert int((out_e.labels < 0).sum()) == 0
    print(f"family parity[{tag}]: exponion == none bit-equal over "
          f"{len(out_e.telemetry)} rounds")
    return out_e


def cross_parity(tag, out_a, out_b, exact_tel=False):
    """Two exponion fits on different backends: labels, centroids and
    per-point bounds bit-identical; telemetry exact for integer fields
    — including the exact-annulus ``n_recomputed`` pair count, which
    the local and sharded paths must agree on — and, across different
    topologies, float scalars only to reduction-order tolerance
    (``exact_tel=True`` for same-topology pairs)."""
    np.testing.assert_array_equal(out_a.labels, out_b.labels)
    np.testing.assert_array_equal(out_a.C, out_b.C)
    np.testing.assert_array_equal(np.asarray(out_a.state.points.d),
                                  np.asarray(out_b.state.points.d))
    np.testing.assert_array_equal(np.asarray(out_a.state.points.lb),
                                  np.asarray(out_b.state.points.lb))
    assert len(out_a.telemetry) == len(out_b.telemetry)
    for ra, rb in zip(out_a.telemetry, out_b.telemetry):
        da, db = ra.to_dict(), rb.to_dict()
        da.pop("t"), db.pop("t")
        if exact_tel:
            assert da == db, (tag, da, db)
            continue
        for key in set(da) | set(db):
            va, vb = da.get(key), db.get(key)
            if isinstance(va, float) and isinstance(vb, float):
                np.testing.assert_allclose(va, vb, rtol=1e-5,
                                           err_msg=f"{tag}:{key}")
            else:
                assert va == vb, (tag, key, da, db)
    print(f"cross-backend[{tag}]: bit-identical"
          f"{' incl. telemetry' if exact_tel else ' (+ pair counts)'}")


# -- 1. family parity on every backend --------------------------------------
out_local = family_parity("local", cfg)

mesh41 = jax.make_mesh((4, 1), ("data", "model"))
cfg_mesh = dataclasses.replace(cfg, backend="mesh", data_axes=("data",))
family_parity("mesh(4)", cfg_mesh, mesh=mesh41)

mesh42 = jax.make_mesh((4, 2), ("data", "model"))
cfg_xl = dataclasses.replace(cfg, backend="xl", data_axes=("data",),
                             model_axis="model")
family_parity("xl(4,2)", cfg_xl, mesh=mesh42)

from repro.launch.mesh import make_multihost_mesh
mesh1d = make_multihost_mesh()
cfg_mh = dataclasses.replace(cfg, backend="multihost")
out_mh = family_parity("multihost", cfg_mh, mesh=mesh1d)

# degenerate rings: k_local = 16/8 = 2 < 4 -> elkan-style full local scan
mesh18 = jax.make_mesh((1, 8), ("data", "model"))
cfg_deg = dataclasses.replace(cfg_xl, k=16, b0=256, capacity_floor=64)
family_parity("xl(1,8) degenerate rings", cfg_deg, mesh=mesh18)

# -- 2. cross-backend parity of the exponion fit itself ----------------------
mesh11 = jax.make_mesh((1, 1), ("data", "model"))
out_xl11 = api.fit(X, cfg_xl, mesh=mesh11)
cross_parity("xl(1,1) == local", out_xl11, out_local)

mesh21 = jax.make_mesh((2, 1), ("data", "model"))
out_xl21 = api.fit(X, cfg_xl, mesh=mesh21)
out_mesh21 = api.fit(X, cfg_mesh, mesh=mesh21)
cross_parity("xl(2,1) == mesh(2)", out_xl21, out_mesh21)

out_mesh1d = api.fit(X, dataclasses.replace(cfg, backend="mesh"),
                     mesh=mesh1d)
cross_parity("mesh == multihost", out_mesh1d, out_mh, exact_tel=True)

# -- 3. kill-and-resume + elastic restore ------------------------------------
mesh22 = jax.make_mesh((2, 2), ("data", "model"))
out_full = api.fit(X, cfg_mesh, mesh=mesh22)
with tempfile.TemporaryDirectory() as ckdir:
    ck = api.CheckpointConfig(checkpoint_dir=ckdir, save_every=4)
    api.fit(X, dataclasses.replace(cfg_mesh, max_rounds=9,
                                   checkpoint=ck), mesh=mesh22)
    km = api.NestedKMeans(dataclasses.replace(cfg_mesh, checkpoint=ck),
                          mesh=mesh22)
    km.fit(X, resume=True)
    np.testing.assert_array_equal(out_full.C, km.cluster_centers_)
    assert len(out_full.telemetry) == len(km.telemetry_)
    for ra, rb in zip(out_full.telemetry, km.telemetry_):
        da, db = ra.to_dict(), rb.to_dict()
        da.pop("t"), db.pop("t")
        assert da == db, (da, db)
    print("exponion mesh kill-and-resume: bit-identical")

    # elastic: the 2-shard exponion checkpoint restores onto local
    kml = api.NestedKMeans(dataclasses.replace(
        cfg, checkpoint=ck))
    kml.fit(X, resume=True)
    assert kml.converged_
    mse_a = float(full_mse(jnp.asarray(X), jnp.asarray(out_full.C)))
    msel = float(full_mse(jnp.asarray(X),
                          jnp.asarray(kml.cluster_centers_)))
    assert abs(mse_a - msel) / mse_a < 0.05, (mse_a, msel)
    print(f"elastic mesh->local exponion resume: converged, "
          f"mse {msel:.5f} (uninterrupted {mse_a:.5f})")

# -- 4. auditors with exponion ------------------------------------------------
for backend in ("local", "xl"):
    v = retrace.audit_backend(backend, bounds="exponion")
    assert not v, [str(x) for x in v]
    print(f"retrace[{backend}] with exponion: one trace per bucket")
v = hostsync.audit_backend("local", bounds="exponion")
assert not v, [str(x) for x in v]
print("hostsync[local] with exponion: zero unsanctioned syncs")
v = replicated_lint.run()
assert not v, [str(x) for x in v]
print("replicated-control-flow lint: clean")

print("bounds smoke OK")
