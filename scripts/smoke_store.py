"""Out-of-core data-plane end-to-end check (run via tests/test_store.py).

The contract under test: a fit streamed off an on-disk `ChunkStore` is
BIT-IDENTICAL (centroids, labels, round-by-round schedule) to the
in-memory fit over the same row sequence — on every backend — while
reading each chunk about once. "Same row sequence" is precise: a stored
fit replays ``X[store_permutation(...)]`` (the chunk-blocked shuffle),
so the reference is an in-memory fit of exactly that array with
``shuffle=False``.

Parent process (4 forced host devices, single process):

  1. local / mesh / xl stored fits, each bitwise against its in-memory
     reference (N % n_shards != 0, ragged tail chunk live);
  2. multihost(1 process) stored == mesh stored, bitwise;
  3. read accounting: the store's own metrics show the prefix-delta
     frontier reads well under ~1.6x one full pass at smoke scale
     (boundary chunks dominate at 256-row chunks; the benchmark gates
     the production ratio at 1.1x);
  4. kill-and-resume from the same store: bitwise continuation, plus
     the dataset-fingerprint gate — resuming against a DIFFERENT store
     is a loud ValueError;
  5. checkpoint corruption: a flipped byte in a chunk fails the crc on
     a verifying reader.

Child processes (2 x 2 forced host devices, a REAL jax.distributed
cluster over a localhost coordinator):

  6. both processes stream off the SAME store directory through their
     own read handles; stored == in-memory multihost bitwise per
     process; identical control-flow traces across processes; each
     process reads the frontier chunks about ONCE per fit (shards
     interleave inside chunks, so the saving is the prefix-delta
     schedule, not a 1/P split);
  7. kill-one-process resume: the 2-process stored fit's checkpoint
     continues on a 1-process MeshEngine from the same store with the
     identical schedule (floats to reduction-order tolerance).
"""
import os
import sys

N_PROC = 2
DEV_PER_PROC = 2
K, D, N = 8, 16, 4001            # 4001 % 4 != 0: tail rows exist
CHUNK_ROWS = 256                 # 16 chunks, ragged 161-row tail


def _dataset(seed=0):
    import numpy as np
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(K, D)) * 5
    return (centers[rng.integers(0, K, N)]
            + rng.normal(size=(N, D))).astype(np.float32)


def _clean_telemetry(telemetry):
    out = []
    for r in telemetry:
        d = r.to_dict()
        d.pop("t")                   # wall-clock is process/run-local
        out.append(d)
    return out


# ---------------------------------------------------------------------------
# child: one process of the 2-process CPU cluster
# ---------------------------------------------------------------------------

def child(proc: int, port: str, workdir: str) -> None:
    from repro.util.env import force_host_device_count
    force_host_device_count(DEV_PER_PROC)
    import dataclasses
    import json

    import numpy as np

    from repro import api
    from repro.data.store import ChunkStore, store_permutation

    store_dir = os.path.join(workdir, "store")
    ck_kill = api.CheckpointConfig(
        checkpoint_dir=os.path.join(workdir, "ck_kill"), save_every=4)
    cfg = api.FitConfig(
        k=K, algorithm="tb", b0=512, max_rounds=80, seed=1,
        backend="multihost", capacity_floor=256,
        coordinator_address=f"localhost:{port}",
        num_processes=N_PROC, process_id=proc)

    # -- 6. stored fit across 2 REAL processes ---------------------------
    st = ChunkStore(store_dir)
    km = api.NestedKMeans(cfg)
    run = km.engine.begin(st, cfg.resolve(N))
    trace = []
    out = api.run_loop(run, cfg.resolve(N), trace=trace)
    assert out.converged
    assert int((out.labels < 0).sum()) == 0, "unlabeled real rows"

    # per-process read accounting: shards interleave inside chunks, so
    # a process reads every frontier chunk — but only ONCE per fit (the
    # prefix-delta schedule), not once per round. The bound is one full
    # pass plus the k-row init and chunk-boundary slack.
    one_pass = N * D * 4
    ratio = st.metrics.bytes_read / one_pass
    assert ratio < 1.3, f"per-process read ratio {ratio:.2f}"

    # the stored fit must equal the in-memory multihost fit over the
    # same row sequence, bitwise, even across processes
    perm = store_permutation(N, CHUNK_ROWS, cfg.seed)
    Xp = st.rows(0, N)[perm]
    out_mem = api.fit(Xp, dataclasses.replace(cfg, shuffle=False))
    np.testing.assert_array_equal(out.C, out_mem.C)
    np.testing.assert_array_equal(out.labels[perm], out_mem.labels)
    assert _clean_telemetry(out.telemetry) == \
        _clean_telemetry(out_mem.telemetry)

    telem = _clean_telemetry(out.telemetry)
    with open(os.path.join(workdir, f"trace_{proc}.json"), "w") as f:
        json.dump({"trace": trace, "telemetry": telem,
                   "read_ratio": ratio}, f)
    if proc == 0:
        np.save(os.path.join(workdir, "C_full.npy"), out.C)

    # -- 7a. the interrupted stored fit: killed at round 9 ---------------
    cfg_kill = dataclasses.replace(cfg, max_rounds=9, checkpoint=ck_kill)
    api.NestedKMeans(cfg_kill).fit(ChunkStore(store_dir))
    print(f"[child {proc}] stored 2-process fit bit-identical to "
          f"in-memory ({len(telem)} rounds, read ratio {ratio:.2f})",
          flush=True)


# ---------------------------------------------------------------------------
# parent: single-process checks + cluster orchestration
# ---------------------------------------------------------------------------

def main() -> None:
    from repro.util.env import force_host_device_count
    force_host_device_count(2 * DEV_PER_PROC)
    import dataclasses
    import json
    import socket
    import subprocess
    import tempfile

    import jax
    import numpy as np

    from repro import api
    from repro.data.store import (ChunkStore, dataset_fingerprint,
                                  store_permutation, write_store)
    from repro.launch.mesh import make_multihost_mesh

    X = _dataset()
    workroot = tempfile.mkdtemp(prefix="smoke_store_")
    store_dir = os.path.join(workroot, "store")
    write_store(store_dir, X, chunk_rows=CHUNK_ROWS)
    perm = store_permutation(N, CHUNK_ROWS, 1)
    Xp = X[perm]                     # the stored fits' exact row sequence
    one_pass = X.nbytes

    mesh1d = make_multihost_mesh()   # (4,) over the forced host devices
    mesh22 = jax.make_mesh((2, 2), ("data", "model"))
    cfg = api.FitConfig(k=K, algorithm="tb", b0=512, max_rounds=80,
                        seed=1, capacity_floor=256)
    cfg_mem = dataclasses.replace(cfg, shuffle=False)

    def assert_bitwise(out_s, out_m, what):
        np.testing.assert_array_equal(out_s.C, out_m.C)
        np.testing.assert_array_equal(out_s.labels[perm], out_m.labels)
        assert _clean_telemetry(out_s.telemetry) == \
            _clean_telemetry(out_m.telemetry), what
        assert int((out_s.labels < 0).sum()) == 0
        print(f"{what} stored fit: bit-identical to in-memory over "
              f"{len(out_s.telemetry)} rounds")

    # -- 1. stored == in-memory on local / mesh / xl ---------------------
    st = ChunkStore(store_dir)
    out_local = api.fit(st, cfg)
    assert_bitwise(out_local, api.fit(Xp, cfg_mem), "local")
    ratio_local = st.metrics.bytes_read / one_pass

    st = ChunkStore(store_dir)
    out_mesh = api.fit(st, dataclasses.replace(cfg, backend="mesh"),
                       mesh=mesh1d)
    assert_bitwise(out_mesh,
                   api.fit(Xp, dataclasses.replace(cfg_mem,
                                                   backend="mesh"),
                           mesh=mesh1d), "mesh")
    ratio_mesh = st.metrics.bytes_read / one_pass

    out_xl = api.fit(ChunkStore(store_dir),
                     dataclasses.replace(cfg, backend="xl",
                                         model_axis="model"),
                     mesh=mesh22)
    assert_bitwise(out_xl,
                   api.fit(Xp, dataclasses.replace(cfg_mem, backend="xl",
                                                   model_axis="model"),
                           mesh=mesh22), "xl")

    # -- 2. multihost(1 process) stored == mesh stored, bitwise ----------
    out_mh = api.fit(ChunkStore(store_dir),
                     dataclasses.replace(cfg, backend="multihost"),
                     mesh=mesh1d)
    np.testing.assert_array_equal(out_mesh.C, out_mh.C)
    np.testing.assert_array_equal(out_mesh.labels, out_mh.labels)
    assert _clean_telemetry(out_mesh.telemetry) == \
        _clean_telemetry(out_mh.telemetry)
    print("multihost(1 process) stored == mesh stored: bit-identical")

    # -- 3. read accounting: the prefix-delta frontier -------------------
    # 256-row chunks make boundary slack visible; the production ratio
    # (65536-row chunks) is gated at 1.1x by benchmarks/outofcore.py
    for name, ratio in (("local", ratio_local), ("mesh", ratio_mesh)):
        assert ratio < 1.6, f"{name} read ratio {ratio:.2f}"
    print(f"read amplification: local {ratio_local:.2f}x, mesh "
          f"{ratio_mesh:.2f}x of one full pass (prefix-delta fetching)")

    # -- 4. kill-and-resume from the same store --------------------------
    ckdir = os.path.join(workroot, "ck")
    ck = api.CheckpointConfig(checkpoint_dir=ckdir, save_every=4)
    cfg_ck = dataclasses.replace(cfg, backend="mesh", checkpoint=ck)
    api.fit(ChunkStore(store_dir),
            dataclasses.replace(cfg_ck, max_rounds=9), mesh=mesh1d)
    km_r = api.NestedKMeans(cfg_ck, mesh=mesh1d)
    km_r.fit(ChunkStore(store_dir), resume=True)
    assert km_r.converged_
    np.testing.assert_array_equal(out_mesh.C, km_r.cluster_centers_)
    np.testing.assert_array_equal(out_mesh.labels, km_r.labels_)
    print("stored kill-and-resume: bit-identical continuation")

    # ... and the dataset-fingerprint gate: a DIFFERENT store (or a
    # different in-memory array) must be refused loudly
    other_dir = os.path.join(workroot, "store_other")
    write_store(other_dir, _dataset(seed=7), chunk_rows=CHUNK_ROWS)
    try:
        api.NestedKMeans(cfg_ck, mesh=mesh1d).fit(ChunkStore(other_dir),
                                                  resume=True)
        raise AssertionError("resume against a different store passed")
    except ValueError as e:
        assert "different dataset" in str(e), e
    fp = dataset_fingerprint(ChunkStore(store_dir))
    assert fp != dataset_fingerprint(ChunkStore(other_dir))
    print("resume against a different store: refused "
          "(fingerprint mismatch)")

    # -- 5. corruption detection -----------------------------------------
    bad_dir = os.path.join(workroot, "store_bad")
    write_store(bad_dir, X, chunk_rows=CHUNK_ROWS)
    with open(os.path.join(bad_dir, "data.bin"), "r+b") as f:
        f.seek(3 * CHUNK_ROWS * D * 4 + 17)
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0xFF]))
    bad = ChunkStore(bad_dir, verify=True)
    try:
        bad.chunk(3)
        raise AssertionError("corrupt chunk read verified")
    except IOError as e:
        assert "corrupt" in str(e)
    print("chunk corruption: crc verification catches a flipped byte")

    # -- 6 + 7. the real 2-process cluster -------------------------------
    with socket.socket() as s:
        s.bind(("localhost", 0))
        port = str(s.getsockname()[1])
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    procs = [subprocess.Popen(
        [sys.executable, os.path.abspath(__file__),
         "--child", str(i), port, workroot],
        env=env, cwd=os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        for i in range(N_PROC)]
    for p in procs:
        assert p.wait(timeout=600) == 0, "child process failed"

    traces = []
    for i in range(N_PROC):
        with open(os.path.join(workroot, f"trace_{i}.json")) as f:
            traces.append(json.load(f))
    assert traces[0]["trace"] == traces[1]["trace"]
    assert traces[0]["telemetry"] == traces[1]["telemetry"]
    print(f"2-process stored cluster: identical traces over "
          f"{len(traces[0]['telemetry'])} rounds; per-process reads "
          f"{traces[0]['read_ratio']:.2f}x / {traces[1]['read_ratio']:.2f}x "
          f"of one pass (prefix-delta: the store is read once per fit)")

    # -- 7b. kill-one-process resume from the same store -----------------
    C2 = np.load(os.path.join(workroot, "C_full.npy"))
    ck = api.CheckpointConfig(
        checkpoint_dir=os.path.join(workroot, "ck_kill"), save_every=4)
    km = api.NestedKMeans(dataclasses.replace(
        cfg, backend="mesh", checkpoint=ck), mesh=mesh1d)
    km.fit(ChunkStore(store_dir), resume=True)
    assert km.converged_
    resumed = _clean_telemetry(km.telemetry_)
    want = traces[0]["telemetry"]
    assert len(resumed) == len(want)
    for ra, wa in zip(resumed, want):
        for key in ("round", "b", "n_changed", "n_recomputed", "grow"):
            assert ra[key] == wa[key], (ra, wa)
        if wa["batch_mse"] is not None:
            assert abs(ra["batch_mse"] - wa["batch_mse"]) \
                <= 1e-4 * abs(wa["batch_mse"]), (ra, wa)
    np.testing.assert_allclose(C2, km.cluster_centers_, atol=1e-5)
    print("kill-one-process resume from the store: 2-process checkpoint "
          "continued on 1 process with the identical schedule")

    print("store smoke OK")


if __name__ == "__main__":
    if "--child" in sys.argv:
        i = sys.argv.index("--child")
        child(int(sys.argv[i + 1]), sys.argv[i + 2], sys.argv[i + 3])
    else:
        main()
