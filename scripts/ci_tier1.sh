#!/usr/bin/env bash
# Fast tier-1 loop: the tier-1 pytest command restricted to the fast
# subset (tests not marked "slow"), so the edit-test loop stays under
# ~2 minutes on this container. The full tier-1 command remains
#     PYTHONPATH=src python -m pytest -x -q
# and is what CI gates on; this script is the developer inner loop.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    exec python -m pytest -x -q -m "not slow" "$@"
