#!/usr/bin/env bash
# Fast tier-1 loop: the tier-1 pytest command restricted to the fast
# subset (tests not marked "slow"), so the edit-test loop stays under
# ~2 minutes. An UNSCOPED invocation additionally runs the mesh
# kill-and-resume subprocess test (slow-marked but checkpoint-critical)
# under its own 10-minute budget; passing any pytest args skips it.
# The full tier-1 command remains
#     PYTHONPATH=src python -m pytest -x -q
# and is what CI gates on; this script is the developer inner loop.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

# Fail loudly if the package is not importable (e.g. src/ missing or a
# clobbered PYTHONPATH) — otherwise pytest "passes" by collecting
# nothing from the api/engine tests.
if ! python -c "import repro" 2>/dev/null; then
    echo "error: cannot import 'repro' with PYTHONPATH=src —" \
         "run from the repo root with src/ present" >&2
    exit 1
fi

# static gate first: the AST lint is sub-second and catches the
# replicated-control-flow regressions before any test spends minutes.
# (The runtime auditors + selftests run in the unscoped block below.)
bash scripts/ci_static.sh lint

python -m pytest -x -q -m "not slow" "$@"

# kill-and-resume must stay green in the inner loop too — but only on
# unscoped runs, so `ci_tier1.sh -k foo` stays a fast scoped loop.
if [ "$#" -eq 0 ]; then
    timeout 600 python -m pytest -x -q tests/test_resume.py \
        -k test_mesh_resume_subprocess
    # the repro.serve concurrency tests are fast (no slow marker) and
    # already ran above; re-assert them by name so a future slow-marking
    # can't silently drop the serving path from the inner loop.
    timeout 600 python -m pytest -x -q tests/test_serve.py
    # the XL engine e2e (slow-marked subprocess smoke: fold parity,
    # run_loop bit-parity vs local/mesh, elastic XL<->local restore).
    # Outer budget > the test's own 600 s subprocess timeout, so a slow
    # smoke fails INSIDE pytest with its captured output, not as a bare
    # exit 124 from this wrapper.
    timeout 700 python -m pytest -x -q tests/test_distributed_xl.py
    # the multihost engine e2e (slow-marked subprocess smoke: 1-process
    # mesh<->multihost bit-parity, elkan-on-sharded parity, sharded
    # partial_fit, and a real 2-process jax.distributed CPU cluster
    # with identical control-flow traces + kill-one-process resume).
    # Outer budget > the test's own 900 s subprocess timeout.
    timeout 1000 python -m pytest -x -q tests/test_multihost.py
    # the out-of-core data plane (fast format/source/fit-parity tests
    # ran above; this adds the slow-marked subprocess smoke: stored-fit
    # bit-parity on local/mesh/xl/multihost, kill-and-resume from disk,
    # the dataset-fingerprint resume gate, and a 2-process cluster
    # streaming off one store directory).
    timeout 1000 python -m pytest -x -q tests/test_store.py
    # the observability plane (fast tracer/registry/instrumented-fit
    # tests ran above; this adds the slow-marked subprocess smoke:
    # traced fits on all four backends where the event log must parse
    # and its round count must equal the loop's own schedule trace,
    # plus lint + hostsync staying green on the INSTRUMENTED loop).
    timeout 700 python -m pytest -x -q tests/test_obs.py
    # the kernel dispatch plane (fast plan/parity/compat tests ran
    # above; this adds the slow-marked subprocess smoke: fused-round op
    # parity, pallas-vs-ref fit bit-parity on local tb/gb and XL
    # m=2/m=1, and retrace + hostsync green with the plan active).
    timeout 700 python -m pytest -x -q tests/test_kernels.py
    # the bound families (fast parity/boundary-tie/resume tests ran
    # above; this adds the slow-marked subprocess smoke: exponion ==
    # none on local/mesh/xl/multihost incl. degenerate rings,
    # cross-backend bit-parity with exact-annulus pair counts, mesh
    # kill-and-resume, and the auditors green with exponion).
    timeout 1000 python -m pytest -x -q tests/test_bounds_smoke.py
    # full static + invariant gate: ruff (if installed), the runtime
    # auditors (hostsync / retrace / donation) across backends, and the
    # planted-bug selftests proving every checker still has teeth.
    timeout 900 bash scripts/ci_static.sh
fi
