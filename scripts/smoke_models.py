"""Dev smoke: reduced-config forward/train/prefill/decode for all archs."""
import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.models import model as M

key = jax.random.PRNGKey(0)

for arch in configs.list_archs():
    cfg = configs.get_reduced(arch)
    params = M.init_params(key, cfg)
    B, S = 2, 32
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S))),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder.n_ctx,
                             cfg.encoder.d_frontend)), jnp.float32)
    if cfg.family == "vlm":
        P = cfg.encoder.n_ctx
        batch["tokens"] = batch["tokens"][:, :S - P]
        batch["labels"] = batch["labels"][:, :S - P]
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, P, cfg.d_model)), jnp.float32)

    loss, metrics = jax.jit(
        lambda p, b: M.train_loss(p, b, cfg))(params, batch)
    assert np.isfinite(float(loss)), arch

    # prefill + 2 decode steps
    cache_len = S + 8
    logits_p, cache = jax.jit(
        lambda p, b: M.prefill(p, b, cfg, cache_len=cache_len))(params, batch)
    tok = jnp.argmax(logits_p[:, -1], axis=-1)[:, None].astype(jnp.int32)
    dec = jax.jit(lambda p, t, c: M.decode_step(p, t, c, cfg))
    l1, cache = dec(params, tok, cache)
    l2, cache = dec(params, tok, cache)
    assert np.isfinite(np.asarray(l1)).all() and np.isfinite(np.asarray(l2)).all()

    # decode from a zero cache (the dry-run path)
    zc = M.make_decode_cache(cfg, batch=B, cache_len=cache_len)
    if cfg.family == "encdec":
        zc["enc_out"] = jnp.zeros_like(zc["enc_out"])
    l3, _ = dec(params, tok, zc)
    assert np.isfinite(np.asarray(l3)).all()
    n_par = sum(x.size for x in jax.tree.leaves(params))
    print(f"{arch:24s} loss={float(loss):8.4f} params={n_par:,}")

print("model zoo smoke OK")
