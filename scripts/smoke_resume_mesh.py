"""Dev smoke: mesh kill-and-resume + tail-row fix (run via subprocess).

Forces host devices so the MeshEngine runs 2 data shards, with a
dataset size that is NOT a multiple of the shard count:
  * a converged mesh fit labels EVERY real row (the tail rows of the
    low shards used to come back -1) and n_active == N_real;
  * a fit checkpointed mid-run and resumed on the SAME shard count is
    bit-identical (centroids + telemetry minus wall-clock) to an
    uninterrupted run;
  * the same checkpoint restores elastically onto a different shard
    count and onto the LocalEngine, converging to the same quality.
"""
from repro.util.env import force_host_device_count
force_host_device_count(4)

import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core.state import full_mse

rng = np.random.default_rng(0)
k, d, n = 8, 16, 4001            # 4001 % 2 != 0: tail rows exist
centers = rng.normal(size=(k, d)) * 5
X = (centers[rng.integers(0, k, n)]
     + rng.normal(size=(n, d))).astype(np.float32)

mesh2 = jax.make_mesh((2, 2), ("data", "model"))
cfg = api.FitConfig(k=k, algorithm="tb", b0=512, max_rounds=80, seed=1,
                    backend="mesh", data_axes=("data",),
                    capacity_floor=256)

# -- tail-row fix: every real row labeled on non-divisible N -------------
out = api.fit(X, cfg, mesh=mesh2)
assert out.converged
n_unlabeled = int((out.labels < 0).sum())
assert n_unlabeled == 0, f"{n_unlabeled} real rows never labeled"
assert out.telemetry[-1].b == n, out.telemetry[-1].b
print(f"tail-row fix: converged, all {n} rows labeled, "
      f"n_active == {out.telemetry[-1].b}")

with tempfile.TemporaryDirectory() as ckdir:
    ck = api.CheckpointConfig(checkpoint_dir=ckdir, save_every=4)

    # -- kill at round 9, resume on the SAME 2 shards: bit-identical -----
    api.fit(X, dataclasses.replace(cfg, max_rounds=9, checkpoint=ck),
            mesh=mesh2)
    km = api.NestedKMeans(dataclasses.replace(cfg, checkpoint=ck),
                          mesh=mesh2)
    km.fit(X, resume=True)
    np.testing.assert_array_equal(out.C, km.cluster_centers_)
    assert len(out.telemetry) == len(km.telemetry_)
    for ra, rb in zip(out.telemetry, km.telemetry_):
        da, db = ra.to_dict(), rb.to_dict()
        da.pop("t"), db.pop("t")        # wall-clock differs across runs
        assert da == db, (da, db)
    print(f"same-shard resume: bit-identical over "
          f"{len(out.telemetry)} telemetry rounds")

mse_a = float(full_mse(jnp.asarray(X), jnp.asarray(out.C)))

with tempfile.TemporaryDirectory() as ckdir:
    ck = api.CheckpointConfig(checkpoint_dir=ckdir, save_every=4)
    api.fit(X, dataclasses.replace(cfg, max_rounds=9, checkpoint=ck),
            mesh=mesh2)

    # -- elastic: the 2-shard checkpoint resumes on 4 shards -------------
    mesh4 = jax.make_mesh((4, 1), ("data", "model"))
    km4 = api.NestedKMeans(dataclasses.replace(cfg, checkpoint=ck),
                           mesh=mesh4)
    km4.fit(X, resume=True)
    assert km4.converged_ and (km4.outcome_.labels >= 0).all()
    mse4 = float(full_mse(jnp.asarray(X),
                          jnp.asarray(km4.cluster_centers_)))
    assert abs(mse_a - mse4) / mse_a < 0.05, (mse_a, mse4)
    print(f"elastic 2->4 shard resume: converged, mse {mse4:.5f} "
          f"(uninterrupted {mse_a:.5f})")

    # -- elastic: the same checkpoint resumes on the LocalEngine ---------
    kml = api.NestedKMeans(dataclasses.replace(
        cfg, backend="local", checkpoint=ck))
    kml.fit(X, resume=True)
    assert kml.converged_
    msel = float(full_mse(jnp.asarray(X),
                          jnp.asarray(kml.cluster_centers_)))
    assert abs(mse_a - msel) / mse_a < 0.05, (mse_a, msel)
    print(f"elastic mesh->local resume: converged, mse {msel:.5f}")

print("resume-mesh smoke OK")
