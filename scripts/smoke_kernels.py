"""Kernel dispatch plane end-to-end check (run via tests/test_kernels.py).

Gates the PR 9 dispatch refactor with 8 forced host devices:

  1. op parity — the fused nested-round Pallas kernel (interpret mode
     off-TPU) matches the jnp oracle at an awkward shape: labels exact,
     floats close;
  2. fit parity, local — full `run_loop` fits with
     ``kernel_backend="pallas"`` are bit-identical in labels to
     ``kernel_backend="ref"`` for both bound families (tb/hamerly2
     rides the fused kernel, gb/none the bound-free variant), and the
     outcome surfaces the resolved `KernelPlan`;
  3. fit parity, XL — same bit-parity on a (4 data, 2 model) mesh
     (m=2: per-op Pallas kernels through the plan) and on (8, 1)
     (m=1: the fused round, model-axis collectives are identity);
  4. auditors stay green with the plan active — retrace (local + xl)
     proves the plan is a constant static (one trace per (b, capacity)
     bucket, nothing else keys the jit cache) and hostsync proves the
     fused dispatch adds no device->host syncs.
"""
from repro.util.env import force_host_device_count
force_host_device_count(8)

import dataclasses

import jax
import numpy as np

from repro import api
from repro.analysis import hostsync, retrace
from repro.kernels.fused_round import (fused_nested_round_pallas,
                                       fused_nested_round_ref)


def blobs(n, k, d, seed=0):
    """Well-separated blobs: inter-center distance dwarfs float32 ulp
    drift in the S->C reduction, so correct kernels give BIT-equal
    labels, not merely close ones."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d)) * 12.0
    a = rng.integers(0, k, size=n)
    return (centers[a] + rng.normal(size=(n, d))).astype(np.float32)


# -- 1. fused kernel vs the jnp oracle at an awkward shape ------------------
rng = np.random.default_rng(1)
n, k, d = 300, 48, 7                       # n % bn != 0, k % 128 != 0
x = rng.normal(size=(n, d)).astype(np.float32)
c = rng.normal(size=(k, d)).astype(np.float32)
a_prev = rng.integers(-1, k, size=n).astype(np.int32)
settled = rng.random(n) < 0.3
d_keep = rng.random(n).astype(np.float32)
lb_keep = rng.random(n).astype(np.float32)
valid = rng.random(n) < 0.9
args = (x, c, a_prev, settled, d_keep, lb_keep, valid)
outs_p = fused_nested_round_pallas(*args, bn=64, interpret=True)
outs_r = fused_nested_round_ref(*args)
np.testing.assert_array_equal(np.asarray(outs_p[0]), np.asarray(outs_r[0]))
for op, orf, name in zip(outs_p[1:], outs_r[1:],
                         ("d", "lb", "S", "v", "sse")):
    np.testing.assert_allclose(np.asarray(op), np.asarray(orf),
                               atol=2e-5, rtol=2e-5, err_msg=name)
print("op parity: fused nested round == oracle at (300, 48, 7)")


# -- 2. full fits, local: pallas labels bit-equal to ref --------------------
def fit_pair(cfg, X, mesh=None):
    out_r = api.fit(X, dataclasses.replace(cfg, kernel_backend="ref"),
                    mesh=mesh)
    out_p = api.fit(X, dataclasses.replace(cfg, kernel_backend="pallas"),
                    mesh=mesh)
    np.testing.assert_array_equal(out_p.labels, out_r.labels)
    assert len(out_p.telemetry) == len(out_r.telemetry)
    assert (out_p.kernel_plan or {}).get("backend") == "pallas", \
        out_p.kernel_plan
    return out_p


X = blobs(2048, 16, 8)
cfg = api.FitConfig(k=16, algorithm="tb", b0=256, max_rounds=60, seed=0,
                    capacity_floor=64)
out = fit_pair(cfg, X)
print(f"local tb (fused hamerly2): labels bit-equal over "
      f"{len(out.telemetry)} rounds, plan={out.kernel_plan['backend']}"
      f"/bn={out.kernel_plan['bn']}")

Xg = blobs(1536, 9, 12, seed=2)
fit_pair(api.FitConfig(k=9, algorithm="gb", b0=100, max_rounds=60,
                       seed=0), Xg)
print("local gb (fused bounds-free): labels bit-equal")

# -- 3. full fits, XL: m=2 (per-op kernels) and m=1 (fused round) ----------
cfg_xl = api.FitConfig(k=16, algorithm="tb", b0=256, max_rounds=60,
                       seed=0, backend="xl", data_axes=("data",),
                       model_axis="model", capacity_floor=64)
fit_pair(cfg_xl, X, mesh=jax.make_mesh((4, 2), ("data", "model")))
print("xl (4,2) m=2: labels bit-equal")
fit_pair(cfg_xl, X, mesh=jax.make_mesh((8, 1), ("data", "model")))
print("xl (8,1) m=1 (fused): labels bit-equal")

# -- 4. auditors with the plan active --------------------------------------
for backend in ("local", "xl"):
    v = retrace.audit_backend(backend, kernel_backend="pallas")
    assert not v, [str(x) for x in v]
    print(f"retrace[{backend}] with pallas plan: one trace per bucket")
v = hostsync.audit_backend("local", kernel_backend="pallas")
assert not v, [str(x) for x in v]
print("hostsync[local] with pallas plan: zero unsanctioned syncs")

print("kernels smoke OK")
