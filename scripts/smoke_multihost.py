"""Multihost-engine end-to-end check (run via tests/test_multihost.py).

Three layers, mirroring the other smokes:

Parent process (4 forced host devices, single process):

  1. mesh <-> multihost bit-parity — a `backend="multihost"` fit on a
     1-process mesh is bit-identical (centroids, labels, per-point
     state, round-by-round schedule) to the MeshEngine on the same
     flat data mesh, with N % n_shards != 0 so the tail-row masking is
     live;
  2. elkan on the sharded engines — `bounds="elkan"` now runs under
     shard_map (the n_valid plumbing): local-vs-mesh parity on
     N % n_shards != 0 (same assignments, matching centroids) and the
     XLEngine's model-sharded l matrix on a (2 data, 2 model) mesh;
  3. sharded partial_fit — the estimator streams through the
     MeshEngine and matches the local streaming path.

Child processes (2 x 2 forced host devices, a REAL jax.distributed
cluster over a localhost coordinator):

  4. replicated control flow — both processes run the shared loop and
     must produce IDENTICAL telemetry and b_global/capacity/patience
     traces (the loop's process-replication invariant), with every real
     row labeled;
  5. kill-one-process resume — a 2-process fit checkpointed mid-run
     (process-0-only writes) restores onto a 1-process MeshEngine at
     the same global shard count and continues with the IDENTICAL
     round-by-round schedule to the uninterrupted 2-process run (the
     float stats match to collective-reduction-order tolerance: a
     cross-process gloo psum and a single-process all-reduce may sum
     the same 4 shard partials in different orders, so cross-TOPOLOGY
     continuation is not bitwise — same-topology resume is, as layer 1
     and scripts/smoke_resume_mesh.py assert).
"""
import os
import sys

# ---------------------------------------------------------------------------
# child: one process of the 2-process CPU cluster
# ---------------------------------------------------------------------------

N_PROC = 2
DEV_PER_PROC = 2
K, D, N = 8, 16, 4001            # 4001 % 4 != 0: tail rows exist


def _dataset():
    import numpy as np
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(K, D)) * 5
    return (centers[rng.integers(0, K, N)]
            + rng.normal(size=(N, D))).astype(np.float32)


def child(proc: int, port: str, workdir: str) -> None:
    from repro.util.env import force_host_device_count
    force_host_device_count(DEV_PER_PROC)
    import dataclasses
    import json

    import numpy as np

    from repro import api

    X = _dataset()
    ck_full = api.CheckpointConfig(
        checkpoint_dir=os.path.join(workdir, "ck_full"), save_every=4)
    cfg = api.FitConfig(
        k=K, algorithm="tb", b0=512, max_rounds=80, seed=1,
        backend="multihost", capacity_floor=256,
        coordinator_address=f"localhost:{port}",
        num_processes=N_PROC, process_id=proc, checkpoint=ck_full)

    # -- 4. full fit: every process records its control-flow trace ------
    km = api.NestedKMeans(cfg)
    run = km.engine.begin(X, cfg.resolve(N))
    trace = []
    out = api.run_loop(run, cfg.resolve(N), trace=trace)
    assert out.converged
    n_unlabeled = int((out.labels < 0).sum())
    assert n_unlabeled == 0, f"{n_unlabeled} real rows never labeled"
    assert out.telemetry[-1].b == N, out.telemetry[-1].b

    telem = [r.to_dict() for r in out.telemetry]
    for r in telem:
        r.pop("t")                       # wall-clock is process-local
    with open(os.path.join(workdir, f"trace_{proc}.json"), "w") as f:
        json.dump({"trace": trace, "telemetry": telem}, f)
    if proc == 0:
        np.save(os.path.join(workdir, "C_full.npy"), out.C)
        np.save(os.path.join(workdir, "labels_full.npy"), out.labels)

    # -- 5a. the interrupted fit: killed at round 9 ----------------------
    ck_kill = api.CheckpointConfig(
        checkpoint_dir=os.path.join(workdir, "ck_kill"), save_every=4)
    cfg_kill = dataclasses.replace(cfg, max_rounds=9, checkpoint=ck_kill)
    api.fit(X, cfg_kill)

    # -- 5b. same-topology resume ON the 2-process cluster: exercises
    # the coordinator-read + broadcast restore (resolve_resume /
    # _read_canonical) and must be bit-identical to the uninterrupted
    # 2-process run. Only process 0 gets a copy of the checkpoints
    # (the parent's own resume test still needs ck_kill mid-run) —
    # process 1's directory stays EMPTY, proving the restore needs no
    # shared filesystem: the coordinator reads, everyone else receives
    # the broadcast.
    import shutil
    # per-process dirs on purpose: process 0's holds the checkpoints,
    # process 1's is brand-new and empty
    my_dir = os.path.join(workdir, f"ck_kill_child_{proc}")
    if proc == 0:
        shutil.copytree(os.path.join(workdir, "ck_kill"), my_dir)
    ck_child = api.CheckpointConfig(checkpoint_dir=my_dir, save_every=4)
    km2 = api.NestedKMeans(dataclasses.replace(cfg, checkpoint=ck_child))
    km2.fit(X, resume=True)
    assert km2.converged_
    np.testing.assert_array_equal(out.C, km2.cluster_centers_)
    resumed = [r.to_dict() for r in km2.telemetry_]
    for r in resumed:
        r.pop("t")
    assert resumed == telem, "2-process resume diverged from the " \
        "uninterrupted run"
    if proc == 0:
        print("2-process multihost resume: bit-identical to the "
              "uninterrupted run", flush=True)
    print(f"[child {proc}] fit + interrupted fit + resume done "
          f"({len(telem)} rounds)", flush=True)


# ---------------------------------------------------------------------------
# parent: single-process checks + cluster orchestration
# ---------------------------------------------------------------------------

def main() -> None:
    from repro.util.env import force_host_device_count
    force_host_device_count(4)
    import dataclasses
    import json
    import socket
    import subprocess
    import tempfile

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import api
    from repro.core.state import full_mse
    from repro.launch.mesh import make_multihost_mesh

    X = _dataset()
    mesh1d = make_multihost_mesh()           # (4,) over the forced host
    cfg = api.FitConfig(k=K, algorithm="tb", b0=512, max_rounds=80,
                        seed=1, capacity_floor=256)

    # -- 1. mesh <-> multihost bit-parity on one process -----------------
    out_mesh = api.fit(X, dataclasses.replace(cfg, backend="mesh"),
                       mesh=mesh1d)
    out_mh = api.fit(X, dataclasses.replace(cfg, backend="multihost"),
                     mesh=mesh1d)
    assert out_mesh.converged and out_mh.converged
    np.testing.assert_array_equal(out_mesh.C, out_mh.C)
    np.testing.assert_array_equal(out_mesh.labels, out_mh.labels)
    np.testing.assert_array_equal(np.asarray(out_mesh.state.points.d),
                                  np.asarray(out_mh.state.points.d))
    np.testing.assert_array_equal(np.asarray(out_mesh.state.points.lb),
                                  np.asarray(out_mh.state.points.lb))
    assert len(out_mesh.telemetry) == len(out_mh.telemetry)
    for ra, rb in zip(out_mesh.telemetry, out_mh.telemetry):
        da, db = ra.to_dict(), rb.to_dict()
        da.pop("t"), db.pop("t")
        assert da == db, (da, db)
    assert int((out_mh.labels < 0).sum()) == 0
    print(f"mesh<->multihost(1 process) bit-identical over "
          f"{len(out_mh.telemetry)} rounds (N={N} on 4 shards)")
    mse_full = float(full_mse(jnp.asarray(X), jnp.asarray(out_mesh.C)))

    # same-topology multihost resume is bitwise: interrupt at round 9,
    # restore through the coordinator-written checkpoint, continue
    with tempfile.TemporaryDirectory() as ckdir:
        ck = api.CheckpointConfig(checkpoint_dir=ckdir, save_every=4)
        cfg_mh = dataclasses.replace(cfg, backend="multihost",
                                     checkpoint=ck)
        api.fit(X, dataclasses.replace(cfg_mh, max_rounds=9),
                mesh=mesh1d)
        km_r = api.NestedKMeans(cfg_mh, mesh=mesh1d)
        km_r.fit(X, resume=True)
        assert km_r.converged_
        np.testing.assert_array_equal(out_mh.C, km_r.cluster_centers_)
        print("multihost kill-and-resume (same topology): bit-identical")

    # -- 2. elkan on the sharded engines ---------------------------------
    out_le = api.fit(X, dataclasses.replace(cfg, bounds="elkan"))
    mesh22 = jax.make_mesh((2, 2), ("data", "model"))
    out_me = api.fit(X, dataclasses.replace(cfg, bounds="elkan",
                                            backend="mesh"), mesh=mesh22)
    assert out_le.converged and out_me.converged
    # local and mesh process the same point set each round (the union
    # of shard prefixes IS the shuffle prefix) with exact bounds, so
    # assignments agree; stats differ only by float summation order.
    np.testing.assert_array_equal(out_le.labels, out_me.labels)
    np.testing.assert_allclose(out_le.C, out_me.C, atol=1e-4)
    assert [r.b for r in out_le.telemetry] == \
        [r.b for r in out_me.telemetry]
    print(f"elkan local<->mesh parity: labels identical, "
          f"|dC| <= 1e-4 over {len(out_me.telemetry)} rounds")

    out_xe = api.fit(X, dataclasses.replace(
        cfg, bounds="elkan", backend="xl", model_axis="model"),
        mesh=mesh22)
    assert out_xe.converged
    np.testing.assert_array_equal(out_le.labels, out_xe.labels)
    np.testing.assert_allclose(out_le.C, out_xe.C, atol=1e-4)
    print("elkan on XL (2 data x 2 model shards): labels identical "
          "to local")

    # -- 3. sharded partial_fit ------------------------------------------
    # same seed -> same shuffle prefix -> same C0 on both engines; the
    # streamed batches are then identical point sets, so the running
    # stats agree up to float summation order
    km_l = api.NestedKMeans(api.FitConfig(k=K, b0=512, seed=3))
    km_m = api.NestedKMeans(api.FitConfig(k=K, b0=512, seed=3,
                                          backend="mesh"), mesh=mesh1d)
    km_l.fit(X[:2000])
    km_m.fit(X[:2000])
    for i in range(3):
        batch = X[2000 + i * 667:2000 + (i + 1) * 667]  # 667 % 4 != 0
        km_l.partial_fit(batch)
        km_m.partial_fit(batch)
    assert km_m.counts_.sum() == km_l.counts_.sum()
    assert km_m.telemetry_[-1].b == 667      # pads masked, not counted
    np.testing.assert_allclose(km_l.cluster_centers_,
                               km_m.cluster_centers_, atol=1e-3)
    print("sharded partial_fit: 3 non-divisible batches through the "
          "MeshEngine match the local stream")

    # -- 4 + 5. the real 2-process cluster -------------------------------
    with socket.socket() as s:
        s.bind(("localhost", 0))
        port = str(s.getsockname()[1])
    with tempfile.TemporaryDirectory() as workdir:
        env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
        env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
        procs = [subprocess.Popen(
            [sys.executable, os.path.abspath(__file__),
             "--child", str(i), port, workdir],
            env=env, cwd=os.path.dirname(os.path.dirname(
                os.path.abspath(__file__))))
            for i in range(N_PROC)]
        for p in procs:
            assert p.wait(timeout=600) == 0, "child process failed"

        traces = []
        for i in range(N_PROC):
            with open(os.path.join(workdir, f"trace_{i}.json")) as f:
                traces.append(json.load(f))
        # the replication invariant: identical round-by-round control
        # flow — b_global / capacity / quiet_rounds — AND identical
        # telemetry (batch_mse etc. are replicated device scalars, so
        # even the floats must agree bit for bit)
        assert traces[0]["trace"] == traces[1]["trace"]
        assert traces[0]["telemetry"] == traces[1]["telemetry"]
        n_rounds = len(traces[0]["telemetry"])
        print(f"2-process cluster: both processes ran the identical "
              f"b_global/capacity/patience trace over {n_rounds} rounds")

        C2 = np.load(os.path.join(workdir, "C_full.npy"))
        labels2 = np.load(os.path.join(workdir, "labels_full.npy"))
        assert int((labels2 < 0).sum()) == 0
        mse2 = float(full_mse(jnp.asarray(X), jnp.asarray(C2)))
        assert abs(mse_full - mse2) / mse_full < 0.05, (mse_full, mse2)
        print(f"2-process fit: all {N} rows labeled, mse {mse2:.5f} "
              f"(1-process {mse_full:.5f})")

        # -- 5b. the kill-one-process resume: 2-process checkpoint ->
        # 1-process MeshEngine at the SAME global shard count (4), so
        # the continuation must be bit-identical to the uninterrupted
        # 2-process run
        ck = api.CheckpointConfig(
            checkpoint_dir=os.path.join(workdir, "ck_kill"), save_every=4)
        km = api.NestedKMeans(dataclasses.replace(
            cfg, backend="mesh", checkpoint=ck), mesh=mesh1d)
        km.fit(X, resume=True)
        assert km.converged_
        # identical schedule, round for round; floats to collective-
        # reduction-order tolerance (see module docstring)
        resumed = [r.to_dict() for r in km.telemetry_]
        want = traces[0]["telemetry"]
        assert len(resumed) == len(want)
        for ra, wa in zip(resumed, want):
            for key in ("round", "b", "n_changed", "n_recomputed",
                        "grow"):
                assert ra[key] == wa[key], (ra, wa)
            if wa["batch_mse"] is not None:
                assert abs(ra["batch_mse"] - wa["batch_mse"]) \
                    <= 1e-4 * abs(wa["batch_mse"]), (ra, wa)
        np.testing.assert_allclose(C2, km.cluster_centers_, atol=1e-5)
        print(f"kill-one-process resume: 2-process checkpoint continued "
              f"on 1 process with the identical {len(resumed)}-round "
              f"schedule (floats within reduction-order tolerance)")

    print("multihost smoke OK")


if __name__ == "__main__":
    if "--child" in sys.argv:
        i = sys.argv.index("--child")
        child(int(sys.argv[i + 1]), sys.argv[i + 2], sys.argv[i + 3])
    else:
        main()
