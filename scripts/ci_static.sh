#!/usr/bin/env bash
# Static + invariant gate: ruff (when installed) and the four JAX-aware
# checkers in src/repro/analysis/:
#
#   lint      replicated-control-flow AST lint over the loop + engines
#   hostsync  device->host sync audit of real fits (transfer_guard +
#             array-conversion interceptor inside LoopAudit scopes)
#   retrace   actual jit trace count vs the analytic pow2 bucket lattice
#   donation  donate_argnums jits must alias, not copy (memory_analysis)
#
# Then `--selftest` replants each checker's historical bug class
# (PR 2 device-scalar branch, PR 6 copying shard_map donation, the
# rho-keyed retrace) and fails if any checker has lost its teeth.
#
# Runtime auditors run real multi-device fits: ~2-3 minutes total.
# `ci_static.sh lint` runs just the AST lint (sub-second, no jax).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

# ruff is not baked into every container image; the config (ruff.toml)
# is checked in so any environment that has it enforces the same rules.
if command -v ruff >/dev/null 2>&1; then
    ruff check src tests scripts benchmarks
else
    echo "[ruff] not installed — skipping (config: ruff.toml)"
fi

if [ "$#" -gt 0 ]; then
    python -m repro.analysis "$@"
    exit 0
fi

python -m repro.analysis lint
python -m repro.analysis hostsync retrace donation --backends local,mesh,xl
python -m repro.analysis all --selftest
