"""Dev smoke: the observability plane end to end, on every backend.

Run via subprocess (forces 4 host devices before jax initialises):

    PYTHONPATH=src python scripts/smoke_obs.py

Per backend (local / mesh / xl / multihost single-process) this drives
one TRACED fit through `run_loop` with a schedule-trace list attached
and asserts the two sides agree:

  * the trace directory parses (`repro.obs.read_events`) and its
    per-round "round" events are exactly the in-loop rounds — one per
    entry of the loop's own schedule trace (the control-flow
    fingerprint `scripts/smoke_multihost.py` compares across
    processes);
  * `summarize` aggregates them (rounds, k-scans, span timings);
  * the k-scan total equals the telemetry's `n_recomputed` sum.

Then the invariant checkers run over the INSTRUMENTED loop:

  * the replicated-control-flow AST lint stays clean;
  * the host-sync auditor stays clean on all four backends WITH a
    FitObserver attached (`hostsync.audit_backend(trace_dir=...)`) —
    tracing adds zero unsanctioned device->host syncs.
"""
from repro.util.env import force_host_device_count
force_host_device_count(4)

import tempfile

import numpy as np

BACKENDS = ("local", "mesh", "xl", "multihost")


def traced_fit(backend: str, trace_dir: str):
    import jax

    from repro.analysis.retrace import _mesh_for
    from repro.api.config import FitConfig
    from repro.api.engines import make_engine
    from repro.api.loop import run_loop
    from repro.obs import FitObserver

    rng = np.random.default_rng(0)
    n, d, k = 4096, 16, 8
    X = rng.normal(size=(n, d)).astype(np.float32)
    X_val = rng.normal(size=(512, d)).astype(np.float32)
    config = FitConfig(k=k, b0=256, seed=0, backend=backend,
                       max_rounds=24, eval_every=4,
                       capacity_floor=32).resolve(n)
    engine = make_engine(config, mesh=_mesh_for(backend, config))
    run = engine.begin(X, config, X_val=X_val)
    obs = FitObserver(trace_dir, process_id=jax.process_index(),
                      k=k, d=d, meta={"backend": backend,
                                      "smoke": "obs"})
    schedule = []
    try:
        out = run_loop(run, config, trace=schedule, obs=obs)
    finally:
        obs.close()
    return out, schedule


def main():
    from repro.obs import read_events, summarize

    for backend in BACKENDS:
        td = tempfile.mkdtemp(prefix=f"smoke-obs-{backend}-")
        out, schedule = traced_fit(backend, td)
        events = read_events(td)
        rounds = [e for e in events if e.get("name") == "round"]
        # tb fits append one schedule-trace entry per in-loop round,
        # and the observer emits one "round" event per in-loop round:
        # the two independently-built records must agree exactly
        assert len(rounds) == len(schedule), \
            f"{backend}: {len(rounds)} round events vs " \
            f"{len(schedule)} schedule-trace entries"
        s = summarize(events)
        assert s["rounds"] == len(schedule), (backend, s["rounds"])
        kscans_tel = sum(r.n_recomputed for r in out.telemetry)
        assert s["kscans_total"] == kscans_tel, \
            f"{backend}: obs kscans {s['kscans_total']} vs " \
            f"telemetry {kscans_tel}"
        assert s["spans"], f"{backend}: no span timings recorded"
        print(f"{backend}: rounds={s['rounds']} "
              f"kscans={s['kscans_total']} "
              f"jit_traces={s['jit_traces']} "
              f"round_s_total={s['round_s_total']:.3f} "
              f"spans={sorted(s['spans'])}")

    from repro.analysis import replicated_lint
    violations = replicated_lint.run()
    assert not violations, \
        f"replicated lint on the instrumented loop: {violations}"
    print("replicated lint: clean")

    from repro.analysis import hostsync
    for backend in BACKENDS:
        td = tempfile.mkdtemp(prefix=f"smoke-obs-hs-{backend}-")
        found = hostsync.audit_backend(backend=backend, trace_dir=td)
        assert not found, f"{backend} hostsync with tracing on: {found}"
        n_ev = len(read_events(td))
        assert n_ev > 0, f"{backend}: audited fit wrote no events"
        print(f"{backend}: hostsync clean with tracing on "
              f"({n_ev} events)")

    print("obs smoke OK")


if __name__ == "__main__":
    main()
