"""XL-engine end-to-end check (run via tests/test_distributed_xl.py).

Promoted from the one-shot round smoke: the centroid-sharded path is
now loop-driven by `repro.api.engines.xl.XLEngine`, and this script gates
the whole stack with 8 forced host devices:

  1. round oracle — `make_xl_round` + `make_dp_round` match one exact
     Lloyd-style update from the same centroids;
  2. sharded top-2 fold parity — `assign_top2_sharded`'s log-depth tree
     fold matches single-device `ops.assign_top2` bit for bit,
     including both top-2 centroids living in the SAME model shard and
     exact-tie centroids duplicated ACROSS shard boundaries;
  3. engine e2e — a full `run_loop` XL fit is bit-identical to the
     LocalEngine on a (1 data, 1 model) mesh and to the MeshEngine on
     (2 data, 1 model); on (2, 2) with N % n_shards != 0 it converges
     with every real row labeled and n_active == N_real;
  4. checkpoint/elastic-restart — XL->XL resume is bit-identical;
     XL->local and local->XL restores converge to the same quality;
  5. config rho reaches the controller (growth under rho=0.5) and the
     gb (bounds="none") family runs sharded.
"""
from repro.util.env import force_host_device_count
force_host_device_count(8)

import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import jax.ops
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import api
from repro.core.distributed import (assign_top2_sharded, make_dp_round,
                                    make_xl_round, shard_map_compat)
from repro.core.state import full_mse
from repro.kernels import ops, ref

mesh = jax.make_mesh((4, 2), ("data", "model"))

rng = np.random.default_rng(0)
k, d, n = 16, 32, 8192
centers = rng.normal(size=(8, d)) * 5
X = (centers[rng.integers(0, 8, n)]
     + rng.normal(size=(n, d))).astype(np.float32)
C0 = jnp.asarray(rng.normal(size=(k, d)), jnp.float32)

# -- 1. one-shot rounds vs an exact Lloyd oracle ---------------------------
d2o = ref.pairwise_dist2(jnp.asarray(X), C0)
ao = jnp.argmin(d2o, axis=1)
So = jax.ops.segment_sum(jnp.asarray(X), ao, num_segments=k)
vo = jax.ops.segment_sum(jnp.ones(n), ao, num_segments=k)
Co = jnp.where((vo > 0)[:, None], So / jnp.maximum(vo, 1)[:, None], C0)

Xd = jax.device_put(jnp.asarray(X), NamedSharding(mesh, P(("data",), None)))
Cd = jax.device_put(C0, NamedSharding(mesh, P("model", None)))
Sd = jax.device_put(jnp.zeros((k, d), jnp.float32),
                    NamedSharding(mesh, P("model", None)))
vd = jax.device_put(jnp.zeros((k,), jnp.float32),
                    NamedSharding(mesh, P("model")))
round_fn = make_xl_round(mesh, k=k, data_axes=("data",),
                         model_axis="model")
C1, S1, v1, a, dd, dd2, grow, r, mse = round_fn(Xd, Cd, Sd, vd)

err_a = int(jnp.sum(a.astype(jnp.int32) != ao.astype(jnp.int32)))
err_C = float(jnp.max(jnp.abs(C1 - Co)))
# both returned distances are EUCLIDEAN now (no mixed units)
err_d = float(jnp.max(jnp.abs(dd * dd - jnp.min(d2o, axis=1))))
assert float(jnp.min(dd2 - dd)) >= 0.0, "d2 must dominate d1"
print(f"xl round: assign mismatches={err_a} "
      f"max|C-C_oracle|={err_C:.2e} mse={float(mse):.3f}")
assert err_a == 0 and err_C < 1e-3 and err_d < 1e-2

dpr = make_dp_round(mesh)
Xd8 = jax.device_put(jnp.asarray(X),
                     NamedSharding(mesh, P(("data", "model"), None)))
C1b, S1b, v1b, a_b, d_b, grow_b, r_b, mse_b = dpr(Xd8, C0)
err_a2 = int(jnp.sum(a_b.astype(jnp.int32) != ao.astype(jnp.int32)))
err_C2 = float(jnp.max(jnp.abs(C1b - Co)))
print(f"dp round: assign mismatches={err_a2} "
      f"max|C-C_oracle|={err_C2:.2e}")
assert err_a2 == 0 and err_C2 < 1e-3


# -- 2. sharded fold parity vs single-device ops.assign_top2 ---------------
def sharded_top2(x, C):
    def fn(xs, Cl):
        off = jax.lax.axis_index("model") * Cl.shape[0]
        return assign_top2_sharded(xs, Cl, model_axis="model",
                                   k_offset=off)
    sm = shard_map_compat(fn, mesh=mesh,
                          in_specs=(P(None, None), P("model", None)),
                          out_specs=(P(None), P(None), P(None)))
    return jax.jit(sm)(x, C)


xq = jnp.asarray(X[:512])
a_sh, d1_sh, d2_sh = sharded_top2(xq, C0)
a_1d, d1_1d, d2_1d = ops.assign_top2(xq, C0)
assert int(jnp.sum(a_sh != a_1d)) == 0
np.testing.assert_array_equal(np.asarray(d1_sh), np.asarray(d1_1d))
np.testing.assert_array_equal(np.asarray(d2_sh), np.asarray(d2_1d))

# same-shard top-2: centroids 2 and 3 (both in model shard 0) are the two
# nearest; cross-shard tie: C[5] == C[13] exactly (shards 0 and 1), so the
# fold must break the tie to the LOWER global index like argmin does
C_tie = np.array(C0, copy=True)
C_tie[3] = C_tie[2] + 1e-3
C_tie[13] = C_tie[5]
C_tie = jnp.asarray(C_tie)
x_tie = jnp.concatenate([C_tie[2:3] + 5e-4,      # nearest two in shard 0
                         C_tie[5:6]])            # dead tie across shards
a_t, d1_t, d2_t = sharded_top2(x_tie, C_tie)
a_r, d1_r, d2_r = ops.assign_top2(x_tie, C_tie)
np.testing.assert_array_equal(np.asarray(a_t), np.asarray(a_r))
np.testing.assert_array_equal(np.asarray(d1_t), np.asarray(d1_r))
np.testing.assert_array_equal(np.asarray(d2_t), np.asarray(d2_r))
assert int(a_t[0]) in (2, 3)             # both top-2 in model shard 0
assert int(a_t[1]) == 5                  # tie resolves to lower index
assert float(d1_t[1]) == 0.0 and float(d2_t[1]) == 0.0
print("fold parity: sharded top-2 == single-device (incl. same-shard "
      "top-2, cross-shard tie)")


# -- 3. XLEngine through run_loop ------------------------------------------
def telemetry_equal(a, b):
    """Schedule decisions (b, grow, counts, evals) must match EXACTLY;
    batch_mse is a pure-telemetry f32 sum whose in-graph reduction
    order differs between shard_map and plain-jit programs — the
    per-point distances are bit-identical (asserted via the state
    below), so it is compared to 2 ulp instead."""
    assert len(a) == len(b), (len(a), len(b))
    for ra, rb in zip(a, b):
        da, db = ra.to_dict(), rb.to_dict()
        da.pop("t"), db.pop("t")
        ma, mb = da.pop("batch_mse"), db.pop("batch_mse")
        assert da == db, (da, db)
        if ma is not None or mb is not None:
            assert abs(ma - mb) <= 4e-7 * abs(mb), (ra.round, ma, mb)


ke, de, ne = 8, 16, 4001                 # 4001: indivisible by 2 and 4
centers_e = rng.normal(size=(ke, de)) * 5
Xe = (centers_e[rng.integers(0, ke, ne)]
      + rng.normal(size=(ne, de))).astype(np.float32)
cfg = api.FitConfig(k=ke, algorithm="tb", b0=512, max_rounds=80, seed=1,
                    backend="xl", data_axes=("data",), model_axis="model",
                    capacity_floor=256)

mesh11 = jax.make_mesh((1, 1), ("data", "model"))
out_xl11 = api.fit(Xe, cfg, mesh=mesh11)
out_loc = api.fit(Xe, dataclasses.replace(cfg, backend="local"))
assert out_xl11.converged
np.testing.assert_array_equal(out_xl11.C, out_loc.C)
np.testing.assert_array_equal(out_xl11.labels, out_loc.labels)
np.testing.assert_array_equal(np.asarray(out_xl11.state.points.d),
                              np.asarray(out_loc.state.points.d))
np.testing.assert_array_equal(np.asarray(out_xl11.state.points.lb),
                              np.asarray(out_loc.state.points.lb))
telemetry_equal(out_xl11.telemetry, out_loc.telemetry)
print(f"engine e2e: XL(1,1) == LocalEngine bit-identically over "
      f"{len(out_loc.telemetry)} rounds (schedule + centroids)")

mesh21 = jax.make_mesh((2, 1), ("data", "model"))
out_xl21 = api.fit(Xe, cfg, mesh=mesh21)
out_mesh = api.fit(Xe, dataclasses.replace(cfg, backend="mesh"),
                   mesh=mesh21)
np.testing.assert_array_equal(out_xl21.C, out_mesh.C)
np.testing.assert_array_equal(out_xl21.labels, out_mesh.labels)
telemetry_equal(out_xl21.telemetry, out_mesh.telemetry)
print("engine e2e: XL(2,1) == MeshEngine(2) bit-identically")

mesh22 = jax.make_mesh((2, 2), ("data", "model"))
out22 = api.fit(Xe, cfg, mesh=mesh22)
assert out22.converged
assert int((out22.labels < 0).sum()) == 0, "real rows left unlabeled"
assert out22.telemetry[-1].b == ne      # final record capped at N_real
assert any(r.b == ne for r in out22.telemetry if r.batch_mse is not None)
mse22 = float(full_mse(jnp.asarray(Xe), jnp.asarray(out22.C)))
mse_ref = float(full_mse(jnp.asarray(Xe), jnp.asarray(out_loc.C)))
assert abs(mse22 - mse_ref) / mse_ref < 0.05, (mse22, mse_ref)
print(f"engine e2e: XL(2,2) on N={ne} converged, all rows labeled, "
      f"n_active == N_real, mse {mse22:.5f} (local {mse_ref:.5f})")

# -- 4. checkpoint / elastic restart ---------------------------------------
with tempfile.TemporaryDirectory() as ckdir:
    ck = api.CheckpointConfig(checkpoint_dir=ckdir, save_every=4)
    api.fit(Xe, dataclasses.replace(cfg, max_rounds=9, checkpoint=ck),
            mesh=mesh22)
    km = api.NestedKMeans(dataclasses.replace(cfg, checkpoint=ck),
                          mesh=mesh22)
    km.fit(Xe, resume=True)
    np.testing.assert_array_equal(out22.C, km.cluster_centers_)
    telemetry_equal(out22.telemetry, km.telemetry_)
    print("checkpoint: XL->XL resume bit-identical")

with tempfile.TemporaryDirectory() as ckdir:
    ck = api.CheckpointConfig(checkpoint_dir=ckdir, save_every=4)
    api.fit(Xe, dataclasses.replace(cfg, max_rounds=9, checkpoint=ck),
            mesh=mesh22)
    kml = api.NestedKMeans(dataclasses.replace(cfg, backend="local",
                                               checkpoint=ck))
    kml.fit(Xe, resume=True)
    assert kml.converged_
    msel = float(full_mse(jnp.asarray(Xe),
                          jnp.asarray(kml.cluster_centers_)))
    assert abs(msel - mse_ref) / mse_ref < 0.05, (msel, mse_ref)

with tempfile.TemporaryDirectory() as ckdir:
    ck = api.CheckpointConfig(checkpoint_dir=ckdir, save_every=4)
    api.fit(Xe, dataclasses.replace(cfg, backend="local", max_rounds=9,
                                    checkpoint=ck))
    kmx = api.NestedKMeans(dataclasses.replace(cfg, checkpoint=ck),
                           mesh=mesh22)
    kmx.fit(Xe, resume=True)
    assert kmx.converged_
    msex = float(full_mse(jnp.asarray(Xe),
                          jnp.asarray(kmx.cluster_centers_)))
    assert abs(msex - mse_ref) / mse_ref < 0.05, (msex, mse_ref)
print("checkpoint: XL<->local elastic restores converge to the same "
      "quality")

# -- 5. rho threading + the gb family sharded ------------------------------
out_rho = api.fit(Xe, dataclasses.replace(cfg, rho=0.5, max_rounds=12),
                  mesh=mesh22)
assert any(r.grow for r in out_rho.telemetry), \
    "rho=0.5 never reached the sharded controller"
out_gb = api.fit(Xe, dataclasses.replace(cfg, algorithm="gb"),
                 mesh=mesh22)
assert out_gb.converged and int((out_gb.labels < 0).sum()) == 0
print("rho threading + gb-on-xl OK")

print("xl smoke OK")
