"""Centroid-sharded (kmeans_xl) round smoke: exactness vs a Lloyd oracle.

Run via subprocess (tests/test_distributed_xl.py) with 8 forced host
devices; checks the `make_xl_round` centroid-sharded round AND the
optimized data-parallel fused round against one exact Lloyd-style
update from the same centroids. This is the CI gate the XL round keeps
until it grows its own Engine (see ROADMAP).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import jax.ops
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.distributed import make_dp_round, make_xl_round
from repro.kernels import ref

mesh = jax.make_mesh((4, 2), ("data", "model"))

rng = np.random.default_rng(0)
k, d, n = 16, 32, 8192
centers = rng.normal(size=(8, d)) * 5
X = (centers[rng.integers(0, 8, n)]
     + rng.normal(size=(n, d))).astype(np.float32)
C0 = jnp.asarray(rng.normal(size=(k, d)), jnp.float32)

# oracle: one exact lloyd-style round from C0
d2o = ref.pairwise_dist2(jnp.asarray(X), C0)
ao = jnp.argmin(d2o, axis=1)
So = jax.ops.segment_sum(jnp.asarray(X), ao, num_segments=k)
vo = jax.ops.segment_sum(jnp.ones(n), ao, num_segments=k)
Co = jnp.where((vo > 0)[:, None], So / jnp.maximum(vo, 1)[:, None], C0)

# centroid-sharded XL round: k=16 sharded over model=2
Xd = jax.device_put(jnp.asarray(X), NamedSharding(mesh, P(("data",), None)))
Cd = jax.device_put(C0, NamedSharding(mesh, P("model", None)))
Sd = jax.device_put(jnp.zeros((k, d), jnp.float32),
                    NamedSharding(mesh, P("model", None)))
vd = jax.device_put(jnp.zeros((k,), jnp.float32),
                    NamedSharding(mesh, P("model")))
round_fn = make_xl_round(mesh, k=k, data_axes=("data",),
                         model_axis="model")
C1, S1, v1, a, dd, d2, grow, r, mse = round_fn(Xd, Cd, Sd, vd)

err_a = int(jnp.sum(a.astype(jnp.int32) != ao.astype(jnp.int32)))
err_C = float(jnp.max(jnp.abs(C1 - Co)))
print(f"xl round: assign mismatches={err_a} "
      f"max|C-C_oracle|={err_C:.2e} mse={float(mse):.3f}")
assert err_a == 0 and err_C < 1e-3

# data-parallel fused round (the optimized kmeans_xl path)
dpr = make_dp_round(mesh)
Xd8 = jax.device_put(jnp.asarray(X),
                     NamedSharding(mesh, P(("data", "model"), None)))
C1b, S1b, v1b, a_b, d_b, grow_b, r_b, mse_b = dpr(Xd8, C0)
err_a2 = int(jnp.sum(a_b.astype(jnp.int32) != ao.astype(jnp.int32)))
err_C2 = float(jnp.max(jnp.abs(C1b - Co)))
print(f"dp round: assign mismatches={err_a2} "
      f"max|C-C_oracle|={err_C2:.2e}")
assert err_a2 == 0 and err_C2 < 1e-3
print("xl smoke OK")
