"""Dev smoke: distributed engine on 8 host devices (run via subprocess)."""
from repro.util.env import force_host_device_count
force_host_device_count(8)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fit
from repro.core.distributed import fit_distributed, make_xl_round
from repro.core.state import full_mse

mesh = jax.make_mesh((4, 2), ("data", "model"))

rng = np.random.default_rng(0)
k, d, n = 8, 32, 8192
centers = rng.normal(size=(k, d)) * 5
X = (centers[rng.integers(0, k, n)] + rng.normal(size=(n, d))).astype(np.float32)

res = fit_distributed(X, k, mesh, data_axes=("data",), b0=512,
                      rho=float("inf"), bounds="hamerly2", max_rounds=60,
                      seed=1)
mse_d = float(full_mse(jnp.asarray(X), jnp.asarray(res.C)))
res1 = fit(X, k, algorithm="tb", b0=512, rho=float("inf"),
           bounds="hamerly2", max_rounds=60, seed=1)
mse_1 = float(full_mse(jnp.asarray(X), jnp.asarray(res1.C)))
print(f"distributed tb-inf: rounds={len(res.telemetry)} conv={res.converged} mse={mse_d:.4f}")
print(f"single-host  tb-inf: rounds={len(res1.telemetry)} conv={res1.converged} mse={mse_1:.4f}")
assert res.converged and abs(mse_d - mse_1) / mse_1 < 0.05

# sharded-centroid XL round: k=16 sharded over model=2
k2 = 16
C0 = jnp.asarray(rng.normal(size=(k2, d)), jnp.float32)
from jax.sharding import NamedSharding, PartitionSpec as P
Xd = jax.device_put(jnp.asarray(X), NamedSharding(mesh, P(("data",), None)))
Cd = jax.device_put(C0, NamedSharding(mesh, P("model", None)))
Sd = jax.device_put(jnp.zeros((k2, d), jnp.float32), NamedSharding(mesh, P("model", None)))
vd = jax.device_put(jnp.zeros((k2,), jnp.float32), NamedSharding(mesh, P("model")))
round_fn = make_xl_round(mesh, k=k2, data_axes=("data",), model_axis="model")
C1, S1, v1, a, dd, d2, grow, r, mse = round_fn(Xd, Cd, Sd, vd)

# oracle: one exact lloyd-style round from C0
from repro.kernels import ref
d2o = ref.pairwise_dist2(jnp.asarray(X), C0)
ao = jnp.argmin(d2o, axis=1)
import jax.ops
So = jax.ops.segment_sum(jnp.asarray(X), ao, num_segments=k2)
vo = jax.ops.segment_sum(jnp.ones(n), ao, num_segments=k2)
Co = jnp.where((vo > 0)[:, None], So / jnp.maximum(vo, 1)[:, None], C0)
err_a = int(jnp.sum(a.astype(jnp.int32) != ao.astype(jnp.int32)))
err_C = float(jnp.max(jnp.abs(C1 - Co)))
print(f"xl round: assign mismatches={err_a} max|C-C_oracle|={err_C:.2e} mse={float(mse):.3f}")
assert err_a == 0 and err_C < 1e-3
print("distributed smoke OK")

# data-parallel fused round (the optimized kmeans_xl path)
from repro.core.distributed import make_dp_round
dpr = make_dp_round(mesh)
Xd8 = jax.device_put(jnp.asarray(X), NamedSharding(mesh, P(("data","model"), None)))
C1b, S1b, v1b, a_b, d_b, grow_b, r_b, mse_b = dpr(Xd8, C0)
err_a2 = int(jnp.sum(a_b.astype(jnp.int32) != ao.astype(jnp.int32)))
err_C2 = float(jnp.max(jnp.abs(C1b - Co)))
print(f"dp round: assign mismatches={err_a2} max|C-C_oracle|={err_C2:.2e}")
assert err_a2 == 0 and err_C2 < 1e-3
print("dp round OK")
