"""Figure 1: validation MSE vs wall time — lloyd / mb / mb-f / gb-inf /
tb-inf on infMNIST-like and RCV1-like data.

Checks the paper's headline claims:
  (1) mb-f dominates mb after ~one pass through the data,
  (2) gb-inf performs favourably vs mb-f,
  (3) tb-inf >> mb in MSE-vs-time and reaches lloyd-grade minima.
"""
from __future__ import annotations

import json
import math
from pathlib import Path

import numpy as np

from benchmarks import common
from repro import api

ART = Path(__file__).resolve().parent.parent / "artifacts" / "bench"

ALGOS = [
    ("lloyd", dict()),
    ("mb", dict(b0=2000)),
    ("mbf", dict(b0=2000)),
    ("gb", dict(b0=2000, rho=math.inf)),
    ("tb", dict(b0=2000, rho=math.inf, bounds="hamerly2")),
]


def run_dataset(ds: str, *, quick: bool, seeds=(0, 1)):
    X, Xv = common.dataset(ds, quick)
    k = 50
    budget = 20.0 if quick else 60.0
    results = {}
    for algo, kw in ALGOS:
        curves = []
        final = []
        for seed in seeds:
            cfg = api.FitConfig(k=k, algorithm=algo, max_rounds=3000,
                                time_budget_s=budget, eval_every=5,
                                seed=seed, **kw)
            res = api.fit(X, cfg, X_val=Xv)
            curves.append(res.telemetry)
            final.append(res.final_mse)
        key = algo if algo != "tb" else "tb-inf"
        key = key if algo != "gb" else "gb-inf"
        results[key] = {"final_mse": float(np.mean(final)),
                        "telemetry": curves[0]}
        print(f"  {ds:9s} {key:7s} final val MSE {np.mean(final):.5f}")
    return results


def main(quick: bool = True):
    print("== Figure 1: MSE vs time ==")
    ok = True
    out = {}
    for ds in ("infmnist", "rcv1"):
        r = run_dataset(ds, quick=quick)
        out[ds] = {k: v["final_mse"] for k, v in r.items()}
        grid = [5.0, 10.0, 20.0] if quick else [10.0, 30.0, 60.0]
        mb_c = common.mse_at_times(r["mb"]["telemetry"], grid)
        mbf_c = common.mse_at_times(r["mbf"]["telemetry"], grid)
        tb_c = common.mse_at_times(r["tb-inf"]["telemetry"], grid)
        ok &= common.check(
            f"{ds}: mb-f <= mb after ~1 pass",
            mbf_c[-1] <= mb_c[-1] * 1.02,
            f"(mbf {mbf_c[-1]:.5f} vs mb {mb_c[-1]:.5f})")
        ok &= common.check(
            f"{ds}: tb-inf beats mb at end of budget",
            tb_c[-1] <= mb_c[-1] * 1.02,
            f"(tb {tb_c[-1]:.5f} vs mb {mb_c[-1]:.5f})")
        ok &= common.check(
            f"{ds}: tb-inf reaches lloyd-grade MSE",
            out[ds]["tb-inf"] <= out[ds]["lloyd"] * 1.05,
            f"(tb {out[ds]['tb-inf']:.5f} vs lloyd {out[ds]['lloyd']:.5f})")
    ART.mkdir(parents=True, exist_ok=True)
    (ART / "fig1.json").write_text(json.dumps(out, indent=1))
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if main(quick=True) else 1)
