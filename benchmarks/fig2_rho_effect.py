"""Figures 2/3: the effect of rho on gb-rho and tb-rho.

Paper's findings to reproduce qualitatively:
  * tb-rho: bigger rho is better; rho=inf optimal (bounds make
    finetuning cheap, so late data addition costs nothing),
  * gb-rho: intermediate rho can win early, but large rho is fine late.
"""
from __future__ import annotations

import json
import math
from pathlib import Path

from benchmarks import common
from repro import api

ART = Path(__file__).resolve().parent.parent / "artifacts" / "bench"
RHOS = [1.0, 10.0, 100.0, math.inf]


def main(quick: bool = True):
    print("== Figures 2/3: rho sweep ==")
    X, Xv = common.dataset("infmnist", quick)
    k = 50
    budget = 15.0 if quick else 45.0
    out = {}
    for algo in ("gb", "tb"):
        out[algo] = {}
        for rho in RHOS:
            res = api.fit(X, api.FitConfig(
                k=k, algorithm=algo, rho=rho, b0=2000, max_rounds=3000,
                time_budget_s=budget, eval_every=5, seed=0), X_val=Xv)
            key = "inf" if math.isinf(rho) else str(int(rho))
            out[algo][key] = res.final_mse
            print(f"  {algo}-rho {key:>4s}: final val MSE "
                  f"{res.final_mse:.5f}")
    ok = common.check(
        "tb: rho=inf within 2% of best rho",
        out["tb"]["inf"] <= min(out["tb"].values()) * 1.02,
        f"(inf {out['tb']['inf']:.5f} best {min(out['tb'].values()):.5f})")
    ok &= common.check(
        "tb: rho=inf beats rho=1 (redundancy slowdown)",
        out["tb"]["inf"] <= out["tb"]["1"] * 1.02)
    ART.mkdir(parents=True, exist_ok=True)
    (ART / "fig2.json").write_text(json.dumps(out, indent=1))
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if main(quick=True) else 1)
