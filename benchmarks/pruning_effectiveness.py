"""Bound-family pruning study: hamerly2 vs elkan vs exponion at large k.

Runs every bound family on the SAME over-segmented `infmnist_like`
workload (k=256, shared init/seed/schedule, tb-inf) and records
per-round pruned-fraction and pair-distance-evaluation curves into
``artifacts/bench/pruning.json``, plus the cross-family gates of the
bounds="exponion" PR.

Two cost metrics per family — both recorded, nothing hidden:

  * ``pair_dist_evals`` — actual (point, centroid) distance
    evaluations a serial implementation performs. ``n_recomputed`` is
    counted in the family's native unit (kscan for none/hamerly2, pair
    for elkan/exponion — `repro.obs.efficiency.BOUNDS_WORK_UNIT`) and
    unit-converted here.
  * ``serial_pair_work`` — distance evals PLUS per-pair bound
    maintenance. For elkan this adds b*k per round: a serial elkan
    round must walk every seen point's k lower bounds (decay by p_j
    and test against the upper bound) even when nearly all tests
    prune, which is exactly the O(b*k) term that stops scaling at
    serving-scale k. hamerly2/exponion test O(1) bounds per point, so
    their work equals their evals.

Elkan is the distance-eval optimum of the classical family (its
per-pair bounds are the tightest), so the honest headline is the work
metric: exponion gets within a small factor of elkan's eval count at
0.1% of elkan's bound-state memory and none of its O(b*k) bound walk.

Gates (recorded under ``"gates"``):
  * exponion reaches 1.01x the best val MSE with <= 0.5x hamerly2's
    pair-distance evals;
  * exponion's serial pair work to the same target is strictly below
    elkan's;
  * exponion labels AND centroids are bit-equal to bounds="none".
"""
from __future__ import annotations

import json
import math
from pathlib import Path

import numpy as np

from benchmarks import common
from repro import api
from repro.api.config import bound_state_bytes
from repro.obs.efficiency import WorkModel

ART = Path(__file__).resolve().parent.parent / "artifacts" / "bench"

FAMILIES = ("none", "hamerly2", "elkan", "exponion")


def _run_family(X, X_val, *, k: int, bounds: str, max_rounds: int):
    cfg = api.FitConfig(k=k, algorithm="tb", b0=2048, rho=math.inf,
                        bounds=bounds, max_rounds=max_rounds,
                        eval_every=1, seed=0)
    res = api.fit(X, cfg, X_val=X_val)
    wm = WorkModel.for_bounds(k, X.shape[-1], bounds)
    evals, work, pruned, b_curve, val = [], [], [], [], []
    for t in res.telemetry:
        e = wm.pair_evals(t.n_recomputed)
        w = e + (t.b * k if bounds == "elkan" else 0)  # bound walk
        evals.append(int(e))
        work.append(int(w))
        pruned.append(1.0 - e / max(t.b * k, 1))
        b_curve.append(int(t.b))
        val.append(t.val_mse)
    return {
        "bounds": bounds,
        "rounds": len(res.telemetry),
        "pair_dist_evals": evals,
        "serial_pair_work": work,
        "pruned_fraction": pruned,
        "b": b_curve,
        "val_mse": val,
        "bound_state_bytes": bound_state_bytes(bounds, len(X), k),
        "_labels": np.asarray(res.labels),
        "_C": np.asarray(res.C),
    }


def _to_target(curve, vals, target):
    """Cumulative cost at the first round whose val MSE <= target."""
    cum = 0
    for c, v in zip(curve, vals):
        cum += c
        if v is not None and v <= target:
            return cum
    return None


def main(quick: bool = True):
    print("== Bound-family pruning at large k (over-segmented) ==")
    X, X_val = common.dataset("infmnist", quick)
    k = 256
    max_rounds = 60 if quick else 120
    runs = {f: _run_family(X, X_val, k=k, bounds=f,
                           max_rounds=max_rounds) for f in FAMILIES}

    best = min(r["val_mse"][-1] for r in runs.values())
    target = 1.01 * best
    for f, r in runs.items():
        r["pair_dist_evals_to_target"] = _to_target(
            r["pair_dist_evals"], r["val_mse"], target)
        r["serial_pair_work_to_target"] = _to_target(
            r["serial_pair_work"], r["val_mse"], target)
        print(f"  {f:9s} rounds={r['rounds']:3d} "
              f"evals_to_target={r['pair_dist_evals_to_target']} "
              f"work_to_target={r['serial_pair_work_to_target']} "
              f"state={r['bound_state_bytes'] >> 10}KiB")

    ex, h2, ek, nn = (runs[f] for f in
                      ("exponion", "hamerly2", "elkan", "none"))
    evals_ok = all(r["pair_dist_evals_to_target"] is not None
                   for r in runs.values())
    ratio_h2 = (ex["pair_dist_evals_to_target"] /
                h2["pair_dist_evals_to_target"]) if evals_ok else None
    ok = common.check(
        "every family reaches 1.01x best val MSE", evals_ok,
        f"target={target:.4f}")
    ok &= common.check(
        "exponion <= 0.5x hamerly2 pair-dist evals to target",
        evals_ok and ratio_h2 <= 0.5,
        f"ratio={ratio_h2:.3f}" if ratio_h2 is not None else "")
    ok &= common.check(
        "exponion serial pair work to target < elkan's",
        evals_ok and (ex["serial_pair_work_to_target"]
                      < ek["serial_pair_work_to_target"]),
        f"{ex['serial_pair_work_to_target']} vs "
        f"{ek['serial_pair_work_to_target']}")
    bit_equal = (np.array_equal(ex["_labels"], nn["_labels"])
                 and np.array_equal(ex["_C"], nn["_C"]))
    ok &= common.check(
        "exponion labels+centroids bit-equal to bounds=none", bit_equal)
    # context, not a gate: elkan's eval count is the family optimum
    print(f"  note: exponion/elkan pair-dist evals to target = "
          f"{ex['pair_dist_evals_to_target'] / ek['pair_dist_evals_to_target']:.2f}"
          f" (elkan buys its eval count with "
          f"{ek['bound_state_bytes'] >> 20}MiB of bound state and the "
          f"O(b*k) bound walk priced in serial_pair_work)")

    ART.mkdir(parents=True, exist_ok=True)
    out = {
        "k": k,
        "dataset": "infmnist_like",
        "n": int(len(X)),
        "quality_target": target,
        "families": {f: {kk: v for kk, v in r.items()
                         if not kk.startswith("_")}
                     for f, r in runs.items()},
        "gates": {
            "exponion_le_half_hamerly2_evals":
                bool(evals_ok and ratio_h2 <= 0.5),
            "exponion_work_lt_elkan":
                bool(evals_ok and ex["serial_pair_work_to_target"]
                     < ek["serial_pair_work_to_target"]),
            "exponion_bit_equal_to_none": bool(bit_equal),
        },
    }
    (ART / "pruning.json").write_text(json.dumps(out, indent=1))
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if main(quick=True) else 1)
