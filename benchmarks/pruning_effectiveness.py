"""§3.2 motivation: bounds only pay off on NESTED batches.

Measures the fraction of assignment work eliminated per round under
(a) the nested schedule (tb-inf) and (b) iid resampling (bounds decayed
by every round's movement but points revisited rarely) — the paper's
argument for why mini-batch k-means needed restructuring before
triangle-inequality acceleration could help.
"""
from __future__ import annotations

import json
import math
from pathlib import Path

import numpy as np

from benchmarks import common
from repro import api

ART = Path(__file__).resolve().parent.parent / "artifacts" / "bench"


def main(quick: bool = True):
    print("== Pruning effectiveness on nested vs resampled batches ==")
    X, _ = common.dataset("infmnist", quick)
    k = 50
    res = api.fit(X, api.FitConfig(
        k=k, algorithm="tb", b0=2000, rho=math.inf, bounds="hamerly2",
        max_rounds=400, time_budget_s=20.0 if quick else 60.0, seed=0))
    fr = [1.0 - t.n_recomputed / max(t.b, 1)
          for t in res.telemetry if t.b]
    early = float(np.mean(fr[:3]))
    late = float(np.mean(fr[-3:]))
    print(f"  nested: pruned fraction {early:.2%} (early) -> "
          f"{late:.2%} (late), rounds={len(fr)}")
    ok = common.check("pruning rises toward ~1 on nested batches",
                      late > 0.9 and late > early)
    ART.mkdir(parents=True, exist_ok=True)
    (ART / "pruning.json").write_text(json.dumps(
        {"early": early, "late": late, "curve": fr}, indent=1))
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if main(quick=True) else 1)
