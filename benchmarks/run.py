"""Benchmark entry point: ``python -m benchmarks.run [--full]``.

One module per paper table/figure + the pruning study + the dry-run
roofline summary + the serving-latency study (`repro.serve`). Exit
code 0 iff every qualitative claim check passes.

Every `api.fit` a suite executes is recorded: the RESOLVED
`FitConfig.to_dict()` manifest of each run is written to
``artifacts/bench/manifests.json``, so any number in any table can be
reproduced with `FitConfig.from_dict` + the same dataset.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

ART = Path(__file__).resolve().parent.parent / "artifacts" / "bench"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale datasets / longer budgets")
    ap.add_argument("--only", default=None,
                    help="comma list: fig1,fig2,table1,table2,pruning,"
                         "roofline,serve,kernels,xl,multihost,outofcore,"
                         "obs")
    ap.add_argument("--suite", dest="only",
                    help="alias for --only")
    args = ap.parse_args()
    quick = not args.full

    # record the exact FitConfig of every fit the suites run, plus its
    # wall clock and a per-round obs summary (k-scans off the telemetry,
    # jit traces off the tracecount hooks scoped to this one fit)
    from benchmarks import common
    from repro import api
    from repro.util import tracecount
    manifests = common.MANIFESTS
    current = {"suite": None}
    orig_fit = api.fit

    def harvest_utilization(trace_dir):
        """Last-round `fit_roofline_utilization` gauge from the trace
        dir's metrics export (max across processes on multihost)."""
        if trace_dir is None:
            return None
        vals = []
        for f in sorted(Path(trace_dir).glob("metrics-p*.json")):
            try:
                g = json.loads(f.read_text()).get("gauges", {})
            except (OSError, ValueError):
                continue
            if g.get("fit_roofline_utilization") is not None:
                vals.append(float(g["fit_roofline_utilization"]))
        return max(vals) if vals else None

    def recording_fit(X, config, **kw):
        tc0 = tracecount.snapshot()
        t0 = time.perf_counter()
        out = orig_fit(X, config, **kw)
        wall = time.perf_counter() - t0
        util = harvest_utilization(out.config.trace_dir)
        cfg = out.config
        # n_recomputed's unit depends on the bound family (kscan vs
        # pair) — record the family, the unit, and the unit-converted
        # pair-distance total so manifests compare across families.
        from repro.api.config import bound_state_bytes
        from repro.obs.efficiency import WorkModel
        wm = WorkModel.for_bounds(cfg.k, X.shape[-1], cfg.bounds)
        n_rec_total = int(sum(r.n_recomputed for r in out.telemetry))
        obs = {
            "rounds": len(out.telemetry),
            "kscans_total": n_rec_total,
            "bounds_family": cfg.bounds,
            "work_unit": wm.unit,
            "pair_dist_evals": wm.pair_evals(n_rec_total),
            "bound_state_bytes": bound_state_bytes(
                cfg.bounds, len(X), cfg.k),
            "retrace_count": int(sum(tracecount.diff(tc0).values())),
            "peak_queue_depth": None,
            "fit_roofline_utilization": util,
        }
        nulls = {"peak_queue_depth":
                 "batch fit — no ingest queue in the path (the serve "
                 "suite records its queue's high-water mark)"}
        if util is None:
            nulls["fit_roofline_utilization"] = (
                "no trace_dir on this fit — the roofline gauge lives "
                "in the obs metrics export (the kernels suite traces "
                "every fit and records it per backend)")
        common.record_manifest(
            current["suite"], out.config.to_dict(),
            wall_s=round(wall, 3), obs=obs,
            kernel_plan=getattr(out, "kernel_plan", None), nulls=nulls)
        return out

    api.fit = recording_fit

    from benchmarks import (fig1_mse_vs_time, fig2_rho_effect, kernels,
                            multihost, obs_overhead, outofcore,
                            pruning_effectiveness, roofline_report,
                            serve_latency, table1_throughput,
                            table2_final_quality, xl_engine)
    suites = {
        "table1": table1_throughput.main,
        "fig1": fig1_mse_vs_time.main,
        "fig2": fig2_rho_effect.main,
        "table2": table2_final_quality.main,
        "pruning": pruning_effectiveness.main,
        "roofline": roofline_report.main,
        "serve": serve_latency.main,
        "kernels": kernels.main,
        "xl": xl_engine.main,
        "multihost": multihost.main,
        "outofcore": outofcore.main,
        "obs": obs_overhead.main,
    }
    chosen = (args.only.split(",") if args.only else list(suites))
    ok = True
    try:
        for name in chosen:
            current["suite"] = name
            t0 = time.time()
            res = suites[name](quick=quick)
            ok &= bool(res)
            print(f"[{name}] {'ok' if res else 'CLAIM-CHECK-FAILED'} "
                  f"({time.time() - t0:.0f}s)\n")
    finally:
        api.fit = orig_fit
        if manifests:
            ART.mkdir(parents=True, exist_ok=True)
            (ART / "manifests.json").write_text(json.dumps(
                {"quick": quick, "runs": manifests}, indent=1))
            print(f"wrote {len(manifests)} FitConfig manifests to "
                  f"{ART / 'manifests.json'}")
    print(f"benchmarks: {'ALL CLAIMS PASS' if ok else 'SOME CLAIMS FAILED'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
