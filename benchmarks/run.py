"""Benchmark entry point: ``python -m benchmarks.run [--full]``.

One module per paper table/figure + the pruning study + the dry-run
roofline summary. Exit code 0 iff every qualitative claim check passes.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale datasets / longer budgets")
    ap.add_argument("--only", default=None,
                    help="comma list: fig1,fig2,table1,table2,pruning,"
                         "roofline")
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (fig1_mse_vs_time, fig2_rho_effect,
                            pruning_effectiveness, roofline_report,
                            table1_throughput, table2_final_quality)
    suites = {
        "table1": table1_throughput.main,
        "fig1": fig1_mse_vs_time.main,
        "fig2": fig2_rho_effect.main,
        "table2": table2_final_quality.main,
        "pruning": pruning_effectiveness.main,
        "roofline": roofline_report.main,
    }
    chosen = (args.only.split(",") if args.only else list(suites))
    ok = True
    for name in chosen:
        t0 = time.time()
        res = suites[name](quick=quick)
        ok &= bool(res)
        print(f"[{name}] {'ok' if res else 'CLAIM-CHECK-FAILED'} "
              f"({time.time() - t0:.0f}s)\n")
    print(f"benchmarks: {'ALL CLAIMS PASS' if ok else 'SOME CLAIMS FAILED'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
