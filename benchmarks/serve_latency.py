"""Serving-path latency: predict p50/p99 with the codebook refresh
OFF vs INLINE vs BACKGROUNDED (`repro.serve.ClusterService`).

The three modes serve the same query stream at the same ingest rate
(every request also delivers ``rows_per_req`` new points toward the
codebook — a router that both answers and learns):

  off         no refresh at all: the latency floor + machine noise.
  inline      the pre-`repro.serve` design (launch/serve.py before this
              subsystem): the SERVING thread folds the accumulated
              buffer through `partial_fit` whenever it fills. Inline
              refreshes must be coarse — folding on every request would
              tax every request — so the unlucky request that triggers
              the fold stalls for the whole round: p99 spikes.
  background  `ClusterService`: a refresher thread drains the same
              stream in small fixed-shape micro-batches and publishes
              snapshots; the serving thread only ever swaps a reference.

Headline claim (gates the suite): BACKGROUND p99 stays within 1.5x of
the refresh-off p99 while INLINE exceeds that bound — background
refresh keeps tail latency flat at equal codebook freshness budget.

Results land in ``artifacts/bench/serve_latency.json``; the base fit's
resolved config is recorded in ``manifests.json`` by `benchmarks.run`.
"""
from __future__ import annotations

import json
import math
from pathlib import Path

import numpy as np

from benchmarks import common
from repro import api
from repro.api import FitConfig, NestedKMeans
from repro.serve import ClusterService, IngestQueue

ART = Path(__file__).resolve().parent.parent / "artifacts" / "bench"

K = 50
QUERY_ROWS = 2048        # per predict request
ROWS_PER_REQ = 256       # ingest stream tied to request rate
MICRO = 256              # background refresher micro-batch
COARSE = 16384           # inline fold size (= 64 requests of stream)
P99_HEADROOM = 1.5


def _fresh(cfg, outcome) -> NestedKMeans:
    return NestedKMeans(cfg).adopt(outcome)


def _warm(km, Q, stream):
    """Compile every (shape, codebook) executable outside the timed loop."""
    km.predict(Q)
    km.partial_fit(stream[:MICRO])
    km.partial_fit(stream[:COARSE])


def _percentiles(lat):
    return {"p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
            "max_ms": float(np.max(lat) * 1e3),
            "n": len(lat)}


def bench_off(km, Q, n):
    import time
    lat = []
    for _ in range(n):
        t0 = time.perf_counter()
        km.predict(Q)
        lat.append(time.perf_counter() - t0)
    return np.array(lat)


def bench_inline(km, Q, stream, n):
    import time
    lat, pos, buf = [], 0, 0
    folds = 0
    for _ in range(n):
        t0 = time.perf_counter()
        buf += ROWS_PER_REQ
        if buf >= COARSE:
            # always a FULL-shape fold (stream holds 2*COARSE rows and
            # pos wraps inside it), so the warmed executable is reused
            # and the measured stall is refresh compute, not recompiles
            km.partial_fit(stream[pos:pos + COARSE])
            pos = (pos + COARSE) % (len(stream) - COARSE + 1)
            buf = 0
            folds += 1
        km.predict(Q)
        lat.append(time.perf_counter() - t0)
    return np.array(lat), folds


def bench_background(km, Q, stream, n):
    import time
    queue = IngestQueue(max_rows=4 * COARSE, policy="drop-oldest")
    svc = ClusterService(km, micro_batch=MICRO, flush_after_s=0.02,
                         queue=queue)
    svc.start()
    lat, pos = [], 0
    for _ in range(n):
        svc.ingest(stream[pos:pos + ROWS_PER_REQ])
        pos = (pos + ROWS_PER_REQ) % (len(stream) - ROWS_PER_REQ + 1)
        t0 = time.perf_counter()
        svc.predict(Q)
        lat.append(time.perf_counter() - t0)
    metrics = svc.export_metrics()
    peak_depth = queue.peak_depth
    svc.stop()
    return np.array(lat), metrics, peak_depth


def main(quick: bool = True):
    print("== Serving latency: refresh off vs inline vs backgrounded ==")
    n_req = 600 if quick else 1200
    # the stream must hold >= 2*COARSE rows so every inline fold is
    # full-shape (quick's dataset half would be smaller than one fold);
    # quick only scales the request count, not the fold shapes.
    from repro.data import synthetic
    n_base = 20_000
    X = synthetic.infmnist_like(n_base + 2 * COARSE, seed=0)
    X_base, stream = X[:n_base], X[n_base:]
    Q = X[:QUERY_ROWS]

    cfg = FitConfig(k=K, algorithm="tb", b0=2000, rho=math.inf,
                    bounds="hamerly2", max_rounds=100,
                    time_budget_s=10.0 if quick else 30.0, seed=0)
    out = api.fit(X_base, cfg)       # recorded in manifests by run.py
    print(f"  base codebook: k={K}, rounds={len(out.telemetry)}, "
          f"converged={out.converged}")

    kms = [_fresh(cfg, out) for _ in range(3)]
    for km in kms:
        _warm(km, Q, stream)

    # the off baseline is measured BEFORE and AFTER the other modes and
    # the worse of the two p99s is the denominator: on a small shared
    # box the machine-noise floor drifts between phases, and comparing
    # against the worse floor keeps the claim about refresh placement,
    # not about which phase caught a scheduler hiccup.
    off_a = bench_off(kms[0], Q, n_req)
    inline, folds = bench_inline(kms[1], Q, stream, n_req)
    background, svc_metrics, peak_depth = bench_background(
        kms[2], Q, stream, n_req)
    off_b = bench_off(kms[0], Q, n_req)
    off = off_a if np.percentile(off_a, 99) >= np.percentile(off_b, 99) \
        else off_b

    r_off, r_inl, r_bg = (_percentiles(off), _percentiles(inline),
                          _percentiles(background))
    ratio_inl = r_inl["p99_ms"] / r_off["p99_ms"]
    ratio_bg = r_bg["p99_ms"] / r_off["p99_ms"]
    refreshes = svc_metrics["refresh"]["count"]
    print(f"  off:        p50 {r_off['p50_ms']:6.1f}ms  "
          f"p99 {r_off['p99_ms']:6.1f}ms")
    print(f"  inline:     p50 {r_inl['p50_ms']:6.1f}ms  "
          f"p99 {r_inl['p99_ms']:6.1f}ms  ({ratio_inl:.2f}x off p99, "
          f"{folds} folds)")
    print(f"  background: p50 {r_bg['p50_ms']:6.1f}ms  "
          f"p99 {r_bg['p99_ms']:6.1f}ms  ({ratio_bg:.2f}x off p99, "
          f"{refreshes} refreshes)")

    ok = common.check(
        "background refresh actually ran during serving",
        refreshes >= 3, f"refreshes={refreshes}")
    ok &= common.check(
        f"background p99 within {P99_HEADROOM}x of refresh-off p99",
        ratio_bg <= P99_HEADROOM, f"ratio={ratio_bg:.2f}")
    ok &= common.check(
        f"inline refresh exceeds the {P99_HEADROOM}x p99 bound",
        ratio_inl > P99_HEADROOM, f"ratio={ratio_inl:.2f}")

    # the serving run's own manifest entry: the background service's
    # queue high-water mark lives here (serve_latency.json's schema is
    # frozen; manifests.json is where obs summaries accumulate)
    common.record_manifest(
        "serve", out.config.to_dict(),
        obs={"rounds": len(out.telemetry),
             "kscans_total": int(sum(r.n_recomputed
                                     for r in out.telemetry)),
             "retrace_count": None,
             "peak_queue_depth": int(peak_depth)},
        nulls={"wall_s": "serving benchmark — the measured quantity is "
                         "per-request latency (serve_latency.json), "
                         "not fit wall-clock",
               "retrace_count": "serving-path folds share the process-"
                                "wide jit caches; per-fit attribution "
                                "is in the base fit's entry"})

    ART.mkdir(parents=True, exist_ok=True)
    (ART / "serve_latency.json").write_text(json.dumps({
        "quick": quick, "n_requests": n_req,
        "query_rows": QUERY_ROWS, "rows_per_req": ROWS_PER_REQ,
        "micro_batch": MICRO, "inline_fold_rows": COARSE,
        "off": r_off, "inline": {**r_inl, "folds": folds},
        "background": {**r_bg, "ratio_vs_off_p99": ratio_bg,
                       "service_metrics": svc_metrics},
        "inline_ratio_vs_off_p99": ratio_inl,
        "p99_headroom": P99_HEADROOM,
        "base_fit_config": out.config.to_dict(),
    }, indent=1))
    print(f"  wrote {ART / 'serve_latency.json'}")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if main(quick=True) else 1)
