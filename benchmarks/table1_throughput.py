"""Table 1: mb implementation throughput — seconds to process N points.

The paper compares implementations (ours/sklearn/sofia) on absolute
wall-time; offline we report our own jit'd throughput (points/s and
effective GFLOP/s of the assignment step) on both dataset stand-ins,
plus the Pallas kernel's interpret-mode validation cost for reference.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks import common
from repro import api

ART = Path(__file__).resolve().parent.parent / "artifacts" / "bench"


def main(quick: bool = True):
    print("== Table 1: mb throughput (one full pass, k=50) ==")
    out = {}
    for ds in ("infmnist", "rcv1"):
        X, _ = common.dataset(ds, quick)
        n, d = X.shape
        k, b = 50, 5000
        res = api.fit(X, api.FitConfig(
            k=k, algorithm="mb", b0=b, max_rounds=n // b,
            eval_every=10 ** 9, seed=0))
        t = res.telemetry[-1].t
        flops = 2.0 * n * d * k
        out[ds] = {"n": n, "d": d, "seconds_per_pass": t,
                   "points_per_s": n / t, "gflops": flops / t / 1e9}
        print(f"  {ds:9s} N={n} d={d}: {t:.2f}s/pass "
              f"({n / t:,.0f} pts/s, {flops / t / 1e9:.1f} GFLOP/s)")

    # host-loop round rate of the nested (tb) path. This is the number
    # the p_max-in-RoundInfo change protects: the convergence check must
    # read already-materialized info, never dispatch an extra
    # device->host sync per round. Compared against the previous
    # artifact (if any) as a coarse non-regression gate.
    ok = True
    X, _ = common.dataset("infmnist", quick)
    res = api.fit(X, api.FitConfig(
        k=50, algorithm="tb", b0=2048, max_rounds=60,
        eval_every=10 ** 9, seed=0))
    n_rounds, t = len(res.telemetry), res.telemetry[-1].t
    rps = n_rounds / max(t, 1e-9)
    out["tb_loop"] = {"rounds": n_rounds, "seconds": t,
                      "rounds_per_s": rps}
    print(f"  tb host loop: {n_rounds} rounds in {t:.2f}s "
          f"({rps:.1f} rounds/s)")
    prev_file = ART / "table1.json"
    if prev_file.exists():
        prev = json.loads(prev_file.read_text()) \
            .get("tb_loop", {}).get("rounds_per_s")
        if prev:
            ok = rps >= 0.5 * prev
            print(f"  vs previous artifact {prev:.1f} rounds/s: "
                  f"{'ok' if ok else 'REGRESSED >2x'}")
            if not ok:
                # keep the old baseline so the gate can't self-heal by
                # overwriting it with the regressed number
                out["tb_loop"]["rounds_per_s"] = prev
                out["tb_loop"]["regressed_rounds_per_s"] = rps

    ART.mkdir(parents=True, exist_ok=True)
    (ART / "table1.json").write_text(json.dumps(out, indent=1))
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if main(quick=True) else 1)
