"""XL-engine benchmark: the nested schedule vs the dense one-shot round.

The claim (paper Alg. 6/9, transplanted to the centroid-sharded
engine): driving the XL round with the nested grow-batch schedule
reaches within 1% of the empirical-minimum validation MSE with FAR less
work than the dense one-shot round (full batch, fresh stats every
round — what `make_xl_round` did before the engine existed). Work is
counted in points touched; "equivalent rounds" normalises it by N so
the two schedules compare in units of full-data passes.

The fits need a multi-device host mesh, so the measurement runs in a
CHILD process (`python -m benchmarks.xl_engine --child`) with forced
host devices; the parent validates the claim from the artifact and
records the child's resolved FitConfig manifests.

Artifact: artifacts/bench/xl_engine.json
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

ART = Path(__file__).resolve().parent.parent / "artifacts" / "bench"
REPO = Path(__file__).resolve().parent.parent


# --------------------------------------------------------------------------
# child: the actual fits (forced host devices)
# --------------------------------------------------------------------------

def _cost_to_target(telemetry, target):
    """(compute_seconds, recompute_work, rounds) until val_mse first
    reaches ``target``; (None,)*3 if the run never does.

    ``recompute_work`` counts the points whose distances were actually
    recomputed (full k-scans) — the honest per-round cost of a bounded
    nested round, where n_active includes settled points the bound test
    skipped. For the dense one-shot round the two coincide at N.
    """
    work = 0
    rounds = 0
    for rec in telemetry:
        if rec.batch_mse is not None:       # compute rounds only
            work += rec.n_recomputed
            rounds += 1
        if rec.val_mse is not None and rec.val_mse <= target:
            return rec.t, work, rounds
    return None, None, None


def child(quick: bool) -> None:
    from repro.util.env import force_host_device_count
    force_host_device_count(8)
    import dataclasses

    import jax

    from repro import api
    from repro.data.synthetic import infmnist_like

    # infMNIST-like stand-in (same family as fig1), over-segmented:
    # k >> the 10 underlying classes, so every schedule faces the same
    # landscape of near-equivalent minima — the paper's Fig. 1 protocol.
    n, k = (12_000, 32) if quick else (40_000, 64)
    mesh_shape = (2, 2) if quick else (4, 2)
    X = infmnist_like(n + n // 10, seed=0)
    X, X_val = X[:n], X[n:]
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))

    base = api.FitConfig(
        k=k, algorithm="tb", rho=float("inf"), b0=256,
        bounds="hamerly2", backend="xl", data_axes=("data",),
        model_axis="model", eval_every=1,
        max_rounds=120 if quick else 200,
        capacity_floor=256, seed=0)
    dense = dataclasses.replace(base, algorithm="gb", b0=n)

    runs = {}
    for name, cfg in (("nested", base), ("dense", dense)):
        out = api.fit(X, cfg, X_val=X_val, mesh=mesh)
        runs[name] = out
        print(f"[xl child] {name}: rounds={len(out.telemetry)} "
              f"converged={out.converged} final_val={out.final_mse:.5f}",
              flush=True)

    emp_min = min(rec.val_mse
                  for out in runs.values()
                  for rec in out.telemetry if rec.val_mse is not None)
    target = 1.01 * emp_min
    report = {"quick": quick, "n": n, "d": X.shape[1], "k": k,
              "mesh": list(mesh_shape), "empirical_min": emp_min}
    for name, out in runs.items():
        t, work, rounds = _cost_to_target(out.telemetry, target)
        report[name] = {
            "t_to_1pct_s": t, "work_to_1pct": work,
            "rounds_to_1pct": rounds,
            "equiv_rounds_to_1pct": (None if work is None else work / n),
            "n_rounds": len(out.telemetry),
            "converged": bool(out.converged),
            "final_val_mse": out.final_mse,
            "config": out.config.to_dict(),
        }
    ART.mkdir(parents=True, exist_ok=True)
    (ART / "xl_engine.json").write_text(json.dumps(report, indent=1))
    print(f"[xl child] wrote {ART / 'xl_engine.json'}", flush=True)


# --------------------------------------------------------------------------
# parent: suite entry point
# --------------------------------------------------------------------------

def main(quick: bool = True) -> bool:
    from benchmarks import common

    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "benchmarks.xl_engine", "--child"]
    if not quick:
        cmd.append("--full")
    try:
        r = subprocess.run(cmd, env=env, cwd=REPO, text=True,
                           capture_output=True, timeout=1800)
    except subprocess.TimeoutExpired as e:
        # funnel through the claim-check machinery like every other
        # failure so the runner still prints its summary
        sys.stdout.write((e.stdout or b"").decode(errors="replace")
                         if isinstance(e.stdout, bytes)
                         else (e.stdout or ""))
        return common.check("xl-child", False,
                            "child timed out after 1800s")
    sys.stdout.write(r.stdout)
    if r.returncode != 0:
        sys.stderr.write(r.stderr)
        return common.check("xl-child", False, "child process failed")

    rep = json.loads((ART / "xl_engine.json").read_text())
    for name in ("nested", "dense"):
        common.record_manifest("xl", rep[name]["config"])

    nested, dense = rep["nested"], rep["dense"]
    ok = True
    reached = (nested["work_to_1pct"] is not None
               and dense["work_to_1pct"] is not None)
    ok &= common.check(
        "xl-both-reach-1pct", reached,
        f"nested={nested['rounds_to_1pct']} dense="
        f"{dense['rounds_to_1pct']} rounds")
    # gate on recompute work (full k-distance scans) — the hardware-
    # independent cost the paper's speedup derives from. Wall time is
    # reported for context but not gated: at this CI toy scale the
    # forced-host-device dispatch overhead of ~40 cheap nested rounds
    # swamps the compute it saves, which is the opposite of the
    # production regime (where one full k=10^5 pass dwarfs dispatch).
    ok &= common.check(
        "xl-nested-beats-dense",
        reached and nested["work_to_1pct"] < dense["work_to_1pct"],
        "" if not reached else
        f"to-1%-of-min: nested {nested['work_to_1pct']:,} k-scans "
        f"({nested['equiv_rounds_to_1pct']:.2f} full-data passes, "
        f"{nested['t_to_1pct_s']:.2f}s) vs "
        f"dense {dense['work_to_1pct']:,} "
        f"({dense['equiv_rounds_to_1pct']:.2f}, "
        f"{dense['t_to_1pct_s']:.2f}s)")
    ok &= common.check(
        "xl-nested-converges", nested["converged"],
        f"final val {nested['final_val_mse']:.5f} "
        f"(empirical min {rep['empirical_min']:.5f})")
    return ok


if __name__ == "__main__":
    if "--child" in sys.argv:
        child(quick="--full" not in sys.argv)
    else:
        sys.exit(0 if main(quick="--full" not in sys.argv) else 1)
