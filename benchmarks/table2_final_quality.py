"""Table 2: final cluster quality — lloyd vs tb-inf across b0.

Paper's finding: equal quality on the dense set for all b0; on the
sparse set tb-inf degrades for SMALL b0 (we check the same direction).
"""
from __future__ import annotations

import json
import math
from pathlib import Path

import numpy as np

from benchmarks import common
from repro import api

ART = Path(__file__).resolve().parent.parent / "artifacts" / "bench"
B0S = [100, 1000, 5000]


def main(quick: bool = True):
    print("== Table 2: final quality, lloyd vs tb-inf over b0 ==")
    seeds = (0,) if quick else (0, 1, 2)
    out = {}
    for ds in ("infmnist", "rcv1"):
        X, Xv = common.dataset(ds, quick)
        k = 50
        rounds = 60 if quick else 200
        lloyd_mse = float(np.mean([
            api.fit(X, api.FitConfig(
                k=k, algorithm="lloyd", max_rounds=rounds,
                eval_every=10 ** 9, seed=s), X_val=Xv).final_mse
            for s in seeds]))
        row = {"lloyd": lloyd_mse}
        for b0 in B0S:
            row[f"tb_b0_{b0}"] = float(np.mean([
                api.fit(X, api.FitConfig(
                    k=k, algorithm="tb", b0=b0, rho=math.inf,
                    max_rounds=30 * rounds, eval_every=10 ** 9,
                    seed=s), X_val=Xv).final_mse
                for s in seeds]))
        out[ds] = row
        print(f"  {ds:9s} lloyd {lloyd_mse:.5f}  " + "  ".join(
            f"tb(b0={b0}) {row[f'tb_b0_{b0}']:.5f}" for b0 in B0S))
    ok = common.check(
        "dense: tb-inf(b0=5000) ~ lloyd",
        out["infmnist"]["tb_b0_5000"] <= out["infmnist"]["lloyd"] * 1.05)
    ok &= common.check(
        "sparse: small b0 no better than large b0",
        out["rcv1"]["tb_b0_100"] >= out["rcv1"]["tb_b0_5000"] * 0.98)
    ART.mkdir(parents=True, exist_ok=True)
    (ART / "table2.json").write_text(json.dumps(out, indent=1))
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if main(quick=True) else 1)
