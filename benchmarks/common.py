"""Shared benchmark utilities: datasets, timing, claim checks."""
from __future__ import annotations

import functools
import time
from typing import List

from repro.data import synthetic

# resolved FitConfig dict of every fit the suites run; benchmarks/run.py
# drains this into artifacts/bench/manifests.json. In-process fits are
# recorded automatically (run.py wraps api.fit); suites that fit in a
# SUBPROCESS (benchmarks/xl_engine.py needs forced host devices) call
# `record_manifest` themselves with the child's resolved configs.
MANIFESTS: List[dict] = []


def record_manifest(suite: str, config_dict: dict) -> None:
    MANIFESTS.append({"suite": suite, "config": config_dict})


@functools.lru_cache(maxsize=None)
def dataset(name: str, quick: bool = False):
    """(X_train, X_val) stand-ins for the paper's two datasets."""
    if name == "infmnist":
        n = 20_000 if quick else 60_000
        X = synthetic.infmnist_like(n + n // 10, seed=0)
    elif name == "rcv1":
        n = 20_000 if quick else 60_000
        dim = 1024 if quick else 2048
        X = synthetic.rcv1_like(n + n // 10, dim=dim, seed=0)
    else:
        raise KeyError(name)
    return X[:n], X[n:]


def mse_at_times(telemetry, grid: List[float]) -> List[float]:
    """Validation MSE at each wall-time point (step function).

    Accepts `repro.api.Telemetry` records or legacy dict records.
    """
    recs = [t.to_dict() if hasattr(t, "to_dict") else t for t in telemetry]
    pts = [(t["t"], t["val_mse"]) for t in recs
           if t.get("val_mse") is not None]
    out = []
    for g in grid:
        best = None
        for t, v in pts:
            if t <= g:
                best = v
        out.append(best if best is not None else float("nan"))
    return out


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0


def check(name: str, ok: bool, detail: str = "") -> bool:
    print(f"  claim[{name}]: {'PASS' if ok else 'FAIL'} {detail}")
    return ok
