"""Shared benchmark utilities: datasets, timing, claim checks."""
from __future__ import annotations

import functools
import time
from typing import List, Optional

from repro.data import synthetic
from repro.obs import OBS_SCHEMA

# resolved FitConfig dict of every fit the suites run; benchmarks/run.py
# drains this into artifacts/bench/manifests.json. In-process fits are
# recorded automatically (run.py wraps api.fit); suites that fit in a
# SUBPROCESS (benchmarks/xl_engine.py needs forced host devices) call
# `record_manifest` themselves with the child's resolved configs.
MANIFESTS: List[dict] = []


def record_manifest(suite: str, config_dict: dict, *,
                    wall_s: Optional[float] = None,
                    obs: Optional[dict] = None,
                    kernel_plan: Optional[dict] = None,
                    nulls: Optional[dict] = None) -> None:
    """Record one run's manifest entry.

    Beyond the resolved config, each entry carries ``wall_s`` (end-to-
    end fit wall-clock), an ``obs`` per-round summary (rounds, total
    k-scans, retrace count, peak queue depth where a queue exists), the
    resolved ``kernel_plan`` the fit dispatched through (backend, block
    sizes, bucket — `repro.kernels.plan.KernelPlan.to_dict`) and the
    ``obs_schema`` version. Every null is EXPLAINED: the ``nulls``
    dict maps each absent field to the reason it is absent, so a
    manifest reader can distinguish "not measured" from "measured
    zero" — the old ``kernel_backend: null`` blind spot, made explicit.
    """
    reasons = dict(nulls or {})
    if wall_s is None:
        reasons.setdefault(
            "wall_s", "fit ran in a subprocess; the child's wall clock "
                      "was not captured")
    if obs is None:
        reasons.setdefault(
            "obs", "fit not driven through api.fit in this process — "
                   "no per-round summary collected")
    if kernel_plan is None:
        reasons.setdefault(
            "kernel_plan", "fit ran in a subprocess or predates the "
                           "dispatch plane — the resolved plan was not "
                           "surfaced on its FitOutcome")
    MANIFESTS.append({"suite": suite, "config": config_dict,
                      "obs_schema": OBS_SCHEMA, "wall_s": wall_s,
                      "obs": obs, "kernel_plan": kernel_plan,
                      "nulls": reasons})


@functools.lru_cache(maxsize=None)
def dataset(name: str, quick: bool = False):
    """(X_train, X_val) stand-ins for the paper's two datasets."""
    if name == "infmnist":
        n = 20_000 if quick else 60_000
        X = synthetic.infmnist_like(n + n // 10, seed=0)
    elif name == "rcv1":
        n = 20_000 if quick else 60_000
        dim = 1024 if quick else 2048
        X = synthetic.rcv1_like(n + n // 10, dim=dim, seed=0)
    else:
        raise KeyError(name)
    return X[:n], X[n:]


def mse_at_times(telemetry, grid: List[float]) -> List[float]:
    """Validation MSE at each wall-time point (step function).

    Accepts `repro.api.Telemetry` records or legacy dict records.
    """
    recs = [t.to_dict() if hasattr(t, "to_dict") else t for t in telemetry]
    pts = [(t["t"], t["val_mse"]) for t in recs
           if t.get("val_mse") is not None]
    out = []
    for g in grid:
        best = None
        for t, v in pts:
            if t <= g:
                best = v
        out.append(best if best is not None else float("nan"))
    return out


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0


def check(name: str, ok: bool, detail: str = "") -> bool:
    print(f"  claim[{name}]: {'PASS' if ok else 'FAIL'} {detail}")
    return ok
