"""Multihost-engine benchmark: the nested schedule at process scale.

The claim (paper Alg. 6/9, carried to the jax.distributed engine): the
nested grow-batch schedule reaches within 1% of the empirical-minimum
validation MSE with FAR less recompute work than the dense one-shot
schedule, and the multihost engine pays no work penalty for running the
identical schedule across sharded processes — its per-round
n_recomputed trace matches the single-process mesh engine's exactly
(the loop's control flow is replicated by construction, so the two
fits ARE the same schedule).

Work is counted in recomputed points (full k-distance scans), not wall
time: at CI toy scale the forced-host-device dispatch overhead swamps
the compute the bounds save, which is the opposite of the production
regime. The fits need forced host devices, so the measurement runs in a
CHILD process (`python -m benchmarks.multihost --child`); the parent
validates the claim from the artifact and records the child's resolved
FitConfig manifests.

Artifact: artifacts/bench/multihost.json
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

ART = Path(__file__).resolve().parent.parent / "artifacts" / "bench"
REPO = Path(__file__).resolve().parent.parent


def _cost_to_target(telemetry, target):
    """(recompute_work, rounds) until val_mse first reaches ``target``;
    (None, None) if the run never does."""
    work = 0
    rounds = 0
    for rec in telemetry:
        if rec.batch_mse is not None:       # compute rounds only
            work += rec.n_recomputed
            rounds += 1
        if rec.val_mse is not None and rec.val_mse <= target:
            return work, rounds
    return None, None


def child(quick: bool) -> None:
    from repro.util.env import force_host_device_count
    force_host_device_count(4)
    import dataclasses

    import jax

    from repro import api
    from repro.data.synthetic import infmnist_like

    # infMNIST-like stand-in, over-segmented: k >> the 10 underlying
    # classes, so every schedule faces the same landscape of
    # near-equivalent minima (the paper's Fig. 1 protocol) and the
    # claim gates on work, not on which minimum a run lands in.
    n, k = (12_000, 32) if quick else (40_000, 64)
    X = infmnist_like(n + n // 10, seed=0)
    X, X_val = X[:n], X[n:]
    mesh = jax.make_mesh((4,), ("data",))

    base = api.FitConfig(
        k=k, algorithm="tb", rho=float("inf"), b0=256,
        bounds="hamerly2", backend="multihost", eval_every=1,
        max_rounds=120 if quick else 200, capacity_floor=256, seed=0)
    dense = dataclasses.replace(base, algorithm="gb", b0=n)
    mesh_cfg = dataclasses.replace(base, backend="mesh")

    runs = {}
    for name, cfg in (("nested", base), ("dense", dense),
                      ("mesh", mesh_cfg)):
        out = api.fit(X, cfg, X_val=X_val, mesh=mesh)
        runs[name] = out
        print(f"[multihost child] {name}: rounds={len(out.telemetry)} "
              f"converged={out.converged} final_val={out.final_mse:.5f}",
              flush=True)

    emp_min = min(rec.val_mse
                  for out in runs.values()
                  for rec in out.telemetry if rec.val_mse is not None)
    target = 1.01 * emp_min
    report = {"quick": quick, "n": n, "d": X.shape[1], "k": k,
              "n_shards": 4, "empirical_min": emp_min,
              "work_trace_equal": (
                  [r.n_recomputed for r in runs["nested"].telemetry]
                  == [r.n_recomputed for r in runs["mesh"].telemetry])}
    for name, out in runs.items():
        work, rounds = _cost_to_target(out.telemetry, target)
        report[name] = {
            "work_to_1pct": work, "rounds_to_1pct": rounds,
            "equiv_rounds_to_1pct": (None if work is None else work / n),
            "n_rounds": len(out.telemetry),
            "converged": bool(out.converged),
            "final_val_mse": out.final_mse,
            "config": out.config.to_dict(),
        }
    ART.mkdir(parents=True, exist_ok=True)
    (ART / "multihost.json").write_text(json.dumps(report, indent=1))
    print(f"[multihost child] wrote {ART / 'multihost.json'}", flush=True)


def main(quick: bool = True) -> bool:
    from benchmarks import common

    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "benchmarks.multihost", "--child"]
    if not quick:
        cmd.append("--full")
    try:
        r = subprocess.run(cmd, env=env, cwd=REPO, text=True,
                           capture_output=True, timeout=1800)
    except subprocess.TimeoutExpired as e:
        sys.stdout.write((e.stdout or b"").decode(errors="replace")
                         if isinstance(e.stdout, bytes)
                         else (e.stdout or ""))
        return common.check("multihost-child", False,
                            "child timed out after 1800s")
    sys.stdout.write(r.stdout)
    if r.returncode != 0:
        sys.stderr.write(r.stderr)
        return common.check("multihost-child", False,
                            "child process failed")

    rep = json.loads((ART / "multihost.json").read_text())
    for name in ("nested", "dense", "mesh"):
        common.record_manifest("multihost", rep[name]["config"])

    nested, dense = rep["nested"], rep["dense"]
    ok = True
    reached = (nested["work_to_1pct"] is not None
               and dense["work_to_1pct"] is not None)
    ok &= common.check(
        "multihost-both-reach-1pct", reached,
        f"nested={nested['rounds_to_1pct']} dense="
        f"{dense['rounds_to_1pct']} rounds")
    ok &= common.check(
        "multihost-nested-beats-dense",
        reached and nested["work_to_1pct"] < dense["work_to_1pct"],
        "" if not reached else
        f"to-1%-of-min: nested {nested['work_to_1pct']:,} k-scans "
        f"({nested['equiv_rounds_to_1pct']:.2f} full-data passes) vs "
        f"dense {dense['work_to_1pct']:,} "
        f"({dense['equiv_rounds_to_1pct']:.2f})")
    ok &= common.check(
        "multihost-schedule-matches-mesh", rep["work_trace_equal"],
        "per-round n_recomputed trace identical to the mesh engine")
    ok &= common.check(
        "multihost-nested-converges", nested["converged"],
        f"final val {nested['final_val_mse']:.5f} "
        f"(empirical min {rep['empirical_min']:.5f})")
    return ok


if __name__ == "__main__":
    if "--child" in sys.argv:
        child(quick="--full" not in sys.argv)
    else:
        sys.exit(0 if main(quick="--full" not in sys.argv) else 1)
