"""Out-of-core benchmark: cluster a dataset bigger than you'd want in RAM.

The claim (the `repro.data.store` data plane): a multi-process fit
streamed off a chunked on-disk store

  * is BIT-IDENTICAL (round-by-round telemetry) to the same fit with the
    data in host memory — out-of-core is a placement strategy, not an
    approximation;
  * keeps each process's fit-attributable peak RSS under a budget BELOW
    the dataset's own ``n*d*4`` bytes (the in-memory fit needs ~2.5x the
    dataset: the rows, their permuted copy, and the device buffer), and
    measurably under the in-memory fit's footprint;
  * reads at most ~1.1x one full-data pass off disk per fit — the
    blocked permutation keeps the nested schedule's disk frontier
    chunk-sequential, so each chunk is loaded about once (a uniform
    shuffle would cost ~log2(n/b0) passes);
  * still beats the dense one-shot schedule on recompute work to reach
    a COMMON quality target — 1.01x the best validation MSE that both
    schedules attain (the paper's work claim, unchanged by the data
    living on disk). Both baselines start from the identical C0 (the
    dense fit consumes the same permuted row sequence), but k-means
    minima are init-sensitive enough that either schedule can converge
    a few percent past the other at any given n; targeting the quality
    BOTH provably reach keeps the gate about WORK, never about which
    basin a run happened to land in (time-to-quality, MLPerf-style).

The fits need forced host devices and real process boundaries (RSS is a
per-process number), so every measurement runs in CHILD processes: four
`jax.distributed` processes for the streamed and in-memory fits, one
local process for the dense baseline. Four processes because the RSS
gate needs them: a process's floor is ~2.3x ITS data share (device
buffer + the first full-batch round's recompute gather + the distance
matrix) plus a ~240 MB jax runtime — only at P >= 4 does that land
well under the dataset's own bytes. The parent writes the store,
orchestrates, and gates on the children's JSON reports.

Artifact: artifacts/bench/outofcore.json
"""
from __future__ import annotations

import json
import os
import socket
import subprocess
import sys
import tempfile
from pathlib import Path

ART = Path(__file__).resolve().parent.parent / "artifacts" / "bench"
REPO = Path(__file__).resolve().parent.parent

N_PROC = 4
DEV_PER_PROC = 1    # 4 shards total, same layout as 2x2
DIM = 64
K = 16
CLASSES = 16
# moderately overlapping blobs. The spread is a protocol knob with
# failure modes on BOTH sides, all measured at this n and d: well
# separated (spread >= 1.5: centers ~17 apart vs noise radius 8 in 64
# dims) the k == classes problem has a snap-to-blobs global minimum
# that only the dense baseline reliably finds, and the work gate
# becomes a local-minima lottery; heavily overlapping (spread <= 0.5)
# the density is so smooth that centroids drift on near-flat valleys,
# the growth controller never sees movement settle, and b crawls — the
# fit never streams the store. At spread 1.0 the minima are
# near-equivalent (final val MSEs within ~1%, either schedule can win)
# and b doubles steadily to n, so the gates measure what they claim:
# recompute WORK to the same quality, over a fit that actually runs
# the full out-of-core path.
SPREAD = 1.0
SEED = 0
N_VAL = 20_000
VAL_BLOCK = 1 << 20              # disjoint from the writer's block range


def _params(quick: bool):
    n = 6_000_000 if quick else 10_000_000
    chunk_rows = 16_384
    data_bytes = n * DIM * 4
    # per-process budget, from the measured footprint model: ~400-450
    # MB of jax runtime + compile caches (one executable per b/capacity
    # bucket), the device buffer (data/P), and the big-b round scratch
    # — the first round at a fresh prefix gathers ~every row once more
    # (another data/P) plus the (rows x k) distance block; measured
    # ~2.2x data/P across scales. The constants below cover that with
    # ~10% headroom and sit well below data_bytes — which is what the
    # IN-memory fit's working set (rows + permuted copy + buffer)
    # costs per process.
    budget = int(560e6 + 2.35 * data_bytes / N_PROC)
    return n, chunk_rows, data_bytes, budget


def _cost_to_target(telemetry, target):
    """(recompute_work, rounds) until val_mse first reaches ``target``
    over dict telemetry records; (None, None) if the run never does."""
    work = 0
    rounds = 0
    for rec in telemetry:
        if rec["batch_mse"] is not None:
            work += rec["n_recomputed"]
            rounds += 1
        if rec["val_mse"] is not None and rec["val_mse"] <= target:
            return work, rounds
    return None, None


# ---------------------------------------------------------------------------
# children (all measurement happens here)
# ---------------------------------------------------------------------------

def child(role: str, proc: int, port: str, workdir: str,
          quick: bool) -> None:
    from repro.util.env import force_host_device_count
    force_host_device_count(DEV_PER_PROC if role != "dense" else 1)
    import dataclasses
    import resource

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import api
    from repro.data.store import ChunkStore
    from repro.data.store.writer import blob_rows
    from repro.launch.mesh import initialize_multihost

    n, chunk_rows, data_bytes, _ = _params(quick)
    if role != "dense":
        initialize_multihost(coordinator_address=f"localhost:{port}",
                             num_processes=N_PROC, process_id=proc)
    jnp.zeros((8,)).block_until_ready()          # backend is up
    rss0 = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024

    base = api.FitConfig(
        k=K, algorithm="tb", rho=float("inf"), b0=4096,
        bounds="hamerly2", eval_every=1, max_rounds=45,
        capacity_floor=4096, seed=SEED)
    X_val = blob_rows(N_VAL, dim=DIM, classes=CLASSES, seed=SEED,
                      spread=SPREAD, block=VAL_BLOCK)
    store_dir = os.path.join(workdir, "store")

    metrics = None
    if role == "stream":
        st = ChunkStore(store_dir)
        cfg = dataclasses.replace(base, backend="multihost")
        out = api.fit(st, cfg, X_val=X_val)
        metrics = st.metrics.to_dict()
    elif role == "inmem":
        # the honest in-memory comparison point: load ALL rows, permute
        # them into the streamed fit's exact row sequence, fit with the
        # shuffle disabled — bit-identical telemetry, in-RAM footprint
        from repro.data.store import store_permutation
        st = ChunkStore(store_dir)
        X = st.rows(0, st.n)
        X = X[store_permutation(st.n, st.chunk_rows, SEED)]
        st.close()
        cfg = dataclasses.replace(base, backend="multihost",
                                  shuffle=False)
        out = api.fit(X, cfg, X_val=X_val)
    elif role == "dense":
        # same permuted sequence as the streamed fit, so the one-shot
        # baseline starts from the IDENTICAL first-k-rows C0 — the
        # work comparison is schedule vs schedule, not init vs init
        from repro.data.store import store_permutation
        st = ChunkStore(store_dir)
        X = st.rows(0, st.n)
        X = X[store_permutation(st.n, st.chunk_rows, SEED)]
        st.close()
        cfg = dataclasses.replace(base, algorithm="gb", b0=n,
                                  max_rounds=12, shuffle=False)
        out = api.fit(X, cfg, X_val=X_val)
    else:
        raise ValueError(role)

    peak = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024
    telem = [r.to_dict() for r in out.telemetry]
    for r in telem:
        r.pop("t")
    report = {
        "role": role, "proc": proc, "quick": quick,
        "rss0": rss0, "rss_peak": peak, "rss_delta": peak - rss0,
        "store_metrics": metrics, "telemetry": telem,
        "converged": bool(out.converged), "final_val_mse": out.final_mse,
        "config": out.config.to_dict(),
    }
    with open(os.path.join(workdir, f"{role}_{proc}.json"), "w") as f:
        json.dump(report, f)
    print(f"[outofcore child {role}/{proc}] rounds={len(telem)} "
          f"converged={out.converged} final_val={out.final_mse:.5f} "
          f"rss_delta={(peak - rss0) / 1e6:.0f}MB", flush=True)


# ---------------------------------------------------------------------------
# parent: store build, orchestration, gates
# ---------------------------------------------------------------------------

def _spawn(role, workdir, quick, n_proc):
    with socket.socket() as s:
        s.bind(("localhost", 0))
        port = str(s.getsockname()[1])
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "benchmarks.outofcore", "--child",
           role, "%d", port, workdir] + ([] if quick else ["--full"])
    procs = [subprocess.Popen([a if a != "%d" else str(i) for a in cmd],
                              env=env, cwd=REPO)
             for i in range(n_proc)]
    for p in procs:
        if p.wait(timeout=1800) != 0:
            raise RuntimeError(f"outofcore child {role} failed")
    reports = []
    for i in range(n_proc):
        with open(os.path.join(workdir, f"{role}_{i}.json")) as f:
            reports.append(json.load(f))
    return reports


def main(quick: bool = True) -> bool:
    from benchmarks import common

    n, chunk_rows, data_bytes, budget = _params(quick)
    workdir = tempfile.mkdtemp(prefix="outofcore_bench_")
    store_dir = os.path.join(workdir, "store")
    print(f"  writing {n:,} x {DIM} f32 rows ({data_bytes / 1e9:.2f} GB) "
          f"to {store_dir} ...", flush=True)
    from repro.data.store.writer import write_synthetic_store
    write_synthetic_store(store_dir, n=n, dim=DIM, classes=CLASSES,
                          seed=SEED, spread=SPREAD, chunk_rows=chunk_rows)

    try:
        stream = _spawn("stream", workdir, quick, N_PROC)
        inmem = _spawn("inmem", workdir, quick, N_PROC)
        dense = _spawn("dense", workdir, quick, 1)[0]
    finally:
        import shutil
        shutil.rmtree(workdir, ignore_errors=True)

    common.record_manifest("outofcore", stream[0]["config"])
    common.record_manifest("outofcore", dense["config"])

    dense_min = min(r["val_mse"] for r in dense["telemetry"]
                    if r["val_mse"] is not None)
    stream_min = min(r["val_mse"] for r in stream[0]["telemetry"]
                     if r["val_mse"] is not None)
    target = 1.01 * max(dense_min, stream_min)
    s_work, s_rounds = _cost_to_target(stream[0]["telemetry"], target)
    d_work, d_rounds = _cost_to_target(dense["telemetry"], target)
    s_rss = max(r["rss_delta"] for r in stream)
    m_rss = min(r["rss_delta"] for r in inmem)
    reads = max(r["store_metrics"]["bytes_read"] for r in stream)

    ok = True
    ok &= common.check(
        "outofcore-bit-parity",
        stream[0]["telemetry"] == inmem[0]["telemetry"]
        and stream[0]["telemetry"] == stream[1]["telemetry"],
        f"streamed == in-memory telemetry over "
        f"{len(stream[0]['telemetry'])} rounds, on both processes")
    ok &= common.check(
        "outofcore-rss-budget",
        s_rss <= budget < data_bytes,
        f"streamed peak ΔRSS {s_rss / 1e6:.0f}MB <= budget "
        f"{budget / 1e6:.0f}MB < data {data_bytes / 1e6:.0f}MB")
    ok &= common.check(
        "outofcore-rss-vs-inmem", s_rss < m_rss,
        f"streamed {s_rss / 1e6:.0f}MB < in-memory {m_rss / 1e6:.0f}MB "
        f"per process")
    ok &= common.check(
        "outofcore-read-amplification", reads <= 1.1 * data_bytes,
        f"worst process read {reads / 1e6:.0f}MB = "
        f"{reads / data_bytes:.2f}x one full pass")
    reached = s_work is not None and d_work is not None
    ok &= common.check(
        "outofcore-reach-common-quality", reached,
        f"rounds to 1.01x the common attained val: streamed={s_rounds} "
        f"dense={d_rounds}")
    ok &= common.check(
        "outofcore-nested-beats-dense",
        reached and s_work < d_work,
        "" if not reached else
        f"to common quality: streamed nested {s_work:,} k-scans "
        f"({s_work / n:.2f} full-data passes) vs dense {d_work:,} "
        f"({d_work / n:.2f})")

    report = {
        "quick": quick, "n": n, "d": DIM, "k": K,
        "chunk_rows": chunk_rows, "data_bytes": data_bytes,
        "rss_budget": budget, "dense_min": dense_min,
        "stream_min": stream_min,
        "stream": {"rss_delta": [r["rss_delta"] for r in stream],
                   "bytes_read": [r["store_metrics"]["bytes_read"]
                                  for r in stream],
                   "store_metrics": stream[0]["store_metrics"],
                   "work_to_1pct": s_work, "rounds_to_1pct": s_rounds,
                   "n_rounds": len(stream[0]["telemetry"]),
                   "converged": stream[0]["converged"],
                   "final_val_mse": stream[0]["final_val_mse"],
                   "config": stream[0]["config"]},
        "inmem": {"rss_delta": [r["rss_delta"] for r in inmem]},
        "dense": {"rss_delta": dense["rss_delta"],
                  "work_to_1pct": d_work, "rounds_to_1pct": d_rounds,
                  "n_rounds": len(dense["telemetry"]),
                  "converged": dense["converged"],
                  "final_val_mse": dense["final_val_mse"],
                  "config": dense["config"]},
        "checks_pass": bool(ok),
    }
    ART.mkdir(parents=True, exist_ok=True)
    (ART / "outofcore.json").write_text(json.dumps(report, indent=1))
    print(f"  wrote {ART / 'outofcore.json'}")
    return ok


if __name__ == "__main__":
    if "--child" in sys.argv:
        i = sys.argv.index("--child")
        child(sys.argv[i + 1], int(sys.argv[i + 2]), sys.argv[i + 3],
              sys.argv[i + 4], quick="--full" not in sys.argv)
    else:
        sys.exit(0 if main(quick="--full" not in sys.argv) else 1)
