"""Render EXPERIMENTS.md tables from artifacts/dryrun/*.json."""
from __future__ import annotations

import json
from pathlib import Path

ART = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def load_cells():
    cells = []
    for f in sorted(ART.glob("*.json")):
        try:
            cells.append(json.loads(f.read_text()))
        except Exception:
            pass
    return cells


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def table(mesh_tag: str) -> str:
    rows = []
    hdr = ("| cell | ok | compute_s | memory_s | collective_s | bottleneck"
           " | useful | roof-frac | peak mem |")
    sep = "|" + "---|" * 9
    rows.append(hdr)
    rows.append(sep)
    for c in load_cells():
        if mesh_tag not in c["cell"]:
            continue
        name = f"{c['arch']}×{c['shape']}"
        if not c.get("ok"):
            rows.append(f"| {name} | FAIL | - | - | - | - | - | - | - |")
            continue
        r = c["roofline"]
        peak = (c.get("memory") or {}).get("peak_bytes")
        uf = r.get("useful_ratio")
        rf = r.get("roofline_fraction")
        rows.append(
            f"| {name} | ok | {r['compute_s']:.3g} | {r['memory_s']:.3g} |"
            f" {r['collective_s']:.3g} | {r['bottleneck']} |"
            f" {uf:.2f} |" if uf is not None else
            f"| {name} | ok | {r['compute_s']:.3g} | {r['memory_s']:.3g} |"
            f" {r['collective_s']:.3g} | {r['bottleneck']} | - |")
        if uf is not None:
            rows[-1] += (f" {rf:.4f} | {fmt_bytes(peak)} |"
                         if rf is not None else f" - | {fmt_bytes(peak)} |")
        else:
            rows[-1] += f" - | {fmt_bytes(peak)} |"
    return "\n".join(rows)


def summary() -> str:
    cells = load_cells()
    n_ok = sum(1 for c in cells if c.get("ok"))
    worst = [c for c in cells if c.get("ok")
             and c["roofline"].get("roofline_fraction") is not None]
    worst.sort(key=lambda c: c["roofline"]["roofline_fraction"])
    lines = [f"cells: {n_ok}/{len(cells)} ok"]
    if worst:
        lines.append("worst roofline fractions:")
        for c in worst[:5]:
            lines.append(f"  {c['cell']}: "
                         f"{c['roofline']['roofline_fraction']:.5f} "
                         f"({c['roofline']['bottleneck']}-bound)")
        coll = [c for c in worst
                if c["roofline"]["bottleneck"] == "collective"]
        lines.append(f"collective-bound cells: {len(coll)}")
    return "\n".join(lines)


def main(quick: bool = True):
    print("== Dry-run / roofline summary ==")
    print(summary())
    out = Path(__file__).resolve().parent.parent / "artifacts" / \
        "roofline_tables.md"
    out.write_text("## single-pod 16x16\n\n" + table("pod16x16")
                   + "\n\n## multi-pod 2x16x16\n\n" + table("pod2x16x16")
                   + "\n")
    print(f"tables -> {out}")
    return True


if __name__ == "__main__":
    main()
