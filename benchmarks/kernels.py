"""Kernel dispatch suite: price each kernel backend against the roofline.

One fit per backend ("ref", "pallas") on the same well-separated blobs,
each traced through `repro.obs` so the `fit_roofline_utilization` gauge
lands in the trace dir's metrics export — the per-backend utilization
the manifest records. Claim checks:

  * label parity — the Pallas fused round must produce labels
    bit-identical to the ref kernels (the dispatch plane's core
    contract, `scripts/smoke_kernels.py` proves it across engines);
  * every traced fit must surface a non-null utilization gauge and a
    resolved `KernelPlan` on its outcome — no unexplained nulls.

Run standalone (`python -m benchmarks.kernels`) or via
`python -m benchmarks.run --suite kernels` (which additionally writes
the per-fit manifests, kernel plans included).
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from benchmarks import common
from repro import api

ART = Path(__file__).resolve().parent.parent / "artifacts" / "bench"
BACKENDS = ("ref", "pallas")


def blobs(n: int, k: int, d: int, seed: int = 0):
    """Well-separated blobs: inter-center distances dwarf float32 ulp
    drift in the S->C path, so a correct kernel produces bit-equal
    labels, not merely close ones."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d)) * 12.0
    a = rng.integers(0, k, size=n)
    return (centers[a] + rng.normal(size=(n, d))).astype(np.float32)


def utilization_from(trace_dir: Path):
    vals = []
    for f in sorted(trace_dir.glob("metrics-p*.json")):
        g = json.loads(f.read_text()).get("gauges", {})
        if g.get("fit_roofline_utilization") is not None:
            vals.append(float(g["fit_roofline_utilization"]))
    return max(vals) if vals else None


def main(quick: bool = True):
    print("== Kernel dispatch: per-backend wall vs roofline ==")
    n = 4096 if quick else 65_536
    k, d = 16, 8
    X = blobs(n, k, d)
    results = {}
    for backend in BACKENDS:
        trace_dir = ART / f"trace-kernels-{backend}"
        trace_dir.mkdir(parents=True, exist_ok=True)
        for old in trace_dir.glob("metrics-p*.json"):
            old.unlink()
        with common.Timer() as t:
            out = api.fit(X, api.FitConfig(
                k=k, b0=max(2 * k, n // 16), seed=0, max_rounds=40,
                kernel_backend=backend, trace_dir=str(trace_dir)))
        util = utilization_from(trace_dir)
        results[backend] = {
            "wall_s": round(t.seconds, 3),
            "fit_roofline_utilization": util,
            "kernel_plan": out.kernel_plan,
            "labels": out.labels,
        }
        ustr = f"{util:.4f}" if util is not None else "None"
        plan = out.kernel_plan or {}
        print(f"  {backend:>6s}: wall {t.seconds:6.2f}s  "
              f"utilization {ustr}  plan "
              f"{plan.get('backend')}/bn={plan.get('bn')}"
              f"/bk={plan.get('bk')}/bd={plan.get('bd')}")

    ok = common.check(
        "pallas labels bit-equal to ref",
        bool(np.array_equal(results["pallas"]["labels"],
                            results["ref"]["labels"])))
    for backend in BACKENDS:
        ok &= common.check(
            f"{backend}: roofline utilization recorded",
            results[backend]["fit_roofline_utilization"] is not None)
        ok &= common.check(
            f"{backend}: resolved kernel plan on the outcome",
            (results[backend]["kernel_plan"] or {}).get("backend")
            == backend)
    ART.mkdir(parents=True, exist_ok=True)
    (ART / "kernels.json").write_text(json.dumps(
        {b: {kk: v for kk, v in r.items() if kk != "labels"}
         for b, r in results.items()}, indent=1))
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if main(quick=True) else 1)
